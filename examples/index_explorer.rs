//! Index explorer: compare the MIPS index families (brute / k-means tree
//! / SimHash LSH) on recall@k, top-1 recall and probe cost — the choice
//! the paper's Table 3 says matters most (top-1 recall drives MIMPS
//! error).
//!
//! ```bash
//! cargo run --release --example index_explorer
//! ```

use zest::data::synth::{generate, SynthConfig};
use zest::experiments::ablations::index_ablation;
use zest::mips::kmeans_tree::{KMeansTreeConfig, KMeansTreeIndex};
use zest::mips::recall::measure;
use zest::mips::brute::BruteIndex;
use zest::util::rng::Rng;

fn main() {
    zest::util::logging::init();
    let store = generate(&SynthConfig {
        n: 30_000,
        d: 64,
        ..Default::default()
    });
    println!("N={} d={}\n-- index families --", store.len(), store.dim());
    for r in index_ablation(&store, 40, 0) {
        println!(
            "{:<12} recall@10={:.3} top1={:.3} probes={:>7.0} build={:?}",
            r.name, r.recall_at_10, r.top1_recall, r.mean_probes, r.build_wall
        );
    }

    println!("\n-- k-means tree probe-budget sweep (recall@10) --");
    let brute = BruteIndex::new(&store);
    for probes in [256usize, 1024, 4096, 16384] {
        let tree = KMeansTreeIndex::build(
            &store,
            KMeansTreeConfig {
                max_probes: probes,
                ..Default::default()
            },
        );
        let mut rng = Rng::seeded(1);
        let rep = measure(&tree, &brute, 10, 40, &mut rng);
        println!(
            "probes={probes:<7} recall@10={:.3} top1={:.3}  ({:.1}% of N scanned)",
            rep.recall,
            rep.top1_recall,
            100.0 * probes as f64 / store.len() as f64
        );
    }
}
