//! End-to-end driver (the paper's §5.2, DESIGN.md Table 4): train a
//! log-bilinear language model with NCE — partition clamped to 1 —
//! through the AOT-compiled PJRT training step, log the loss curve, then
//! estimate the partition function on held-out contexts with MIMPS over
//! a k-means-tree MIPS index and compare against the Z = 1 heuristic.
//!
//! ```bash
//! make artifacts && cargo run --release --example lm_partition
//! # env: ZEST_LBL_STEPS=600 ZEST_LM_CONTEXTS=2000
//! ```

use zest::experiments::table4::{render, run, Table4Config};

fn main() {
    zest::util::logging::init();
    let dir = std::path::PathBuf::from(
        std::env::var("ZEST_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string()),
    );
    let meta = match zest::runtime::ArtifactsMeta::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("need artifacts: {e:#}\nrun `make artifacts` first");
            std::process::exit(1);
        }
    };
    let steps: usize = std::env::var("ZEST_LBL_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(600);
    let contexts: usize = std::env::var("ZEST_LM_CONTEXTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2000);
    let vocab = meta.config_usize("vocab").unwrap();
    let cfg = Table4Config {
        lbl: zest::lm::LblConfig {
            vocab,
            d: meta.config_usize("lbl_d").unwrap(),
            ctx: meta.config_usize("ctx").unwrap(),
            seed: 0,
        },
        nce: zest::lm::NceConfig {
            batch: meta.config_usize("lbl_batch").unwrap(),
            noise_k: meta.config_usize("noise_k").unwrap(),
            lr: 0.3,
        },
        train_steps: steps,
        contexts,
        corpus: zest::data::corpus::CorpusConfig {
            vocab,
            ..Default::default()
        },
        ..Default::default()
    };
    println!(
        "LBL: vocab={} d={} ctx={} | NCE batch={} K={} | {} steps, {} eval contexts",
        cfg.lbl.vocab, cfg.lbl.d, cfg.lbl.ctx, cfg.nce.batch, cfg.nce.noise_k, steps, contexts
    );
    let (rt, join) =
        zest::runtime::spawn_runtime_thread(dir.clone(), Some(vec!["lbl_nce_step".into()]))
            .expect("spawn pjrt runtime");
    let t = run(&cfg, &rt, &dir).expect("table4 run");
    print!("{}", render(&t));
    println!(
        "\nReading: AbsE-MIPS < AbsE-NCE means estimating Z sublinearly beats \
         assuming Z=1; Speedup is wall-clock vs brute force."
    );
    rt.shutdown();
    join.join().ok();
}
