//! Serving demo: run the batching coordinator under concurrent load and
//! report latency percentiles + batching metrics — the L3 system shape
//! (bounded queue → dynamic batcher → worker pool) around the paper's
//! estimators.
//!
//! ```bash
//! cargo run --release --example serving
//! ```

use std::sync::Arc;
use zest::coordinator::*;
use zest::data::synth::{generate, SynthConfig};
use zest::estimators::EstimatorKind;
use zest::mips::kmeans_tree::KMeansTreeIndex;
use zest::mips::MipsIndex;
use zest::util::rng::Rng;

fn main() {
    zest::util::logging::init();
    let store = Arc::new(generate(&SynthConfig {
        n: 50_000,
        d: 128,
        ..Default::default()
    }));
    let index: Arc<dyn MipsIndex> =
        Arc::new(KMeansTreeIndex::build(&store, Default::default()));
    let svc = Arc::new(PartitionService::start(
        store.clone(),
        index,
        Router::new(Default::default()),
        ServiceConfig {
            workers: 4,
            queue_capacity: 512,
            backpressure: BackpressurePolicy::Block,
            ..Default::default()
        },
        None,
    ));

    // 8 client threads × 200 requests, mixed estimator kinds.
    let clients = 8;
    let per_client = 200;
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let svc = svc.clone();
            let store = store.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::seeded(c as u64);
                let mut lat = Vec::with_capacity(per_client);
                for _ in 0..per_client {
                    let qi = rng.below(store.len());
                    let kind = match rng.below(10) {
                        0 => EstimatorKind::Uniform,
                        1 => EstimatorKind::Mince,
                        _ => EstimatorKind::Mimps, // the recommended estimator
                    };
                    let t = std::time::Instant::now();
                    let resp = svc
                        .estimate(
                            EstimateSpec::new(store.row(qi).to_vec())
                                .kind(kind)
                                .k(100)
                                .l(100),
                        )
                        .expect("estimate");
                    lat.push(t.elapsed());
                    assert!(resp.z.is_finite());
                }
                lat
            })
        })
        .collect();
    let mut all: Vec<std::time::Duration> = Vec::new();
    for h in handles {
        all.extend(h.join().unwrap());
    }
    let wall = t0.elapsed();
    all.sort();
    let total = clients * per_client;
    println!(
        "{total} requests / {clients} clients in {wall:?} => {:.0} req/s",
        total as f64 / wall.as_secs_f64()
    );
    println!(
        "client latency p50={:?} p95={:?} p99={:?}",
        all[total / 2],
        all[(total as f64 * 0.95) as usize],
        all[(total as f64 * 0.99) as usize]
    );
    println!("service metrics: {}", svc.metrics());
}
