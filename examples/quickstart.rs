//! Quickstart: generate a small embedding set, build a MIPS index, and
//! estimate the partition function with each of the paper's estimators.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use zest::data::synth::{generate, SynthConfig};
use zest::estimators::{EstimateContext, Estimator};
use zest::mips::brute::BruteIndex;
use zest::mips::kmeans_tree::{KMeansTreeConfig, KMeansTreeIndex};
use zest::util::rng::Rng;

fn main() {
    zest::util::logging::init();
    // 1. A small word2vec-like embedding set (see data::synth for how the
    //    norm/frequency structure mirrors the paper's dataset).
    let store = generate(&SynthConfig {
        n: 20_000,
        d: 64,
        ..Default::default()
    });
    println!("generated N={} d={} embeddings", store.len(), store.dim());

    // 2. Ground truth for one query (a rare token → peaked distribution).
    let q = store.row(store.len() - 5).to_vec();
    let brute = BruteIndex::new(&store);
    let z_true = brute.partition(&q);
    println!("true Z(q) = {z_true:.3}\n");

    // 3. A sublinear MIPS index (k-means tree over the Bachrach lift).
    let tree = KMeansTreeIndex::build(&store, KMeansTreeConfig::default());

    // 4. Every estimator at k = l = 100 — 1% of the categories.
    let mut rng = Rng::seeded(0);
    let estimators: Vec<Box<dyn Estimator>> = vec![
        Box::new(zest::estimators::uniform::Uniform::new(200)),
        Box::new(zest::estimators::nmimps::Nmimps::new(100)),
        Box::new(zest::estimators::mimps::Mimps::new(100, 100)),
        Box::new(zest::estimators::mince::Mince::new(100, 100)),
    ];
    println!("{:<22} {:>16} {:>8} {:>9}", "estimator", "Z-hat", "err %", "scorings");
    for est in estimators {
        let mut ctx = EstimateContext::new(&store, &tree, &mut rng);
        let z = est.estimate(&mut ctx, &q);
        println!(
            "{:<22} {:>16.3} {:>8.2} {:>9}",
            est.name(),
            z,
            zest::metrics::abs_rel_err_pct(z, z_true),
            est.scorings(store.len())
        );
    }
    println!(
        "\nMIMPS reads ~{} of {} categories — that is the paper's point.",
        200,
        store.len()
    );
}
