//! Quickstart: generate a small embedding set, build a MIPS index, and
//! estimate the partition function with each of the paper's estimators.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use zest::data::synth::{generate, SynthConfig};
use zest::estimators::{EstimateContext, Estimator};
use zest::mips::brute::BruteIndex;
use zest::mips::kmeans_tree::{KMeansTreeConfig, KMeansTreeIndex};
use zest::util::rng::Rng;

fn main() {
    zest::util::logging::init();
    // 1. A small word2vec-like embedding set (see data::synth for how the
    //    norm/frequency structure mirrors the paper's dataset).
    let store = generate(&SynthConfig {
        n: 20_000,
        d: 64,
        ..Default::default()
    });
    println!("generated N={} d={} embeddings", store.len(), store.dim());

    // 2. Ground truth for one query (a rare token → peaked distribution).
    let q = store.row(store.len() - 5).to_vec();
    let brute = BruteIndex::new(&store);
    let z_true = brute.partition(&q);
    println!("true Z(q) = {z_true:.3}\n");

    // 3. A sublinear MIPS index (k-means tree over the Bachrach lift).
    let tree = KMeansTreeIndex::build(&store, KMeansTreeConfig::default());

    // 4. Every estimator at k = l = 100 — 1% of the categories.
    let mut rng = Rng::seeded(0);
    let estimators: Vec<Box<dyn Estimator>> = vec![
        Box::new(zest::estimators::uniform::Uniform::new(200)),
        Box::new(zest::estimators::nmimps::Nmimps::new(100)),
        Box::new(zest::estimators::mimps::Mimps::new(100, 100)),
        Box::new(zest::estimators::mince::Mince::new(100, 100)),
    ];
    println!("{:<22} {:>16} {:>8} {:>9}", "estimator", "Z-hat", "err %", "scorings");
    for est in estimators {
        let mut ctx = EstimateContext::new(&store, &tree, &mut rng);
        let z = est.estimate(&mut ctx, &q);
        println!(
            "{:<22} {:>16.3} {:>8.2} {:>9}",
            est.name(),
            z,
            zest::metrics::abs_rel_err_pct(z, z_true),
            est.scorings(store.len())
        );
    }
    println!(
        "\nMIMPS reads ~{} of {} categories — that is the paper's point.",
        200,
        store.len()
    );

    // 5. Sharded serving with live category insertion: partition the
    //    categories into 4 shards, serve through epoch snapshots, and
    //    publish new categories while estimates are in flight.
    use std::sync::Arc;
    use zest::coordinator::{EstimateSpec, PartitionService, Router, ServiceConfig};
    use zest::store::{ShardedStore, SnapshotHandle, StoreView};

    let handle = Arc::new(SnapshotHandle::brute(ShardedStore::split(&store, 4)));
    let svc = PartitionService::start_sharded(
        handle.clone(),
        Router::new(Default::default()),
        ServiceConfig::default(),
        None,
    );
    // Pin epoch 0 explicitly — this Arc<Snapshot> stays valid and
    // unchanged no matter how many epochs are published after it.
    let pinned = handle.load();
    let rx = svc.submit(EstimateSpec::new(q.clone())).unwrap();
    // Publish epoch 1 while that request may still be in flight: the
    // batch answering it pins whichever snapshot was current when it
    // started executing — never a half-updated category set.
    let extra = generate(&SynthConfig {
        n: 1_000,
        d: 64,
        seed: 1,
        ..Default::default()
    });
    let epoch = handle.add_categories(extra).unwrap();
    let r = rx.recv().unwrap();
    println!(
        "\nsharded service: Z={:.3} answered from epoch {} while epoch {epoch} was being \
         published (pinned epoch-0 snapshot still reads N={})",
        r.z,
        r.epoch,
        StoreView::len(pinned.store.as_ref()),
    );
    let r2 = svc.estimate(EstimateSpec::new(q.clone())).unwrap();
    println!(
        "after the swap: Z={:.3} at epoch {} — the epoch advanced, in-flight answers never \
         mixed category sets",
        r2.z, r2.epoch
    );
    println!("{}", svc.metrics());
    svc.shutdown();
}
