//! Cross-process shards quickstart: two shard workers + a partition
//! server over Unix domain sockets, driven by the network client.
//!
//! This example hosts the two workers and the server **in one process**
//! (three `net::Server` instances on three sockets) so it runs without
//! coordinating binaries; the protocol is byte-identical to the real
//! multi-process deployment:
//!
//! ```bash
//! zest-shard-worker --listen unix:///tmp/shard0.sock --synth 100000,128,0 --range 0,50000 &
//! zest-shard-worker --listen unix:///tmp/shard1.sock --synth 100000,128,0 --range 50000,100000 &
//! zest-server --listen unix:///tmp/zest.sock \
//!     --workers unix:///tmp/shard0.sock,unix:///tmp/shard1.sock
//! ```
//!
//! ```bash
//! cargo run --release --example remote_shards
//! ```

use std::sync::Arc;
use zest::coordinator::{EstimateSpec, ServiceMetrics};
use zest::data::synth::{generate, SynthConfig};
use zest::estimators::EstimatorKind;
use zest::mips::brute::BruteIndex;
use zest::net::client::{ClientConfig, PartitionClient};
use zest::net::remote::{aligned_split, ClusterHandler, RemoteCluster};
use zest::net::server::{Server, ServerConfig};
use zest::net::shard::ShardWorker;
use zest::net::Addr;

fn main() {
    zest::util::logging::init();
    let store = generate(&SynthConfig {
        n: 100_000,
        d: 128,
        ..Default::default()
    });
    let sock = |name: &str| {
        Addr::Unix(std::env::temp_dir().join(format!("zest-example-{}-{name}.sock", std::process::id())))
    };

    // Two "shard worker processes": each serves a 4-aligned half of the
    // rows (the alignment keeps remote Exact bit-identical — see
    // net::remote docs).
    let mut worker_servers = Vec::new();
    let mut worker_addrs = Vec::new();
    for (i, block) in aligned_split(&store, 2).into_iter().enumerate() {
        let addr = sock(&format!("shard{i}"));
        println!("shard worker {i}: {} rows on {addr}", block.len());
        let server = Server::serve(
            &addr,
            Arc::new(ShardWorker::new(block)),
            ServerConfig::default(),
            Arc::new(ServiceMetrics::new()),
        )
        .expect("start shard worker");
        worker_addrs.push(server.local_addr().clone());
        worker_servers.push(server);
    }

    // The partition server scatters across the workers.
    let cluster = Arc::new(
        RemoteCluster::connect(&worker_addrs, ClientConfig::default()).expect("connect workers"),
    );
    println!(
        "cluster: {} categories × {} dims over {} workers (epoch {})",
        cluster.len(),
        cluster.dim(),
        cluster.num_shards(),
        cluster.epoch()
    );
    let front = sock("front");
    let server = Server::serve(
        &front,
        Arc::new(ClusterHandler::new(cluster.clone(), 0)),
        ServerConfig::default(),
        Arc::new(ServiceMetrics::new()),
    )
    .expect("start partition server");

    // A client estimates over the wire; compare against local compute.
    let client =
        PartitionClient::connect(server.local_addr().clone(), ClientConfig::default()).unwrap();
    let q = store.row(4321).to_vec();
    let remote = client.estimate(EstimateSpec::new(q.clone())).unwrap();
    let local = BruteIndex::new(&store).partition(&q);
    println!(
        "Exact over 2 remote shards: Ẑ = {:.6e} (local {:.6e}, exec {:?})",
        remote.z, local, remote.exec_time
    );

    let mimps = client
        .estimate(
            EstimateSpec::new(q.clone())
                .kind(EstimatorKind::Mimps)
                .k(1000)
                .l(1000),
        )
        .unwrap();
    println!(
        "MIMPS(k=1000,l=1000) remote: Ẑ = {:.6e} ({} scorings vs N = {})",
        mimps.z,
        mimps.scorings,
        cluster.len()
    );

    // Live category insertion: a two-phase publish across both workers.
    let added = generate(&SynthConfig {
        n: 5_000,
        d: 128,
        seed: 9,
        ..Default::default()
    });
    let epoch = cluster.add_categories(&added).expect("two-phase publish");
    let grown = client.estimate(EstimateSpec::new(q)).unwrap();
    println!(
        "after add_categories (epoch {epoch}): N = {}, Ẑ = {:.6e} (epoch tag {})",
        cluster.len(),
        grown.z,
        grown.epoch
    );

    // Release pooled client connections before joining the servers.
    drop(client);
    server.shutdown();
    drop(cluster);
    for w in worker_servers {
        w.shutdown();
    }
}
