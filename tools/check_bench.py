#!/usr/bin/env python3
"""Schema lint for committed zest-loadgen records (`BENCH_load.json`).

Usage: check_bench.py FILE.json [FILE.json ...]

Validates the `zest-load-v1` document shape that `zest-loadgen` emits
and `rust/src/loadgen/report.rs` defines:

  {"schema": "zest-load-v1", "runs": [<run>, ...]}       # runs non-empty

with every run carrying the sweep config plus a non-empty `points`
ladder, and every point internally consistent (accounting adds up,
quantiles are ordered, rates are sane). Because the record is committed
to the repo, a field rename or a hand-edited impossible number fails CI
here rather than silently drifting from the Rust schema.
"""

import json
import sys
from pathlib import Path

SCHEMA = "zest-load-v1"
KNEE_RATIO = 0.95
ARRIVALS = ("fixed", "poisson")

RUN_FIELDS = {
    "scenario": str,
    "users": (int, float),
    "zipf_s": (int, float),
    "sessions": (int, float),
    "duration_ms": (int, float),
    "arrival": str,
    "seed": (int, float),
    "shards": (int, float),
    "replicas": (int, float),
    "points": list,
}
POINT_COUNTERS = ("sent", "ok", "shed", "rejected", "failed", "failovers", "hedges")
POINT_NUMBERS = (
    "offered_hz",
    "achieved_hz",
    "p50_ms",
    "p99_ms",
    "p999_ms",
    "cache_hit_rate",
)


def check_point(where: str, p) -> list[str]:
    bad = []
    if not isinstance(p, dict):
        return [f"{where}: point is not an object"]
    for name in POINT_COUNTERS:
        v = p.get(name)
        if not isinstance(v, (int, float)) or v < 0 or v != int(v):
            bad.append(f"{where}: {name} must be a non-negative integer, got {v!r}")
    for name in POINT_NUMBERS:
        v = p.get(name)
        if not isinstance(v, (int, float)) or v < 0:
            bad.append(f"{where}: {name} must be a non-negative number, got {v!r}")
    if bad:
        return bad
    if p["sent"] != p["ok"] + p["shed"] + p["rejected"] + p["failed"]:
        bad.append(f"{where}: accounting broken (sent != ok+shed+rejected+failed)")
    if p["sent"] > 0 and p["offered_hz"] <= 0:
        bad.append(f"{where}: sent requests but offered_hz is 0")
    if not p["p50_ms"] <= p["p99_ms"] <= p["p999_ms"]:
        bad.append(f"{where}: quantiles not ordered (p50 <= p99 <= p999)")
    if not 0.0 <= p["cache_hit_rate"] <= 1.0:
        bad.append(f"{where}: cache_hit_rate outside [0, 1]")
    return bad


def check_run(where: str, run) -> list[str]:
    bad = []
    if not isinstance(run, dict):
        return [f"{where}: run is not an object"]
    for name, ty in RUN_FIELDS.items():
        if name not in run:
            bad.append(f"{where}: missing field {name!r}")
        elif not isinstance(run[name], ty):
            bad.append(f"{where}: field {name!r} has wrong type {type(run[name]).__name__}")
    if "knee_hz" not in run:
        bad.append(f"{where}: missing field 'knee_hz' (number or null)")
    elif run["knee_hz"] is not None and not isinstance(run["knee_hz"], (int, float)):
        bad.append(f"{where}: knee_hz must be a number or null")
    if bad:
        return bad
    if run["arrival"] not in ARRIVALS:
        bad.append(f"{where}: arrival {run['arrival']!r} not in {ARRIVALS}")
    if not run["points"]:
        bad.append(f"{where}: points must be non-empty")
    for i, p in enumerate(run["points"]):
        bad.extend(check_point(f"{where}.points[{i}]", p))
    if bad:
        return bad
    # The recorded knee must agree with the recorded points: it is the
    # first offered rate whose achieved rate lags KNEE_RATIO × offered.
    knee = next(
        (
            p["offered_hz"]
            for p in run["points"]
            if p["achieved_hz"] < KNEE_RATIO * p["offered_hz"]
        ),
        None,
    )
    if knee != run["knee_hz"]:
        bad.append(
            f"{where}: knee_hz {run['knee_hz']!r} disagrees with the points "
            f"(recomputed {knee!r} at ratio {KNEE_RATIO})"
        )
    return bad


def check(path: Path) -> list[str]:
    try:
        doc = json.loads(path.read_text(encoding="utf-8"))
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    if not isinstance(doc, dict):
        return [f"{path}: top level is not an object"]
    if doc.get("schema") != SCHEMA:
        return [f"{path}: schema is {doc.get('schema')!r}, want {SCHEMA!r}"]
    runs = doc.get("runs")
    if not isinstance(runs, list) or not runs:
        return [f"{path}: runs must be a non-empty array"]
    bad = []
    for i, run in enumerate(runs):
        label = run.get("scenario", i) if isinstance(run, dict) else i
        bad.extend(check_run(f"{path}: runs[{label}]", run))
    return bad


def main(argv: list[str]) -> int:
    if not argv:
        print(__doc__)
        return 2
    bad = []
    for name in argv:
        bad.extend(check(Path(name)))
    for line in bad:
        print(line, file=sys.stderr)
    if bad:
        print(f"{len(bad)} schema violation(s)", file=sys.stderr)
        return 1
    print(f"bench schema OK ({len(argv)} file(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
