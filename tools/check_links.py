#!/usr/bin/env python3
"""Check that relative links in the given markdown files resolve.

Usage: check_links.py FILE.md [FILE.md ...]

Validates every inline markdown link `[text](target)` whose target is a
relative path (external URLs and pure #anchors are skipped): the target
file or directory must exist relative to the linking file's directory.
Exits non-zero listing every broken link.
"""

import re
import sys
from pathlib import Path

# Inline links only; reference-style definitions are rare in this repo.
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def check(path: Path) -> list[str]:
    broken = []
    text = path.read_text(encoding="utf-8")
    # Strip fenced code blocks: their bracketed text is not a link.
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for match in LINK.finditer(text):
        target = match.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not (path.parent / rel).exists():
            broken.append(f"{path}: broken link -> {target}")
    return broken


def main(argv: list[str]) -> int:
    if not argv:
        print(__doc__)
        return 2
    broken = []
    for name in argv:
        path = Path(name)
        if not path.exists():
            broken.append(f"{name}: file not found")
            continue
        broken.extend(check(path))
    for line in broken:
        print(line, file=sys.stderr)
    if broken:
        print(f"{len(broken)} broken link(s)", file=sys.stderr)
        return 1
    print(f"link check OK ({len(argv)} file(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
