//! Minimal offline substitute for the `anyhow` crate, covering the subset
//! zest uses: [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`]
//! macros, and the [`Context`] extension trait for `Result` and `Option`.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion (and therefore `?` on any
//! std-error type) possible.

use std::fmt;

/// A dynamic error with an optional cause chain.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `Result` specialized to [`Error`], with an overridable error type so
/// `Result<T, OtherError>` annotations keep working.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error {
            msg: m.to_string(),
            source: None,
        }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error {
            msg: c.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// The cause chain, outermost first (for `{:#}` rendering and tests).
    pub fn chain(&self) -> impl Iterator<Item = &Error> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: "context: cause: root cause", like real anyhow.
            let mut first = true;
            for e in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{}", e.msg)?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<&Error> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {}", c.msg)?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the std source chain as context layers.
        let mut msgs = Vec::new();
        msgs.push(e.to_string());
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            err = Some(Error {
                msg: m,
                source: err.map(Box::new),
            });
        }
        err.expect("at least one message")
    }
}

/// Extension trait adding `.context()` / `.with_context()`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(::std::concat!("condition failed: ", ::std::stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing"));
    }

    #[test]
    fn context_layers_render_in_alternate_mode() {
        let e: Result<()> = Err(io_err());
        let e = e.with_context(|| "reading config").unwrap_err();
        let rendered = format!("{e:#}");
        assert!(rendered.starts_with("reading config"), "{rendered}");
        assert!(rendered.contains("missing"), "{rendered}");
        assert_eq!(format!("{e}"), "reading config");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(e.to_string(), "empty");
        assert_eq!(Some(7u32).context("empty").unwrap(), 7);
    }

    #[test]
    fn macros_build_errors() {
        let x = 3;
        let e = anyhow!("value {x} bad");
        assert_eq!(e.to_string(), "value 3 bad");
        let e = anyhow!("pair {} {}", 1, 2);
        assert_eq!(e.to_string(), "pair 1 2");
        let e = anyhow!(String::from("owned"));
        assert_eq!(e.to_string(), "owned");

        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(1)
        }
        assert!(f(true).is_ok());
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
    }
}
