//! Minimal offline substitute for the `log` facade crate, providing the
//! subset zest uses: the five level macros, [`Log`]/[`Record`]/[`Metadata`],
//! [`set_logger`]/[`set_max_level`]/[`max_level`], and `Level` ⇄
//! `LevelFilter` comparisons. API-compatible with the real crate for these
//! items so swapping the real `log` back in is a one-line Cargo change.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a single record (lower = more severe).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Maximum-verbosity filter (includes `Off`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}

/// Metadata about a record (level + target).
#[derive(Clone, Copy, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record, passed by reference to [`Log::log`].
#[derive(Clone, Copy)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }

    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }
}

/// A logging backend.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata<'_>) -> bool;
    fn log(&self, record: &Record<'_>);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _: &Metadata<'_>) -> bool {
        false
    }
    fn log(&self, _: &Record<'_>) {}
    fn flush(&self) {}
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (first call wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum level.
pub fn set_max_level(level: LevelFilter) {
    MAX_LEVEL.store(level as usize, Ordering::Relaxed);
}

/// Current global maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

fn logger() -> &'static dyn Log {
    static NOP: NopLogger = NopLogger;
    match LOGGER.get() {
        Some(l) => *l,
        None => &NOP,
    }
}

/// Internal macro plumbing — not part of the public API contract.
#[doc(hidden)]
pub fn __log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    let record = Record {
        metadata: Metadata { level, target },
        args,
    };
    let l = logger();
    if l.enabled(record.metadata()) {
        l.log(&record);
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {{
        let lvl = $lvl;
        if lvl <= $crate::max_level() {
            $crate::__log(lvl, ::std::module_path!(), ::std::format_args!($($arg)+));
        }
    }};
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filter_comparisons() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(LevelFilter::Trace >= Level::Trace);
    }

    #[test]
    fn max_level_roundtrip() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
    }

    #[test]
    fn macros_do_not_panic_without_logger() {
        set_max_level(LevelFilter::Trace);
        info!("hello {}", 42);
        warn!("inline capture {x}", x = 7);
        set_max_level(LevelFilter::Off);
    }
}
