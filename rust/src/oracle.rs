//! Oracle retrieval with deterministic error injection — the harness for
//! the paper's §5.1 controlled experiments.
//!
//! The oracle wraps the exact brute-force index and can be configured to
//! *drop* specific ranks from every retrieved set (`ret err=1` drops the
//! single best inner-product vector, `ret err=[1 2]` drops the top two…),
//! "restrictively simulat[ing] the type of errors that these estimators
//! might encounter in a real setting where the vector with the highest or
//! second highest inner product might not be made available" (Table 3).
//! The retrieved set still contains `k` items: lower-ranked vectors shift
//! up, exactly as an approximate index that misses the true top-1 would
//! return its next-best candidates.

use crate::mips::brute::BruteIndex;
use crate::mips::{Hit, MipsIndex};

/// Which (1-based) ranks of the true top-k to remove from every retrieval.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RetrievalError {
    pub drop_ranks: Vec<usize>,
}

impl RetrievalError {
    pub fn none() -> Self {
        RetrievalError { drop_ranks: vec![] }
    }

    /// `ret err=1` in the paper's Table 3.
    pub fn drop_first() -> Self {
        RetrievalError {
            drop_ranks: vec![1],
        }
    }

    /// `ret err=2`.
    pub fn drop_second() -> Self {
        RetrievalError {
            drop_ranks: vec![2],
        }
    }

    /// `ret err=[1 2]`.
    pub fn drop_first_two() -> Self {
        RetrievalError {
            drop_ranks: vec![1, 2],
        }
    }

    pub fn label(&self) -> String {
        if self.drop_ranks.is_empty() {
            "None".to_string()
        } else if self.drop_ranks.len() == 1 {
            format!("{}", self.drop_ranks[0])
        } else {
            format!(
                "[{}]",
                self.drop_ranks
                    .iter()
                    .map(|r| r.to_string())
                    .collect::<Vec<_>>()
                    .join(" ")
            )
        }
    }
}

/// The oracle: exact retrieval with configurable injected errors.
pub struct OracleIndex {
    brute: BruteIndex,
    err: RetrievalError,
}

impl OracleIndex {
    pub fn new(brute: BruteIndex) -> Self {
        OracleIndex {
            brute,
            err: RetrievalError::none(),
        }
    }

    pub fn with_error(brute: BruteIndex, err: RetrievalError) -> Self {
        OracleIndex { brute, err }
    }

    pub fn set_error(&mut self, err: RetrievalError) {
        self.err = err;
    }

    pub fn brute(&self) -> &BruteIndex {
        &self.brute
    }
}

impl MipsIndex for OracleIndex {
    fn top_k(&self, q: &[f32], k: usize) -> Vec<Hit> {
        if self.err.drop_ranks.is_empty() {
            return self.brute.top_k(q, k);
        }
        // Retrieve enough extra ranks to backfill the dropped ones.
        let extra = self.err.drop_ranks.len();
        let full = self.brute.top_k(q, k + extra);
        full.into_iter()
            .enumerate()
            .filter(|(pos, _)| !self.err.drop_ranks.contains(&(pos + 1)))
            .map(|(_, h)| h)
            .take(k)
            .collect()
    }

    fn len(&self) -> usize {
        self.brute.len()
    }

    fn probe_cost(&self, k: usize) -> usize {
        self.brute.probe_cost(k)
    }

    fn name(&self) -> &'static str {
        "oracle"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};

    fn setup() -> (crate::data::embeddings::EmbeddingStore, BruteIndex) {
        let s = generate(&SynthConfig {
            n: 400,
            d: 16,
            ..SynthConfig::tiny()
        });
        let b = BruteIndex::new(&s);
        (s, b)
    }

    #[test]
    fn no_error_equals_brute() {
        let (s, b) = setup();
        let oracle = OracleIndex::new(BruteIndex::new(&s));
        let q = s.row(3).to_vec();
        assert_eq!(oracle.top_k(&q, 10), b.top_k(&q, 10));
    }

    #[test]
    fn drop_first_removes_argmax_and_backfills() {
        let (s, b) = setup();
        let oracle = OracleIndex::with_error(BruteIndex::new(&s), RetrievalError::drop_first());
        let q = s.row(3).to_vec();
        let truth = b.top_k(&q, 11);
        let got = oracle.top_k(&q, 10);
        assert_eq!(got.len(), 10);
        assert_eq!(got[0].idx, truth[1].idx, "rank-2 becomes first");
        assert_eq!(got[9].idx, truth[10].idx, "backfilled from rank-11");
        assert!(got.iter().all(|h| h.idx != truth[0].idx));
    }

    #[test]
    fn drop_first_two() {
        let (s, b) = setup();
        let oracle =
            OracleIndex::with_error(BruteIndex::new(&s), RetrievalError::drop_first_two());
        let q = s.row(7).to_vec();
        let truth = b.top_k(&q, 12);
        let got = oracle.top_k(&q, 10);
        assert_eq!(got.len(), 10);
        assert_eq!(got[0].idx, truth[2].idx);
        assert!(got.iter().all(|h| h.idx != truth[0].idx && h.idx != truth[1].idx));
    }

    #[test]
    fn drop_second_keeps_first() {
        let (s, b) = setup();
        let oracle = OracleIndex::with_error(BruteIndex::new(&s), RetrievalError::drop_second());
        let q = s.row(9).to_vec();
        let truth = b.top_k(&q, 11);
        let got = oracle.top_k(&q, 10);
        assert_eq!(got[0].idx, truth[0].idx, "top-1 preserved");
        assert_eq!(got[1].idx, truth[2].idx, "rank-2 dropped");
    }

    #[test]
    fn labels_match_paper_table() {
        assert_eq!(RetrievalError::none().label(), "None");
        assert_eq!(RetrievalError::drop_first().label(), "1");
        assert_eq!(RetrievalError::drop_first_two().label(), "[1 2]");
    }
}
