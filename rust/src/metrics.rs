//! Statistical reporting used by every experiment: the paper's headline
//! metric is the **percentage mean absolute relative error**
//! `μ = 100·|Ẑ − Z|/Z` averaged over queries, with a standard error σ
//! computed over seed replicas (each table cell reports μ over 3 seeds).

/// Percentage absolute relative error of a single estimate.
#[inline]
pub fn abs_rel_err_pct(z_hat: f64, z_true: f64) -> f64 {
    debug_assert!(z_true > 0.0);
    100.0 * ((z_hat - z_true) / z_true).abs()
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Standard error of the mean.
pub fn std_err(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    std_dev(xs) / (xs.len() as f64).sqrt()
}

/// A (μ, σ) table cell: mean over per-seed means, stderr across seeds —
/// matching the paper's "every experimental setting was ran three times
/// with different seeds" protocol.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Cell {
    pub mu: f64,
    pub sigma: f64,
}

impl Cell {
    /// Aggregate per-seed mean errors into a table cell.
    pub fn from_seed_means(per_seed: &[f64]) -> Cell {
        Cell {
            mu: mean(per_seed),
            sigma: std_err(per_seed),
        }
    }
}

impl std::fmt::Display for Cell {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:>9.1} {:>6.1}", self.mu, self.sigma)
    }
}

/// Online accumulator for error statistics over a query stream.
#[derive(Clone, Debug, Default)]
pub struct ErrStats {
    pub count: usize,
    sum: f64,
    sum_sq: f64,
}

impl ErrStats {
    pub fn push(&mut self, err: f64) {
        self.count += 1;
        self.sum += err;
        self.sum_sq += err * err;
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn merge(&mut self, other: &ErrStats) {
        self.count += other.count;
        self.sum += other.sum;
        self.sum_sq += other.sum_sq;
    }
}

/// Paired comparison for Table 4's %Better column: fraction of queries
/// where |a_i - t_i| < |b_i - t_i| (a strictly closer to truth than b),
/// as a percentage.
pub fn pct_better(a: &[f64], b: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    assert_eq!(a.len(), truth.len());
    if a.is_empty() {
        return f64::NAN;
    }
    let wins = a
        .iter()
        .zip(b)
        .zip(truth)
        .filter(|((ai, bi), t)| (*ai - **t).abs() < (*bi - **t).abs())
        .count();
    100.0 * wins as f64 / a.len() as f64
}

/// Total absolute error for Table 4's AbsE column: Σ |ẑ_i − z_i|.
pub fn total_abs_err(est: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(est.len(), truth.len());
    est.iter()
        .zip(truth)
        .map(|(e, t)| (e - t).abs())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn abs_rel_err_basic() {
        assert_eq!(abs_rel_err_pct(110.0, 100.0), 10.0.into());
        assert_eq!(abs_rel_err_pct(90.0, 100.0), 10.0);
        assert_eq!(abs_rel_err_pct(100.0, 100.0), 0.0);
    }

    #[test]
    fn stats_basics() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn empty_and_singleton() {
        assert!(mean(&[]).is_nan());
        assert_eq!(std_dev(&[3.0]), 0.0);
        assert_eq!(std_err(&[3.0]), 0.0);
    }

    #[test]
    fn err_stats_merge_equals_sequential() {
        let mut a = ErrStats::default();
        let mut b = ErrStats::default();
        let mut c = ErrStats::default();
        for i in 0..10 {
            let x = i as f64;
            c.push(x);
            if i < 5 {
                a.push(x)
            } else {
                b.push(x)
            }
        }
        a.merge(&b);
        assert_eq!(a.count, c.count);
        assert!((a.mean() - c.mean()).abs() < 1e-12);
    }

    #[test]
    fn pct_better_counts_strict_wins() {
        let truth = [10.0, 10.0, 10.0, 10.0];
        let a = [10.5, 12.0, 9.0, 10.0]; // errors: .5, 2, 1, 0
        let b = [11.0, 11.0, 9.5, 10.0]; // errors: 1, 1, .5, 0
        // a wins on #0, b wins on #1 and #2, tie on #3 → 25%
        assert_eq!(pct_better(&a, &b, &truth), 25.0);
    }

    #[test]
    fn total_abs_err_sums() {
        assert_eq!(total_abs_err(&[1.0, 3.0], &[2.0, 1.0]), 3.0);
    }

    #[test]
    fn cell_from_seed_means() {
        let c = Cell::from_seed_means(&[1.0, 2.0, 3.0]);
        assert!((c.mu - 2.0).abs() < 1e-12);
        assert!((c.sigma - 1.0 / 3.0f64.sqrt()).abs() < 1e-12);
    }
}
