//! The partition-estimation service: bounded ingress queue → batcher →
//! worker pool → per-request reply channels. See module docs in
//! [`crate::coordinator`].

use super::batcher::{Batch, BatchAssembler, BatcherConfig};
use super::metrics::ServiceMetrics;
use super::router::Router;
use crate::data::embeddings::EmbeddingStore;
use crate::estimators::EstimatorKind;
use crate::mips::MipsIndex;
use crate::runtime::{HostTensor, RuntimeHandle};
use crate::util::rng::Rng;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// One estimation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub query: Vec<f32>,
    pub kind: EstimatorKind,
    pub k: usize,
    pub l: usize,
}

/// The service's answer.
#[derive(Clone, Debug)]
pub struct Response {
    pub z: f64,
    pub kind: EstimatorKind,
    /// Time from submission until this request's batch group started
    /// executing (includes any earlier groups of the same drained batch).
    pub queue_wait: std::time::Duration,
    /// Execution time of the **batch group** that answered this request
    /// — requests batched together share one `estimate_batch` call, so
    /// they all report the same (shared) execution time, not a
    /// per-request slice of it.
    pub exec_time: std::time::Duration,
    /// Category scorings this request cost (sublinearity accounting).
    pub scorings: usize,
}

/// Internal: request + reply channel + enqueue timestamp.
pub struct QueuedRequest {
    pub request: Request,
    pub reply: mpsc::Sender<Response>,
    pub enqueued: Instant,
}

/// What to do when the ingress queue is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackpressurePolicy {
    /// Block the submitter until space frees up.
    Block,
    /// Reject immediately with [`SubmitError::Overloaded`].
    Shed,
}

/// Service construction parameters.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub workers: usize,
    pub queue_capacity: usize,
    pub batcher: BatcherConfig,
    pub backpressure: BackpressurePolicy,
    pub seed: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: crate::util::threadpool::default_threads().min(8),
            queue_capacity: 1024,
            batcher: BatcherConfig::default(),
            backpressure: BackpressurePolicy::Block,
            seed: 0,
        }
    }
}

/// Submission failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue full under [`BackpressurePolicy::Shed`].
    Overloaded,
    /// Service has shut down.
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded => write!(f, "service overloaded (queue full)"),
            SubmitError::Closed => write!(f, "service closed"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// The running service.
pub struct PartitionService {
    ingress: mpsc::SyncSender<QueuedRequest>,
    metrics: Arc<ServiceMetrics>,
    policy: BackpressurePolicy,
    threads: Vec<std::thread::JoinHandle<()>>,
}

/// Shared worker state.
struct WorkerCtx {
    store: Arc<EmbeddingStore>,
    index: Arc<dyn MipsIndex>,
    router: Arc<Router>,
    metrics: Arc<ServiceMetrics>,
    runtime: Option<RuntimeHandle>,
}

impl PartitionService {
    /// Start the batcher + worker threads.
    pub fn start(
        store: Arc<EmbeddingStore>,
        index: Arc<dyn MipsIndex>,
        router: Router,
        cfg: ServiceConfig,
        runtime: Option<RuntimeHandle>,
    ) -> PartitionService {
        let metrics = Arc::new(ServiceMetrics::new());
        let (ingress_tx, ingress_rx) = mpsc::sync_channel::<QueuedRequest>(cfg.queue_capacity);
        let (batch_tx, batch_rx) = mpsc::channel::<Batch>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let mut threads = Vec::new();

        // Batcher thread.
        {
            let metrics = metrics.clone();
            let bcfg = cfg.batcher.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("zest-batcher".into())
                    .spawn(move || {
                        let mut asm = BatchAssembler::new(bcfg);
                        while let Some(batch) = asm.next_batch(&ingress_rx) {
                            metrics.on_batch(batch.requests.len());
                            if batch_tx.send(batch).is_err() {
                                break;
                            }
                        }
                    })
                    .expect("spawn batcher"),
            );
        }

        // Worker threads.
        let ctx = Arc::new(WorkerCtx {
            store,
            index,
            router: Arc::new(router),
            metrics: metrics.clone(),
            runtime,
        });
        let mut seed_rng = Rng::seeded(cfg.seed ^ 0x5E55_1011);
        for w in 0..cfg.workers.max(1) {
            let ctx = ctx.clone();
            let rx = batch_rx.clone();
            let mut rng = seed_rng.fork();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("zest-worker-{w}"))
                    .spawn(move || loop {
                        let batch = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match batch {
                            Ok(b) => Self::run_batch(&ctx, b, &mut rng),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }

        PartitionService {
            ingress: ingress_tx,
            metrics,
            policy: cfg.backpressure,
            threads,
        }
    }

    fn run_batch(ctx: &WorkerCtx, batch: Batch, rng: &mut Rng) {
        // Exact batches ride the PJRT scoring artifact when attached.
        if batch.kind == EstimatorKind::Exact {
            if let Some(rt) = &ctx.runtime {
                if Self::run_exact_batch_pjrt(ctx, &batch, rt).is_ok() {
                    return;
                }
                log::warn!("PJRT exact batch failed; falling back to native path");
            }
        }
        let n = ctx.store.len();
        // The batcher guarantees one kind per batch; sub-group by the
        // (k, l) hyper-parameters so each group maps onto one estimator
        // instance and is answered by a single `estimate_batch` call —
        // one shared retrieval/scoring pass instead of a per-request
        // loop. Order within a group is preserved; in practice a batch
        // is one group (clients of a kind use one configuration).
        let mut groups: Vec<((usize, usize), Vec<QueuedRequest>)> = Vec::new();
        for qr in batch.requests {
            let key = (qr.request.k, qr.request.l);
            match groups.iter_mut().find(|(g, _)| *g == key) {
                Some((_, v)) => v.push(qr),
                None => groups.push((key, vec![qr])),
            }
        }
        for ((k, l), mut reqs) in groups {
            let started = Instant::now();
            let qs: Vec<Vec<f32>> = reqs
                .iter_mut()
                .map(|qr| std::mem::take(&mut qr.request.query))
                .collect();
            let zs = ctx.router.estimate_batch(
                batch.kind,
                k,
                l,
                &ctx.store,
                ctx.index.as_ref(),
                &qs,
                rng,
            );
            let exec = started.elapsed();
            ctx.metrics.on_batch_executed(reqs.len(), exec);
            let scorings = ctx.router.scorings(batch.kind, k, l, n);
            for (qr, z) in reqs.into_iter().zip(zs) {
                let queue_wait = started.duration_since(qr.enqueued);
                ctx.metrics.on_complete(queue_wait, exec);
                let _ = qr.reply.send(Response {
                    z,
                    kind: batch.kind,
                    queue_wait,
                    exec_time: exec,
                    scorings,
                });
            }
        }
    }

    /// Batched exact partition via the AOT `score_batch` artifact:
    /// pad the query batch to the artifact's B, stream the category
    /// matrix in artifact-sized chunks (zero-padding the last one and
    /// correcting the +1-per-padded-row bias), sum partials per query.
    fn run_exact_batch_pjrt(
        ctx: &WorkerCtx,
        batch: &Batch,
        rt: &RuntimeHandle,
    ) -> anyhow::Result<()> {
        let store = &ctx.store;
        let (n, d) = (store.len(), store.dim());
        // Artifact shapes come from meta.json via a probe call contract:
        // the service caches them in the handle-free config instead; here
        // we read the declared shapes lazily from the first run failure.
        // Shapes: v (chunk, d_a), qs (b_a, d_a) -> (b_a,)
        let (chunk, d_a, b_a) = rt_score_batch_dims(rt)?;
        anyhow::ensure!(d_a == d, "artifact d {d_a} != store d {d}");
        let started = Instant::now();
        let reqs = &batch.requests;
        let mut zs = vec![0f64; reqs.len()];
        for q_chunk in (0..reqs.len()).step_by(b_a) {
            let q_hi = (q_chunk + b_a).min(reqs.len());
            let mut qs = vec![0f32; b_a * d];
            for (bi, qr) in reqs[q_chunk..q_hi].iter().enumerate() {
                anyhow::ensure!(qr.request.query.len() == d, "query dim mismatch");
                qs[bi * d..(bi + 1) * d].copy_from_slice(&qr.request.query);
            }
            let qs_t = HostTensor::f32(qs, &[b_a, d]);
            for lo in (0..n).step_by(chunk) {
                let hi = (lo + chunk).min(n);
                let rows = hi - lo;
                let pad = chunk - rows;
                let mut v = vec![0f32; chunk * d];
                v[..rows * d].copy_from_slice(store.rows(lo, hi));
                let out = rt.run(
                    "score_batch",
                    vec![HostTensor::f32(v, &[chunk, d]), qs_t.clone()],
                )?;
                let partials = out[0]
                    .as_f32()
                    .ok_or_else(|| anyhow::anyhow!("score_batch returned non-f32"))?;
                for (bi, z) in zs[q_chunk..q_hi].iter_mut().enumerate() {
                    // Padded rows contribute exp(0) = 1 each; remove them.
                    *z += partials[bi] as f64 - pad as f64;
                }
            }
        }
        let exec = started.elapsed();
        ctx.metrics.on_batch_executed(reqs.len(), exec);
        for (qr, z) in reqs.iter().zip(zs) {
            let queue_wait = started.duration_since(qr.enqueued);
            ctx.metrics.on_complete(queue_wait, exec);
            let _ = qr.reply.send(Response {
                z,
                kind: EstimatorKind::Exact,
                queue_wait,
                exec_time: exec,
                scorings: n,
            });
        }
        Ok(())
    }

    /// Submit a request; returns the reply receiver.
    pub fn submit(&self, request: Request) -> Result<mpsc::Receiver<Response>, SubmitError> {
        let (tx, rx) = mpsc::channel();
        let qr = QueuedRequest {
            request,
            reply: tx,
            enqueued: Instant::now(),
        };
        self.metrics.on_submit();
        match self.policy {
            BackpressurePolicy::Block => self
                .ingress
                .send(qr)
                .map_err(|_| SubmitError::Closed)
                .map(|_| rx),
            BackpressurePolicy::Shed => match self.ingress.try_send(qr) {
                Ok(()) => Ok(rx),
                Err(mpsc::TrySendError::Full(_)) => {
                    self.metrics.on_shed();
                    Err(SubmitError::Overloaded)
                }
                Err(mpsc::TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
            },
        }
    }

    /// Convenience: submit and wait.
    pub fn estimate(&self, request: Request) -> Result<Response, SubmitError> {
        let rx = self.submit(request)?;
        rx.recv().map_err(|_| SubmitError::Closed)
    }

    pub fn metrics(&self) -> super::metrics::MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Drain and stop all threads.
    pub fn shutdown(self) {
        drop(self.ingress);
        for t in self.threads {
            let _ = t.join();
        }
    }
}

/// score_batch artifact dims cache: (chunk, d, batch). Read once from the
/// exporter's meta via the runtime thread environment variable contract.
fn rt_score_batch_dims(_rt: &RuntimeHandle) -> anyhow::Result<(usize, usize, usize)> {
    // The handle intentionally carries no meta; the service reads the
    // artifacts dir the same way the runtime did.
    let dir = std::env::var("ZEST_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    let meta = crate::runtime::ArtifactsMeta::load(std::path::Path::new(&dir))?;
    let (_, args) = meta
        .graphs
        .get("score_batch")
        .ok_or_else(|| anyhow::anyhow!("score_batch not exported"))?;
    let chunk = args[0].shape[0];
    let d = args[0].shape[1];
    let b = args[1].shape[0];
    Ok((chunk, d, b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::estimators::fmbe::FmbeConfig;
    use crate::mips::brute::BruteIndex;

    fn start_service(
        policy: BackpressurePolicy,
        capacity: usize,
    ) -> (PartitionService, Arc<EmbeddingStore>) {
        let store = Arc::new(generate(&SynthConfig {
            n: 500,
            d: 16,
            ..SynthConfig::tiny()
        }));
        let index: Arc<dyn MipsIndex> = Arc::new(BruteIndex::new(&store));
        let svc = PartitionService::start(
            store.clone(),
            index,
            Router::new(FmbeConfig {
                p_features: 100,
                ..Default::default()
            }),
            ServiceConfig {
                workers: 2,
                queue_capacity: capacity,
                backpressure: policy,
                ..Default::default()
            },
            None,
        );
        (svc, store)
    }

    #[test]
    fn end_to_end_estimates_match_exact_within_tolerance() {
        let (svc, store) = start_service(BackpressurePolicy::Block, 64);
        let brute = BruteIndex::new(&store);
        let q = store.row(450).to_vec();
        let want = brute.partition(&q);
        let resp = svc
            .estimate(Request {
                query: q,
                kind: EstimatorKind::Mimps,
                k: 100,
                l: 100,
            })
            .unwrap();
        let rel = ((resp.z - want) / want).abs();
        assert!(rel < 0.5, "service MIMPS {} vs exact {want}", resp.z);
        assert_eq!(resp.scorings, 200);
        svc.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let (svc, store) = start_service(BackpressurePolicy::Block, 256);
        let svc = Arc::new(svc);
        let mut handles = Vec::new();
        for t in 0..4 {
            let svc = svc.clone();
            let store = store.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..25 {
                    let q = store.row((t * 25 + i) % store.len()).to_vec();
                    let r = svc
                        .estimate(Request {
                            query: q,
                            kind: EstimatorKind::Mimps,
                            k: 20,
                            l: 20,
                        })
                        .unwrap();
                    assert!(r.z.is_finite() && r.z > 0.0);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = svc.metrics();
        assert_eq!(m.completed, 100);
        assert_eq!(m.shed, 0);
        assert!(m.batches >= 1);
        assert!(
            m.batch_throughput_rps > 0.0,
            "batched execution must record throughput"
        );
        Arc::try_unwrap(svc).ok().map(|s| s.shutdown());
    }

    #[test]
    fn mixed_hyperparams_in_one_batch_answer_independently() {
        // Two different (k, l) configs of one kind may share a drained
        // batch; the (k, l) grouping must answer each with its own
        // estimator instance.
        let (svc, store) = start_service(BackpressurePolicy::Block, 64);
        let q = store.row(10).to_vec();
        let rx_a = svc
            .submit(Request {
                query: q.clone(),
                kind: EstimatorKind::Nmimps,
                k: 50,
                l: 0,
            })
            .unwrap();
        let rx_b = svc
            .submit(Request {
                query: q,
                kind: EstimatorKind::Nmimps,
                k: 500,
                l: 0,
            })
            .unwrap();
        let a = rx_a.recv().unwrap();
        let b = rx_b.recv().unwrap();
        assert_eq!(a.scorings, 50);
        assert_eq!(b.scorings, 500);
        assert!(
            a.z <= b.z,
            "NMIMPS head sum grows with k: {} vs {}",
            a.z,
            b.z
        );
        svc.shutdown();
    }

    #[test]
    fn shed_policy_rejects_when_flooded() {
        // Tiny queue + tiny batches: flood with slow Exact requests.
        let store = Arc::new(generate(&SynthConfig {
            n: 4000,
            d: 64,
            ..SynthConfig::tiny()
        }));
        let index: Arc<dyn MipsIndex> = Arc::new(BruteIndex::with_threads(&store, 1));
        let svc = PartitionService::start(
            store.clone(),
            index,
            Router::new(FmbeConfig::default()),
            ServiceConfig {
                workers: 1,
                queue_capacity: 2,
                backpressure: BackpressurePolicy::Shed,
                batcher: BatcherConfig {
                    max_batch: 1,
                    max_wait: std::time::Duration::from_millis(1),
                },
                ..Default::default()
            },
            None,
        );
        let mut rejected = 0;
        let mut receivers = Vec::new();
        for i in 0..200 {
            match svc.submit(Request {
                query: store.row(i % store.len()).to_vec(),
                kind: EstimatorKind::Exact,
                k: 0,
                l: 0,
            }) {
                Ok(rx) => receivers.push(rx),
                Err(SubmitError::Overloaded) => rejected += 1,
                Err(e) => panic!("{e}"),
            }
        }
        assert!(rejected > 0, "flood should shed load");
        for rx in receivers {
            let _ = rx.recv();
        }
        assert!(svc.metrics().shed as usize == rejected);
        svc.shutdown();
    }
}
