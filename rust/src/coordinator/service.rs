//! The partition-estimation service: bounded ingress queue → batcher →
//! worker pool → per-request reply channels, answering from any
//! [`PartitionBackend`]. See module docs in [`crate::coordinator`].

use super::backend::{
    BackendError, GroupParams, PartitionBackend, Precision, SnapshotBackend, StaticBackend,
};
use super::batcher::{Batch, BatchAssembler, BatcherConfig};
use super::frontdoor::{Admission, CacheConfig, Fingerprint, FrontDoor};
use super::metrics::ServiceMetrics;
use super::router::Router;
use crate::data::embeddings::EmbeddingStore;
use crate::estimators::EstimatorKind;
use crate::mips::MipsIndex;
use crate::obs::{Trace, TraceRing, TraceSampler, COORD_TRACK};
use crate::runtime::RuntimeHandle;
use crate::store::SnapshotHandle;
use crate::util::rng::Rng;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// One estimation request, built fluently:
///
/// ```no_run
/// # use zest::coordinator::{EstimateSpec, Precision};
/// # use zest::estimators::EstimatorKind;
/// # let query = vec![0.0f32; 16];
/// # let deadline = std::time::Instant::now() + std::time::Duration::from_millis(5);
/// let spec = EstimateSpec::new(query)
///     .kind(EstimatorKind::Mimps)
///     .k(100)
///     .l(10)
///     .precision(Precision::Pipelined)
///     .deadline(deadline);
/// ```
///
/// Defaults: [`EstimatorKind::Exact`] with `k = l = 0`,
/// [`Precision::BitExact`], no deadline — the always-correct (and most
/// expensive) configuration; callers opt into sublinearity explicitly.
///
/// The struct is `#[non_exhaustive]`: construct through
/// [`EstimateSpec::new`] + the builder methods so new request knobs can
/// be added without breaking callers.
#[non_exhaustive]
#[derive(Clone, Debug)]
pub struct EstimateSpec {
    /// The query vector q (must match the backend's dimensionality).
    pub query: Vec<f32>,
    /// Which estimator answers.
    pub kind: EstimatorKind,
    /// Head budget (top-k retrieval size); meaning is estimator-specific.
    pub k: usize,
    /// Tail budget (uniform sample size); meaning is estimator-specific.
    pub l: usize,
    /// Bit-exact vs pipelined multi-worker `Exact` (see [`Precision`]).
    pub precision: Precision,
    /// Drop-dead time: a request still queued when its deadline passes
    /// is shed by the batcher at drain time (counted in
    /// [`super::MetricsSnapshot::deadline_shed`]) instead of wasting a
    /// batch slot on an answer nobody is waiting for.
    pub deadline: Option<Instant>,
    /// Per-request trace handle. `None` (the default) means the
    /// service's own sampler decides
    /// ([`ServiceConfig::trace_sample_rate`]); attaching one with
    /// [`EstimateSpec::trace`] forces this request to be traced
    /// regardless of the sampling rate. The handle travels with the
    /// request through the queue, batcher and backend; the completed
    /// trace lands in [`PartitionService::traces`]. Ignored by
    /// fingerprinting — traced and untraced twins still coalesce.
    pub trace: Option<Trace>,
}

impl EstimateSpec {
    /// A spec for `query` with the default (exact, no-deadline) knobs.
    pub fn new(query: Vec<f32>) -> EstimateSpec {
        EstimateSpec {
            query,
            kind: EstimatorKind::Exact,
            k: 0,
            l: 0,
            precision: Precision::BitExact,
            deadline: None,
            trace: None,
        }
    }

    /// A query-less spec used as the parameter template of batched
    /// calls (e.g. `PartitionClient::estimate_batch`, where the queries
    /// travel separately).
    pub fn template() -> EstimateSpec {
        EstimateSpec::new(Vec::new())
    }

    /// Select the estimator kind.
    pub fn kind(mut self, kind: EstimatorKind) -> EstimateSpec {
        self.kind = kind;
        self
    }

    /// Set the head budget k.
    pub fn k(mut self, k: usize) -> EstimateSpec {
        self.k = k;
        self
    }

    /// Set the tail budget l.
    pub fn l(mut self, l: usize) -> EstimateSpec {
        self.l = l;
        self
    }

    /// Select the `Exact` precision mode (ignored by in-process
    /// backends, which are always bit-exact).
    pub fn precision(mut self, precision: Precision) -> EstimateSpec {
        self.precision = precision;
        self
    }

    /// Set an absolute drop-dead time.
    pub fn deadline(mut self, deadline: Instant) -> EstimateSpec {
        self.deadline = Some(deadline);
        self
    }

    /// Set the deadline as a budget from now.
    pub fn deadline_in(self, budget: Duration) -> EstimateSpec {
        self.deadline(Instant::now() + budget)
    }

    /// Attach a [`Trace`]: this request records stage spans regardless
    /// of the service's sampling rate, and its completed trace lands in
    /// [`PartitionService::traces`].
    pub fn trace(mut self, trace: Trace) -> EstimateSpec {
        self.trace = Some(trace);
        self
    }

    /// The knobs a batch group shares (everything but query, kind and
    /// deadline) — the coordinator's sub-batch grouping key.
    pub fn params(&self) -> GroupParams {
        GroupParams {
            k: self.k,
            l: self.l,
            precision: self.precision,
        }
    }
}

/// The service's answer.
#[derive(Clone, Debug)]
pub struct Response {
    /// The estimated partition value Ẑ(q).
    pub z: f64,
    /// Estimator that produced the answer.
    pub kind: EstimatorKind,
    /// Snapshot epoch the answering batch group pinned. Always 0 for a
    /// service over a monolithic store; for epoch-publishing backends
    /// this is the epoch whose category set produced `z` (a request
    /// drained after an `add_categories` answers from the new epoch
    /// even if it was submitted before the swap — pinning happens at
    /// batch execution). `Fmbe` included: the router refits its λ̃ sums
    /// whenever the pinned epoch differs from the one it fitted on.
    pub epoch: u64,
    /// Time from submission until this request's batch group started
    /// executing (includes any earlier groups of the same drained batch).
    pub queue_wait: Duration,
    /// Execution time of the **batch group** that answered this request
    /// — requests batched together share one `estimate_batch` call, so
    /// they all report the same (shared) execution time, not a
    /// per-request slice of it.
    pub exec_time: Duration,
    /// Category scorings this request cost (sublinearity accounting).
    /// A cache hit reports the **original** execution's cost — the
    /// number of scorings that produced the answer — even though the
    /// repeat itself scored nothing.
    pub scorings: usize,
    /// `true` when the answer was served synchronously from the
    /// front-door result cache (bit-identical to the execution that
    /// filled it, same epoch; `queue_wait`/`exec_time` are zero).
    /// Coalesced followers report `false` — their answer came from a
    /// live execution, just a shared one.
    pub served_from_cache: bool,
}

/// Internal: request + reply channel + enqueue timestamp.
pub struct QueuedRequest {
    /// The request being served.
    pub spec: EstimateSpec,
    /// Where the worker sends the answer (dropped on deadline shed).
    pub reply: mpsc::Sender<Response>,
    /// Submission timestamp (queue-wait accounting).
    pub enqueued: Instant,
    /// The front-door fingerprint whose in-flight slot this request
    /// **leads** — its completion fills the cache and answers the
    /// coalesced followers; its death (deadline shed, backend error)
    /// must abandon them. `None` for independent duplicates (they own
    /// no slot) and for requests built outside the submit path.
    pub fingerprint: Option<Fingerprint>,
}

/// What to do when the ingress queue is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackpressurePolicy {
    /// Block the submitter until space frees up.
    Block,
    /// Reject immediately with [`SubmitError::Overloaded`].
    Shed,
}

/// Service construction parameters.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Worker threads executing drained batches.
    pub workers: usize,
    /// Bounded ingress queue capacity.
    pub queue_capacity: usize,
    /// Dynamic-batcher policy knobs.
    pub batcher: BatcherConfig,
    /// Full-queue behavior (block vs shed).
    pub backpressure: BackpressurePolicy,
    /// Seed of the per-worker sampling RNG forks.
    pub seed: u64,
    /// Front-door result-cache capacity in entries (`0` disables the
    /// cache; single-flight coalescing stays on regardless).
    pub cache_entries: usize,
    /// Front-door result-cache capacity in bytes (`0` disables the
    /// cache); the effective bound is the tighter of the two
    /// capacities.
    pub cache_bytes: usize,
    /// Fraction of requests that record a stage-span [`Trace`]
    /// (`0.0` = off, `1.0` = every request; rounded to an every-Nth
    /// period — see [`TraceSampler`]). Requests carrying an explicit
    /// [`EstimateSpec::trace`] are always traced.
    pub trace_sample_rate: f64,
    /// Completed traces retained for dumping (bounded ring, oldest
    /// evicted; `0` drops completed traces — stage histograms still
    /// fill).
    pub trace_ring: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: crate::util::threadpool::default_threads().min(8),
            queue_capacity: 1024,
            batcher: BatcherConfig::default(),
            backpressure: BackpressurePolicy::Block,
            seed: 0,
            cache_entries: CacheConfig::default().entries,
            cache_bytes: CacheConfig::default().bytes,
            trace_sample_rate: 0.0,
            trace_ring: 256,
        }
    }
}

/// Submission failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue full under [`BackpressurePolicy::Shed`].
    Overloaded,
    /// Service has shut down (or the answering backend failed — the
    /// reply channel was dropped without an answer).
    Closed,
    /// The spec's deadline passed before the request could execute:
    /// rejected at submit when already expired, or shed by the batcher
    /// at drain time.
    DeadlineExceeded,
    /// `EstimateSpec.query` dimensionality differs from the store's.
    /// Checked at `submit()` so a malformed request is rejected
    /// immediately instead of waiting in queue and then failing (and
    /// poisoning its batch group) mid-drain.
    DimMismatch {
        /// The submitted query's dimensionality.
        got: usize,
        /// The served store's dimensionality.
        want: usize,
    },
    /// The spec's head budget `k` is unusable for its kind — zero, or
    /// larger than the served category count. Checked at `submit()`
    /// for the kinds that read `k` (`Nmimps`, `Mimps`, `Mince`), so a
    /// garbage spec can't reach mid-drain estimator code or fragment
    /// the front door's fingerprint space.
    KOutOfRange {
        /// The submitted head budget.
        got: usize,
        /// The served category count (inclusive upper bound for `k`).
        max: usize,
    },
    /// The spec's tail budget `l` is zero for a kind that draws a
    /// uniform sample (`Uniform`, `Mimps`, `Mince`). Same submit-time
    /// rejection rationale as [`SubmitError::KOutOfRange`].
    LOutOfRange {
        /// The submitted tail budget.
        got: usize,
    },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded => write!(f, "service overloaded (queue full)"),
            SubmitError::Closed => write!(f, "service closed"),
            SubmitError::DeadlineExceeded => write!(f, "deadline exceeded"),
            SubmitError::DimMismatch { got, want } => {
                write!(f, "query dimensionality {got} != store dimensionality {want}")
            }
            SubmitError::KOutOfRange { got, max } => {
                write!(f, "head budget k={got} out of range (want 1..={max})")
            }
            SubmitError::LOutOfRange { got } => {
                write!(f, "tail budget l={got} out of range (want >= 1)")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// The running service: bounded queue → dynamic batcher → worker pool,
/// answering from one [`PartitionBackend`].
pub struct PartitionService {
    ingress: mpsc::SyncSender<QueuedRequest>,
    metrics: Arc<ServiceMetrics>,
    policy: BackpressurePolicy,
    /// Backend dimensionality, for submit-time query validation
    /// (invariant across snapshot epochs — mutations cannot change d).
    dim: usize,
    /// What the workers answer from; also serves manifest queries.
    backend: Arc<dyn PartitionBackend>,
    /// The fingerprint → cache → coalesce stage in front of the queue.
    frontdoor: Arc<FrontDoor>,
    /// Every-Nth request sampler handing out [`Trace`]s at submit.
    sampler: TraceSampler,
    /// Bounded ring of completed traces (Chrome-dumpable).
    traces: Arc<TraceRing>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

/// Shared worker state.
struct WorkerCtx {
    backend: Arc<dyn PartitionBackend>,
    metrics: Arc<ServiceMetrics>,
    frontdoor: Arc<FrontDoor>,
    traces: Arc<TraceRing>,
}

impl PartitionService {
    /// Start over a monolithic store + index ([`StaticBackend`];
    /// `runtime` attaches the PJRT `score_batch` artifact for `Exact`).
    pub fn start(
        store: Arc<EmbeddingStore>,
        index: Arc<dyn MipsIndex>,
        router: Router,
        cfg: ServiceConfig,
        runtime: Option<RuntimeHandle>,
    ) -> PartitionService {
        Self::start_with_backend(
            StaticBackend::new(store, index, router).with_runtime(runtime),
            cfg,
        )
    }

    /// Start over epoch snapshots of a sharded store
    /// ([`SnapshotBackend`]). Batch groups scatter across the
    /// snapshot's shards (through its
    /// [`crate::mips::sharded::ShardedIndex`]) and per-shard metrics
    /// are exported; the caller keeps its `Arc<SnapshotHandle>` to
    /// publish category mutations while the service runs. The
    /// `runtime` parameter is accepted for signature compatibility but
    /// unused: the PJRT scoring artifact streams one contiguous matrix
    /// and rides only the monolithic [`PartitionService::start`] path.
    pub fn start_sharded(
        handle: Arc<SnapshotHandle>,
        router: Router,
        cfg: ServiceConfig,
        runtime: Option<RuntimeHandle>,
    ) -> PartitionService {
        if runtime.is_some() {
            log::warn!("PJRT runtime ignored for sharded serving (monolithic-only artifact)");
        }
        Self::start_with_backend(SnapshotBackend::new(handle, router), cfg)
    }

    /// Start the batcher + worker threads over **any**
    /// [`PartitionBackend`] — the seam that puts the bounded queue,
    /// dynamic batcher, backpressure policy and [`ServiceMetrics`] in
    /// front of in-process *and* remote serving alike:
    ///
    /// ```no_run
    /// # use zest::coordinator::{ClusterBackend, PartitionService, ServiceConfig};
    /// # use zest::net::client::ClientConfig;
    /// # let addrs: Vec<zest::net::Addr> = vec![];
    /// let svc = PartitionService::start_with_backend(
    ///     ClusterBackend::connect(&addrs, ClientConfig::default()).unwrap(),
    ///     ServiceConfig::default(),
    /// );
    /// ```
    pub fn start_with_backend<B: PartitionBackend>(
        backend: B,
        cfg: ServiceConfig,
    ) -> PartitionService {
        let backend: Arc<dyn PartitionBackend> = Arc::new(backend);
        let dim = backend.dim();
        let metrics = Arc::new(ServiceMetrics::new());
        let frontdoor = Arc::new(FrontDoor::new(CacheConfig {
            entries: cfg.cache_entries,
            bytes: cfg.cache_bytes,
        }));
        // Align the cache generation with the backend's current epoch,
        // so a service started over an already-mutated backend caches
        // under the epoch it actually serves from the first request on.
        frontdoor.observe_epoch(backend.epoch(), &metrics);
        let sampler = TraceSampler::new(cfg.trace_sample_rate);
        let traces = Arc::new(TraceRing::new(cfg.trace_ring));
        let (ingress_tx, ingress_rx) = mpsc::sync_channel::<QueuedRequest>(cfg.queue_capacity);
        let (batch_tx, batch_rx) = mpsc::channel::<Batch>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let mut threads = Vec::new();

        // Batcher thread: assembles batches and enforces deadlines at
        // drain time — a request whose deadline passed while queued is
        // shed (reply channel dropped, counted in metrics) instead of
        // occupying a batch slot.
        {
            let metrics = metrics.clone();
            let frontdoor = frontdoor.clone();
            let bcfg = cfg.batcher.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("zest-batcher".into())
                    .spawn(move || {
                        let mut asm = BatchAssembler::new(bcfg);
                        while let Some(mut batch) = asm.next_batch(&ingress_rx) {
                            let now = Instant::now();
                            let expired =
                                sweep_expired(&mut batch.requests, now, &frontdoor, &metrics);
                            if expired > 0 {
                                metrics.on_deadline_shed(expired);
                            }
                            if batch.requests.is_empty() {
                                continue;
                            }
                            metrics.on_batch(batch.requests.len());
                            if batch_tx.send(batch).is_err() {
                                break;
                            }
                        }
                    })
                    .expect("spawn batcher"),
            );
        }

        // Worker threads.
        let ctx = Arc::new(WorkerCtx {
            backend: backend.clone(),
            metrics: metrics.clone(),
            frontdoor: frontdoor.clone(),
            traces: traces.clone(),
        });
        let mut seed_rng = Rng::seeded(cfg.seed ^ 0x5E55_1011);
        for w in 0..cfg.workers.max(1) {
            let ctx = ctx.clone();
            let rx = batch_rx.clone();
            let mut rng = seed_rng.fork();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("zest-worker-{w}"))
                    .spawn(move || loop {
                        let batch = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match batch {
                            Ok(b) => Self::run_batch(&ctx, b, &mut rng),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }

        PartitionService {
            ingress: ingress_tx,
            metrics,
            policy: cfg.backpressure,
            dim,
            backend,
            frontdoor,
            sampler,
            traces,
            threads,
        }
    }

    fn run_batch(ctx: &WorkerCtx, mut batch: Batch, rng: &mut Rng) {
        // Second deadline sweep at execution time: a drained batch can
        // wait in the worker channel behind slow groups, so re-check
        // before paying the backend for answers nobody is waiting for
        // (the batcher's drain-time sweep only covers queue wait).
        let now = Instant::now();
        let expired = sweep_expired(&mut batch.requests, now, &ctx.frontdoor, &ctx.metrics);
        if expired > 0 {
            ctx.metrics.on_deadline_shed(expired);
        }
        // The batcher guarantees one kind per batch; sub-group by the
        // request params ((k, l) hyper-parameters + precision mode) so
        // each group maps onto one backend configuration and is
        // answered by a single `estimate_batch` call — one shared
        // retrieval/scoring pass instead of a per-request loop. The
        // backend pins one consistent view (snapshot epoch / cluster
        // layout) per group. Order within a group is preserved; in
        // practice a batch is one group (clients of a kind use one
        // configuration).
        let mut groups: Vec<(GroupParams, Vec<QueuedRequest>)> = Vec::new();
        for qr in batch.requests {
            let key = qr.spec.params();
            match groups.iter_mut().find(|(g, _)| *g == key) {
                Some((_, v)) => v.push(qr),
                None => groups.push((key, vec![qr])),
            }
        }
        for (params, mut reqs) in groups {
            let started = Instant::now();
            // Queue span per traced request: submit-side enqueue to the
            // moment its group starts executing. The first trace in the
            // group also rides into the backend, where cluster backends
            // attribute per-shard scatter RPCs to it.
            let group_trace = reqs.iter().find_map(|qr| qr.spec.trace.clone());
            for qr in &reqs {
                if let Some(t) = &qr.spec.trace {
                    t.span_at(
                        "queue",
                        qr.enqueued,
                        started.duration_since(qr.enqueued),
                        COORD_TRACK,
                        Vec::new(),
                    );
                }
            }
            let qs: Vec<Vec<f32>> = reqs
                .iter_mut()
                .map(|qr| std::mem::take(&mut qr.spec.query))
                .collect();
            let answer =
                ctx.backend
                    .estimate_batch(batch.kind, params, &qs, rng, group_trace.as_ref());
            let exec = started.elapsed();
            let answer = match answer {
                Ok(a) => a,
                Err(e) => {
                    // Dropping `reqs` drops the reply senders: waiting
                    // callers observe a closed channel (SubmitError::
                    // Closed), never a silent hang. Leaders first
                    // abandon their in-flight slot so coalesced
                    // followers observe the same failure — and nothing
                    // is cached, so one failure never poisons its
                    // fingerprint.
                    log::warn!(
                        "batch group of {} {} request(s) failed: {e}",
                        reqs.len(),
                        batch.kind
                    );
                    ctx.metrics.on_backend_error();
                    // Cluster backends attribute scatter failures to the
                    // worker that caused them (`ClientError::Shard`);
                    // surface that in the per-shard error counters so a
                    // failing worker is identifiable from metrics alone.
                    if let Some(shard) = e.shard() {
                        ctx.metrics.on_shard_error(shard);
                    }
                    for qr in &reqs {
                        if let Some(fp) = qr.fingerprint {
                            ctx.frontdoor.abandon(&fp, &ctx.metrics);
                        }
                    }
                    // Failed traces still seal (without a batch span) so
                    // the ring shows where the pipeline stopped.
                    for qr in reqs {
                        Self::finish_trace(ctx, qr.spec.trace);
                    }
                    continue;
                }
            };
            for qr in &reqs {
                if let Some(t) = &qr.spec.trace {
                    t.span_at(
                        "batch",
                        started,
                        exec,
                        COORD_TRACK,
                        vec![
                            ("requests".into(), reqs.len().to_string()),
                            ("epoch".into(), answer.epoch.to_string()),
                        ],
                    );
                }
            }
            ctx.metrics.on_batch_executed(reqs.len(), exec);
            ctx.metrics.on_epoch(answer.epoch);
            // The pinned view's epoch reaches the front door before any
            // completion below tries to cache under it — without this,
            // the first batch after an externally-published epoch would
            // be refused by the cache's generation check.
            ctx.frontdoor.observe_epoch(answer.epoch, &ctx.metrics);
            let n = answer.len;
            let scorings = ctx.backend.scorings(batch.kind, params, n);
            // Per-shard accounting: apportion the request's scoring
            // budget across shards by their share of the rows (exact
            // for `Exact`, where scorings = n; proportional attribution
            // for the samplers), and attribute the group's shared
            // execution time to every shard the scatter touched.
            for (s, &shard_len) in answer.shard_lens.iter().enumerate() {
                let per_request = scorings * shard_len / n.max(1);
                ctx.metrics.on_shard_batch(
                    answer.epoch,
                    s,
                    shard_len,
                    per_request * reqs.len(),
                    exec,
                );
            }
            for (qr, z) in reqs.into_iter().zip(answer.zs) {
                let queue_wait = started.duration_since(qr.enqueued);
                ctx.metrics.on_complete(queue_wait, exec);
                let resp = Response {
                    z,
                    kind: batch.kind,
                    epoch: answer.epoch,
                    queue_wait,
                    exec_time: exec,
                    scorings,
                    served_from_cache: false,
                };
                // A leader's completion settles its flight: the cache
                // fills (if the answering epoch still matches the
                // fingerprint) and the coalesced followers get the
                // answer, each with its own queue wait.
                if let Some(fp) = qr.fingerprint {
                    ctx.frontdoor.complete(&fp, &resp, &ctx.metrics);
                }
                // Seal before the reply send: a caller that has its
                // answer can rely on the completed trace being in the
                // ring already.
                Self::finish_trace(ctx, qr.spec.trace);
                let _ = qr.reply.send(resp);
            }
        }
    }

    /// Seal a request's trace (if any): feed the per-stage histograms
    /// and retain the completed trace in the dump ring.
    fn finish_trace(ctx: &WorkerCtx, trace: Option<Trace>) {
        if let Some(t) = trace {
            let done = t.finish();
            ctx.metrics.on_trace(&done);
            ctx.traces.push(done);
        }
    }

    /// Submit a request; returns the reply receiver. Dimensionality,
    /// estimator budgets and an already-expired deadline are validated
    /// here — before the request can occupy queue space — so a doomed
    /// query fails fast instead of after its queue wait.
    ///
    /// Validated requests then pass the front door: a result cached
    /// under the current epoch answers synchronously (the receiver is
    /// returned already holding the [`Response`], `served_from_cache`
    /// set); a request identical to one already in flight coalesces
    /// behind it instead of occupying a second batch slot; everything
    /// else enqueues toward the batcher as the leader of its
    /// fingerprint.
    pub fn submit(&self, mut spec: EstimateSpec) -> Result<mpsc::Receiver<Response>, SubmitError> {
        if spec.query.len() != self.dim {
            return Err(SubmitError::DimMismatch {
                got: spec.query.len(),
                want: self.dim,
            });
        }
        let (n, epoch) = self.backend.serving_info();
        // Budget validation, scoped to the budgets the kind reads (the
        // default Exact spec carries k = l = 0 and must stay valid).
        if matches!(
            spec.kind,
            EstimatorKind::Nmimps | EstimatorKind::Mimps | EstimatorKind::Mince
        ) && (spec.k == 0 || spec.k > n)
        {
            return Err(SubmitError::KOutOfRange { got: spec.k, max: n });
        }
        if matches!(
            spec.kind,
            EstimatorKind::Uniform | EstimatorKind::Mimps | EstimatorKind::Mince
        ) && spec.l == 0
        {
            return Err(SubmitError::LOutOfRange { got: spec.l });
        }
        if let Some(d) = spec.deadline {
            if Instant::now() >= d {
                self.metrics.on_deadline_shed(1);
                return Err(SubmitError::DeadlineExceeded);
            }
        }
        // Sampling decision: an explicit spec-attached trace wins;
        // otherwise the service's every-Nth sampler decides. From here
        // the handle rides inside the spec, through queue and batcher
        // to the backend.
        if spec.trace.is_none() {
            spec.trace = self.sampler.sample();
        }
        let trace = spec.trace.clone();
        let fd_start = Instant::now();
        // Observe the serving epoch before fingerprinting so a publish
        // that bypassed the service's own hooks still invalidates the
        // cache no later than the next submit.
        self.frontdoor.observe_epoch(epoch, &self.metrics);
        let fp = Fingerprint::of(&spec, epoch);
        let (tx, rx) = mpsc::channel();
        let frontdoor_span = |outcome: &str| {
            if let Some(t) = &trace {
                t.span_at(
                    "frontdoor",
                    fd_start,
                    fd_start.elapsed(),
                    COORD_TRACK,
                    vec![("outcome".into(), outcome.into())],
                );
            }
        };
        // A request answered (or subsumed) at the front door never
        // reaches a worker: seal its trace here.
        let seal = |trace: Option<Trace>| {
            if let Some(t) = trace {
                let done = t.finish();
                self.metrics.on_trace(&done);
                self.traces.push(done);
            }
        };
        let fingerprint = match self.frontdoor.admit(fp, &tx, spec.deadline, &self.metrics) {
            Admission::Hit(resp) => {
                frontdoor_span("hit");
                seal(trace);
                self.metrics.on_submit();
                self.metrics.on_complete(Duration::ZERO, Duration::ZERO);
                let _ = tx.send(resp);
                return Ok(rx);
            }
            Admission::Coalesced => {
                frontdoor_span("coalesced");
                seal(trace);
                self.metrics.on_submit();
                return Ok(rx);
            }
            Admission::Lead(fingerprint) => {
                frontdoor_span("lead");
                fingerprint
            }
        };
        let qr = QueuedRequest {
            spec,
            reply: tx,
            enqueued: Instant::now(),
            fingerprint,
        };
        self.metrics.on_submit();
        // An enqueue failure on a registered leader must abandon its
        // flight: followers observe the failure now, and the next
        // identical submit can lead instead of coalescing forever
        // behind a request that never ran.
        let abandon = |e: SubmitError| {
            if let Some(fp) = &fingerprint {
                self.frontdoor.abandon(fp, &self.metrics);
            }
            e
        };
        match self.policy {
            BackpressurePolicy::Block => self
                .ingress
                .send(qr)
                .map_err(|_| abandon(SubmitError::Closed))
                .map(|_| rx),
            BackpressurePolicy::Shed => match self.ingress.try_send(qr) {
                Ok(()) => Ok(rx),
                Err(mpsc::TrySendError::Full(_)) => {
                    self.metrics.on_shed();
                    Err(abandon(SubmitError::Overloaded))
                }
                Err(mpsc::TrySendError::Disconnected(_)) => Err(abandon(SubmitError::Closed)),
            },
        }
    }

    /// Convenience: submit and wait. A dropped reply channel surfaces
    /// as [`SubmitError::DeadlineExceeded`] when the spec's deadline
    /// has passed, else [`SubmitError::Closed`] — deliberately "no
    /// answer by the deadline is a deadline miss", even if the
    /// underlying drop was a backend failure (which
    /// [`super::MetricsSnapshot::backend_errors`] still records).
    pub fn estimate(&self, spec: EstimateSpec) -> Result<Response, SubmitError> {
        let deadline = spec.deadline;
        let rx = self.submit(spec)?;
        rx.recv().map_err(|_| match deadline {
            Some(d) if Instant::now() >= d => SubmitError::DeadlineExceeded,
            _ => SubmitError::Closed,
        })
    }

    /// A point-in-time copy of the service counters.
    pub fn metrics(&self) -> super::metrics::MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// The live metrics sink, shareable with a network front-end so
    /// wire-level counters land next to the batching/queueing ones.
    pub fn metrics_handle(&self) -> Arc<ServiceMetrics> {
        self.metrics.clone()
    }

    /// Store dimensionality served (invariant across epochs).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// `(categories, epoch)` currently served, straight from the
    /// backend's manifest. Used by network front-ends to answer
    /// manifest requests.
    pub fn serving_info(&self) -> (usize, u64) {
        self.backend.serving_info()
    }

    /// The serving backend (publish hooks, manifest). Publishes issued
    /// directly on the backend are still safe — every submit re-reads
    /// the manifest — but prefer
    /// [`add_categories`](PartitionService::add_categories) /
    /// [`remove_categories`](PartitionService::remove_categories) so
    /// the front-door cache is invalidated at publish time rather than
    /// at the next request.
    pub fn backend(&self) -> &Arc<dyn PartitionBackend> {
        &self.backend
    }

    /// Publish hook: append `rows` as new categories through the
    /// backend, then observe the new epoch at the front door — every
    /// result cached under the previous epoch is invalidated in O(1)
    /// before this returns.
    pub fn add_categories(&self, rows: EmbeddingStore) -> Result<u64, BackendError> {
        let epoch = self.backend.add_categories(rows)?;
        self.frontdoor.observe_epoch(epoch, &self.metrics);
        Ok(epoch)
    }

    /// Publish hook: remove the given global ids through the backend,
    /// with the same immediate front-door invalidation as
    /// [`add_categories`](PartitionService::add_categories).
    pub fn remove_categories(&self, ids: &[usize]) -> Result<u64, BackendError> {
        let epoch = self.backend.remove_categories(ids)?;
        self.frontdoor.observe_epoch(epoch, &self.metrics);
        Ok(epoch)
    }

    /// The front door (cache/coalescer introspection for tests and
    /// operational tooling).
    pub fn frontdoor(&self) -> &Arc<FrontDoor> {
        &self.frontdoor
    }

    /// The bounded ring of completed request traces — dump with
    /// [`TraceRing::to_chrome_json`]. Empty unless
    /// [`ServiceConfig::trace_sample_rate`] is non-zero or specs carry
    /// explicit [`EstimateSpec::trace`] handles.
    pub fn traces(&self) -> &Arc<TraceRing> {
        &self.traces
    }

    /// Drain and stop all threads.
    pub fn shutdown(self) {
        drop(self.ingress);
        for t in self.threads {
            let _ = t.join();
        }
    }
}

/// Drop requests whose deadline passed, abandoning the in-flight slot
/// of any shed **leader** so its coalesced followers observe the
/// failure immediately (and the fingerprint becomes claimable again)
/// instead of waiting on a flight nobody will complete. Returns the
/// dropped count for `on_deadline_shed`.
fn sweep_expired(
    requests: &mut Vec<QueuedRequest>,
    now: Instant,
    frontdoor: &FrontDoor,
    metrics: &ServiceMetrics,
) -> usize {
    let before = requests.len();
    requests.retain(|qr| {
        let keep = qr.spec.deadline.is_none_or(|d| now < d);
        if !keep {
            if let Some(fp) = qr.fingerprint {
                frontdoor.abandon(&fp, metrics);
            }
        }
        keep
    });
    before - requests.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::estimators::fmbe::FmbeConfig;
    use crate::mips::brute::BruteIndex;

    fn start_service(
        policy: BackpressurePolicy,
        capacity: usize,
    ) -> (PartitionService, Arc<EmbeddingStore>) {
        let store = Arc::new(generate(&SynthConfig {
            n: 500,
            d: 16,
            ..SynthConfig::tiny()
        }));
        let index: Arc<dyn MipsIndex> = Arc::new(BruteIndex::new(&store));
        let svc = PartitionService::start(
            store.clone(),
            index,
            Router::new(FmbeConfig {
                p_features: 100,
                ..Default::default()
            }),
            ServiceConfig {
                workers: 2,
                queue_capacity: capacity,
                backpressure: policy,
                ..Default::default()
            },
            None,
        );
        (svc, store)
    }

    #[test]
    fn spec_builder_defaults_are_exact() {
        let spec = EstimateSpec::new(vec![1.0, 2.0]);
        assert_eq!(spec.kind, EstimatorKind::Exact);
        assert_eq!((spec.k, spec.l), (0, 0));
        assert_eq!(spec.precision, Precision::BitExact);
        assert!(spec.deadline.is_none());
        let spec = spec
            .kind(EstimatorKind::Mimps)
            .k(100)
            .l(10)
            .precision(Precision::Pipelined)
            .deadline_in(Duration::from_secs(1));
        assert_eq!(spec.kind, EstimatorKind::Mimps);
        assert_eq!((spec.k, spec.l), (100, 10));
        assert_eq!(
            spec.params(),
            GroupParams {
                k: 100,
                l: 10,
                precision: Precision::Pipelined
            }
        );
        assert!(spec.deadline.is_some());
    }

    #[test]
    fn end_to_end_estimates_match_exact_within_tolerance() {
        let (svc, store) = start_service(BackpressurePolicy::Block, 64);
        let brute = BruteIndex::new(&store);
        let q = store.row(450).to_vec();
        let want = brute.partition(&q);
        let resp = svc
            .estimate(
                EstimateSpec::new(q)
                    .kind(EstimatorKind::Mimps)
                    .k(100)
                    .l(100),
            )
            .unwrap();
        let rel = ((resp.z - want) / want).abs();
        assert!(rel < 0.5, "service MIMPS {} vs exact {want}", resp.z);
        assert_eq!(resp.scorings, 200);
        svc.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let (svc, store) = start_service(BackpressurePolicy::Block, 256);
        let svc = Arc::new(svc);
        let mut handles = Vec::new();
        for t in 0..4 {
            let svc = svc.clone();
            let store = store.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..25 {
                    let q = store.row((t * 25 + i) % store.len()).to_vec();
                    let r = svc
                        .estimate(EstimateSpec::new(q).kind(EstimatorKind::Mimps).k(20).l(20))
                        .unwrap();
                    assert!(r.z.is_finite() && r.z > 0.0);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = svc.metrics();
        assert_eq!(m.completed, 100);
        assert_eq!(m.shed, 0);
        assert!(m.batches >= 1);
        assert!(
            m.batch_throughput_rps > 0.0,
            "batched execution must record throughput"
        );
        Arc::try_unwrap(svc).ok().map(|s| s.shutdown());
    }

    #[test]
    fn mixed_hyperparams_in_one_batch_answer_independently() {
        // Two different (k, l) configs of one kind may share a drained
        // batch; the params grouping must answer each with its own
        // estimator instance.
        let (svc, store) = start_service(BackpressurePolicy::Block, 64);
        let q = store.row(10).to_vec();
        let rx_a = svc
            .submit(EstimateSpec::new(q.clone()).kind(EstimatorKind::Nmimps).k(50))
            .unwrap();
        let rx_b = svc
            .submit(EstimateSpec::new(q).kind(EstimatorKind::Nmimps).k(500))
            .unwrap();
        let a = rx_a.recv().unwrap();
        let b = rx_b.recv().unwrap();
        assert_eq!(a.scorings, 50);
        assert_eq!(b.scorings, 500);
        assert!(
            a.z <= b.z,
            "NMIMPS head sum grows with k: {} vs {}",
            a.z,
            b.z
        );
        svc.shutdown();
    }

    #[test]
    fn dim_mismatch_rejected_at_submit_time() {
        let (svc, store) = start_service(BackpressurePolicy::Block, 16);
        let err = svc
            .submit(
                EstimateSpec::new(vec![0.0; 7])
                    .kind(EstimatorKind::Mimps)
                    .k(5)
                    .l(5),
            )
            .unwrap_err();
        assert_eq!(err, SubmitError::DimMismatch { got: 7, want: 16 });
        assert_eq!(
            err.to_string(),
            "query dimensionality 7 != store dimensionality 16"
        );
        // Rejected requests never occupy the queue; valid ones still flow.
        let ok = svc
            .estimate(
                EstimateSpec::new(store.row(0).to_vec())
                    .kind(EstimatorKind::Nmimps)
                    .k(10),
            )
            .unwrap();
        assert!(ok.z > 0.0);
        let m = svc.metrics();
        assert_eq!(m.submitted, 1, "dim-mismatched submit must not count");
        svc.shutdown();
    }

    #[test]
    fn budgets_validated_at_submit_per_kind() {
        let (svc, store) = start_service(BackpressurePolicy::Block, 16);
        let q = store.row(0).to_vec();
        // k out of range for a k-reading kind (n = 500).
        let err = svc
            .submit(EstimateSpec::new(q.clone()).kind(EstimatorKind::Nmimps).k(501))
            .unwrap_err();
        assert_eq!(err, SubmitError::KOutOfRange { got: 501, max: 500 });
        assert_eq!(
            err.to_string(),
            "head budget k=501 out of range (want 1..=500)"
        );
        let err = svc
            .submit(
                EstimateSpec::new(q.clone())
                    .kind(EstimatorKind::Mimps)
                    .k(0)
                    .l(10),
            )
            .unwrap_err();
        assert_eq!(err, SubmitError::KOutOfRange { got: 0, max: 500 });
        // l = 0 for a sampling kind.
        let err = svc
            .submit(EstimateSpec::new(q.clone()).kind(EstimatorKind::Uniform))
            .unwrap_err();
        assert_eq!(err, SubmitError::LOutOfRange { got: 0 });
        assert_eq!(err.to_string(), "tail budget l=0 out of range (want >= 1)");
        // The default Exact spec ignores both budgets and stays valid.
        let ok = svc.estimate(EstimateSpec::new(q)).unwrap();
        assert!(ok.z > 0.0);
        let m = svc.metrics();
        assert_eq!(m.submitted, 1, "rejected specs never count as submitted");
        svc.shutdown();
    }

    #[test]
    fn cache_hit_is_bit_identical_and_counted() {
        let (svc, store) = start_service(BackpressurePolicy::Block, 64);
        let spec = || {
            EstimateSpec::new(store.row(3).to_vec())
                .kind(EstimatorKind::Mimps)
                .k(50)
                .l(50)
        };
        let r1 = svc.estimate(spec()).unwrap();
        assert!(!r1.served_from_cache);
        let r2 = svc.estimate(spec()).unwrap();
        assert!(r2.served_from_cache, "identical repeat must hit the cache");
        assert_eq!(r1.z.to_bits(), r2.z.to_bits(), "hits are bit-identical");
        assert_eq!(r2.kind, r1.kind);
        assert_eq!(r2.epoch, r1.epoch);
        assert_eq!(
            r2.scorings, r1.scorings,
            "a hit reports the original execution's scoring cost"
        );
        assert_eq!(r2.queue_wait, Duration::ZERO);
        assert_eq!(r2.exec_time, Duration::ZERO);
        // A different budget is a different fingerprint.
        let r3 = svc.estimate(spec().k(60)).unwrap();
        assert!(!r3.served_from_cache);
        let m = svc.metrics();
        assert_eq!(m.cache_hits, 1);
        assert_eq!(m.cache_misses, 2);
        assert_eq!(m.completed, 3, "hits still count as completed requests");
        assert_eq!(svc.frontdoor().cached_entries(), 2);
        svc.shutdown();
    }

    #[test]
    fn publish_invalidates_cache_and_next_answer_is_fresh() {
        use crate::store::{ShardedStore, SnapshotHandle};
        let store = generate(&SynthConfig {
            n: 600,
            d: 16,
            ..SynthConfig::tiny()
        });
        let handle = Arc::new(SnapshotHandle::brute(ShardedStore::split(&store, 2)));
        let svc = PartitionService::start_sharded(
            handle,
            Router::new(FmbeConfig::default()),
            ServiceConfig {
                workers: 1,
                ..Default::default()
            },
            None,
        );
        let q = store.row(7).to_vec();
        let r0 = svc.estimate(EstimateSpec::new(q.clone())).unwrap();
        let hit = svc.estimate(EstimateSpec::new(q.clone())).unwrap();
        assert!(hit.served_from_cache);
        assert_eq!(hit.z.to_bits(), r0.z.to_bits());
        // Publish through the service wrapper: the cache dies with the
        // epoch, before the call returns.
        let added = generate(&SynthConfig {
            n: 32,
            d: 16,
            seed: 9,
            ..SynthConfig::tiny()
        });
        assert_eq!(svc.add_categories(added).unwrap(), 1);
        let r1 = svc.estimate(EstimateSpec::new(q)).unwrap();
        assert!(!r1.served_from_cache, "publish must invalidate the hit");
        assert_eq!(r1.epoch, 1);
        assert!(r1.z > r0.z, "new categories add positive mass");
        let m = svc.metrics();
        assert_eq!(m.cache_invalidations, 1);
        assert_eq!(m.cache_hits, 1);
        assert_eq!(m.cache_misses, 2);
        svc.shutdown();
    }

    /// A backend that sleeps, then fails once: lets a follower coalesce
    /// behind a leader whose execution errors.
    struct FailOnceBackend {
        inner: StaticBackend,
        fail_next: std::sync::atomic::AtomicBool,
        calls: Arc<std::sync::atomic::AtomicUsize>,
    }

    impl PartitionBackend for FailOnceBackend {
        fn dim(&self) -> usize {
            self.inner.dim()
        }
        fn serving_info(&self) -> (usize, u64) {
            self.inner.serving_info()
        }
        fn estimate_batch(
            &self,
            kind: EstimatorKind,
            params: GroupParams,
            qs: &[Vec<f32>],
            rng: &mut Rng,
            trace: Option<&Trace>,
        ) -> Result<super::super::backend::GroupAnswer, BackendError> {
            use std::sync::atomic::Ordering;
            self.calls.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(Duration::from_millis(120));
            if self.fail_next.swap(false, Ordering::SeqCst) {
                return Err(BackendError::new("injected failure"));
            }
            self.inner.estimate_batch(kind, params, qs, rng, trace)
        }
        fn scorings(&self, kind: EstimatorKind, params: GroupParams, n: usize) -> usize {
            self.inner.scorings(kind, params, n)
        }
        fn add_categories(&self, rows: EmbeddingStore) -> Result<u64, BackendError> {
            self.inner.add_categories(rows)
        }
        fn remove_categories(&self, ids: &[usize]) -> Result<u64, BackendError> {
            self.inner.remove_categories(ids)
        }
    }

    #[test]
    fn leader_error_propagates_to_followers_without_poisoning() {
        let store = Arc::new(generate(&SynthConfig {
            n: 200,
            d: 8,
            ..SynthConfig::tiny()
        }));
        let index: Arc<dyn MipsIndex> = Arc::new(BruteIndex::new(&store));
        let calls = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let backend = FailOnceBackend {
            inner: StaticBackend::new(store.clone(), index, Router::new(FmbeConfig::default())),
            fail_next: std::sync::atomic::AtomicBool::new(true),
            calls: calls.clone(),
        };
        let svc = PartitionService::start_with_backend(
            backend,
            ServiceConfig {
                workers: 1,
                ..Default::default()
            },
        );
        let q = store.row(0).to_vec();
        // Leader drains quickly (250 µs window) and sleeps 120 ms in
        // the backend; the follower submits well inside that window.
        let rx_lead = svc.submit(EstimateSpec::new(q.clone())).unwrap();
        let rx_follow = svc.submit(EstimateSpec::new(q.clone())).unwrap();
        assert!(
            rx_lead.recv().is_err(),
            "leader observes the backend failure as a dropped channel"
        );
        assert!(
            rx_follow.recv().is_err(),
            "the coalesced follower observes the same failure"
        );
        let m = svc.metrics();
        assert_eq!(m.coalesced, 1, "second identical submit coalesced");
        assert_eq!(m.backend_errors, 1);
        assert_eq!(svc.frontdoor().cached_entries(), 0, "failure cached nothing");
        assert_eq!(svc.frontdoor().inflight_len(), 0, "flight fully settled");
        // The fingerprint is not poisoned: a fresh submit re-executes
        // and succeeds.
        let r = svc.estimate(EstimateSpec::new(q)).unwrap();
        assert!(r.z > 0.0 && !r.served_from_cache);
        assert_eq!(
            calls.load(std::sync::atomic::Ordering::SeqCst),
            2,
            "failed flight + retry; the coalesced follower cost no call"
        );
        svc.shutdown();
    }

    #[test]
    fn expired_deadline_rejected_at_submit_and_shed_at_drain() {
        let (svc, store) = start_service(BackpressurePolicy::Block, 64);
        let q = store.row(0).to_vec();
        // Already expired at submit: fast rejection, no queue space.
        let err = svc
            .estimate(
                EstimateSpec::new(q.clone()).deadline(Instant::now() - Duration::from_millis(1)),
            )
            .unwrap_err();
        assert_eq!(err, SubmitError::DeadlineExceeded);
        assert_eq!(svc.metrics().deadline_shed, 1);
        // A generous deadline passes untouched.
        let ok = svc
            .estimate(EstimateSpec::new(q).deadline_in(Duration::from_secs(30)))
            .unwrap();
        assert!(ok.z > 0.0);
        assert_eq!(svc.metrics().deadline_shed, 1);
        svc.shutdown();
    }

    #[test]
    fn sharded_service_matches_monolithic_and_tracks_epochs() {
        use crate::store::{exp_sum_view, ShardedStore, SnapshotHandle};
        let store = generate(&SynthConfig {
            n: 600,
            d: 16,
            ..SynthConfig::tiny()
        });
        let handle = Arc::new(SnapshotHandle::brute(ShardedStore::split(&store, 4)));
        let svc = PartitionService::start_sharded(
            handle.clone(),
            Router::new(FmbeConfig {
                p_features: 100,
                ..Default::default()
            }),
            ServiceConfig {
                workers: 2,
                ..Default::default()
            },
            None,
        );
        let q = store.row(10).to_vec();
        let r0 = svc.estimate(EstimateSpec::new(q.clone())).unwrap();
        assert_eq!(r0.epoch, 0);
        // The service rides the batched exact kernel; the single-query
        // reference agrees to the last ulp on AVX2, while the scalar
        // GEMM's different f32 accumulation order needs the same 1e-6
        // bound tests/batching.rs uses (bit-level sharding equality is
        // pinned like-for-like in tests/sharding.rs).
        let want = exp_sum_view(&store, &q);
        assert!(
            (r0.z - want).abs() <= 1e-6 * want,
            "sharded Exact {} vs monolithic {want}",
            r0.z
        );
        // Publish a new epoch; subsequent requests answer from it.
        let added = generate(&SynthConfig {
            n: 40,
            d: 16,
            seed: 99,
            ..SynthConfig::tiny()
        });
        assert_eq!(handle.add_categories(added).unwrap(), 1);
        let r1 = svc.estimate(EstimateSpec::new(q.clone())).unwrap();
        assert_eq!(r1.epoch, 1);
        assert!(r1.z > r0.z, "new categories only add positive mass");
        // MIMPS flows through the sharded scatter too.
        let rm = svc
            .estimate(EstimateSpec::new(q).kind(EstimatorKind::Mimps).k(50).l(50))
            .unwrap();
        assert!(rm.z.is_finite() && rm.z > 0.0);
        assert_eq!(rm.epoch, 1);
        let m = svc.metrics();
        assert_eq!(m.epoch, 1);
        assert_eq!(m.shard_stats.len(), 5, "4 original shards + 1 added");
        assert!(m.shard_stats.iter().all(|s| s.batches >= 1));
        // The trait's publish hooks reach the same handle.
        let more = generate(&SynthConfig {
            n: 16,
            d: 16,
            seed: 5,
            ..SynthConfig::tiny()
        });
        assert_eq!(svc.backend().add_categories(more).unwrap(), 2);
        assert_eq!(svc.serving_info(), (656, 2));
        svc.shutdown();
    }

    #[test]
    fn sampled_traces_record_stage_spans_and_land_in_ring() {
        let (svc, store) = start_service_traced(1.0);
        let q = store.row(2).to_vec();
        let r = svc.estimate(EstimateSpec::new(q.clone())).unwrap();
        assert!(r.z > 0.0);
        assert_eq!(svc.traces().len(), 1, "every request sampled at rate 1.0");
        let done = &svc.traces().completed()[0];
        let names: Vec<&str> = done.events.iter().map(|e| e.name.as_str()).collect();
        assert_eq!(
            names,
            vec!["frontdoor", "queue", "batch"],
            "coordinator span tree in start order"
        );
        assert!(done.wall_ns >= done.stage_ns("batch"));
        assert!(done
            .events
            .iter()
            .all(|e| e.track == crate::obs::COORD_TRACK));
        // A cache hit's trace ends at the front door.
        let hit = svc.estimate(EstimateSpec::new(q)).unwrap();
        assert!(hit.served_from_cache);
        let traces = svc.traces().completed();
        assert_eq!(traces.len(), 2);
        let hit_trace = &traces[1];
        assert_eq!(hit_trace.events.len(), 1);
        assert_eq!(hit_trace.events[0].name, "frontdoor");
        assert_eq!(
            hit_trace.events[0].args,
            vec![("outcome".to_string(), "hit".to_string())]
        );
        // Chrome dump of the ring parses as JSON.
        let dump = svc.traces().to_chrome_json();
        assert!(crate::util::json::Json::parse(&dump).is_ok(), "{dump}");
        // Stage histograms picked the frontdoor spans up.
        let m = svc.metrics();
        assert!(m.stage_stats.iter().any(|s| s.stage == "frontdoor"));
        svc.shutdown();
    }

    #[test]
    fn tracing_off_records_nothing_but_explicit_traces_still_work() {
        let (svc, store) = start_service_traced(0.0);
        let q = store.row(5).to_vec();
        let r = svc.estimate(EstimateSpec::new(q.clone())).unwrap();
        assert!(r.z > 0.0);
        assert!(svc.traces().is_empty(), "rate 0.0 samples nothing");
        // An explicitly attached trace is honored regardless of rate.
        let t = crate::obs::Trace::start(77);
        let r = svc
            .estimate(EstimateSpec::new(store.row(6).to_vec()).trace(t))
            .unwrap();
        assert!(r.z > 0.0);
        let traces = svc.traces().completed();
        assert_eq!(traces.len(), 1);
        assert_eq!(traces[0].id, 77);
        assert!(traces[0].stage_ns("batch") > 0);
        svc.shutdown();
    }

    fn start_service_traced(rate: f64) -> (PartitionService, Arc<EmbeddingStore>) {
        let store = Arc::new(generate(&SynthConfig {
            n: 300,
            d: 16,
            ..SynthConfig::tiny()
        }));
        let index: Arc<dyn MipsIndex> = Arc::new(BruteIndex::new(&store));
        let svc = PartitionService::start(
            store.clone(),
            index,
            Router::new(FmbeConfig {
                p_features: 100,
                ..Default::default()
            }),
            ServiceConfig {
                workers: 1,
                trace_sample_rate: rate,
                ..Default::default()
            },
            None,
        );
        (svc, store)
    }

    #[test]
    fn shed_policy_rejects_when_flooded() {
        // Tiny queue + tiny batches: flood with slow Exact requests.
        let store = Arc::new(generate(&SynthConfig {
            n: 4000,
            d: 64,
            ..SynthConfig::tiny()
        }));
        let index: Arc<dyn MipsIndex> = Arc::new(BruteIndex::with_threads(&store, 1));
        let svc = PartitionService::start(
            store.clone(),
            index,
            Router::new(FmbeConfig::default()),
            ServiceConfig {
                workers: 1,
                queue_capacity: 2,
                backpressure: BackpressurePolicy::Shed,
                batcher: BatcherConfig {
                    max_batch: 1,
                    max_wait: std::time::Duration::from_millis(1),
                },
                ..Default::default()
            },
            None,
        );
        let mut rejected = 0;
        let mut receivers = Vec::new();
        for i in 0..200 {
            match svc.submit(EstimateSpec::new(store.row(i % store.len()).to_vec())) {
                Ok(rx) => receivers.push(rx),
                Err(SubmitError::Overloaded) => rejected += 1,
                Err(e) => panic!("{e}"),
            }
        }
        assert!(rejected > 0, "flood should shed load");
        for rx in receivers {
            let _ = rx.recv();
        }
        assert!(svc.metrics().shed as usize == rejected);
        svc.shutdown();
    }
}
