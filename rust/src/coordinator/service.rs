//! The partition-estimation service: bounded ingress queue → batcher →
//! worker pool → per-request reply channels. See module docs in
//! [`crate::coordinator`].

use super::batcher::{Batch, BatchAssembler, BatcherConfig};
use super::metrics::ServiceMetrics;
use super::router::Router;
use crate::data::embeddings::EmbeddingStore;
use crate::estimators::EstimatorKind;
use crate::mips::MipsIndex;
use crate::runtime::{HostTensor, RuntimeHandle};
use crate::store::{SnapshotHandle, StoreView};
use crate::util::rng::Rng;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// One estimation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub query: Vec<f32>,
    pub kind: EstimatorKind,
    pub k: usize,
    pub l: usize,
}

/// The service's answer.
#[derive(Clone, Debug)]
pub struct Response {
    pub z: f64,
    pub kind: EstimatorKind,
    /// Snapshot epoch the answering batch group pinned. Always 0 for a
    /// service over a monolithic store; for sharded services this is the
    /// epoch whose category set produced `z` (a request drained after an
    /// `add_categories` answers from the new epoch even if it was
    /// submitted before the swap — pinning happens at batch execution).
    /// `Fmbe` included: the router refits its λ̃ sums whenever the
    /// pinned epoch differs from the one it fitted on.
    pub epoch: u64,
    /// Time from submission until this request's batch group started
    /// executing (includes any earlier groups of the same drained batch).
    pub queue_wait: std::time::Duration,
    /// Execution time of the **batch group** that answered this request
    /// — requests batched together share one `estimate_batch` call, so
    /// they all report the same (shared) execution time, not a
    /// per-request slice of it.
    pub exec_time: std::time::Duration,
    /// Category scorings this request cost (sublinearity accounting).
    pub scorings: usize,
}

/// Internal: request + reply channel + enqueue timestamp.
pub struct QueuedRequest {
    pub request: Request,
    pub reply: mpsc::Sender<Response>,
    pub enqueued: Instant,
}

/// What to do when the ingress queue is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackpressurePolicy {
    /// Block the submitter until space frees up.
    Block,
    /// Reject immediately with [`SubmitError::Overloaded`].
    Shed,
}

/// Service construction parameters.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub workers: usize,
    pub queue_capacity: usize,
    pub batcher: BatcherConfig,
    pub backpressure: BackpressurePolicy,
    pub seed: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: crate::util::threadpool::default_threads().min(8),
            queue_capacity: 1024,
            batcher: BatcherConfig::default(),
            backpressure: BackpressurePolicy::Block,
            seed: 0,
        }
    }
}

/// Submission failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue full under [`BackpressurePolicy::Shed`].
    Overloaded,
    /// Service has shut down.
    Closed,
    /// `Request.query` dimensionality differs from the store's. Checked
    /// at `submit()` so a malformed request is rejected immediately
    /// instead of waiting in queue and then failing (and poisoning its
    /// batch group) mid-drain.
    DimMismatch { got: usize, want: usize },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded => write!(f, "service overloaded (queue full)"),
            SubmitError::Closed => write!(f, "service closed"),
            SubmitError::DimMismatch { got, want } => {
                write!(f, "query dimensionality {got} != store dimensionality {want}")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// The running service.
pub struct PartitionService {
    ingress: mpsc::SyncSender<QueuedRequest>,
    metrics: Arc<ServiceMetrics>,
    policy: BackpressurePolicy,
    /// Store dimensionality, for submit-time query validation (invariant
    /// across snapshot epochs — mutations cannot change d).
    dim: usize,
    /// Shared with the workers; lets the service report what it is
    /// serving (length / epoch) to network front-ends.
    serving: Arc<Serving>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

/// What the workers answer from.
enum Serving {
    /// One immutable monolithic store + index.
    Static {
        store: Arc<EmbeddingStore>,
        index: Arc<dyn MipsIndex>,
    },
    /// Epoch snapshots over a sharded store: each drained batch pins the
    /// current snapshot for its whole execution, so `add_categories` /
    /// `remove_categories` swap epochs without pausing in-flight work.
    Sharded { handle: Arc<SnapshotHandle> },
}

/// Shared worker state.
struct WorkerCtx {
    serving: Arc<Serving>,
    router: Arc<Router>,
    metrics: Arc<ServiceMetrics>,
    runtime: Option<RuntimeHandle>,
}

impl PartitionService {
    /// Start the batcher + worker threads over a monolithic store.
    pub fn start(
        store: Arc<EmbeddingStore>,
        index: Arc<dyn MipsIndex>,
        router: Router,
        cfg: ServiceConfig,
        runtime: Option<RuntimeHandle>,
    ) -> PartitionService {
        let dim = store.dim();
        Self::start_serving(Serving::Static { store, index }, dim, router, cfg, runtime)
    }

    /// Start over epoch snapshots of a sharded store. Batch groups
    /// scatter across the snapshot's shards (through its
    /// [`crate::mips::sharded::ShardedIndex`]) and per-shard metrics are
    /// exported; the caller keeps its `Arc<SnapshotHandle>` to publish
    /// category mutations while the service runs.
    pub fn start_sharded(
        handle: Arc<SnapshotHandle>,
        router: Router,
        cfg: ServiceConfig,
        runtime: Option<RuntimeHandle>,
    ) -> PartitionService {
        let dim = StoreView::dim(handle.load().store.as_ref());
        Self::start_serving(Serving::Sharded { handle }, dim, router, cfg, runtime)
    }

    fn start_serving(
        serving: Serving,
        dim: usize,
        router: Router,
        cfg: ServiceConfig,
        runtime: Option<RuntimeHandle>,
    ) -> PartitionService {
        let metrics = Arc::new(ServiceMetrics::new());
        let (ingress_tx, ingress_rx) = mpsc::sync_channel::<QueuedRequest>(cfg.queue_capacity);
        let (batch_tx, batch_rx) = mpsc::channel::<Batch>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let mut threads = Vec::new();

        // Batcher thread.
        {
            let metrics = metrics.clone();
            let bcfg = cfg.batcher.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("zest-batcher".into())
                    .spawn(move || {
                        let mut asm = BatchAssembler::new(bcfg);
                        while let Some(batch) = asm.next_batch(&ingress_rx) {
                            metrics.on_batch(batch.requests.len());
                            if batch_tx.send(batch).is_err() {
                                break;
                            }
                        }
                    })
                    .expect("spawn batcher"),
            );
        }

        // Worker threads.
        let serving = Arc::new(serving);
        let ctx = Arc::new(WorkerCtx {
            serving: serving.clone(),
            router: Arc::new(router),
            metrics: metrics.clone(),
            runtime,
        });
        let mut seed_rng = Rng::seeded(cfg.seed ^ 0x5E55_1011);
        for w in 0..cfg.workers.max(1) {
            let ctx = ctx.clone();
            let rx = batch_rx.clone();
            let mut rng = seed_rng.fork();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("zest-worker-{w}"))
                    .spawn(move || loop {
                        let batch = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match batch {
                            Ok(b) => Self::run_batch(&ctx, b, &mut rng),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }

        PartitionService {
            ingress: ingress_tx,
            metrics,
            policy: cfg.backpressure,
            dim,
            serving,
            threads,
        }
    }

    fn run_batch(ctx: &WorkerCtx, batch: Batch, rng: &mut Rng) {
        // Pin the serving state once for the whole drained batch: every
        // group answers from one consistent snapshot even if a category
        // mutation publishes a new epoch mid-batch.
        let pinned;
        let (view, index, epoch): (&dyn StoreView, &dyn MipsIndex, u64) = match ctx.serving.as_ref()
        {
            Serving::Static { store, index } => (store.as_ref(), index.as_ref(), 0),
            Serving::Sharded { handle } => {
                pinned = handle.load();
                (pinned.store.as_ref(), pinned.index.as_ref(), pinned.epoch)
            }
        };
        // Exact batches ride the PJRT scoring artifact when attached
        // (monolithic serving only — the artifact streams one contiguous
        // matrix).
        if batch.kind == EstimatorKind::Exact {
            if let (Serving::Static { store, .. }, Some(rt)) = (ctx.serving.as_ref(), &ctx.runtime)
            {
                if Self::run_exact_batch_pjrt(ctx, store, &batch, rt).is_ok() {
                    return;
                }
                log::warn!("PJRT exact batch failed; falling back to native path");
            }
        }
        let n = view.len();
        // The batcher guarantees one kind per batch; sub-group by the
        // (k, l) hyper-parameters so each group maps onto one estimator
        // instance and is answered by a single `estimate_batch` call —
        // one shared retrieval/scoring pass instead of a per-request
        // loop. On sharded snapshots that pass scatters across shards in
        // parallel inside `ShardedIndex::top_k_batch`. Order within a
        // group is preserved; in practice a batch is one group (clients
        // of a kind use one configuration).
        let mut groups: Vec<((usize, usize), Vec<QueuedRequest>)> = Vec::new();
        for qr in batch.requests {
            let key = (qr.request.k, qr.request.l);
            match groups.iter_mut().find(|(g, _)| *g == key) {
                Some((_, v)) => v.push(qr),
                None => groups.push((key, vec![qr])),
            }
        }
        for ((k, l), mut reqs) in groups {
            let started = Instant::now();
            let qs: Vec<Vec<f32>> = reqs
                .iter_mut()
                .map(|qr| std::mem::take(&mut qr.request.query))
                .collect();
            let zs = ctx
                .router
                .estimate_batch(batch.kind, k, l, view, index, epoch, &qs, rng);
            let exec = started.elapsed();
            ctx.metrics.on_batch_executed(reqs.len(), exec);
            ctx.metrics.on_epoch(epoch);
            let scorings = ctx.router.scorings(batch.kind, k, l, n);
            // Per-shard accounting: apportion the request's scoring
            // budget across shards by their share of the rows (exact for
            // `Exact`, where scorings = n; proportional attribution for
            // the samplers), and attribute the group's shared execution
            // time to every shard the scatter touched.
            if let Some(sharded) = view.as_sharded() {
                for (s, shard) in sharded.shards().iter().enumerate() {
                    let per_request = scorings * shard.len() / n.max(1);
                    ctx.metrics
                        .on_shard_batch(epoch, s, shard.len(), per_request * reqs.len(), exec);
                }
            }
            for (qr, z) in reqs.into_iter().zip(zs) {
                let queue_wait = started.duration_since(qr.enqueued);
                ctx.metrics.on_complete(queue_wait, exec);
                let _ = qr.reply.send(Response {
                    z,
                    kind: batch.kind,
                    epoch,
                    queue_wait,
                    exec_time: exec,
                    scorings,
                });
            }
        }
    }

    /// Batched exact partition via the AOT `score_batch` artifact:
    /// pad the query batch to the artifact's B, stream the category
    /// matrix in artifact-sized chunks (zero-padding the last one and
    /// correcting the +1-per-padded-row bias), sum partials per query.
    fn run_exact_batch_pjrt(
        ctx: &WorkerCtx,
        store: &Arc<EmbeddingStore>,
        batch: &Batch,
        rt: &RuntimeHandle,
    ) -> anyhow::Result<()> {
        let (n, d) = (store.len(), store.dim());
        // Artifact shapes come from meta.json via a probe call contract:
        // the service caches them in the handle-free config instead; here
        // we read the declared shapes lazily from the first run failure.
        // Shapes: v (chunk, d_a), qs (b_a, d_a) -> (b_a,)
        let (chunk, d_a, b_a) = rt_score_batch_dims(rt)?;
        anyhow::ensure!(d_a == d, "artifact d {d_a} != store d {d}");
        let started = Instant::now();
        let reqs = &batch.requests;
        let mut zs = vec![0f64; reqs.len()];
        for q_chunk in (0..reqs.len()).step_by(b_a) {
            let q_hi = (q_chunk + b_a).min(reqs.len());
            let mut qs = vec![0f32; b_a * d];
            for (bi, qr) in reqs[q_chunk..q_hi].iter().enumerate() {
                anyhow::ensure!(qr.request.query.len() == d, "query dim mismatch");
                qs[bi * d..(bi + 1) * d].copy_from_slice(&qr.request.query);
            }
            let qs_t = HostTensor::f32(qs, &[b_a, d]);
            for lo in (0..n).step_by(chunk) {
                let hi = (lo + chunk).min(n);
                let rows = hi - lo;
                let pad = chunk - rows;
                let mut v = vec![0f32; chunk * d];
                v[..rows * d].copy_from_slice(store.rows(lo, hi));
                let out = rt.run(
                    "score_batch",
                    vec![HostTensor::f32(v, &[chunk, d]), qs_t.clone()],
                )?;
                let partials = out[0]
                    .as_f32()
                    .ok_or_else(|| anyhow::anyhow!("score_batch returned non-f32"))?;
                for (bi, z) in zs[q_chunk..q_hi].iter_mut().enumerate() {
                    // Padded rows contribute exp(0) = 1 each; remove them.
                    *z += partials[bi] as f64 - pad as f64;
                }
            }
        }
        let exec = started.elapsed();
        ctx.metrics.on_batch_executed(reqs.len(), exec);
        for (qr, z) in reqs.iter().zip(zs) {
            let queue_wait = started.duration_since(qr.enqueued);
            ctx.metrics.on_complete(queue_wait, exec);
            let _ = qr.reply.send(Response {
                z,
                kind: EstimatorKind::Exact,
                epoch: 0,
                queue_wait,
                exec_time: exec,
                scorings: n,
            });
        }
        Ok(())
    }

    /// Submit a request; returns the reply receiver. Dimensionality is
    /// validated here — before the request can occupy queue space — so a
    /// malformed query fails fast instead of after its queue wait.
    pub fn submit(&self, request: Request) -> Result<mpsc::Receiver<Response>, SubmitError> {
        if request.query.len() != self.dim {
            return Err(SubmitError::DimMismatch {
                got: request.query.len(),
                want: self.dim,
            });
        }
        let (tx, rx) = mpsc::channel();
        let qr = QueuedRequest {
            request,
            reply: tx,
            enqueued: Instant::now(),
        };
        self.metrics.on_submit();
        match self.policy {
            BackpressurePolicy::Block => self
                .ingress
                .send(qr)
                .map_err(|_| SubmitError::Closed)
                .map(|_| rx),
            BackpressurePolicy::Shed => match self.ingress.try_send(qr) {
                Ok(()) => Ok(rx),
                Err(mpsc::TrySendError::Full(_)) => {
                    self.metrics.on_shed();
                    Err(SubmitError::Overloaded)
                }
                Err(mpsc::TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
            },
        }
    }

    /// Convenience: submit and wait.
    pub fn estimate(&self, request: Request) -> Result<Response, SubmitError> {
        let rx = self.submit(request)?;
        rx.recv().map_err(|_| SubmitError::Closed)
    }

    pub fn metrics(&self) -> super::metrics::MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// The live metrics sink, shareable with a network front-end so
    /// wire-level counters land next to the batching/queueing ones.
    pub fn metrics_handle(&self) -> Arc<ServiceMetrics> {
        self.metrics.clone()
    }

    /// Store dimensionality served (invariant across epochs).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// `(categories, epoch)` currently served: the static store's size
    /// (epoch 0) or the currently published snapshot's. Used by network
    /// front-ends to answer manifest requests.
    pub fn serving_info(&self) -> (usize, u64) {
        match self.serving.as_ref() {
            Serving::Static { store, .. } => (store.len(), 0),
            Serving::Sharded { handle } => {
                let snap = handle.load();
                (StoreView::len(snap.store.as_ref()), snap.epoch)
            }
        }
    }

    /// Drain and stop all threads.
    pub fn shutdown(self) {
        drop(self.ingress);
        for t in self.threads {
            let _ = t.join();
        }
    }
}

/// score_batch artifact dims cache: (chunk, d, batch). Read once from the
/// exporter's meta via the runtime thread environment variable contract.
fn rt_score_batch_dims(_rt: &RuntimeHandle) -> anyhow::Result<(usize, usize, usize)> {
    // The handle intentionally carries no meta; the service reads the
    // artifacts dir the same way the runtime did.
    let dir = std::env::var("ZEST_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    let meta = crate::runtime::ArtifactsMeta::load(std::path::Path::new(&dir))?;
    let (_, args) = meta
        .graphs
        .get("score_batch")
        .ok_or_else(|| anyhow::anyhow!("score_batch not exported"))?;
    let chunk = args[0].shape[0];
    let d = args[0].shape[1];
    let b = args[1].shape[0];
    Ok((chunk, d, b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::estimators::fmbe::FmbeConfig;
    use crate::mips::brute::BruteIndex;

    fn start_service(
        policy: BackpressurePolicy,
        capacity: usize,
    ) -> (PartitionService, Arc<EmbeddingStore>) {
        let store = Arc::new(generate(&SynthConfig {
            n: 500,
            d: 16,
            ..SynthConfig::tiny()
        }));
        let index: Arc<dyn MipsIndex> = Arc::new(BruteIndex::new(&store));
        let svc = PartitionService::start(
            store.clone(),
            index,
            Router::new(FmbeConfig {
                p_features: 100,
                ..Default::default()
            }),
            ServiceConfig {
                workers: 2,
                queue_capacity: capacity,
                backpressure: policy,
                ..Default::default()
            },
            None,
        );
        (svc, store)
    }

    #[test]
    fn end_to_end_estimates_match_exact_within_tolerance() {
        let (svc, store) = start_service(BackpressurePolicy::Block, 64);
        let brute = BruteIndex::new(&store);
        let q = store.row(450).to_vec();
        let want = brute.partition(&q);
        let resp = svc
            .estimate(Request {
                query: q,
                kind: EstimatorKind::Mimps,
                k: 100,
                l: 100,
            })
            .unwrap();
        let rel = ((resp.z - want) / want).abs();
        assert!(rel < 0.5, "service MIMPS {} vs exact {want}", resp.z);
        assert_eq!(resp.scorings, 200);
        svc.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let (svc, store) = start_service(BackpressurePolicy::Block, 256);
        let svc = Arc::new(svc);
        let mut handles = Vec::new();
        for t in 0..4 {
            let svc = svc.clone();
            let store = store.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..25 {
                    let q = store.row((t * 25 + i) % store.len()).to_vec();
                    let r = svc
                        .estimate(Request {
                            query: q,
                            kind: EstimatorKind::Mimps,
                            k: 20,
                            l: 20,
                        })
                        .unwrap();
                    assert!(r.z.is_finite() && r.z > 0.0);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = svc.metrics();
        assert_eq!(m.completed, 100);
        assert_eq!(m.shed, 0);
        assert!(m.batches >= 1);
        assert!(
            m.batch_throughput_rps > 0.0,
            "batched execution must record throughput"
        );
        Arc::try_unwrap(svc).ok().map(|s| s.shutdown());
    }

    #[test]
    fn mixed_hyperparams_in_one_batch_answer_independently() {
        // Two different (k, l) configs of one kind may share a drained
        // batch; the (k, l) grouping must answer each with its own
        // estimator instance.
        let (svc, store) = start_service(BackpressurePolicy::Block, 64);
        let q = store.row(10).to_vec();
        let rx_a = svc
            .submit(Request {
                query: q.clone(),
                kind: EstimatorKind::Nmimps,
                k: 50,
                l: 0,
            })
            .unwrap();
        let rx_b = svc
            .submit(Request {
                query: q,
                kind: EstimatorKind::Nmimps,
                k: 500,
                l: 0,
            })
            .unwrap();
        let a = rx_a.recv().unwrap();
        let b = rx_b.recv().unwrap();
        assert_eq!(a.scorings, 50);
        assert_eq!(b.scorings, 500);
        assert!(
            a.z <= b.z,
            "NMIMPS head sum grows with k: {} vs {}",
            a.z,
            b.z
        );
        svc.shutdown();
    }

    #[test]
    fn dim_mismatch_rejected_at_submit_time() {
        let (svc, store) = start_service(BackpressurePolicy::Block, 16);
        let err = svc
            .submit(Request {
                query: vec![0.0; 7],
                kind: EstimatorKind::Mimps,
                k: 5,
                l: 5,
            })
            .unwrap_err();
        assert_eq!(err, SubmitError::DimMismatch { got: 7, want: 16 });
        assert_eq!(
            err.to_string(),
            "query dimensionality 7 != store dimensionality 16"
        );
        // Rejected requests never occupy the queue; valid ones still flow.
        let ok = svc
            .estimate(Request {
                query: store.row(0).to_vec(),
                kind: EstimatorKind::Nmimps,
                k: 10,
                l: 0,
            })
            .unwrap();
        assert!(ok.z > 0.0);
        let m = svc.metrics();
        assert_eq!(m.submitted, 1, "dim-mismatched submit must not count");
        svc.shutdown();
    }

    #[test]
    fn sharded_service_matches_monolithic_and_tracks_epochs() {
        use crate::store::{exp_sum_view, ShardedStore, SnapshotHandle};
        let store = generate(&SynthConfig {
            n: 600,
            d: 16,
            ..SynthConfig::tiny()
        });
        let handle = Arc::new(SnapshotHandle::brute(ShardedStore::split(&store, 4)));
        let svc = PartitionService::start_sharded(
            handle.clone(),
            Router::new(FmbeConfig {
                p_features: 100,
                ..Default::default()
            }),
            ServiceConfig {
                workers: 2,
                ..Default::default()
            },
            None,
        );
        let q = store.row(10).to_vec();
        let r0 = svc
            .estimate(Request {
                query: q.clone(),
                kind: EstimatorKind::Exact,
                k: 0,
                l: 0,
            })
            .unwrap();
        assert_eq!(r0.epoch, 0);
        // The service rides the batched exact kernel; the single-query
        // reference agrees to the last ulp on AVX2, while the scalar
        // GEMM's different f32 accumulation order needs the same 1e-6
        // bound tests/batching.rs uses (bit-level sharding equality is
        // pinned like-for-like in tests/sharding.rs).
        let want = exp_sum_view(&store, &q);
        assert!(
            (r0.z - want).abs() <= 1e-6 * want,
            "sharded Exact {} vs monolithic {want}",
            r0.z
        );
        // Publish a new epoch; subsequent requests answer from it.
        let added = generate(&SynthConfig {
            n: 40,
            d: 16,
            seed: 99,
            ..SynthConfig::tiny()
        });
        assert_eq!(handle.add_categories(added).unwrap(), 1);
        let r1 = svc
            .estimate(Request {
                query: q.clone(),
                kind: EstimatorKind::Exact,
                k: 0,
                l: 0,
            })
            .unwrap();
        assert_eq!(r1.epoch, 1);
        assert!(r1.z > r0.z, "new categories only add positive mass");
        // MIMPS flows through the sharded scatter too.
        let rm = svc
            .estimate(Request {
                query: q,
                kind: EstimatorKind::Mimps,
                k: 50,
                l: 50,
            })
            .unwrap();
        assert!(rm.z.is_finite() && rm.z > 0.0);
        assert_eq!(rm.epoch, 1);
        let m = svc.metrics();
        assert_eq!(m.epoch, 1);
        assert_eq!(m.shard_stats.len(), 5, "4 original shards + 1 added");
        assert!(m.shard_stats.iter().all(|s| s.batches >= 1));
        svc.shutdown();
    }

    #[test]
    fn shed_policy_rejects_when_flooded() {
        // Tiny queue + tiny batches: flood with slow Exact requests.
        let store = Arc::new(generate(&SynthConfig {
            n: 4000,
            d: 64,
            ..SynthConfig::tiny()
        }));
        let index: Arc<dyn MipsIndex> = Arc::new(BruteIndex::with_threads(&store, 1));
        let svc = PartitionService::start(
            store.clone(),
            index,
            Router::new(FmbeConfig::default()),
            ServiceConfig {
                workers: 1,
                queue_capacity: 2,
                backpressure: BackpressurePolicy::Shed,
                batcher: BatcherConfig {
                    max_batch: 1,
                    max_wait: std::time::Duration::from_millis(1),
                },
                ..Default::default()
            },
            None,
        );
        let mut rejected = 0;
        let mut receivers = Vec::new();
        for i in 0..200 {
            match svc.submit(Request {
                query: store.row(i % store.len()).to_vec(),
                kind: EstimatorKind::Exact,
                k: 0,
                l: 0,
            }) {
                Ok(rx) => receivers.push(rx),
                Err(SubmitError::Overloaded) => rejected += 1,
                Err(e) => panic!("{e}"),
            }
        }
        assert!(rejected > 0, "flood should shed load");
        for rx in receivers {
            let _ = rx.recv();
        }
        assert!(svc.metrics().shed as usize == rejected);
        svc.shutdown();
    }
}
