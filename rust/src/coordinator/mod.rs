//! L4 coordinator: the serving system around the estimators.
//!
//! Shape (vLLM-router-like, scaled to this paper): requests — an
//! [`EstimateSpec`] built fluently from a query vector (estimator kind,
//! k/l budgets, [`Precision`] mode, optional deadline) — enter a
//! **bounded** queue after submit-time dimensionality validation; a
//! batcher thread drains it under a max-batch/max-delay policy, sheds
//! requests whose deadline expired while queued, and groups the rest by
//! estimator kind; a worker pool executes each drained batch as **one**
//! [`PartitionBackend::estimate_batch`] call per
//! [`backend::GroupParams`] group — a single batched retrieval/scoring
//! pass (multi-query GEMM on the brute index) instead of a per-request
//! loop.
//!
//! What the workers answer from is a [`PartitionBackend`] — the seam
//! that lets one batching/backpressure/metrics front-end serve every
//! category-set topology:
//!
//! * [`backend::StaticBackend`] — an immutable monolithic store (the
//!   PJRT `score_batch` artifact rides `Exact` groups when attached);
//! * [`backend::SnapshotBackend`] — epoch snapshots of a sharded store:
//!   each batch group pins the current `Arc<Snapshot>` for its whole
//!   execution and scatters across the snapshot's shards in parallel
//!   (inside [`crate::mips::sharded::ShardedIndex::top_k_batch`]);
//!   `add_categories` / `remove_categories` publish new epochs without
//!   pausing in-flight batches;
//! * [`backend::ClusterBackend`] — a [`crate::net::remote::RemoteCluster`]
//!   of shard-worker processes, so the dynamic batcher and
//!   `ServiceMetrics` front remote serving too
//!   ([`PartitionService::start_with_backend`]).
//!
//! In front of the queue sits the [`frontdoor`]: every validated
//! request is fingerprinted (`query-hash`, kind, canonicalized k/l,
//! precision, serving epoch); an epoch-keyed sharded LRU answers
//! repeats **bit-exactly** without enqueueing (every estimator is
//! deterministic per epoch under a fixed seed, and a category publish
//! invalidates the previous epoch in O(1)); concurrent identical
//! requests single-flight behind one leader so a thundering herd costs
//! one batcher slot and one backend call.
//!
//! Metrics track queue wait, execution time, shed load (backpressure
//! and deadline), per-batch execution throughput, backend failures, the
//! serving epoch, per-shard scorings/exec time, and front-door traffic
//! (cache hits/misses/evictions/invalidations, coalesced followers).

// The serving API is the crate's outward face; every public item
// carries its contract in docs (CI builds rustdoc with warnings denied).
#![warn(missing_docs)]

pub mod backend;
pub mod batcher;
pub mod frontdoor;
pub mod metrics;
pub mod router;
pub mod service;

pub use backend::{
    BackendError, ClusterBackend, GroupAnswer, GroupParams, PartitionBackend, Precision,
    SnapshotBackend, StaticBackend,
};
pub use batcher::{Batch, BatcherConfig};
pub use frontdoor::{Admission, CacheConfig, FrontDoor, Fingerprint};
pub use metrics::{MetricsSnapshot, NetStats, ServiceMetrics, ShardStat};
pub use router::{EpochCache, Router};
pub use service::{
    BackpressurePolicy, EstimateSpec, PartitionService, Response, ServiceConfig, SubmitError,
};
