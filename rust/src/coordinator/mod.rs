//! L3 coordinator: the serving system around the estimators.
//!
//! Shape (vLLM-router-like, scaled to this paper): requests — (query
//! vector, estimator kind, k, l) — enter a **bounded** queue; a batcher
//! thread drains it under a max-batch/max-delay policy and groups
//! requests by estimator kind; a worker pool executes each drained
//! batch as **one** `Estimator::estimate_batch` call per (k, l) group —
//! a single batched retrieval/scoring pass (multi-query GEMM on the
//! brute index) instead of a per-request loop. `Exact` requests ride
//! the AOT-compiled PJRT `score_batch` artifact when a runtime is
//! attached. Metrics track queue wait, execution time, shed load, and
//! per-batch execution throughput.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod service;

pub use batcher::{Batch, BatcherConfig};
pub use metrics::{MetricsSnapshot, ServiceMetrics};
pub use router::Router;
pub use service::{
    BackpressurePolicy, PartitionService, Request, Response, ServiceConfig, SubmitError,
};
