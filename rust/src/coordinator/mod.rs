//! L3 coordinator: the serving system around the estimators.
//!
//! Shape (vLLM-router-like, scaled to this paper): requests — (query
//! vector, estimator kind, k, l) — enter a **bounded** queue; a batcher
//! thread drains it under a max-batch/max-delay policy and groups
//! requests by estimator kind; a worker pool retrieves `S_k` from the
//! MIPS index and combines head + tail into Ẑ; `Exact` requests ride the
//! AOT-compiled PJRT `score_batch` artifact when a runtime is attached
//! (the brute-force path is the one worth batching — it's the only
//! O(N·d) one). Metrics track queue wait, execution time and shed load.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod service;

pub use batcher::{Batch, BatcherConfig};
pub use metrics::{MetricsSnapshot, ServiceMetrics};
pub use router::Router;
pub use service::{
    BackpressurePolicy, PartitionService, Request, Response, ServiceConfig, SubmitError,
};
