//! L3 coordinator: the serving system around the estimators.
//!
//! Shape (vLLM-router-like, scaled to this paper): requests — (query
//! vector, estimator kind, k, l) — enter a **bounded** queue after
//! submit-time dimensionality validation; a batcher thread drains it
//! under a max-batch/max-delay policy and groups requests by estimator
//! kind; a worker pool executes each drained batch as **one**
//! `Estimator::estimate_batch` call per (k, l) group — a single batched
//! retrieval/scoring pass (multi-query GEMM on the brute index) instead
//! of a per-request loop. `Exact` requests ride the AOT-compiled PJRT
//! `score_batch` artifact when a runtime is attached (monolithic
//! serving).
//!
//! Sharded serving ([`PartitionService::start_sharded`]): workers answer
//! from epoch snapshots of a [`crate::store::ShardedStore`]. Each
//! drained batch pins the current `Arc<Snapshot>` for its whole
//! execution and scatters its retrieval pass across the snapshot's
//! shards in parallel (inside
//! [`crate::mips::sharded::ShardedIndex::top_k_batch`], on the scoped
//! thread pool); `add_categories` / `remove_categories` on the
//! [`crate::store::SnapshotHandle`] publish new epochs without pausing
//! in-flight batches. Metrics track queue wait, execution time, shed
//! load, per-batch execution throughput, the serving epoch, and
//! per-shard scorings/exec time.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod service;

pub use batcher::{Batch, BatcherConfig};
pub use metrics::{MetricsSnapshot, NetStats, ServiceMetrics, ShardStat};
pub use router::{EpochCache, Router};
pub use service::{
    BackpressurePolicy, PartitionService, Request, Response, ServiceConfig, SubmitError,
};
