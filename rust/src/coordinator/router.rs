//! Estimator routing: maps an [`EstimatorKind`] + per-request (k, l) to a
//! concrete estimator instance. FMBE is stateful (fitted feature maps),
//! so the router owns one fitted copy — fitted lazily on the **first**
//! store it is asked to serve and never refitted, so under epoch
//! snapshots FMBE answers reflect the category set at fit time, not the
//! batch's pinned epoch (ROADMAP: "FMBE refresh on epoch swap"). The
//! sampling estimators are constructed per call (they are zero-cost POD
//! structs) and always read the pinned snapshot.

use crate::estimators::{
    exact::Exact, fmbe::Fmbe, fmbe::FmbeConfig, mimps::Mimps, mince::Mince, nmimps::Nmimps,
    uniform::Uniform, EstimateContext, Estimator, EstimatorKind,
};
use crate::mips::MipsIndex;
use crate::store::StoreView;
use crate::util::rng::Rng;

/// Routing table with a lazily fitted FMBE.
pub struct Router {
    fmbe: std::sync::OnceLock<Fmbe>,
    fmbe_cfg: FmbeConfig,
    stratified_tail: bool,
}

impl Router {
    pub fn new(fmbe_cfg: FmbeConfig) -> Self {
        Router {
            fmbe: std::sync::OnceLock::new(),
            fmbe_cfg,
            stratified_tail: false,
        }
    }

    /// Route MIMPS tail sampling through the shard-stratified draw
    /// (proportional per-shard budgets) when the service's store is
    /// sharded. Off by default: the global draw keeps estimates
    /// invariant to the shard layout under a fixed seed.
    pub fn with_stratified_tail(mut self) -> Self {
        self.stratified_tail = true;
        self
    }

    fn mimps(&self, k: usize, l: usize) -> Mimps {
        if self.stratified_tail {
            Mimps::stratified(k, l)
        } else {
            Mimps::new(k, l)
        }
    }

    /// Estimate through the routed estimator. `store`/`index` are the
    /// service's (monolithic, or an epoch-pinned sharded snapshot);
    /// `k`/`l` come from the request.
    pub fn estimate(
        &self,
        kind: EstimatorKind,
        k: usize,
        l: usize,
        store: &dyn StoreView,
        index: &dyn MipsIndex,
        q: &[f32],
        rng: &mut Rng,
    ) -> f64 {
        let mut ctx = EstimateContext::new(store, index, rng);
        match kind {
            EstimatorKind::Exact => Exact.estimate(&mut ctx, q),
            EstimatorKind::Uniform => Uniform::new(l).estimate(&mut ctx, q),
            EstimatorKind::Nmimps => Nmimps::new(k).estimate(&mut ctx, q),
            EstimatorKind::Mimps => self.mimps(k, l).estimate(&mut ctx, q),
            EstimatorKind::Mince => Mince::new(k, l).estimate(&mut ctx, q),
            EstimatorKind::Fmbe => {
                let fmbe = self
                    .fmbe
                    .get_or_init(|| Fmbe::fit(store, self.fmbe_cfg.clone()));
                fmbe.estimate(&mut ctx, q)
            }
        }
    }

    /// Batched variant of [`Router::estimate`]: one estimator instance
    /// serves the whole same-(kind, k, l) query block through
    /// `Estimator::estimate_batch`, which shares a single retrieval /
    /// scoring pass on batch-aware estimators. Results are in `qs` order.
    pub fn estimate_batch(
        &self,
        kind: EstimatorKind,
        k: usize,
        l: usize,
        store: &dyn StoreView,
        index: &dyn MipsIndex,
        qs: &[Vec<f32>],
        rng: &mut Rng,
    ) -> Vec<f64> {
        let mut ctx = EstimateContext::new(store, index, rng);
        match kind {
            EstimatorKind::Exact => Exact.estimate_batch(&mut ctx, qs),
            EstimatorKind::Uniform => Uniform::new(l).estimate_batch(&mut ctx, qs),
            EstimatorKind::Nmimps => Nmimps::new(k).estimate_batch(&mut ctx, qs),
            EstimatorKind::Mimps => self.mimps(k, l).estimate_batch(&mut ctx, qs),
            EstimatorKind::Mince => Mince::new(k, l).estimate_batch(&mut ctx, qs),
            EstimatorKind::Fmbe => {
                let fmbe = self
                    .fmbe
                    .get_or_init(|| Fmbe::fit(store, self.fmbe_cfg.clone()));
                fmbe.estimate_batch(&mut ctx, qs)
            }
        }
    }

    /// Scoring budget of a routed request (for cost accounting).
    pub fn scorings(&self, kind: EstimatorKind, k: usize, l: usize, n: usize) -> usize {
        match kind {
            EstimatorKind::Exact => n,
            EstimatorKind::Uniform => l,
            EstimatorKind::Nmimps => k.min(n),
            EstimatorKind::Mimps | EstimatorKind::Mince => (k + l).min(n),
            EstimatorKind::Fmbe => self.fmbe_cfg.p_features.min(n),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::mips::brute::BruteIndex;

    #[test]
    fn all_kinds_route_and_return_positive() {
        let store = generate(&SynthConfig {
            n: 400,
            d: 16,
            ..SynthConfig::tiny()
        });
        let index = BruteIndex::new(&store);
        let router = Router::new(FmbeConfig {
            p_features: 200,
            ..Default::default()
        });
        let mut rng = Rng::seeded(1);
        let q = store.row(10).to_vec();
        for kind in EstimatorKind::all() {
            let z = router.estimate(*kind, 20, 20, &store, &index, &q, &mut rng);
            assert!(
                z.is_finite(),
                "{kind}: estimate must be finite, got {z}"
            );
            if *kind != EstimatorKind::Fmbe {
                assert!(z > 0.0, "{kind}: {z}");
            }
        }
    }

    #[test]
    fn exact_route_matches_partition() {
        let store = generate(&SynthConfig {
            n: 300,
            d: 8,
            ..SynthConfig::tiny()
        });
        let index = BruteIndex::new(&store);
        let router = Router::new(FmbeConfig::default());
        let mut rng = Rng::seeded(2);
        let q = store.row(0).to_vec();
        let z = router.estimate(EstimatorKind::Exact, 0, 0, &store, &index, &q, &mut rng);
        let want = index.partition(&q);
        assert!((z - want).abs() < 1e-9 * want);
    }

    #[test]
    fn scorings_accounting() {
        let router = Router::new(FmbeConfig {
            p_features: 100,
            ..Default::default()
        });
        assert_eq!(router.scorings(EstimatorKind::Exact, 5, 5, 1000), 1000);
        assert_eq!(router.scorings(EstimatorKind::Mimps, 50, 60, 1000), 110);
        assert_eq!(router.scorings(EstimatorKind::Fmbe, 0, 0, 1000), 100);
    }
}
