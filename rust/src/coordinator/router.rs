//! Estimator routing: maps an [`EstimatorKind`] + per-request (k, l) to a
//! concrete estimator instance. FMBE is stateful (fitted feature maps
//! with store-wide precomputed λ̃ sums), so the router owns one fitted
//! copy **tagged with the snapshot epoch it was fitted on**: a request
//! pinned to a different epoch refits before answering, so FMBE answers
//! always reflect the pinned category set instead of whichever snapshot
//! the router saw first (this closes the ROADMAP "FMBE refresh on epoch
//! swap" item). The feature draw depends only on `(seed, d)`, so a refit
//! re-reads the store for new λ̃ sums without changing the feature maps.
//! The sampling estimators are constructed per call (they are zero-cost
//! POD structs) and always read the pinned snapshot.

use crate::estimators::{
    exact::Exact, fmbe::Fmbe, fmbe::FmbeConfig, mimps::Mimps, mince::Mince, nmimps::Nmimps,
    uniform::Uniform, EstimateContext, Estimator, EstimatorKind,
};
use crate::mips::MipsIndex;
use crate::store::StoreView;
use crate::util::rng::Rng;
use std::sync::{Arc, RwLock};

/// Epoch-tagged single-slot cache for fitted estimator state.
///
/// Holds `(fitted_epoch, fitted value)` — `None` until the first fit.
/// Readers clone the `Arc` out and use it without holding the lock; a
/// request pinned to a different epoch refits under the write lock
/// (double-checked, so concurrent workers on the same epoch fit once).
/// Requests pinned to an **older** epoch refit backwards too —
/// correctness (answers match the pinned category set) over fit reuse;
/// in steady state epochs advance monotonically and each is fitted
/// once. Shared by [`Router`] (in-process FMBE) and
/// `net::remote::RemoteCluster` (cluster-wide FMBE over shard workers,
/// whose fit is fallible — hence the `try` variant).
pub struct EpochCache<T> {
    slot: RwLock<Option<(u64, Arc<T>)>>,
}

impl<T> Default for EpochCache<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EpochCache<T> {
    /// An empty cache (first access fits).
    pub fn new() -> Self {
        EpochCache {
            slot: RwLock::new(None),
        }
    }

    /// The cached value for `epoch`, or `fit()` installed under the
    /// write lock. A failed fit leaves the cache unchanged (the next
    /// request retries).
    pub fn get_or_try_fit<E>(
        &self,
        epoch: u64,
        fit: impl FnOnce() -> Result<T, E>,
    ) -> Result<Arc<T>, E> {
        if let Some((e, f)) = self.slot.read().unwrap().as_ref() {
            if *e == epoch {
                return Ok(f.clone());
            }
        }
        let mut slot = self.slot.write().unwrap();
        if let Some((e, f)) = slot.as_ref() {
            if *e == epoch {
                return Ok(f.clone());
            }
        }
        let fitted = Arc::new(fit()?);
        *slot = Some((epoch, fitted.clone()));
        Ok(fitted)
    }

    /// Infallible wrapper around [`EpochCache::get_or_try_fit`].
    pub fn get_or_fit(&self, epoch: u64, fit: impl FnOnce() -> T) -> Arc<T> {
        let fitted: Result<Arc<T>, std::convert::Infallible> =
            self.get_or_try_fit(epoch, || Ok(fit()));
        match fitted {
            Ok(f) => f,
            Err(never) => match never {},
        }
    }
}

/// Routing table with a lazily fitted, epoch-tagged FMBE.
pub struct Router {
    /// FMBE is stateful (fitted feature maps + store-wide λ̃ sums), so
    /// the router owns one fitted copy per epoch through [`EpochCache`].
    fmbe: EpochCache<Fmbe>,
    fmbe_cfg: FmbeConfig,
    stratified_tail: bool,
}

impl Router {
    /// A router with an unfitted FMBE slot configured by `fmbe_cfg`.
    pub fn new(fmbe_cfg: FmbeConfig) -> Self {
        Router {
            fmbe: EpochCache::new(),
            fmbe_cfg,
            stratified_tail: false,
        }
    }

    /// The fitted FMBE for `epoch`, refitting from `store` when the
    /// cached copy was fitted on a different epoch (see [`EpochCache`]).
    fn fmbe_for(&self, epoch: u64, store: &dyn StoreView) -> Arc<Fmbe> {
        self.fmbe
            .get_or_fit(epoch, || Fmbe::fit(store, self.fmbe_cfg.clone()))
    }

    /// Route MIMPS tail sampling through the shard-stratified draw
    /// (proportional per-shard budgets) when the service's store is
    /// sharded. Off by default: the global draw keeps estimates
    /// invariant to the shard layout under a fixed seed.
    pub fn with_stratified_tail(mut self) -> Self {
        self.stratified_tail = true;
        self
    }

    fn mimps(&self, k: usize, l: usize) -> Mimps {
        if self.stratified_tail {
            Mimps::stratified(k, l)
        } else {
            Mimps::new(k, l)
        }
    }

    /// Estimate through the routed estimator. `store`/`index` are the
    /// service's (monolithic, or an epoch-pinned sharded snapshot);
    /// `epoch` is the snapshot epoch they were pinned at (0 for
    /// monolithic serving) — FMBE refits when it advances; `k`/`l` come
    /// from the request.
    #[allow(clippy::too_many_arguments)]
    pub fn estimate(
        &self,
        kind: EstimatorKind,
        k: usize,
        l: usize,
        store: &dyn StoreView,
        index: &dyn MipsIndex,
        epoch: u64,
        q: &[f32],
        rng: &mut Rng,
    ) -> f64 {
        let mut ctx = EstimateContext::new(store, index, rng);
        match kind {
            EstimatorKind::Exact => Exact.estimate(&mut ctx, q),
            EstimatorKind::Uniform => Uniform::new(l).estimate(&mut ctx, q),
            EstimatorKind::Nmimps => Nmimps::new(k).estimate(&mut ctx, q),
            EstimatorKind::Mimps => self.mimps(k, l).estimate(&mut ctx, q),
            EstimatorKind::Mince => Mince::new(k, l).estimate(&mut ctx, q),
            EstimatorKind::Fmbe => self.fmbe_for(epoch, store).estimate(&mut ctx, q),
        }
    }

    /// Batched variant of [`Router::estimate`]: one estimator instance
    /// serves the whole same-(kind, k, l) query block through
    /// `Estimator::estimate_batch`, which shares a single retrieval /
    /// scoring pass on batch-aware estimators. Results are in `qs` order.
    #[allow(clippy::too_many_arguments)]
    pub fn estimate_batch(
        &self,
        kind: EstimatorKind,
        k: usize,
        l: usize,
        store: &dyn StoreView,
        index: &dyn MipsIndex,
        epoch: u64,
        qs: &[Vec<f32>],
        rng: &mut Rng,
    ) -> Vec<f64> {
        let mut ctx = EstimateContext::new(store, index, rng);
        match kind {
            EstimatorKind::Exact => Exact.estimate_batch(&mut ctx, qs),
            EstimatorKind::Uniform => Uniform::new(l).estimate_batch(&mut ctx, qs),
            EstimatorKind::Nmimps => Nmimps::new(k).estimate_batch(&mut ctx, qs),
            EstimatorKind::Mimps => self.mimps(k, l).estimate_batch(&mut ctx, qs),
            EstimatorKind::Mince => Mince::new(k, l).estimate_batch(&mut ctx, qs),
            EstimatorKind::Fmbe => self.fmbe_for(epoch, store).estimate_batch(&mut ctx, qs),
        }
    }

    /// Scoring budget of a routed request (for cost accounting).
    pub fn scorings(&self, kind: EstimatorKind, k: usize, l: usize, n: usize) -> usize {
        match kind {
            EstimatorKind::Exact => n,
            EstimatorKind::Uniform => l,
            EstimatorKind::Nmimps => k.min(n),
            EstimatorKind::Mimps | EstimatorKind::Mince => (k + l).min(n),
            EstimatorKind::Fmbe => self.fmbe_cfg.p_features.min(n),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::mips::brute::BruteIndex;

    #[test]
    fn all_kinds_route_and_return_positive() {
        let store = generate(&SynthConfig {
            n: 400,
            d: 16,
            ..SynthConfig::tiny()
        });
        let index = BruteIndex::new(&store);
        let router = Router::new(FmbeConfig {
            p_features: 200,
            ..Default::default()
        });
        let mut rng = Rng::seeded(1);
        let q = store.row(10).to_vec();
        for kind in EstimatorKind::all() {
            let z = router.estimate(*kind, 20, 20, &store, &index, 0, &q, &mut rng);
            assert!(
                z.is_finite(),
                "{kind}: estimate must be finite, got {z}"
            );
            if *kind != EstimatorKind::Fmbe {
                assert!(z > 0.0, "{kind}: {z}");
            }
        }
    }

    #[test]
    fn exact_route_matches_partition() {
        let store = generate(&SynthConfig {
            n: 300,
            d: 8,
            ..SynthConfig::tiny()
        });
        let index = BruteIndex::new(&store);
        let router = Router::new(FmbeConfig::default());
        let mut rng = Rng::seeded(2);
        let q = store.row(0).to_vec();
        let z = router.estimate(EstimatorKind::Exact, 0, 0, &store, &index, 0, &q, &mut rng);
        let want = index.partition(&q);
        assert!((z - want).abs() < 1e-9 * want);
    }

    /// FMBE must refit when the epoch advances: the λ̃ sums are
    /// store-wide precomputations, so an FMBE answer from a stale fit
    /// would ignore every category added since. The feature draw is
    /// seed-deterministic, so the refitted estimate equals a fresh fit
    /// on the new store exactly.
    #[test]
    fn fmbe_refits_on_epoch_advance() {
        use crate::store::{ShardedStore, SnapshotHandle};
        let store = generate(&SynthConfig {
            n: 300,
            d: 8,
            ..SynthConfig::tiny()
        });
        let cfg = FmbeConfig {
            p_features: 300,
            ..Default::default()
        };
        let router = Router::new(cfg.clone());
        let handle = SnapshotHandle::brute(ShardedStore::split(&store, 2));
        let q = store.row(3).to_vec();
        let mut rng = Rng::seeded(4);

        let snap0 = handle.load();
        let z0 = router.estimate(
            EstimatorKind::Fmbe,
            0,
            0,
            snap0.store.as_ref(),
            snap0.index.as_ref(),
            snap0.epoch,
            &q,
            &mut rng,
        );
        let want0 = crate::estimators::fmbe::Fmbe::fit(snap0.store.as_ref(), cfg.clone())
            .estimate_query(&q);
        assert_eq!(z0, want0, "epoch-0 fit matches a direct fit");

        // Publish a bigger category set; the router must refit.
        let added = generate(&SynthConfig {
            n: 80,
            d: 8,
            seed: 42,
            ..SynthConfig::tiny()
        });
        handle.add_categories(added).unwrap();
        let snap1 = handle.load();
        let z1 = router.estimate(
            EstimatorKind::Fmbe,
            0,
            0,
            snap1.store.as_ref(),
            snap1.index.as_ref(),
            snap1.epoch,
            &q,
            &mut rng,
        );
        let want1 = crate::estimators::fmbe::Fmbe::fit(snap1.store.as_ref(), cfg.clone())
            .estimate_query(&q);
        assert_eq!(z1, want1, "epoch-1 answer reflects the refit");
        assert_ne!(z0, z1, "λ̃ must change with the category set");

        // Same epoch again: the cached fit is reused (same bits).
        let z1_again = router.estimate(
            EstimatorKind::Fmbe,
            0,
            0,
            snap1.store.as_ref(),
            snap1.index.as_ref(),
            snap1.epoch,
            &q,
            &mut rng,
        );
        assert_eq!(z1, z1_again);
    }

    #[test]
    fn scorings_accounting() {
        let router = Router::new(FmbeConfig {
            p_features: 100,
            ..Default::default()
        });
        assert_eq!(router.scorings(EstimatorKind::Exact, 5, 5, 1000), 1000);
        assert_eq!(router.scorings(EstimatorKind::Mimps, 50, 60, 1000), 110);
        assert_eq!(router.scorings(EstimatorKind::Fmbe, 0, 0, 1000), 100);
    }
}
