//! The serving backend seam: one trait every `PartitionService`
//! front-end answers from, whether the categories live in this process
//! or in a cluster of shard workers.
//!
//! Before this module the crate had **three** parallel serving
//! front-ends, each re-mirroring `estimate`/`estimate_batch`: the
//! coordinator's `PartitionService` (batching, backpressure, metrics —
//! but only over in-process stores via a private enum), the
//! `RemoteCluster` (cluster estimators, but no queue/batcher/metrics),
//! and the wire `PartitionClient`. [`PartitionBackend`] collapses that
//! triplication: the service's dynamic batcher, backpressure policy and
//! metrics now sit in front of **any** backend, and the three
//! implementations are
//!
//! * [`StaticBackend`] — one immutable monolithic store + MIPS index
//!   (with the optional PJRT `score_batch` artifact for `Exact`);
//! * [`SnapshotBackend`] — epoch snapshots of a sharded store behind a
//!   [`SnapshotHandle`], publishing mutations without pausing in-flight
//!   batches;
//! * [`ClusterBackend`] — a [`RemoteCluster`] of shard-worker
//!   processes, putting the batcher in front of remote serving for the
//!   first time (`zest-server --cluster …`).
//!
//! A backend answers whole **batch groups** — every request in one
//! [`PartitionBackend::estimate_batch`] call shares one `(kind,
//! [`GroupParams`])` configuration — and pins one consistent view
//! (snapshot epoch / cluster layout) per group, reporting the pinned
//! epoch back so responses name the category set that produced them.

use super::router::Router;
use crate::data::embeddings::EmbeddingStore;
use crate::estimators::EstimatorKind;
use crate::mips::MipsIndex;
use crate::net::client::{ClientConfig, ClientError};
use crate::net::remote::RemoteCluster;
use crate::net::Addr;
use crate::obs::{MetricsBlob, Trace};
use crate::runtime::{HostTensor, RuntimeHandle};
use crate::store::{SnapshotHandle, StoreView};
use crate::util::rng::Rng;
use std::sync::Arc;

/// How an `Exact` request may trade bit-exactness for latency on
/// backends where the exp-sum spans multiple workers.
///
/// In-process backends ignore the mode (their accumulation is always
/// the bit-pinned kernel chain). Over a [`ClusterBackend`]:
///
/// * [`Precision::BitExact`] — the chained exp-sum: S **sequential**
///   worker round-trips, each continuing the running f64
///   accumulator(s) in strict global row order. Bit-identical to the
///   in-process `Exact` kernels (given 4-aligned worker splits).
/// * [`Precision::Pipelined`] — one `ExpSumPart` fan-out to **all**
///   workers concurrently; each returns its per-query partial sums and
///   the cluster reduces them in worker order. Latency is
///   max-over-workers instead of Σ-over-workers, at the cost of a
///   different f64 summation grouping: answers are **last-ulp
///   different** from the chained mode (relative error on the order of
///   S × f64 ulp; identical bits at S = 1).
///
/// Sampling estimators and FMBE are unaffected by the mode (their
/// remote execution already fans out).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Precision {
    /// Bit-identical to in-process execution; S sequential round-trips
    /// for remote `Exact`.
    #[default]
    BitExact,
    /// Concurrent per-worker partials reduced in worker order;
    /// max-over-workers latency, last-ulp-different `Exact` answers.
    Pipelined,
}

/// The per-request knobs a batch group shares (everything of an
/// [`super::EstimateSpec`] except the query, the kind — groups are
/// already same-kind — and the deadline, which is per-request).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct GroupParams {
    /// Head budget (top-k retrieval size); estimator-specific meaning.
    pub k: usize,
    /// Tail budget (uniform sample size); estimator-specific meaning.
    pub l: usize,
    /// Precision mode for multi-worker `Exact` (see [`Precision`]).
    pub precision: Precision,
}

/// A backend failure (wire outage, unsupported publish, artifact
/// error). The service logs it and drops the group's reply channels;
/// publish hooks surface it to the caller. Cluster backends carry the
/// failing worker's index when the underlying fan-out named one
/// (`ClientError::Shard`), which the service feeds into the per-shard
/// error counters — a failed scatter names its shard from a
/// `MetricsSnapshot` alone.
#[derive(Debug)]
pub struct BackendError {
    msg: String,
    shard: Option<usize>,
}

impl BackendError {
    /// Wrap a message as a backend failure.
    pub fn new(msg: impl Into<String>) -> BackendError {
        BackendError {
            msg: msg.into(),
            shard: None,
        }
    }

    /// Attribute the failure to a worker shard index.
    pub fn with_shard(mut self, shard: Option<usize>) -> BackendError {
        self.shard = shard;
        self
    }

    /// The worker shard this failure is attributed to, if any.
    pub fn shard(&self) -> Option<usize> {
        self.shard
    }
}

impl std::fmt::Display for BackendError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "backend: {}", self.msg)
    }
}

impl std::error::Error for BackendError {}

/// One batch group's answers plus the pinned view that produced them.
#[derive(Clone, Debug)]
pub struct GroupAnswer {
    /// Ẑ per query, in request order.
    pub zs: Vec<f64>,
    /// Epoch of the pinned view (0 for epoch-less static backends).
    pub epoch: u64,
    /// Categories the pinned view served (the `n` of scoring budgets).
    pub len: usize,
    /// Per-shard row counts of the pinned view, in shard order — empty
    /// for monolithic backends. Feeds the service's per-shard metrics.
    pub shard_lens: Vec<usize>,
}

/// What a [`super::PartitionService`] answers from: a category set
/// behind an epoch-pinned batched estimation call, a manifest, and
/// (where supported) live category mutations.
///
/// Implementations must be callable from multiple worker threads
/// concurrently and must **never panic** on request input — a remote
/// backend converts transport failures into [`BackendError`].
pub trait PartitionBackend: Send + Sync + 'static {
    /// Dimensionality served. Invariant across epochs (mutations cannot
    /// change d) — the service validates queries against it at submit.
    fn dim(&self) -> usize;

    /// `(categories, epoch)` currently served — the manifest network
    /// front-ends answer from.
    fn serving_info(&self) -> (usize, u64);

    /// The serving epoch alone (the epoch component of
    /// [`serving_info`](PartitionBackend::serving_info)). The front
    /// door keys its result cache on this: the submit path reads it
    /// into every fingerprint, and publishes advance it — which is
    /// exactly what invalidates every previously cached answer.
    fn epoch(&self) -> u64 {
        self.serving_info().1
    }

    /// Answer one same-`(kind, params)` batch group, pinning one
    /// consistent view (snapshot / cluster layout) for the whole group.
    /// Results are in `qs` order. `trace`, when present, is a sampled
    /// request's span collector: backends that fan work out (the
    /// cluster backend's per-worker scatter RPCs) record per-shard
    /// spans on it; in-process backends may ignore it.
    fn estimate_batch(
        &self,
        kind: EstimatorKind,
        params: GroupParams,
        qs: &[Vec<f32>],
        rng: &mut Rng,
        trace: Option<&Trace>,
    ) -> Result<GroupAnswer, BackendError>;

    /// Category scorings one request of this shape costs (sublinearity
    /// accounting; `n` is the pinned view's category count).
    fn scorings(&self, kind: EstimatorKind, params: GroupParams, n: usize) -> usize;

    /// Publish hook: append `rows` as new categories, returning the new
    /// epoch. Backends without mutation support return an error.
    /// Publish through [`super::PartitionService::add_categories`] when
    /// a service fronts this backend, so the front door observes the
    /// new epoch immediately instead of at the next executed batch.
    fn add_categories(&self, rows: EmbeddingStore) -> Result<u64, BackendError>;

    /// Publish hook: remove the given global ids (current epoch's
    /// positions), returning the new epoch (same front-door observation
    /// note as [`add_categories`](PartitionBackend::add_categories)).
    fn remove_categories(&self, ids: &[usize]) -> Result<u64, BackendError>;

    /// Backend-side telemetry, if the backend has any of its own:
    /// cluster backends fan `GetMetrics` out to their shard workers and
    /// return the merged per-worker blob; in-process backends have
    /// nothing beyond what the service already measures and return
    /// `None` (the default).
    fn metrics(&self) -> Option<MetricsBlob> {
        None
    }
}

/// Delegation so an already-shared backend (`Arc<dyn PartitionBackend>`
/// or `Arc<ClusterBackend>` kept for publishes) can be handed to
/// [`super::PartitionService::start_with_backend`] directly.
impl<T: PartitionBackend + ?Sized> PartitionBackend for Arc<T> {
    fn dim(&self) -> usize {
        (**self).dim()
    }

    fn serving_info(&self) -> (usize, u64) {
        (**self).serving_info()
    }

    fn epoch(&self) -> u64 {
        (**self).epoch()
    }

    fn estimate_batch(
        &self,
        kind: EstimatorKind,
        params: GroupParams,
        qs: &[Vec<f32>],
        rng: &mut Rng,
        trace: Option<&Trace>,
    ) -> Result<GroupAnswer, BackendError> {
        (**self).estimate_batch(kind, params, qs, rng, trace)
    }

    fn scorings(&self, kind: EstimatorKind, params: GroupParams, n: usize) -> usize {
        (**self).scorings(kind, params, n)
    }

    fn add_categories(&self, rows: EmbeddingStore) -> Result<u64, BackendError> {
        (**self).add_categories(rows)
    }

    fn remove_categories(&self, ids: &[usize]) -> Result<u64, BackendError> {
        (**self).remove_categories(ids)
    }

    fn metrics(&self) -> Option<MetricsBlob> {
        (**self).metrics()
    }
}

// ---------------------------------------------------------------------
// StaticBackend

/// One immutable monolithic store + MIPS index (epoch 0 forever).
/// `Exact` groups ride the AOT PJRT `score_batch` artifact when a
/// runtime is attached, falling back to the native kernels.
pub struct StaticBackend {
    store: Arc<EmbeddingStore>,
    index: Arc<dyn MipsIndex>,
    router: Router,
    runtime: Option<RuntimeHandle>,
}

impl StaticBackend {
    /// Serve `store` through `index`, routing estimators via `router`.
    pub fn new(store: Arc<EmbeddingStore>, index: Arc<dyn MipsIndex>, router: Router) -> Self {
        StaticBackend {
            store,
            index,
            router,
            runtime: None,
        }
    }

    /// Attach a PJRT runtime: `Exact` groups execute on the AOT
    /// `score_batch` artifact (native fallback on any failure).
    pub fn with_runtime(mut self, runtime: Option<RuntimeHandle>) -> Self {
        self.runtime = runtime;
        self
    }

    /// Batched exact partition via the AOT `score_batch` artifact: pad
    /// the query batch to the artifact's B, stream the category matrix
    /// in artifact-sized chunks (zero-padding the last one and
    /// correcting the +1-per-padded-row bias), sum partials per query.
    fn exact_batch_pjrt(&self, qs: &[Vec<f32>], rt: &RuntimeHandle) -> anyhow::Result<Vec<f64>> {
        let (n, d) = (self.store.len(), self.store.dim());
        // Shapes: v (chunk, d_a), qs (b_a, d_a) -> (b_a,)
        let (chunk, d_a, b_a) = rt_score_batch_dims(rt)?;
        anyhow::ensure!(d_a == d, "artifact d {d_a} != store d {d}");
        let mut zs = vec![0f64; qs.len()];
        for q_chunk in (0..qs.len()).step_by(b_a) {
            let q_hi = (q_chunk + b_a).min(qs.len());
            let mut flat = vec![0f32; b_a * d];
            for (bi, q) in qs[q_chunk..q_hi].iter().enumerate() {
                anyhow::ensure!(q.len() == d, "query dim mismatch");
                flat[bi * d..(bi + 1) * d].copy_from_slice(q);
            }
            let qs_t = HostTensor::f32(flat, &[b_a, d]);
            for lo in (0..n).step_by(chunk) {
                let hi = (lo + chunk).min(n);
                let rows = hi - lo;
                let pad = chunk - rows;
                let mut v = vec![0f32; chunk * d];
                v[..rows * d].copy_from_slice(self.store.rows(lo, hi));
                let out = rt.run(
                    "score_batch",
                    vec![HostTensor::f32(v, &[chunk, d]), qs_t.clone()],
                )?;
                let partials = out[0]
                    .as_f32()
                    .ok_or_else(|| anyhow::anyhow!("score_batch returned non-f32"))?;
                for (bi, z) in zs[q_chunk..q_hi].iter_mut().enumerate() {
                    // Padded rows contribute exp(0) = 1 each; remove them.
                    *z += partials[bi] as f64 - pad as f64;
                }
            }
        }
        Ok(zs)
    }
}

impl PartitionBackend for StaticBackend {
    fn dim(&self) -> usize {
        self.store.dim()
    }

    fn serving_info(&self) -> (usize, u64) {
        (self.store.len(), 0)
    }

    fn estimate_batch(
        &self,
        kind: EstimatorKind,
        params: GroupParams,
        qs: &[Vec<f32>],
        rng: &mut Rng,
        _trace: Option<&Trace>,
    ) -> Result<GroupAnswer, BackendError> {
        // Exact groups ride the PJRT scoring artifact when attached
        // (the artifact streams one contiguous matrix).
        if kind == EstimatorKind::Exact {
            if let Some(rt) = &self.runtime {
                match self.exact_batch_pjrt(qs, rt) {
                    Ok(zs) => {
                        return Ok(GroupAnswer {
                            zs,
                            epoch: 0,
                            len: self.store.len(),
                            shard_lens: vec![],
                        })
                    }
                    Err(e) => {
                        log::warn!("PJRT exact batch failed ({e:#}); falling back to native path")
                    }
                }
            }
        }
        let zs = self.router.estimate_batch(
            kind,
            params.k,
            params.l,
            self.store.as_ref(),
            self.index.as_ref(),
            0,
            qs,
            rng,
        );
        Ok(GroupAnswer {
            zs,
            epoch: 0,
            len: self.store.len(),
            shard_lens: vec![],
        })
    }

    fn scorings(&self, kind: EstimatorKind, params: GroupParams, n: usize) -> usize {
        self.router.scorings(kind, params.k, params.l, n)
    }

    fn add_categories(&self, _rows: EmbeddingStore) -> Result<u64, BackendError> {
        Err(BackendError::new(
            "static backend is immutable (serve a SnapshotBackend for live mutations)",
        ))
    }

    fn remove_categories(&self, _ids: &[usize]) -> Result<u64, BackendError> {
        Err(BackendError::new(
            "static backend is immutable (serve a SnapshotBackend for live mutations)",
        ))
    }
}

/// score_batch artifact dims cache: (chunk, d, batch). Read once from
/// the exporter's meta via the runtime's artifacts-dir environment
/// variable contract.
fn rt_score_batch_dims(_rt: &RuntimeHandle) -> anyhow::Result<(usize, usize, usize)> {
    // The handle intentionally carries no meta; the backend reads the
    // artifacts dir the same way the runtime did.
    let dir = std::env::var("ZEST_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    let meta = crate::runtime::ArtifactsMeta::load(std::path::Path::new(&dir))?;
    let (_, args) = meta
        .graphs
        .get("score_batch")
        .ok_or_else(|| anyhow::anyhow!("score_batch not exported"))?;
    let chunk = args[0].shape[0];
    let d = args[0].shape[1];
    let b = args[1].shape[0];
    Ok((chunk, d, b))
}

// ---------------------------------------------------------------------
// SnapshotBackend

/// Epoch snapshots over a sharded store: each batch group pins the
/// current snapshot for its whole execution, so `add_categories` /
/// `remove_categories` swap epochs without pausing in-flight work.
pub struct SnapshotBackend {
    handle: Arc<SnapshotHandle>,
    router: Router,
}

impl SnapshotBackend {
    /// Serve epoch snapshots published by `handle`; the caller may keep
    /// its own `Arc<SnapshotHandle>` to publish mutations directly (the
    /// trait's publish hooks delegate to the same handle).
    pub fn new(handle: Arc<SnapshotHandle>, router: Router) -> Self {
        SnapshotBackend { handle, router }
    }

    /// The underlying snapshot handle (shared, publish-capable).
    pub fn handle(&self) -> &Arc<SnapshotHandle> {
        &self.handle
    }
}

impl PartitionBackend for SnapshotBackend {
    fn dim(&self) -> usize {
        StoreView::dim(self.handle.load().store.as_ref())
    }

    fn serving_info(&self) -> (usize, u64) {
        let snap = self.handle.load();
        (StoreView::len(snap.store.as_ref()), snap.epoch)
    }

    fn estimate_batch(
        &self,
        kind: EstimatorKind,
        params: GroupParams,
        qs: &[Vec<f32>],
        rng: &mut Rng,
        _trace: Option<&Trace>,
    ) -> Result<GroupAnswer, BackendError> {
        // Pin one snapshot for the whole group: the group answers from
        // one consistent category set even if a mutation publishes a
        // new epoch mid-execution.
        let pinned = self.handle.load();
        let view: &dyn StoreView = pinned.store.as_ref();
        let zs = self.router.estimate_batch(
            kind,
            params.k,
            params.l,
            view,
            pinned.index.as_ref(),
            pinned.epoch,
            qs,
            rng,
        );
        let shard_lens = view
            .as_sharded()
            .map(|s| s.shards().iter().map(|shard| shard.len()).collect())
            .unwrap_or_default();
        Ok(GroupAnswer {
            zs,
            epoch: pinned.epoch,
            len: view.len(),
            shard_lens,
        })
    }

    fn scorings(&self, kind: EstimatorKind, params: GroupParams, n: usize) -> usize {
        self.router.scorings(kind, params.k, params.l, n)
    }

    fn add_categories(&self, rows: EmbeddingStore) -> Result<u64, BackendError> {
        self.handle
            .add_categories(rows)
            .map_err(|e| BackendError::new(e.to_string()))
    }

    fn remove_categories(&self, ids: &[usize]) -> Result<u64, BackendError> {
        self.handle
            .remove_categories(ids)
            .map_err(|e| BackendError::new(e.to_string()))
    }
}

// ---------------------------------------------------------------------
// ClusterBackend

/// A [`RemoteCluster`] of shard-worker processes behind the service
/// seam: the dynamic batcher, backpressure policy and `ServiceMetrics`
/// in front of cross-process serving. `Exact` groups honor the
/// request's [`Precision`] mode (chained vs `ExpSumPart` fan-out).
pub struct ClusterBackend {
    cluster: Arc<RemoteCluster>,
}

impl ClusterBackend {
    /// Connect to every worker and wrap the cluster as a backend.
    pub fn connect(addrs: &[Addr], cfg: ClientConfig) -> Result<ClusterBackend, ClientError> {
        Ok(ClusterBackend {
            cluster: Arc::new(RemoteCluster::connect(addrs, cfg)?),
        })
    }

    /// Connect to every **replica group** (`groups[s]` holds shard
    /// `s`'s replica addresses) and wrap the cluster as a backend.
    /// Reads load-balance across each group's healthy replicas and fail
    /// over transparently; see `RemoteCluster::connect_groups`.
    pub fn connect_groups(
        groups: &[Vec<Addr>],
        cfg: ClientConfig,
    ) -> Result<ClusterBackend, ClientError> {
        Ok(ClusterBackend {
            cluster: Arc::new(RemoteCluster::connect_groups(groups, cfg)?),
        })
    }

    /// Wrap an existing (possibly shared) cluster handle.
    pub fn new(cluster: Arc<RemoteCluster>) -> ClusterBackend {
        ClusterBackend { cluster }
    }

    /// The wrapped cluster (manifest refreshes, `resolve_token`, …).
    pub fn cluster(&self) -> &Arc<RemoteCluster> {
        &self.cluster
    }
}

impl PartitionBackend for ClusterBackend {
    fn dim(&self) -> usize {
        self.cluster.dim()
    }

    fn serving_info(&self) -> (usize, u64) {
        (self.cluster.len(), self.cluster.epoch())
    }

    fn estimate_batch(
        &self,
        kind: EstimatorKind,
        params: GroupParams,
        qs: &[Vec<f32>],
        rng: &mut Rng,
        trace: Option<&Trace>,
    ) -> Result<GroupAnswer, BackendError> {
        // The scatter index's MipsIndex methods panic on wire failures
        // (the trait has no error channel). In the service's worker
        // pool that panic would kill the worker thread, so convert it
        // to a BackendError here — the serving analogue of the
        // net::Server catch_unwind boundary.
        let answer = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.cluster
                .estimate_batch(kind, params.k, params.l, params.precision, qs, rng, trace)
        }))
        .map_err(|p| {
            let msg = p
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| p.downcast_ref::<&str>().copied())
                .unwrap_or("no panic message");
            BackendError::new(format!("remote scatter panicked: {msg}"))
        })?
        // `ClientError::Shard` attribution (set at the cluster fan-out
        // join sites) flows through to the service's per-shard error
        // counters; the message keeps the "worker N:" rendering.
        .map_err(|e| BackendError::new(e.to_string()).with_shard(e.shard()))?;
        Ok(GroupAnswer {
            zs: answer.zs,
            epoch: answer.epoch,
            len: answer.len,
            shard_lens: answer.shard_lens,
        })
    }

    fn scorings(&self, kind: EstimatorKind, params: GroupParams, n: usize) -> usize {
        // The one cluster-serving cost table, shared with ClusterHandler.
        crate::net::remote::scorings_for(
            kind,
            params.k,
            params.l,
            n,
            self.cluster.fmbe_config().p_features,
        )
    }

    fn add_categories(&self, rows: EmbeddingStore) -> Result<u64, BackendError> {
        self.cluster
            .add_categories(&rows)
            .map_err(|e| BackendError::new(e.to_string()).with_shard(e.shard()))
    }

    fn remove_categories(&self, ids: &[usize]) -> Result<u64, BackendError> {
        self.cluster
            .remove_categories(ids)
            .map_err(|e| BackendError::new(e.to_string()).with_shard(e.shard()))
    }

    fn metrics(&self) -> Option<MetricsBlob> {
        // Best-effort: a worker that cannot be scraped right now drops
        // out of this snapshot rather than failing the whole scrape.
        Some(self.cluster.cluster_metrics())
    }
}
