//! Dynamic batcher: drains the request queue under a size/deadline
//! policy and groups requests by estimator kind so the worker can run a
//! whole group with one retrieval setup (and, for `Exact`, one batched
//! PJRT scoring call).
//!
//! Policy: close a batch when it reaches `max_batch` requests of one
//! kind, or when `max_wait` elapsed since the oldest queued request —
//! the standard latency/throughput trade every dynamic batcher makes.
//! Request deadlines are enforced by the batcher *thread* at drain time
//! (see `PartitionService`): a closed batch is swept for requests whose
//! `EstimateSpec::deadline` passed while queued before it reaches a
//! worker. Within a kind, batches drain **earliest-deadline-first**:
//! when more requests are buffered than one batch holds, the ones
//! closest to their deadline ship first (deadline-less requests last,
//! in arrival order), shrinking the shed count under burst load.
//!
//! Batch *sizing* is deadline-aware too: when the earliest queued
//! deadline would expire before the accumulation window closes,
//! waiting the window out could only convert that request into a
//! drain-time shed — the assembler ships the partial batch immediately
//! with whatever slack the request still has, instead of waiting out
//! the full `max_wait` timer.

use super::service::QueuedRequest;
use crate::estimators::EstimatorKind;
use std::collections::HashMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Close a batch once this many same-kind requests are buffered.
    pub max_batch: usize,
    /// Flush a partial batch this long after its oldest request.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 16,
            // §Perf: 2 ms added ~350% latency overhead for single-stream
            // clients while batching gains only matter under sustained
            // load; 250 µs keeps tail batching without the latency tax.
            max_wait: Duration::from_micros(250),
        }
    }
}

/// A closed batch: same-kind requests ready for one worker.
pub struct Batch {
    /// The estimator kind every member shares.
    pub kind: EstimatorKind,
    /// The batched requests, in earliest-deadline-first order (requests
    /// without a deadline come last, preserving arrival order among
    /// themselves) — see [`BatchAssembler`].
    pub requests: Vec<QueuedRequest>,
}

/// Pull one batch from the queue, honoring the policy. Returns `None`
/// when the queue has disconnected and is empty.
///
/// The batcher keeps per-kind pending buffers: requests of other kinds
/// seen while filling a batch are retained for subsequent calls.
pub struct BatchAssembler {
    cfg: BatcherConfig,
    pending: HashMap<EstimatorKind, Vec<QueuedRequest>>,
}

impl BatchAssembler {
    /// An assembler with empty per-kind buffers.
    pub fn new(cfg: BatcherConfig) -> Self {
        BatchAssembler {
            cfg,
            pending: HashMap::new(),
        }
    }

    fn ready_batch(&mut self, force: bool) -> Option<Batch> {
        // Prefer the fullest kind; under `force`, emit anything non-empty.
        let kind = self
            .pending
            .iter()
            .filter(|(_, v)| !v.is_empty())
            .max_by_key(|(_, v)| v.len())
            .map(|(k, _)| *k)?;
        let v = self.pending.get_mut(&kind).unwrap();
        if v.len() >= self.cfg.max_batch || force {
            // Earliest-deadline-first drain: when the buffer overflows
            // one batch, the requests closest to their deadline ship in
            // the first batch instead of waiting behind earlier
            // arrivals — fewer deadline sweeps under burst load. The
            // sort is stable, so deadline-less requests (sorted last)
            // keep arrival order among themselves.
            v.sort_by_key(|qr| (qr.spec.deadline.is_none(), qr.spec.deadline));
            let take = v.len().min(self.cfg.max_batch);
            let requests: Vec<QueuedRequest> = v.drain(..take).collect();
            return Some(Batch { kind, requests });
        }
        None
    }

    fn total_pending(&self) -> usize {
        self.pending.values().map(|v| v.len()).sum()
    }

    /// Earliest `EstimateSpec::deadline` across every pending buffer
    /// (not just the fullest kind: any tight request justifies an
    /// early flush, and `ready_batch(force)` prefers the fullest kind
    /// only among non-empty buffers it will reach on subsequent calls).
    fn earliest_pending_deadline(&self) -> Option<Instant> {
        self.pending
            .values()
            .flatten()
            .filter_map(|qr| qr.spec.deadline)
            .min()
    }

    /// Blocking assembly loop step.
    pub fn next_batch(&mut self, rx: &mpsc::Receiver<QueuedRequest>) -> Option<Batch> {
        // Fast path: a full batch is already buffered.
        if let Some(b) = self.ready_batch(false) {
            return Some(b);
        }
        // Wait for the first request (or use buffered leftovers' deadline).
        let deadline = if self.total_pending() == 0 {
            match rx.recv() {
                Ok(req) => {
                    let kind = req.spec.kind;
                    self.pending.entry(kind).or_default().push(req);
                    Instant::now() + self.cfg.max_wait
                }
                Err(_) => return None, // disconnected, nothing buffered
            }
        } else {
            Instant::now() + self.cfg.max_wait
        };
        // Fill until deadline or a full batch forms.
        loop {
            if let Some(b) = self.ready_batch(false) {
                return Some(b);
            }
            // Deadline-aware sizing: a queued request whose deadline
            // falls inside the accumulation window gains nothing from
            // further waiting (it would only be swept at drain time) —
            // flush the partial batch now, preserving its slack.
            if self
                .earliest_pending_deadline()
                .is_some_and(|d| d <= deadline)
            {
                return self.ready_batch(true);
            }
            let now = Instant::now();
            if now >= deadline {
                return self.ready_batch(true);
            }
            match rx.recv_timeout(deadline - now) {
                Ok(req) => {
                    let kind = req.spec.kind;
                    self.pending.entry(kind).or_default().push(req);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    return self.ready_batch(true);
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    return self.ready_batch(true);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::{EstimateSpec, QueuedRequest};

    fn req(kind: EstimatorKind) -> QueuedRequest {
        let (tx, _rx) = mpsc::channel();
        QueuedRequest {
            spec: EstimateSpec::new(vec![0.0; 4]).kind(kind).k(10).l(10),
            reply: tx,
            enqueued: Instant::now(),
            fingerprint: None,
        }
    }

    #[test]
    fn full_batch_closes_at_max_batch() {
        let cfg = BatcherConfig {
            max_batch: 3,
            max_wait: Duration::from_secs(10), // never hit
        };
        let (tx, rx) = mpsc::channel();
        for _ in 0..5 {
            tx.send(req(EstimatorKind::Mimps)).unwrap();
        }
        let mut asm = BatchAssembler::new(cfg);
        let b = asm.next_batch(&rx).unwrap();
        assert_eq!(b.requests.len(), 3);
        assert_eq!(b.kind, EstimatorKind::Mimps);
        // Leftovers flush on a later call (disconnected sender forces it).
        drop(tx);
        let b2 = asm.next_batch(&rx).unwrap();
        assert_eq!(b2.requests.len(), 2);
        assert!(asm.next_batch(&rx).is_none(), "queue drained");
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let cfg = BatcherConfig {
            max_batch: 100,
            max_wait: Duration::from_millis(5),
        };
        let (tx, rx) = mpsc::channel();
        tx.send(req(EstimatorKind::Uniform)).unwrap();
        let mut asm = BatchAssembler::new(cfg);
        let t0 = Instant::now();
        let b = asm.next_batch(&rx).unwrap();
        assert_eq!(b.requests.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(4));
    }

    #[test]
    fn tight_deadline_shrinks_the_flush_window() {
        let cfg = BatcherConfig {
            max_batch: 100,
            max_wait: Duration::from_secs(10), // would dominate the test if waited out
        };
        let (tx, rx) = mpsc::channel();
        let mut q = req(EstimatorKind::Uniform);
        q.spec = q.spec.deadline(Instant::now() + Duration::from_millis(20));
        tx.send(q).unwrap();
        let mut asm = BatchAssembler::new(cfg);
        let t0 = Instant::now();
        let b = asm.next_batch(&rx).unwrap();
        assert_eq!(b.requests.len(), 1);
        assert!(
            t0.elapsed() < Duration::from_secs(1),
            "a deadline inside the window flushes immediately, not after max_wait \
             (elapsed {:?})",
            t0.elapsed()
        );
    }

    #[test]
    fn drain_is_earliest_deadline_first() {
        let cfg = BatcherConfig {
            max_batch: 3,
            max_wait: Duration::from_secs(10), // never hit
        };
        let (tx, rx) = mpsc::channel();
        let far = Instant::now() + Duration::from_secs(60);
        let near = Instant::now() + Duration::from_secs(1);
        let mid = Instant::now() + Duration::from_secs(30);
        // Arrival order: far, (none A), near, (none B), mid.
        let mut with_deadline = |d: Instant| {
            let mut q = req(EstimatorKind::Mimps);
            q.spec = q.spec.deadline(d);
            q
        };
        tx.send(with_deadline(far)).unwrap();
        tx.send(req(EstimatorKind::Mimps)).unwrap();
        tx.send(with_deadline(near)).unwrap();
        tx.send(req(EstimatorKind::Mimps)).unwrap();
        tx.send(with_deadline(mid)).unwrap();
        drop(tx);
        let mut asm = BatchAssembler::new(cfg);
        // The batch closes after the first three arrivals (far, none,
        // near) and drains them earliest-deadline-first, deadline-less
        // last.
        let b = asm.next_batch(&rx).unwrap();
        let deadlines: Vec<Option<Instant>> =
            b.requests.iter().map(|r| r.spec.deadline).collect();
        assert_eq!(deadlines, vec![Some(near), Some(far), None]);
        // Leftovers (none, mid) reorder the same way on the forced flush.
        let b2 = asm.next_batch(&rx).unwrap();
        let deadlines: Vec<Option<Instant>> =
            b2.requests.iter().map(|r| r.spec.deadline).collect();
        assert_eq!(deadlines, vec![Some(mid), None]);
        assert!(asm.next_batch(&rx).is_none(), "queue drained");
    }

    #[test]
    fn kinds_are_not_mixed() {
        let cfg = BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(5),
        };
        let (tx, rx) = mpsc::channel();
        tx.send(req(EstimatorKind::Mimps)).unwrap();
        tx.send(req(EstimatorKind::Mince)).unwrap();
        tx.send(req(EstimatorKind::Mimps)).unwrap();
        drop(tx);
        let mut asm = BatchAssembler::new(cfg);
        let mut sizes = std::collections::HashMap::new();
        while let Some(b) = asm.next_batch(&rx) {
            assert!(b.requests.iter().all(|r| r.spec.kind == b.kind));
            *sizes.entry(b.kind).or_insert(0) += b.requests.len();
        }
        assert_eq!(sizes[&EstimatorKind::Mimps], 2);
        assert_eq!(sizes[&EstimatorKind::Mince], 1);
    }
}
