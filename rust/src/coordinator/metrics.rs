//! Service metrics: lock-free counters plus lock-free log-linear
//! latency histograms, cheap enough to sit on the request path.
//!
//! Latency percentiles come from [`crate::obs::Histogram`]s — every
//! sample lands forever (the bounded reservoirs this module used to
//! keep silently dropped everything past the first 65,536 samples, so
//! percentiles reflected only startup traffic; the
//! `histograms_reflect_late_traffic_not_just_startup` test pins the
//! fix). Sampled request traces additionally feed per-stage histograms
//! (frontdoor / per-worker RPC / worker-side exec) via
//! [`ServiceMetrics::on_trace`], and the whole sink exports as a
//! mergeable [`MetricsBlob`] for the `GetMetrics` wire op and the
//! Prometheus endpoint.

use crate::obs::hist::Histogram;
use crate::obs::{CompletedTrace, MetricsBlob};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Shared metrics sink (one per service).
#[derive(Default)]
pub struct ServiceMetrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    shed: AtomicU64,
    /// Requests dropped for an expired `EstimateSpec::deadline` —
    /// rejected at submit (already expired) or shed by the batcher at
    /// drain time.
    deadline_shed: AtomicU64,
    /// Batch groups whose backend call failed (wire outage, artifact
    /// error); every member's reply channel was dropped unanswered.
    backend_errors: AtomicU64,
    batches: AtomicU64,
    batched_requests: AtomicU64,
    /// Wall-clock nanoseconds spent executing batches (not queueing) and
    /// the requests those executions completed — together they give the
    /// per-batch throughput the §Perf pass tracks.
    batch_exec_ns: AtomicU64,
    batch_exec_requests: AtomicU64,
    /// Snapshot epoch observed by the most recently executed batch group
    /// (0 until one executes; monolithic services stay at 0).
    epoch: AtomicU64,
    /// Nanosecond latency histograms (lock-free, unbounded sample
    /// count). `exec_ns` records the *batch-group* execution time once
    /// per completed request (all members of a group share one
    /// `estimate_batch` call), so exec percentiles reflect batch
    /// latency, not per-request CPU share — divide by
    /// `mean_batch_size` for a per-request view. `e2e_ns` is queue
    /// wait + execution per completed request.
    queue_ns: Histogram,
    exec_ns: Histogram,
    e2e_ns: Histogram,
    /// Per-stage histograms fed by sampled request traces
    /// ([`ServiceMetrics::on_trace`]): front-door admit time, client
    /// wall of one per-worker scatter RPC, and worker-reported
    /// server-side exec of that RPC. Sampled — their counts are a
    /// fraction of `completed`.
    frontdoor_ns: Histogram,
    rpc_ns: Histogram,
    worker_exec_ns: Histogram,
    /// Server-side frame handling, fed by the net handler pool
    /// ([`ServiceMetrics::on_net_handle`]): decode-to-handler lag and
    /// handler wall time, for every frame (not sampled).
    net_handle_ns: Histogram,
    net_exec_ns: Histogram,
    /// Per-shard accumulators (sharded serving only), indexed by shard
    /// position — scoped to one epoch (the `u64`), because shard
    /// positions are only stable within a snapshot: a mutation can
    /// compact or extend them. Advancing the epoch restarts the table;
    /// recordings from workers still draining an older snapshot are
    /// dropped rather than conflated into the wrong position.
    shards: Mutex<(u64, Vec<ShardStatAcc>)>,
    /// Front-door counters (`coordinator::frontdoor`): requests
    /// answered synchronously from the epoch-keyed result cache, cache
    /// misses that went on to enqueue, followers coalesced behind an
    /// in-flight identical leader, LRU evictions, and O(1) whole-epoch
    /// invalidations triggered by a category publish. Hits and
    /// coalesced followers still count in `submitted`/`completed`.
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    coalesced: AtomicU64,
    cache_evictions: AtomicU64,
    cache_invalidations: AtomicU64,
    /// Network-front-end counters (`net::Server` feeds these; all zero
    /// for purely in-process services).
    net_accepted: AtomicU64,
    net_rejected: AtomicU64,
    net_active: AtomicU64,
    net_frames_in: AtomicU64,
    net_frames_out: AtomicU64,
    net_wire_errors: AtomicU64,
}

#[derive(Clone, Copy, Default)]
struct ShardStatAcc {
    len: u64,
    scorings: u64,
    batches: u64,
    exec_ns: u64,
    errors: u64,
    failovers: u64,
    hedges: u64,
}

/// Point-in-time per-shard counters (sharded serving only). Counters
/// cover the **current serving epoch** — shard positions are only
/// meaningful within one snapshot, so the table restarts when the epoch
/// advances (`MetricsSnapshot::epoch` says which epoch these belong to).
#[derive(Clone, Debug)]
pub struct ShardStat {
    /// Shard position within the epoch's snapshot.
    pub shard: usize,
    /// Rows the shard held at the last batch that touched it.
    pub len: u64,
    /// Category scorings attributed to this shard this epoch.
    pub scorings: u64,
    /// Batch groups that scattered over this shard this epoch.
    pub batches: u64,
    /// Wall-clock execution time of those groups (each group's time is
    /// attributed to every shard it scattered over).
    pub exec_ns: u64,
    /// Failed fan-out calls attributed to this shard this epoch (a
    /// scatter whose failure named this worker — see
    /// `ClientError::Shard` in `net::client`). Lets an operator spot
    /// the failing worker from a metrics snapshot alone.
    pub errors: u64,
    /// Reads transparently re-routed to an alternate replica of this
    /// shard this epoch (`net::RemoteCluster` replica failover). A
    /// rising count with zero `errors` is the healthy-failover
    /// signature: a replica is down but its peers absorb the traffic.
    pub failovers: u64,
    /// Hedged reads this epoch: duplicates fired to a second replica
    /// after the hedge delay elapsed with the primary unanswered
    /// (`--hedge-delay-ms`). A rising count with flat `failovers` means
    /// the tail is being shaved, not that anything is down.
    pub hedges: u64,
}

impl ServiceMetrics {
    /// A zeroed sink.
    pub fn new() -> Self {
        Self::default()
    }

    /// One request accepted into the queue.
    pub fn on_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// One request rejected by the shedding backpressure policy.
    pub fn on_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// `count` requests dropped for an expired deadline (at submit or
    /// by the batcher at drain time).
    pub fn on_deadline_shed(&self, count: usize) {
        self.deadline_shed
            .fetch_add(count as u64, Ordering::Relaxed);
    }

    /// One batch group failed in the backend (members unanswered).
    pub fn on_backend_error(&self) {
        self.backend_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// One batch of `size` requests drained toward the workers.
    pub fn on_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests
            .fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Record one executed batch group: `size` requests answered by a
    /// single `estimate_batch` call that took `exec` wall-clock time.
    pub fn on_batch_executed(&self, size: usize, exec: Duration) {
        self.batch_exec_ns
            .fetch_add(exec.as_nanos() as u64, Ordering::Relaxed);
        self.batch_exec_requests
            .fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Record the snapshot epoch a batch group executed against.
    pub fn on_epoch(&self, epoch: u64) {
        self.epoch.store(epoch, Ordering::Relaxed);
    }

    /// Attribute one executed batch group to shard `shard` of the
    /// snapshot at `epoch`: the shard's current row count, the scorings
    /// its sub-scan cost, and the group's (shared) execution time. A
    /// newer epoch restarts the table (positions are snapshot-scoped);
    /// recordings from an older pinned epoch are dropped.
    pub fn on_shard_batch(
        &self,
        epoch: u64,
        shard: usize,
        len: usize,
        scorings: usize,
        exec: Duration,
    ) {
        let mut g = self.shards.lock().unwrap();
        if epoch != g.0 {
            if epoch < g.0 {
                return; // stale snapshot — don't conflate positions
            }
            g.0 = epoch;
            g.1.clear();
        }
        if g.1.len() <= shard {
            g.1.resize(shard + 1, ShardStatAcc::default());
        }
        let acc = &mut g.1[shard];
        acc.len = len as u64;
        acc.scorings += scorings as u64;
        acc.batches += 1;
        acc.exec_ns += exec.as_nanos() as u64;
    }

    /// Attribute one failed fan-out call to shard `shard` of the
    /// **current** epoch table (failures are observed on the serving
    /// path, which always runs against the current snapshot; the table
    /// grows as needed so an error on a never-recorded shard still
    /// lands).
    pub fn on_shard_error(&self, shard: usize) {
        let mut g = self.shards.lock().unwrap();
        if g.1.len() <= shard {
            g.1.resize(shard + 1, ShardStatAcc::default());
        }
        g.1[shard].errors += 1;
    }

    /// Attribute one replica failover (a read transparently re-routed
    /// to an alternate replica) to shard `shard` of the **current**
    /// epoch table, mirroring [`ServiceMetrics::on_shard_error`]'s
    /// grow-as-needed semantics.
    pub fn on_shard_failover(&self, shard: usize) {
        let mut g = self.shards.lock().unwrap();
        if g.1.len() <= shard {
            g.1.resize(shard + 1, ShardStatAcc::default());
        }
        g.1[shard].failovers += 1;
    }

    /// Attribute one hedged read (a duplicate fired to a second replica
    /// after the hedge delay) to shard `shard` of the **current** epoch
    /// table, mirroring [`ServiceMetrics::on_shard_error`]'s
    /// grow-as-needed semantics.
    pub fn on_shard_hedge(&self, shard: usize) {
        let mut g = self.shards.lock().unwrap();
        if g.1.len() <= shard {
            g.1.resize(shard + 1, ShardStatAcc::default());
        }
        g.1[shard].hedges += 1;
    }

    /// One request answered synchronously from the result cache.
    pub fn on_cache_hit(&self) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// One request that missed the cache and went on to enqueue (as a
    /// flight leader or an independent duplicate).
    pub fn on_cache_miss(&self) {
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// One request coalesced behind an identical in-flight leader
    /// (never enqueued; answered by the leader's completion).
    pub fn on_coalesced(&self) {
        self.coalesced.fetch_add(1, Ordering::Relaxed);
    }

    /// `count` entries evicted from the result cache by the LRU bound.
    pub fn on_cache_evictions(&self, count: u64) {
        self.cache_evictions.fetch_add(count, Ordering::Relaxed);
    }

    /// One whole-epoch cache invalidation (a category publish advanced
    /// the serving epoch past every cached entry).
    pub fn on_cache_invalidation(&self) {
        self.cache_invalidations.fetch_add(1, Ordering::Relaxed);
    }

    /// One network connection accepted and being served.
    pub fn on_conn_open(&self) {
        self.net_accepted.fetch_add(1, Ordering::Relaxed);
        self.net_active.fetch_add(1, Ordering::Relaxed);
    }

    /// A served connection closed (any reason).
    pub fn on_conn_close(&self) {
        self.net_active.fetch_sub(1, Ordering::Relaxed);
    }

    /// A connection turned away at the limit (answered `Busy`, closed).
    pub fn on_conn_rejected(&self) {
        self.net_rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// One request frame decoded off a connection.
    pub fn on_frame_in(&self) {
        self.net_frames_in.fetch_add(1, Ordering::Relaxed);
    }

    /// One response frame written to a connection.
    pub fn on_frame_out(&self) {
        self.net_frames_out.fetch_add(1, Ordering::Relaxed);
    }

    /// A malformed/truncated frame or an I/O failure on a connection.
    pub fn on_wire_error(&self) {
        self.net_wire_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// One request answered: its queue wait, (shared) group execution
    /// time, and their sum land in the latency histograms.
    pub fn on_complete(&self, queue_wait: Duration, exec: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.queue_ns.record_duration(queue_wait);
        self.exec_ns.record_duration(exec);
        self.e2e_ns
            .record_duration(queue_wait.saturating_add(exec));
    }

    /// Fold one completed sampled trace into the per-stage histograms:
    /// `frontdoor` spans feed the admit histogram, `rpc` spans (one per
    /// scattered worker) the RPC-wall histogram, and `worker` spans
    /// (the worker's self-reported exec from the wire timing annex) the
    /// worker-exec histogram.
    pub fn on_trace(&self, trace: &CompletedTrace) {
        for ev in &trace.events {
            match ev.name.as_str() {
                "frontdoor" => self.frontdoor_ns.record(ev.dur_ns),
                "rpc" => self.rpc_ns.record(ev.dur_ns),
                "worker" => self.worker_exec_ns.record(ev.dur_ns),
                _ => {}
            }
        }
    }

    /// One frame handled by the network handler pool: `lag` between
    /// frame decode and handler start, `exec` the handler wall time.
    pub fn on_net_handle(&self, lag: Duration, exec: Duration) {
        self.net_handle_ns.record_duration(lag);
        self.net_exec_ns.record_duration(exec);
    }

    /// A point-in-time copy of every counter and latency percentile.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let queue = self.queue_ns.snapshot();
        let exec = self.exec_ns.snapshot();
        let e2e = self.e2e_ns.snapshot();
        let stage_stats = [
            ("frontdoor", &self.frontdoor_ns),
            ("rpc", &self.rpc_ns),
            ("worker_exec", &self.worker_exec_ns),
            ("net_handle", &self.net_handle_ns),
            ("net_exec", &self.net_exec_ns),
        ]
        .into_iter()
        .filter(|(_, h)| h.count() > 0)
        .map(|(name, h)| {
            let s = h.snapshot();
            StageStat {
                stage: name.to_string(),
                count: s.count,
                p50: s.p50(),
                p99: s.p99(),
            }
        })
        .collect();
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            deadline_shed: self.deadline_shed.load(Ordering::Relaxed),
            backend_errors: self.backend_errors.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            mean_batch_size: {
                let b = self.batches.load(Ordering::Relaxed);
                if b == 0 {
                    0.0
                } else {
                    self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
                }
            },
            batch_throughput_rps: {
                let ns = self.batch_exec_ns.load(Ordering::Relaxed);
                if ns == 0 {
                    0.0
                } else {
                    self.batch_exec_requests.load(Ordering::Relaxed) as f64
                        / (ns as f64 / 1e9)
                }
            },
            epoch: self.epoch.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            cache_evictions: self.cache_evictions.load(Ordering::Relaxed),
            cache_invalidations: self.cache_invalidations.load(Ordering::Relaxed),
            shard_stats: self
                .shards
                .lock()
                .unwrap()
                .1
                .iter()
                .enumerate()
                .map(|(shard, a)| ShardStat {
                    shard,
                    len: a.len,
                    scorings: a.scorings,
                    batches: a.batches,
                    exec_ns: a.exec_ns,
                    errors: a.errors,
                    failovers: a.failovers,
                    hedges: a.hedges,
                })
                .collect(),
            net: NetStats {
                accepted: self.net_accepted.load(Ordering::Relaxed),
                rejected: self.net_rejected.load(Ordering::Relaxed),
                active: self.net_active.load(Ordering::Relaxed),
                frames_in: self.net_frames_in.load(Ordering::Relaxed),
                frames_out: self.net_frames_out.load(Ordering::Relaxed),
                wire_errors: self.net_wire_errors.load(Ordering::Relaxed),
            },
            queue_p50: queue.quantile_duration(0.50),
            queue_p95: queue.quantile_duration(0.95),
            queue_p99: queue.quantile_duration(0.99),
            exec_p50: exec.quantile_duration(0.50),
            exec_p95: exec.quantile_duration(0.95),
            exec_p99: exec.quantile_duration(0.99),
            e2e_p50: e2e.quantile_duration(0.50),
            e2e_p99: e2e.quantile_duration(0.99),
            e2e_p999: e2e.quantile_duration(0.999),
            stage_stats,
        }
    }

    /// Export every counter and histogram as a mergeable, wire-ready
    /// [`MetricsBlob`] — the payload of the `GetMetrics` op and the
    /// source of the Prometheus endpoint. `epoch` and `net_active` are
    /// point-in-time gauges; everything else is monotone.
    pub fn blob(&self) -> MetricsBlob {
        let c = |a: &AtomicU64| a.load(Ordering::Relaxed);
        MetricsBlob {
            counters: vec![
                ("submitted".to_string(), c(&self.submitted)),
                ("completed".to_string(), c(&self.completed)),
                ("shed".to_string(), c(&self.shed)),
                ("deadline_shed".to_string(), c(&self.deadline_shed)),
                ("backend_errors".to_string(), c(&self.backend_errors)),
                ("batches".to_string(), c(&self.batches)),
                ("batched_requests".to_string(), c(&self.batched_requests)),
                ("batch_exec_ns".to_string(), c(&self.batch_exec_ns)),
                (
                    "batch_exec_requests".to_string(),
                    c(&self.batch_exec_requests),
                ),
                ("epoch".to_string(), c(&self.epoch)),
                ("cache_hits".to_string(), c(&self.cache_hits)),
                ("cache_misses".to_string(), c(&self.cache_misses)),
                ("coalesced".to_string(), c(&self.coalesced)),
                ("cache_evictions".to_string(), c(&self.cache_evictions)),
                (
                    "cache_invalidations".to_string(),
                    c(&self.cache_invalidations),
                ),
                ("net_accepted".to_string(), c(&self.net_accepted)),
                ("net_rejected".to_string(), c(&self.net_rejected)),
                ("net_active".to_string(), c(&self.net_active)),
                ("net_frames_in".to_string(), c(&self.net_frames_in)),
                ("net_frames_out".to_string(), c(&self.net_frames_out)),
                ("net_wire_errors".to_string(), c(&self.net_wire_errors)),
            ],
            hists: vec![
                ("queue_ns".to_string(), self.queue_ns.snapshot()),
                ("exec_ns".to_string(), self.exec_ns.snapshot()),
                ("e2e_ns".to_string(), self.e2e_ns.snapshot()),
                ("frontdoor_ns".to_string(), self.frontdoor_ns.snapshot()),
                ("rpc_ns".to_string(), self.rpc_ns.snapshot()),
                ("worker_exec_ns".to_string(), self.worker_exec_ns.snapshot()),
                ("net_handle_ns".to_string(), self.net_handle_ns.snapshot()),
                ("net_exec_ns".to_string(), self.net_exec_ns.snapshot()),
            ],
        }
    }
}

/// Network-front-end counters (all zero for in-process-only services).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NetStats {
    /// Connections accepted and served.
    pub accepted: u64,
    /// Connections turned away at the connection limit.
    pub rejected: u64,
    /// Connections currently being served.
    pub active: u64,
    /// Request frames decoded.
    pub frames_in: u64,
    /// Response frames written.
    pub frames_out: u64,
    /// Malformed frames / connection I/O failures.
    pub wire_errors: u64,
}

/// Point-in-time view of the service counters.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Requests accepted into the queue.
    pub submitted: u64,
    /// Requests answered.
    pub completed: u64,
    /// Requests rejected by the shedding backpressure policy.
    pub shed: u64,
    /// Requests dropped for an expired deadline (at submit or at the
    /// batcher's drain-time sweep).
    pub deadline_shed: u64,
    /// Batch groups whose backend call failed (members unanswered).
    pub backend_errors: u64,
    /// Batches drained toward the workers.
    pub batches: u64,
    /// Mean drained-batch size.
    pub mean_batch_size: f64,
    /// Requests per second across executed batch groups (execution time
    /// only — queue wait excluded). 0.0 until a batch has executed.
    pub batch_throughput_rps: f64,
    /// Snapshot epoch of the most recently executed batch group (0 for
    /// monolithic services).
    pub epoch: u64,
    /// Requests answered synchronously from the front-door result cache
    /// (bit-exact within their epoch; counted in `completed` too).
    pub cache_hits: u64,
    /// Requests that missed the cache and enqueued toward the batcher.
    pub cache_misses: u64,
    /// Requests coalesced behind an identical in-flight leader — they
    /// consumed no batcher slot and no backend call.
    pub coalesced: u64,
    /// Result-cache entries evicted by the LRU capacity bounds.
    pub cache_evictions: u64,
    /// Whole-epoch cache invalidations (category publishes observed by
    /// the front door).
    pub cache_invalidations: u64,
    /// Per-shard counters; empty for monolithic services.
    pub shard_stats: Vec<ShardStat>,
    /// Network-front-end counters; all zero without a `net::Server`.
    pub net: NetStats,
    /// Median queue wait.
    pub queue_p50: Duration,
    /// 95th-percentile queue wait.
    pub queue_p95: Duration,
    /// 99th-percentile queue wait.
    pub queue_p99: Duration,
    /// Median batch-group execution time.
    pub exec_p50: Duration,
    /// 95th-percentile batch-group execution time.
    pub exec_p95: Duration,
    /// 99th-percentile batch-group execution time.
    pub exec_p99: Duration,
    /// Median end-to-end (queue + exec) latency.
    pub e2e_p50: Duration,
    /// 99th-percentile end-to-end latency.
    pub e2e_p99: Duration,
    /// 99.9th-percentile end-to-end latency.
    pub e2e_p999: Duration,
    /// Per-stage percentiles from sampled traces and the net handler
    /// pool; empty until a stage has recorded a sample.
    pub stage_stats: Vec<StageStat>,
}

/// Percentiles of one pipeline stage (`frontdoor`, `rpc`,
/// `worker_exec`, `net_handle`, `net_exec`). Trace-fed stages only
/// count sampled requests.
#[derive(Clone, Debug)]
pub struct StageStat {
    /// Stage name.
    pub stage: String,
    /// Samples recorded.
    pub count: u64,
    /// Median stage latency.
    pub p50: Duration,
    /// 99th-percentile stage latency.
    pub p99: Duration,
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "submitted={} completed={} shed={} batches={} mean_batch={:.1} \
             batch_rps={:.0} queue_p50={:?} queue_p95={:?} exec_p50={:?} exec_p95={:?} \
             queue_p99={:?} exec_p99={:?} e2e_p50={:?} e2e_p99={:?} e2e_p999={:?}",
            self.submitted,
            self.completed,
            self.shed,
            self.batches,
            self.mean_batch_size,
            self.batch_throughput_rps,
            self.queue_p50,
            self.queue_p95,
            self.exec_p50,
            self.exec_p95,
            self.queue_p99,
            self.exec_p99,
            self.e2e_p50,
            self.e2e_p99,
            self.e2e_p999
        )?;
        if !self.stage_stats.is_empty() {
            write!(f, " stages=[")?;
            for (i, s) in self.stage_stats.iter().enumerate() {
                if i > 0 {
                    write!(f, " ")?;
                }
                write!(
                    f,
                    "{}:n={},p50={:?},p99={:?}",
                    s.stage, s.count, s.p50, s.p99
                )?;
            }
            write!(f, "]")?;
        }
        if self.deadline_shed > 0 {
            write!(f, " deadline_shed={}", self.deadline_shed)?;
        }
        if self.backend_errors > 0 {
            write!(f, " backend_errors={}", self.backend_errors)?;
        }
        if self.cache_hits > 0 || self.cache_misses > 0 || self.coalesced > 0 {
            write!(
                f,
                " frontdoor[hits={} misses={} coalesced={}",
                self.cache_hits, self.cache_misses, self.coalesced
            )?;
            if self.cache_evictions > 0 {
                write!(f, " evictions={}", self.cache_evictions)?;
            }
            if self.cache_invalidations > 0 {
                write!(f, " invalidations={}", self.cache_invalidations)?;
            }
            write!(f, "]")?;
        }
        if !self.shard_stats.is_empty() {
            write!(f, " epoch={} shards=[", self.epoch)?;
            for (i, s) in self.shard_stats.iter().enumerate() {
                if i > 0 {
                    write!(f, " ")?;
                }
                write!(
                    f,
                    "{}:len={},scorings={},batches={},exec={:?}",
                    s.shard,
                    s.len,
                    s.scorings,
                    s.batches,
                    Duration::from_nanos(s.exec_ns)
                )?;
                if s.errors > 0 {
                    write!(f, ",errors={}", s.errors)?;
                }
                if s.failovers > 0 {
                    write!(f, ",failovers={}", s.failovers)?;
                }
                if s.hedges > 0 {
                    write!(f, ",hedges={}", s.hedges)?;
                }
            }
            write!(f, "]")?;
        }
        if self.net.accepted > 0 || self.net.rejected > 0 {
            write!(
                f,
                " net[conns={}/{} active={} frames={}/{} wire_errors={}]",
                self.net.accepted,
                self.net.rejected,
                self.net.active,
                self.net.frames_in,
                self.net.frames_out,
                self.net.wire_errors
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = ServiceMetrics::new();
        m.on_submit();
        m.on_submit();
        m.on_shed();
        m.on_batch(4);
        m.on_complete(Duration::from_millis(1), Duration::from_millis(2));
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.shed, 1);
        assert_eq!(s.completed, 1);
        assert_eq!(s.mean_batch_size, 4.0);
        assert!(s.queue_p50 >= Duration::from_millis(1));
        assert!(s.exec_p95 >= Duration::from_millis(2));
    }

    #[test]
    fn batch_throughput_counts_only_exec_time() {
        let m = ServiceMetrics::new();
        // 64 requests in 100 ms + 36 in 100 ms → 100 req / 0.2 s = 500 rps.
        m.on_batch_executed(64, Duration::from_millis(100));
        m.on_batch_executed(36, Duration::from_millis(100));
        let s = m.snapshot();
        assert!(
            (s.batch_throughput_rps - 500.0).abs() < 1.0,
            "rps {}",
            s.batch_throughput_rps
        );
    }

    #[test]
    fn deadline_and_backend_counters_accumulate() {
        let m = ServiceMetrics::new();
        m.on_deadline_shed(3);
        m.on_deadline_shed(1);
        m.on_backend_error();
        let s = m.snapshot();
        assert_eq!(s.deadline_shed, 4);
        assert_eq!(s.backend_errors, 1);
        let text = s.to_string();
        assert!(text.contains("deadline_shed=4"), "{text}");
        assert!(text.contains("backend_errors=1"), "{text}");
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let s = ServiceMetrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.deadline_shed, 0);
        assert_eq!(s.backend_errors, 0);
        assert_eq!(s.queue_p95, Duration::ZERO);
        assert_eq!(s.mean_batch_size, 0.0);
        assert_eq!(s.batch_throughput_rps, 0.0);
        assert_eq!(s.epoch, 0);
        assert!(s.shard_stats.is_empty());
        assert_eq!(s.cache_hits, 0);
        assert_eq!(s.cache_misses, 0);
        assert_eq!(s.coalesced, 0);
        assert!(!s.to_string().contains("frontdoor["));
    }

    #[test]
    fn frontdoor_counters_accumulate_and_render() {
        let m = ServiceMetrics::new();
        m.on_cache_hit();
        m.on_cache_hit();
        m.on_cache_miss();
        m.on_coalesced();
        m.on_cache_evictions(5);
        m.on_cache_invalidation();
        let s = m.snapshot();
        assert_eq!(s.cache_hits, 2);
        assert_eq!(s.cache_misses, 1);
        assert_eq!(s.coalesced, 1);
        assert_eq!(s.cache_evictions, 5);
        assert_eq!(s.cache_invalidations, 1);
        let text = s.to_string();
        assert!(
            text.contains("frontdoor[hits=2 misses=1 coalesced=1 evictions=5 invalidations=1]"),
            "{text}"
        );
    }

    #[test]
    fn net_counters_track_connections_and_frames() {
        let m = ServiceMetrics::new();
        m.on_conn_open();
        m.on_conn_open();
        m.on_conn_rejected();
        m.on_frame_in();
        m.on_frame_out();
        m.on_frame_in();
        m.on_wire_error();
        m.on_conn_close();
        let s = m.snapshot();
        assert_eq!(s.net.accepted, 2);
        assert_eq!(s.net.rejected, 1);
        assert_eq!(s.net.active, 1);
        assert_eq!(s.net.frames_in, 2);
        assert_eq!(s.net.frames_out, 1);
        assert_eq!(s.net.wire_errors, 1);
        let text = s.to_string();
        assert!(text.contains("net[conns=2/1"), "{text}");
    }

    #[test]
    fn shard_stats_accumulate_per_shard() {
        let m = ServiceMetrics::new();
        m.on_epoch(3);
        m.on_shard_batch(3, 0, 100, 100, Duration::from_millis(2));
        m.on_shard_batch(3, 1, 50, 50, Duration::from_millis(2));
        m.on_shard_batch(3, 1, 50, 75, Duration::from_millis(1));
        let s = m.snapshot();
        assert_eq!(s.epoch, 3);
        assert_eq!(s.shard_stats.len(), 2);
        assert_eq!(s.shard_stats[0].scorings, 100);
        assert_eq!(s.shard_stats[0].batches, 1);
        assert_eq!(s.shard_stats[1].len, 50);
        assert_eq!(s.shard_stats[1].scorings, 125);
        assert_eq!(s.shard_stats[1].batches, 2);
        assert_eq!(s.shard_stats[1].exec_ns, 3_000_000);
        let text = s.to_string();
        assert!(text.contains("epoch=3"), "{text}");
        assert!(text.contains("shards=["), "{text}");
    }

    #[test]
    fn shard_errors_attribute_to_the_failing_worker() {
        let m = ServiceMetrics::new();
        m.on_shard_batch(1, 0, 10, 10, Duration::from_millis(1));
        m.on_shard_batch(1, 1, 10, 10, Duration::from_millis(1));
        // Two failures on worker 1, one on a worker the batch path never
        // recorded (the table grows to hold it).
        m.on_shard_error(1);
        m.on_shard_error(1);
        m.on_shard_error(3);
        let s = m.snapshot();
        assert_eq!(s.shard_stats.len(), 4);
        assert_eq!(s.shard_stats[0].errors, 0);
        assert_eq!(s.shard_stats[1].errors, 2);
        assert_eq!(s.shard_stats[3].errors, 1);
        let text = s.to_string();
        assert!(text.contains("errors=2"), "{text}");
        assert!(!text.contains("0:len=10,scorings=10,batches=1,exec=1ms,errors"), "{text}");
    }

    /// Regression for the reservoir saturation bug: the old bounded
    /// reservoirs silently dropped every sample past 65,536, so
    /// percentiles froze on startup traffic. With histograms, 100k
    /// fast startup samples followed by 100k samples 100× slower must
    /// move p99 (and the median) to the late traffic.
    #[test]
    fn histograms_reflect_late_traffic_not_just_startup() {
        let m = ServiceMetrics::new();
        let fast = Duration::from_micros(10);
        let slow = Duration::from_millis(1);
        for _ in 0..100_000 {
            m.on_complete(fast, fast);
        }
        for _ in 0..100_000 {
            m.on_complete(slow, slow);
        }
        let s = m.snapshot();
        assert_eq!(s.completed, 200_000);
        // Late traffic is half the distribution: p99 must sit at the
        // slow mode, far above the fast startup samples the old
        // reservoir would have frozen on.
        assert!(
            s.queue_p99 >= slow,
            "queue_p99 {:?} ignores post-saturation traffic",
            s.queue_p99
        );
        assert!(s.exec_p99 >= slow, "exec_p99 {:?}", s.exec_p99);
        assert!(s.e2e_p99 >= Duration::from_millis(2), "e2e_p99 {:?}", s.e2e_p99);
        // ...while the histogram keeps the early samples too (p-low
        // stays fast, within the 1/32 bucket error).
        assert!(s.queue_p50 <= slow, "queue_p50 {:?}", s.queue_p50);
    }

    #[test]
    fn traces_feed_stage_histograms() {
        use crate::obs::{SpanEvent, Trace};
        let m = ServiceMetrics::new();
        let t = Trace::start(1);
        for (name, dur_ns, track) in [
            ("frontdoor", 1_000, 0),
            ("queue", 5_000, 0),
            ("rpc", 40_000, 1),
            ("rpc", 60_000, 2),
            ("worker", 30_000, 1),
            ("worker", 50_000, 2),
        ] {
            t.add(SpanEvent {
                name: name.to_string(),
                start_ns: 0,
                dur_ns,
                track,
                args: vec![],
            });
        }
        m.on_trace(&t.finish());
        m.on_net_handle(Duration::from_micros(2), Duration::from_micros(90));
        let s = m.snapshot();
        let stage = |name: &str| {
            s.stage_stats
                .iter()
                .find(|st| st.stage == name)
                .unwrap_or_else(|| panic!("stage {name} missing: {:?}", s.stage_stats))
                .clone()
        };
        assert_eq!(stage("frontdoor").count, 1);
        assert_eq!(stage("rpc").count, 2);
        assert_eq!(stage("worker_exec").count, 2);
        assert!(stage("worker_exec").p99 >= Duration::from_nanos(50_000));
        assert_eq!(stage("net_handle").count, 1);
        assert!(stage("net_exec").p50 >= Duration::from_micros(90));
        // "queue" spans are already covered by on_complete, not stages.
        assert!(!s.stage_stats.iter().any(|st| st.stage == "queue"));
        let text = s.to_string();
        assert!(text.contains("stages=["), "{text}");
        assert!(text.contains("rpc:n=2"), "{text}");
        // The wire blob exports the same histograms by name.
        let blob = m.blob();
        assert_eq!(blob.hist("rpc_ns").unwrap().count, 2);
        assert_eq!(blob.hist("worker_exec_ns").unwrap().count, 2);
    }

    #[test]
    fn blob_exports_counters_and_merges() {
        let m = ServiceMetrics::new();
        m.on_submit();
        m.on_submit();
        m.on_shed();
        m.on_complete(Duration::from_micros(50), Duration::from_micros(100));
        let blob = m.blob();
        assert_eq!(blob.counter("submitted"), 2);
        assert_eq!(blob.counter("shed"), 1);
        assert_eq!(blob.counter("completed"), 1);
        assert_eq!(blob.hist("queue_ns").unwrap().count, 1);
        assert_eq!(blob.hist("e2e_ns").unwrap().count, 1);
        // Merging two services' blobs adds counters and histograms —
        // the coordinator+workers `GetMetrics` path.
        let m2 = ServiceMetrics::new();
        m2.on_submit();
        m2.on_complete(Duration::from_micros(70), Duration::from_micros(70));
        let mut merged = blob.clone();
        merged.merge(&m2.blob());
        assert_eq!(merged.counter("submitted"), 3);
        assert_eq!(merged.hist("queue_ns").unwrap().count, 2);
    }

    #[test]
    fn shard_table_restarts_per_epoch_and_drops_stale() {
        let m = ServiceMetrics::new();
        m.on_shard_batch(0, 0, 10, 10, Duration::from_millis(1));
        m.on_shard_batch(0, 1, 10, 10, Duration::from_millis(1));
        m.on_shard_batch(0, 2, 10, 10, Duration::from_millis(1));
        // New epoch (e.g. a shard was removed and positions compacted):
        // the table restarts so old positions cannot conflate.
        m.on_shard_batch(1, 0, 8, 5, Duration::from_millis(1));
        // A worker still draining the old snapshot is ignored.
        m.on_shard_batch(0, 2, 10, 99, Duration::from_millis(1));
        let s = m.snapshot();
        assert_eq!(s.shard_stats.len(), 1);
        assert_eq!(s.shard_stats[0].len, 8);
        assert_eq!(s.shard_stats[0].scorings, 5);
    }
}
