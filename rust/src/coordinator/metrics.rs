//! Service metrics: lock-free counters plus latency reservoirs, cheap
//! enough to sit on the request path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Shared metrics sink (one per service).
#[derive(Default)]
pub struct ServiceMetrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    shed: AtomicU64,
    batches: AtomicU64,
    batched_requests: AtomicU64,
    /// Wall-clock nanoseconds spent executing batches (not queueing) and
    /// the requests those executions completed — together they give the
    /// per-batch throughput the §Perf pass tracks.
    batch_exec_ns: AtomicU64,
    batch_exec_requests: AtomicU64,
    /// Nanosecond latency samples (bounded reservoir). `exec_ns` records
    /// the *batch-group* execution time once per completed request (all
    /// members of a group share one `estimate_batch` call), so exec
    /// percentiles reflect batch latency, not per-request CPU share —
    /// divide by `mean_batch_size` for a per-request view.
    queue_ns: Mutex<Vec<u64>>,
    exec_ns: Mutex<Vec<u64>>,
}

const RESERVOIR: usize = 65_536;

impl ServiceMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn on_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests
            .fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Record one executed batch group: `size` requests answered by a
    /// single `estimate_batch` call that took `exec` wall-clock time.
    pub fn on_batch_executed(&self, size: usize, exec: Duration) {
        self.batch_exec_ns
            .fetch_add(exec.as_nanos() as u64, Ordering::Relaxed);
        self.batch_exec_requests
            .fetch_add(size as u64, Ordering::Relaxed);
    }

    pub fn on_complete(&self, queue_wait: Duration, exec: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        let mut q = self.queue_ns.lock().unwrap();
        if q.len() < RESERVOIR {
            q.push(queue_wait.as_nanos() as u64);
        }
        drop(q);
        let mut e = self.exec_ns.lock().unwrap();
        if e.len() < RESERVOIR {
            e.push(exec.as_nanos() as u64);
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let pct = |v: &Mutex<Vec<u64>>, p: f64| -> Duration {
            let mut s = v.lock().unwrap().clone();
            if s.is_empty() {
                return Duration::ZERO;
            }
            s.sort_unstable();
            let idx = ((s.len() - 1) as f64 * p) as usize;
            Duration::from_nanos(s[idx])
        };
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            mean_batch_size: {
                let b = self.batches.load(Ordering::Relaxed);
                if b == 0 {
                    0.0
                } else {
                    self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
                }
            },
            batch_throughput_rps: {
                let ns = self.batch_exec_ns.load(Ordering::Relaxed);
                if ns == 0 {
                    0.0
                } else {
                    self.batch_exec_requests.load(Ordering::Relaxed) as f64
                        / (ns as f64 / 1e9)
                }
            },
            queue_p50: pct(&self.queue_ns, 0.50),
            queue_p95: pct(&self.queue_ns, 0.95),
            exec_p50: pct(&self.exec_ns, 0.50),
            exec_p95: pct(&self.exec_ns, 0.95),
        }
    }
}

/// Point-in-time view of the service counters.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub shed: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    /// Requests per second across executed batch groups (execution time
    /// only — queue wait excluded). 0.0 until a batch has executed.
    pub batch_throughput_rps: f64,
    pub queue_p50: Duration,
    pub queue_p95: Duration,
    pub exec_p50: Duration,
    pub exec_p95: Duration,
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "submitted={} completed={} shed={} batches={} mean_batch={:.1} \
             batch_rps={:.0} queue_p50={:?} queue_p95={:?} exec_p50={:?} exec_p95={:?}",
            self.submitted,
            self.completed,
            self.shed,
            self.batches,
            self.mean_batch_size,
            self.batch_throughput_rps,
            self.queue_p50,
            self.queue_p95,
            self.exec_p50,
            self.exec_p95
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = ServiceMetrics::new();
        m.on_submit();
        m.on_submit();
        m.on_shed();
        m.on_batch(4);
        m.on_complete(Duration::from_millis(1), Duration::from_millis(2));
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.shed, 1);
        assert_eq!(s.completed, 1);
        assert_eq!(s.mean_batch_size, 4.0);
        assert!(s.queue_p50 >= Duration::from_millis(1));
        assert!(s.exec_p95 >= Duration::from_millis(2));
    }

    #[test]
    fn batch_throughput_counts_only_exec_time() {
        let m = ServiceMetrics::new();
        // 64 requests in 100 ms + 36 in 100 ms → 100 req / 0.2 s = 500 rps.
        m.on_batch_executed(64, Duration::from_millis(100));
        m.on_batch_executed(36, Duration::from_millis(100));
        let s = m.snapshot();
        assert!(
            (s.batch_throughput_rps - 500.0).abs() < 1.0,
            "rps {}",
            s.batch_throughput_rps
        );
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let s = ServiceMetrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.queue_p95, Duration::ZERO);
        assert_eq!(s.mean_batch_size, 0.0);
        assert_eq!(s.batch_throughput_rps, 0.0);
    }
}
