//! Service metrics: lock-free counters plus latency reservoirs, cheap
//! enough to sit on the request path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Shared metrics sink (one per service).
#[derive(Default)]
pub struct ServiceMetrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    shed: AtomicU64,
    batches: AtomicU64,
    batched_requests: AtomicU64,
    /// Nanosecond latency samples (bounded reservoir).
    queue_ns: Mutex<Vec<u64>>,
    exec_ns: Mutex<Vec<u64>>,
}

const RESERVOIR: usize = 65_536;

impl ServiceMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn on_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests
            .fetch_add(size as u64, Ordering::Relaxed);
    }

    pub fn on_complete(&self, queue_wait: Duration, exec: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        let mut q = self.queue_ns.lock().unwrap();
        if q.len() < RESERVOIR {
            q.push(queue_wait.as_nanos() as u64);
        }
        drop(q);
        let mut e = self.exec_ns.lock().unwrap();
        if e.len() < RESERVOIR {
            e.push(exec.as_nanos() as u64);
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let pct = |v: &Mutex<Vec<u64>>, p: f64| -> Duration {
            let mut s = v.lock().unwrap().clone();
            if s.is_empty() {
                return Duration::ZERO;
            }
            s.sort_unstable();
            let idx = ((s.len() - 1) as f64 * p) as usize;
            Duration::from_nanos(s[idx])
        };
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            mean_batch_size: {
                let b = self.batches.load(Ordering::Relaxed);
                if b == 0 {
                    0.0
                } else {
                    self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
                }
            },
            queue_p50: pct(&self.queue_ns, 0.50),
            queue_p95: pct(&self.queue_ns, 0.95),
            exec_p50: pct(&self.exec_ns, 0.50),
            exec_p95: pct(&self.exec_ns, 0.95),
        }
    }
}

/// Point-in-time view of the service counters.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub shed: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub queue_p50: Duration,
    pub queue_p95: Duration,
    pub exec_p50: Duration,
    pub exec_p95: Duration,
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "submitted={} completed={} shed={} batches={} mean_batch={:.1} \
             queue_p50={:?} queue_p95={:?} exec_p50={:?} exec_p95={:?}",
            self.submitted,
            self.completed,
            self.shed,
            self.batches,
            self.mean_batch_size,
            self.queue_p50,
            self.queue_p95,
            self.exec_p50,
            self.exec_p95
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = ServiceMetrics::new();
        m.on_submit();
        m.on_submit();
        m.on_shed();
        m.on_batch(4);
        m.on_complete(Duration::from_millis(1), Duration::from_millis(2));
        let s = m.snapshot();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.shed, 1);
        assert_eq!(s.completed, 1);
        assert_eq!(s.mean_batch_size, 4.0);
        assert!(s.queue_p50 >= Duration::from_millis(1));
        assert!(s.exec_p95 >= Duration::from_millis(2));
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let s = ServiceMetrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.queue_p95, Duration::ZERO);
        assert_eq!(s.mean_batch_size, 0.0);
    }
}
