//! Bounded, sharded, epoch-generation-tagged LRU result cache.
//!
//! Capacity is bounded twice — in **entries** and in **bytes** — and
//! the effective per-shard cap is whichever bound is tighter (every
//! entry costs the same [`ENTRY_BYTES`]: the query is represented only
//! by its fingerprint hash, so nothing variable-length is stored).
//!
//! **Epoch invalidation is O(1) and sweep-free**: the cache keeps one
//! atomic *generation* (the highest epoch it has observed), every
//! entry is tagged with the epoch it answers for, and a publish simply
//! advances the generation. Entries of older generations can never be
//! served — a fresh fingerprint embeds the new epoch and misses them,
//! and a stale fingerprint that does reach one is rejected and lazily
//! removed on touch — so no lock is held over the whole map and no
//! eviction storm follows a publish; dead entries age out through the
//! normal LRU tail.

use super::fingerprint::Fingerprint;
use crate::estimators::EstimatorKind;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Result-cache capacity knobs (see
/// [`ServiceConfig`](crate::coordinator::ServiceConfig) for where they
/// are configured and the `--cache-entries` / `--cache-bytes` flags on
/// the binaries).
#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    /// Maximum cached results across all shards; 0 disables the cache
    /// (in-flight coalescing still runs).
    pub entries: usize,
    /// Maximum cache footprint in bytes ([`ENTRY_BYTES`] per entry);
    /// 0 disables the cache.
    pub bytes: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            entries: 8192,
            bytes: 4 << 20,
        }
    }
}

/// The epoch-exact payload a hit serves back (timings are not cached:
/// a hit's queue wait and execution time are ~zero by construction).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CachedAnswer {
    /// The estimate Ẑ(q), bit-identical to the execution that filled
    /// the entry.
    pub z: f64,
    /// Estimator that produced it.
    pub kind: EstimatorKind,
    /// Epoch the answer was computed at (doubles as the entry's
    /// generation tag).
    pub epoch: u64,
    /// Scoring cost of the *original* execution — a hit re-serves the
    /// accounting along with the answer so sublinearity bookkeeping
    /// stays meaningful.
    pub scorings: usize,
}

/// Accounted bytes per cache entry: slot payload + intrusive-list
/// links + hash-map key/index overhead, rounded up to a stable
/// constant so the byte bound is deterministic across platforms.
pub const ENTRY_BYTES: usize = 128;

const NIL: usize = usize::MAX;
const SHARDS: usize = 8;

struct Slot {
    fp: Fingerprint,
    val: CachedAnswer,
    /// Toward more-recently-used (NIL at the head).
    prev: usize,
    /// Toward less-recently-used (NIL at the tail).
    next: usize,
}

/// One lock's worth of LRU state: an index map plus an intrusive
/// doubly-linked recency list over a slot arena (free slots recycled
/// through a free list, so a warm shard never reallocates).
struct ShardState {
    map: HashMap<Fingerprint, usize>,
    slots: Vec<Slot>,
    free: Vec<usize>,
    head: usize,
    tail: usize,
}

impl ShardState {
    fn new() -> ShardState {
        ShardState {
            map: HashMap::new(),
            slots: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
        }
    }

    fn detach(&mut self, i: usize) {
        let (prev, next) = (self.slots[i].prev, self.slots[i].next);
        match prev {
            NIL => self.head = next,
            p => self.slots[p].next = next,
        }
        match next {
            NIL => self.tail = prev,
            n => self.slots[n].prev = prev,
        }
    }

    fn push_front(&mut self, i: usize) {
        self.slots[i].prev = NIL;
        self.slots[i].next = self.head;
        match self.head {
            NIL => self.tail = i,
            h => self.slots[h].prev = i,
        }
        self.head = i;
    }

    /// Remove slot `i` entirely (map + list), recycling its arena slot.
    fn remove(&mut self, i: usize) {
        self.detach(i);
        self.map.remove(&self.slots[i].fp);
        self.free.push(i);
    }
}

/// The sharded LRU described in the module docs. All methods are
/// `&self`: shards lock independently, the generation is atomic.
pub struct ResultCache {
    shards: Vec<Mutex<ShardState>>,
    /// Effective per-shard entry cap (min of the entry bound and the
    /// byte bound ÷ [`ENTRY_BYTES`], split across shards).
    shard_cap: usize,
    /// Highest epoch observed; entries tagged below it are dead.
    generation: AtomicU64,
}

impl ResultCache {
    /// Build with `cfg` capacities; either bound at 0 disables caching.
    pub fn new(cfg: CacheConfig) -> ResultCache {
        let total = cfg.entries.min(cfg.bytes / ENTRY_BYTES);
        ResultCache {
            shards: (0..SHARDS).map(|_| Mutex::new(ShardState::new())).collect(),
            shard_cap: total.div_ceil(SHARDS),
            generation: AtomicU64::new(0),
        }
    }

    fn shard(&self, fp: &Fingerprint) -> &Mutex<ShardState> {
        &self.shards[(fp.mix() % SHARDS as u64) as usize]
    }

    /// Advance the generation to `epoch` (a publish observation).
    /// Returns `true` when this call actually moved it forward — the
    /// O(1) invalidation of everything cached for earlier epochs.
    pub fn advance_generation(&self, epoch: u64) -> bool {
        self.generation.fetch_max(epoch, Ordering::AcqRel) < epoch
    }

    /// The highest epoch observed so far.
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }

    /// Look `fp` up; a hit refreshes its recency. An entry from an
    /// older generation is treated as absent and lazily removed.
    pub fn get(&self, fp: &Fingerprint) -> Option<CachedAnswer> {
        if self.shard_cap == 0 {
            return None;
        }
        let generation = self.generation();
        let mut s = self.shard(fp).lock().unwrap();
        let i = *s.map.get(fp)?;
        if s.slots[i].val.epoch != generation {
            s.remove(i);
            return None;
        }
        let val = s.slots[i].val;
        s.detach(i);
        s.push_front(i);
        Some(val)
    }

    /// Insert (or refresh) `fp → val`, evicting least-recently-used
    /// entries past the shard cap. Returns how many entries were
    /// evicted. Values not tagged with the current generation are
    /// dropped (a group that pinned an older view racing a publish)
    /// rather than cached unreachable.
    pub fn insert(&self, fp: Fingerprint, val: CachedAnswer) -> usize {
        if self.shard_cap == 0 || val.epoch != self.generation() {
            return 0;
        }
        let mut s = self.shard(&fp).lock().unwrap();
        if let Some(&i) = s.map.get(&fp) {
            s.slots[i].val = val;
            s.detach(i);
            s.push_front(i);
            return 0;
        }
        let i = match s.free.pop() {
            Some(i) => {
                s.slots[i].fp = fp;
                s.slots[i].val = val;
                i
            }
            None => {
                s.slots.push(Slot {
                    fp,
                    val,
                    prev: NIL,
                    next: NIL,
                });
                s.slots.len() - 1
            }
        };
        s.map.insert(fp, i);
        s.push_front(i);
        let mut evicted = 0;
        while s.map.len() > self.shard_cap {
            let t = s.tail;
            debug_assert_ne!(t, NIL, "cap > 0 and over-full ⇒ non-empty tail");
            s.remove(t);
            evicted += 1;
        }
        evicted
    }

    /// Live entries across all shards (stale-generation entries still
    /// count until lazily removed — they hold real capacity).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    /// Whether no entries are held.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fp(q: f32, epoch: u64) -> Fingerprint {
        Fingerprint {
            query_hash: super::super::fingerprint::hash_query(&[q]),
            kind: EstimatorKind::Exact,
            k: 0,
            l: 0,
            precision: crate::coordinator::backend::Precision::BitExact,
            epoch,
        }
    }

    fn val(z: f64, epoch: u64) -> CachedAnswer {
        CachedAnswer {
            z,
            kind: EstimatorKind::Exact,
            epoch,
            scorings: 7,
        }
    }

    #[test]
    fn hit_returns_exactly_what_was_inserted() {
        let c = ResultCache::new(CacheConfig::default());
        assert_eq!(c.get(&fp(1.0, 0)), None);
        assert_eq!(c.insert(fp(1.0, 0), val(42.5, 0)), 0);
        let hit = c.get(&fp(1.0, 0)).unwrap();
        assert_eq!(hit.z.to_bits(), 42.5f64.to_bits());
        assert_eq!(hit.scorings, 7);
        assert_eq!(c.get(&fp(2.0, 0)), None, "distinct query misses");
    }

    #[test]
    fn lru_evicts_least_recently_used_within_bounds() {
        // One entry per shard * SHARDS total; force everything into a
        // tiny cap so eviction order is observable per shard.
        let c = ResultCache::new(CacheConfig {
            entries: SHARDS, // cap 1 per shard
            bytes: usize::MAX,
        });
        // Find three fingerprints landing on the same shard.
        let mut same: Vec<Fingerprint> = Vec::new();
        let target = fp(0.0, 0).mix() % SHARDS as u64;
        let mut q = 1.0f32;
        same.push(fp(0.0, 0));
        while same.len() < 3 {
            if fp(q, 0).mix() % SHARDS as u64 == target {
                same.push(fp(q, 0));
            }
            q += 1.0;
        }
        assert_eq!(c.insert(same[0], val(1.0, 0)), 0);
        let evicted = c.insert(same[1], val(2.0, 0));
        assert_eq!(evicted, 1, "cap 1: second insert evicts the first");
        assert_eq!(c.get(&same[0]), None);
        assert_eq!(c.get(&same[1]).unwrap().z, 2.0);
        // Refresh keeps the refreshed entry alive.
        assert_eq!(c.insert(same[1], val(2.5, 0)), 0);
        c.insert(same[2], val(3.0, 0));
        assert_eq!(c.get(&same[1]), None);
        assert_eq!(c.get(&same[2]).unwrap().z, 3.0);
    }

    #[test]
    fn byte_bound_caps_like_the_entry_bound() {
        let c = ResultCache::new(CacheConfig {
            entries: usize::MAX,
            bytes: SHARDS * ENTRY_BYTES, // again cap 1 per shard
        });
        assert_eq!(c.shard_cap, 1);
        let zero = CacheConfig {
            entries: 100,
            bytes: 0,
        };
        let disabled = ResultCache::new(zero);
        assert_eq!(disabled.insert(fp(1.0, 0), val(1.0, 0)), 0);
        assert_eq!(disabled.get(&fp(1.0, 0)), None, "bytes=0 disables");
        assert!(disabled.is_empty());
    }

    #[test]
    fn generation_advance_invalidates_without_a_sweep() {
        let c = ResultCache::new(CacheConfig::default());
        c.insert(fp(1.0, 0), val(1.0, 0));
        c.insert(fp(2.0, 0), val(2.0, 0));
        assert_eq!(c.len(), 2);
        assert!(c.advance_generation(1), "first observation advances");
        assert!(!c.advance_generation(1), "repeat observation does not");
        assert!(!c.advance_generation(0), "older epochs never regress");
        assert_eq!(c.generation(), 1);
        // Old-epoch fingerprints are dead (and lazily removed on touch).
        assert_eq!(c.get(&fp(1.0, 0)), None);
        assert_eq!(c.len(), 1, "touched stale entry was removed");
        // Inserts tagged with a stale epoch are refused.
        assert_eq!(c.insert(fp(3.0, 0), val(3.0, 0)), 0);
        assert_eq!(c.get(&fp(3.0, 0)), None);
        // The new generation caches normally.
        c.insert(fp(1.0, 1), val(10.0, 1));
        assert_eq!(c.get(&fp(1.0, 1)).unwrap().z, 10.0);
    }
}
