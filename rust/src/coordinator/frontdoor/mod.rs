//! The service **front door**: the performance layer between
//! [`PartitionService::submit`](crate::coordinator::PartitionService::submit)
//! and the dynamic batcher, over any
//! [`PartitionBackend`](crate::coordinator::PartitionBackend).
//!
//! Every estimator in this crate is deterministic per epoch under a
//! fixed seed, so a result cached under its serving epoch is **bit
//! exact** — not a stale approximation — until the next category
//! publish. The front door exploits that in three pieces, applied in
//! order at submit time (after dimension/budget validation):
//!
//! 1. **[`fingerprint`]** — the canonical request identity
//!    `(query-hash over f32 bit patterns, kind, k, l, precision,
//!    epoch)`, with budgets the kind ignores canonicalized away.
//! 2. **[`cache`]** — a bounded, sharded LRU over fingerprints
//!    (capacity in entries *and* bytes). Hits are answered
//!    synchronously from `submit` without ever enqueueing; a publish
//!    invalidates the previous epoch in O(1) via a generation tag, no
//!    sweep.
//! 3. **[`coalesce`]** — single-flight execution: concurrent identical
//!    requests behind one in-flight leader cost one batcher slot and
//!    one backend call (one cluster scatter), with per-follower
//!    deadlines and leader errors propagated without poisoning the
//!    cache.
//!
//! Front-door traffic is accounted in
//! [`MetricsSnapshot`](crate::coordinator::MetricsSnapshot):
//! `cache_hits` / `cache_misses` / `coalesced` / `cache_evictions` /
//! `cache_invalidations`. Hits and coalesced followers still count as
//! `submitted` and `completed` — they are answered requests; the
//! counters above explain *how cheaply*.

pub mod cache;
pub mod coalesce;
pub mod fingerprint;

pub use cache::{CacheConfig, CachedAnswer, ResultCache, ENTRY_BYTES};
pub use fingerprint::Fingerprint;

use super::metrics::ServiceMetrics;
use super::service::Response;
use coalesce::{Coalescer, Role};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// What the front door decided about one submitted request.
pub enum Admission {
    /// Served synchronously from the result cache: deliver this
    /// response on the reply channel and return — nothing enqueues.
    Hit(Response),
    /// Subscribed to an identical in-flight request — nothing to
    /// enqueue; the leader's completion will answer it.
    Coalesced,
    /// The request must be enqueued. `Some(fp)` when it leads the
    /// flight for its fingerprint (its completion/abandonment settles
    /// the followers); `None` for an independent duplicate that owns
    /// no in-flight slot (it outlives the current leader's deadline).
    Lead(Option<Fingerprint>),
}

/// The assembled front door (cache + coalescer). One per service,
/// shared by the submit path, the batcher's deadline sweep, and the
/// worker completion path.
pub struct FrontDoor {
    cache: ResultCache,
    coalescer: Coalescer,
}

impl FrontDoor {
    /// Build with the given cache capacities (a zero capacity disables
    /// caching; coalescing is always on).
    pub fn new(cfg: CacheConfig) -> FrontDoor {
        FrontDoor {
            cache: ResultCache::new(cfg),
            coalescer: Coalescer::new(),
        }
    }

    fn hit_response(a: CachedAnswer) -> Response {
        Response {
            z: a.z,
            kind: a.kind,
            epoch: a.epoch,
            queue_wait: Duration::ZERO,
            exec_time: Duration::ZERO,
            scorings: a.scorings,
            served_from_cache: true,
        }
    }

    /// Classify one validated request. Cache probe first; on a miss,
    /// join the in-flight table (re-probing the cache under the table
    /// lock, so a completion racing this submit cannot slip between
    /// the two checks). Ticks the hit/miss/coalesced counters.
    pub fn admit(
        &self,
        fp: Fingerprint,
        tx: &mpsc::Sender<Response>,
        deadline: Option<Instant>,
        metrics: &ServiceMetrics,
    ) -> Admission {
        if let Some(a) = self.cache.get(&fp) {
            metrics.on_cache_hit();
            return Admission::Hit(Self::hit_response(a));
        }
        match self
            .coalescer
            .join(fp, tx, deadline, || self.cache.get(&fp))
        {
            Err(a) => {
                metrics.on_cache_hit();
                Admission::Hit(Self::hit_response(a))
            }
            Ok(Role::Follow) => {
                metrics.on_coalesced();
                Admission::Coalesced
            }
            Ok(Role::Lead) => {
                metrics.on_cache_miss();
                Admission::Lead(Some(fp))
            }
            Ok(Role::IndependentDuplicate) => {
                metrics.on_cache_miss();
                Admission::Lead(None)
            }
        }
    }

    /// A leader completed with `resp`: fill the cache (unless the
    /// answering view raced past the fingerprint's epoch) and fan the
    /// answer out to the followers, shedding the individually-expired
    /// ones. Fan-out recipients are counted as completed requests.
    pub fn complete(&self, fp: &Fingerprint, resp: &Response, metrics: &ServiceMetrics) {
        if resp.epoch == fp.epoch {
            let evicted = self.cache.insert(
                *fp,
                CachedAnswer {
                    z: resp.z,
                    kind: resp.kind,
                    epoch: resp.epoch,
                    scorings: resp.scorings,
                },
            );
            if evicted > 0 {
                metrics.on_cache_evictions(evicted as u64);
            }
        }
        let (answered, shed) = self.coalescer.complete(fp, resp);
        if shed > 0 {
            metrics.on_deadline_shed(shed);
        }
        for r in answered {
            metrics.on_complete(r.queue_wait, r.exec_time);
        }
    }

    /// A leader died unanswered (backend error, deadline shed, or an
    /// ingress rejection): drop its followers so they observe the
    /// failure, caching nothing — a failed flight never poisons its
    /// fingerprint.
    pub fn abandon(&self, fp: &Fingerprint, metrics: &ServiceMetrics) {
        let shed = self.coalescer.abandon(fp);
        if shed > 0 {
            metrics.on_deadline_shed(shed);
        }
    }

    /// Observe a serving epoch (submit-time manifest read, a batch
    /// group's answer, or a publish through the service). The first
    /// observation of a new epoch invalidates every earlier-epoch
    /// cache entry in O(1) and ticks `cache_invalidations`.
    pub fn observe_epoch(&self, epoch: u64, metrics: &ServiceMetrics) {
        if self.cache.advance_generation(epoch) {
            metrics.on_cache_invalidation();
        }
    }

    /// Live cached entries (tests/introspection).
    pub fn cached_entries(&self) -> usize {
        self.cache.len()
    }

    /// In-flight coalescing slots (tests/introspection).
    pub fn inflight_len(&self) -> usize {
        self.coalescer.len()
    }
}
