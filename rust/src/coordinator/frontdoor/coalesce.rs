//! Single-flight coalescing: N in-flight identical requests cost one
//! batcher slot and one backend execution.
//!
//! The first request for a fingerprint becomes the **leader** and is
//! enqueued normally; every later identical request while the leader
//! is in flight becomes a **follower** — it never touches the queue,
//! it just subscribes its reply sender to the leader's completion.
//! When the leader's batch group completes, the answer fans out to
//! every follower (each gets its own `queue_wait`, measured from its
//! own subscription). Deadlines stay per-follower: a follower whose
//! deadline expired before the leader completed is shed individually
//! (its sender dropped, `deadline_shed` counted) instead of receiving
//! a late answer.
//!
//! Failure semantics:
//!
//! * A **leader error** (backend failure) drops every follower's
//!   sender — they observe the same `Closed` the leader does — and
//!   caches nothing, so one failure never poisons the fingerprint.
//! * A leader **shed** (deadline expired while queued, or the ingress
//!   rejected the enqueue) abandons the flight the same way; followers
//!   map the dropped channel through their own deadline exactly like
//!   direct submitters.
//! * A follower whose deadline **outlives** the leader's is not
//!   coalesced (the leader might be shed before answering it): it runs
//!   as an independent duplicate instead, without replacing the
//!   in-flight slot — whichever execution completes first answers the
//!   subscribed followers.

use super::fingerprint::Fingerprint;
use crate::coordinator::service::Response;
use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::{mpsc, Mutex};
use std::time::Instant;

/// One subscribed follower.
struct Subscriber {
    tx: mpsc::Sender<Response>,
    deadline: Option<Instant>,
    subscribed: Instant,
}

/// One in-flight fingerprint: the leader's deadline (for the
/// outlives check) plus everyone waiting on its completion.
struct InFlight {
    leader_deadline: Option<Instant>,
    followers: Vec<Subscriber>,
}

/// How [`Coalescer::join`] classified a request.
pub(crate) enum Role {
    /// Subscribed to an in-flight leader; do not enqueue.
    Follow,
    /// No flight existed — the caller is now the leader and owns the
    /// in-flight slot (must `complete` or `abandon` it).
    Lead,
    /// A flight exists but this request outlives its leader: enqueue
    /// it as an independent duplicate that owns no slot.
    IndependentDuplicate,
}

/// The in-flight request table. All methods are `&self` behind one
/// mutex — every operation is a short map touch; the fan-out sends
/// happen after the lock is released.
pub(crate) struct Coalescer {
    inflight: Mutex<HashMap<Fingerprint, InFlight>>,
}

/// `candidate` can still need an answer after `leader` has given up.
fn outlives(candidate: Option<Instant>, leader: Option<Instant>) -> bool {
    match (candidate, leader) {
        (_, None) => false,
        (None, Some(_)) => true,
        (Some(c), Some(l)) => c > l,
    }
}

impl Coalescer {
    pub(crate) fn new() -> Coalescer {
        Coalescer {
            inflight: Mutex::new(HashMap::new()),
        }
    }

    /// Join the flight for `fp`: subscribe behind an existing leader,
    /// or claim leadership. `miss_recheck` runs under the table lock
    /// when no flight exists — the front door re-probes the cache
    /// there, closing the race where a completion (cache fill, then
    /// slot removal) lands between the caller's cache miss and this
    /// call; `Some(answer)` short-circuits the join.
    pub(crate) fn join<F, T>(
        &self,
        fp: Fingerprint,
        tx: &mpsc::Sender<Response>,
        deadline: Option<Instant>,
        miss_recheck: F,
    ) -> Result<Role, T>
    where
        F: FnOnce() -> Option<T>,
    {
        let mut table = self.inflight.lock().unwrap();
        match table.entry(fp) {
            Entry::Occupied(mut e) => {
                let flight = e.get_mut();
                if outlives(deadline, flight.leader_deadline) {
                    return Ok(Role::IndependentDuplicate);
                }
                flight.followers.push(Subscriber {
                    tx: tx.clone(),
                    deadline,
                    subscribed: Instant::now(),
                });
                Ok(Role::Follow)
            }
            Entry::Vacant(v) => {
                if let Some(hit) = miss_recheck() {
                    return Err(hit);
                }
                v.insert(InFlight {
                    leader_deadline: deadline,
                    followers: Vec::new(),
                });
                Ok(Role::Lead)
            }
        }
    }

    /// Take the flight for `fp` down (leader completed or died),
    /// returning the followers to answer/drop. `None` when no flight
    /// was registered (an independent duplicate finishing second).
    fn take(&self, fp: &Fingerprint) -> Option<Vec<Subscriber>> {
        self.inflight
            .lock()
            .unwrap()
            .remove(fp)
            .map(|f| f.followers)
    }

    /// Fan a completed leader's `resp` out to every follower whose
    /// deadline still stands. Returns `(answered, shed)` follower
    /// counts; each answered follower reports its own queue wait and
    /// the leader group's shared execution time.
    pub(crate) fn complete(&self, fp: &Fingerprint, resp: &Response) -> (Vec<Response>, usize) {
        let Some(followers) = self.take(fp) else {
            return (Vec::new(), 0);
        };
        let now = Instant::now();
        let mut shed = 0;
        let mut answered = Vec::new();
        for sub in followers {
            if sub.deadline.is_some_and(|d| now >= d) {
                shed += 1; // sender dropped: follower sees DeadlineExceeded
                continue;
            }
            let fanned = Response {
                queue_wait: now.duration_since(sub.subscribed),
                ..resp.clone()
            };
            if sub.tx.send(fanned.clone()).is_ok() {
                answered.push(fanned);
            }
        }
        (answered, shed)
    }

    /// Drop the flight without an answer (leader error or shed): every
    /// follower's sender is dropped, propagating the failure without
    /// caching anything. Returns how many dropped followers had
    /// already-expired deadlines (counted as deadline sheds).
    pub(crate) fn abandon(&self, fp: &Fingerprint) -> usize {
        let Some(followers) = self.take(fp) else {
            return 0;
        };
        let now = Instant::now();
        followers
            .iter()
            .filter(|s| s.deadline.is_some_and(|d| now >= d))
            .count()
    }

    /// In-flight fingerprints (tests/metrics).
    pub(crate) fn len(&self) -> usize {
        self.inflight.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimators::EstimatorKind;
    use std::time::Duration;

    fn fp() -> Fingerprint {
        Fingerprint {
            query_hash: 9,
            kind: EstimatorKind::Exact,
            k: 0,
            l: 0,
            precision: crate::coordinator::backend::Precision::BitExact,
            epoch: 0,
        }
    }

    fn resp(z: f64) -> Response {
        Response {
            z,
            kind: EstimatorKind::Exact,
            epoch: 0,
            queue_wait: Duration::ZERO,
            exec_time: Duration::from_micros(5),
            scorings: 3,
            served_from_cache: false,
        }
    }

    #[test]
    fn leader_then_followers_then_fanout() {
        let c = Coalescer::new();
        let (ltx, _lrx) = mpsc::channel();
        assert!(matches!(
            c.join(fp(), &ltx, None, || None::<Response>),
            Ok(Role::Lead)
        ));
        let (ftx, frx) = mpsc::channel();
        assert!(matches!(
            c.join(fp(), &ftx, None, || None::<Response>),
            Ok(Role::Follow)
        ));
        assert_eq!(c.len(), 1);
        let (answered, shed) = c.complete(&fp(), &resp(4.5));
        assert_eq!((answered.len(), shed), (1, 0));
        let got = frx.recv().unwrap();
        assert_eq!(got.z.to_bits(), 4.5f64.to_bits());
        assert_eq!(c.len(), 0);
        // Completing again (independent duplicate) is a quiet no-op.
        assert_eq!(c.complete(&fp(), &resp(4.5)).0.len(), 0);
    }

    #[test]
    fn expired_follower_is_shed_individually() {
        let c = Coalescer::new();
        let (ltx, _lrx) = mpsc::channel();
        let leader_dl = Some(Instant::now() + Duration::from_secs(60));
        c.join(fp(), &ltx, leader_dl, || None::<Response>).ok();
        let (ftx, frx) = mpsc::channel();
        // Expired (relative to fan-out time) but earlier than the
        // leader's deadline, so it coalesces rather than duplicating.
        c.join(fp(), &ftx, Some(Instant::now()), || None::<Response>)
            .ok();
        drop(ftx);
        std::thread::sleep(Duration::from_millis(2));
        let (answered, shed) = c.complete(&fp(), &resp(1.0));
        assert_eq!((answered.len(), shed), (0, 1));
        assert!(frx.recv().is_err(), "shed follower's channel is dropped");
    }

    #[test]
    fn outliving_deadline_becomes_independent_duplicate() {
        let c = Coalescer::new();
        let (ltx, _lrx) = mpsc::channel();
        let soon = Some(Instant::now() + Duration::from_millis(1));
        c.join(fp(), &ltx, soon, || None::<Response>).ok();
        let (dtx, _drx) = mpsc::channel();
        assert!(matches!(
            c.join(fp(), &dtx, None, || None::<Response>),
            Ok(Role::IndependentDuplicate)
        ));
        assert_eq!(c.len(), 1, "duplicate owns no slot");
    }

    #[test]
    fn abandon_drops_followers_without_poisoning() {
        let c = Coalescer::new();
        let (ltx, _lrx) = mpsc::channel();
        c.join(fp(), &ltx, None, || None::<Response>).ok();
        let (ftx, frx) = mpsc::channel();
        c.join(fp(), &ftx, None, || None::<Response>).ok();
        drop(ftx);
        assert_eq!(c.abandon(&fp()), 0);
        assert!(frx.recv().is_err(), "follower observes the failure");
        // The fingerprint is immediately usable again.
        let (t2, _r2) = mpsc::channel();
        assert!(matches!(
            c.join(fp(), &t2, None, || None::<Response>),
            Ok(Role::Lead)
        ));
    }

    #[test]
    fn miss_recheck_short_circuits_under_the_lock() {
        let c = Coalescer::new();
        let (tx, _rx) = mpsc::channel();
        let got = c.join(fp(), &tx, None, || Some(resp(7.0)));
        match got {
            Err(r) => assert_eq!(r.z, 7.0),
            Ok(_) => panic!("recheck hit must short-circuit"),
        }
        assert_eq!(c.len(), 0, "no slot registered on a recheck hit");
    }
}
