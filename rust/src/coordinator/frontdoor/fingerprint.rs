//! Canonical request fingerprints: the identity under which the front
//! door caches and coalesces.
//!
//! A fingerprint is `(query-hash over f32 bit patterns, kind, k, l,
//! precision, epoch)`. Two requests with equal fingerprints are served
//! interchangeably:
//!
//! * The query is folded in by **bit pattern** (`f32::to_bits`), not by
//!   float comparison — `0.0` and `-0.0` fingerprint differently, NaNs
//!   fingerprint stably, and the hash is exactly reproducible across
//!   platforms.
//! * `k`/`l` are **canonicalized per kind** before hashing: `Exact` and
//!   `Fmbe` ignore both budgets, `Uniform` only reads `l`, `Nmimps`
//!   only reads `k` — so e.g. `Exact` requests with stray `k` values
//!   all land on one cache line instead of fragmenting the hit space.
//! * The **epoch** is baked into the identity, which is what makes
//!   cache hits exact rather than stale: a publish changes the epoch,
//!   every new fingerprint changes with it, and nothing cached under
//!   the previous epoch can match again (see
//!   [`super::cache::ResultCache`] for the eager half of that
//!   invalidation).
//!
//! The query itself is *not* stored anywhere — the 64-bit FNV-1a hash
//! stands in for it, exactly as the fingerprint is specified. A hash
//! collision between two distinct queries would alias their cache
//! slots; at 64 bits that requires on the order of 2³² distinct live
//! queries before birthday collisions become plausible, far beyond the
//! cache capacities the front door is configured with.

use crate::coordinator::backend::Precision;
use crate::coordinator::service::EstimateSpec;
use crate::estimators::EstimatorKind;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over the little-endian bytes of each component's f32 bit
/// pattern, length included (so a prefix never hashes equal to the
/// full vector).
pub fn hash_query(q: &[f32]) -> u64 {
    let mut h = FNV_OFFSET;
    for b in (q.len() as u64).to_le_bytes() {
        h = (h ^ u64::from(b)).wrapping_mul(FNV_PRIME);
    }
    for x in q {
        for b in x.to_bits().to_le_bytes() {
            h = (h ^ u64::from(b)).wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// The canonical identity of a request for caching/coalescing: equal
/// fingerprints ⇒ interchangeable answers (within the fingerprint's
/// epoch; see the module docs for the query-hash collision caveat).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Fingerprint {
    /// [`hash_query`] of the query's f32 bit patterns.
    pub query_hash: u64,
    /// Estimator kind answering the request.
    pub kind: EstimatorKind,
    /// Head budget, canonicalized to 0 for kinds that ignore it.
    pub k: usize,
    /// Tail budget, canonicalized to 0 for kinds that ignore it.
    pub l: usize,
    /// `Exact` precision mode (kept for all kinds: a future pipelined
    /// sampler mode must not alias today's bit-exact answers).
    pub precision: Precision,
    /// The serving epoch observed at submit. Publishes advance it, so
    /// stale entries can never match a fresh fingerprint.
    pub epoch: u64,
}

impl Fingerprint {
    /// Fingerprint `spec` as served at `epoch`, canonicalizing the
    /// budgets the spec's kind does not read.
    pub fn of(spec: &EstimateSpec, epoch: u64) -> Fingerprint {
        let (k, l) = match spec.kind {
            EstimatorKind::Exact | EstimatorKind::Fmbe => (0, 0),
            EstimatorKind::Uniform => (0, spec.l),
            EstimatorKind::Nmimps => (spec.k, 0),
            EstimatorKind::Mimps | EstimatorKind::Mince => (spec.k, spec.l),
        };
        Fingerprint {
            query_hash: hash_query(&spec.query),
            kind: spec.kind,
            k,
            l,
            precision: spec.precision,
            epoch,
        }
    }

    /// A well-mixed 64-bit digest of every field, used by the sharded
    /// cache to pick a shard (the raw `query_hash` alone would send all
    /// kinds/budgets of one query to one shard).
    pub(crate) fn mix(&self) -> u64 {
        let mut h = self.query_hash ^ FNV_OFFSET;
        for word in [
            self.kind as u64,
            self.k as u64,
            self.l as u64,
            match self.precision {
                Precision::BitExact => 0,
                Precision::Pipelined => 1,
            },
            self.epoch,
        ] {
            for b in word.to_le_bytes() {
                h = (h ^ u64::from(b)).wrapping_mul(FNV_PRIME);
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(kind: EstimatorKind, k: usize, l: usize) -> EstimateSpec {
        EstimateSpec::new(vec![1.0, -2.5, 3.25]).kind(kind).k(k).l(l)
    }

    #[test]
    fn query_hash_is_bit_pattern_sensitive() {
        assert_ne!(hash_query(&[0.0]), hash_query(&[-0.0]));
        assert_ne!(hash_query(&[1.0, 2.0]), hash_query(&[2.0, 1.0]));
        assert_ne!(hash_query(&[1.0]), hash_query(&[1.0, 0.0]));
        assert_eq!(hash_query(&[1.5, -7.0]), hash_query(&[1.5, -7.0]));
    }

    #[test]
    fn budgets_canonicalized_per_kind() {
        // Exact ignores both budgets: stray values collapse.
        assert_eq!(
            Fingerprint::of(&spec(EstimatorKind::Exact, 10, 20), 0),
            Fingerprint::of(&spec(EstimatorKind::Exact, 0, 0), 0)
        );
        // Uniform reads only l.
        assert_eq!(
            Fingerprint::of(&spec(EstimatorKind::Uniform, 99, 20), 0),
            Fingerprint::of(&spec(EstimatorKind::Uniform, 0, 20), 0)
        );
        assert_ne!(
            Fingerprint::of(&spec(EstimatorKind::Uniform, 0, 20), 0),
            Fingerprint::of(&spec(EstimatorKind::Uniform, 0, 21), 0)
        );
        // Mimps reads both.
        assert_ne!(
            Fingerprint::of(&spec(EstimatorKind::Mimps, 10, 20), 0),
            Fingerprint::of(&spec(EstimatorKind::Mimps, 11, 20), 0)
        );
    }

    #[test]
    fn epoch_and_precision_separate_fingerprints() {
        let s = spec(EstimatorKind::Exact, 0, 0);
        assert_ne!(Fingerprint::of(&s, 0), Fingerprint::of(&s, 1));
        let p = s.clone().precision(Precision::Pipelined);
        assert_ne!(Fingerprint::of(&s, 0), Fingerprint::of(&p, 0));
        assert_ne!(Fingerprint::of(&s, 0).mix(), Fingerprint::of(&s, 1).mix());
    }
}
