//! `zest` CLI — the leader entrypoint: dataset generation, index
//! exploration, single estimates, the serving demo, LBL training, and
//! one subcommand per paper table/figure.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use zest::config::Config;
use zest::data::embeddings::EmbeddingStore;
use zest::data::synth::{generate, SynthConfig};
use zest::util::cli::Args;
use zest::util::json::Json;

fn main() {
    zest::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn usage() -> String {
    let mut s = String::from(
        "zest — sublinear partition estimation (Rastogi & Van Durme 2015)\n\n\
         USAGE: zest <command> [flags]\n\nCOMMANDS:\n",
    );
    for (name, about) in [
        ("gen-data", "generate + cache the synthetic embedding set"),
        ("estimate", "estimate Z(q) for one query with every estimator"),
        ("classify", "argmax class + estimated probability (paper eq. 2-3)"),
        ("recall", "recall@k report for the MIPS indexes"),
        ("serve", "run the batching service demo and print metrics"),
        ("train-lm", "train the LBL language model via the PJRT artifact"),
        ("figure1", "reproduce Figure 1 (CDF of sorted contributions)"),
        ("table1", "reproduce Table 1 (error vs k, l grid)"),
        ("table2", "reproduce Table 2 (query-noise sweep)"),
        ("table3", "reproduce Table 3 (retrieval-error injection)"),
        ("table4", "reproduce Table 4 (LBL end-to-end)"),
        ("ablations", "solver / index / probe-budget ablations"),
    ] {
        s.push_str(&format!("  {name:<10} {about}\n"));
    }
    s.push_str("\nCommon flags: --n --d --seed --seeds --queries --k --l --threads --out-dir --config <json>\n");
    s
}

fn run(argv: Vec<String>) -> Result<()> {
    let Some(cmd) = argv.first().cloned() else {
        print!("{}", usage());
        return Ok(());
    };
    let args = Args::parse(argv[1..].to_vec()).map_err(|e| anyhow::anyhow!(e))?;
    if args.get_bool("help") {
        print!("{}", usage());
        return Ok(());
    }
    let cfg = base_config(&args)?;
    match cmd.as_str() {
        "gen-data" => cmd_gen_data(&cfg, &args),
        "estimate" => cmd_estimate(&cfg, &args),
        "classify" => cmd_classify(&cfg, &args),
        "recall" => cmd_recall(&cfg, &args),
        "serve" => cmd_serve(&cfg, &args),
        "train-lm" => cmd_train_lm(&cfg, &args),
        "figure1" => cmd_figure1(&cfg, &args),
        "table1" => cmd_table1(&cfg, &args),
        "table2" => cmd_table2(&cfg, &args),
        "table3" => cmd_table3(&cfg, &args),
        "table4" => cmd_table4(&cfg, &args),
        "ablations" => cmd_ablations(&cfg, &args),
        "help" | "--help" | "-h" => {
            print!("{}", usage());
            Ok(())
        }
        other => bail!("unknown command {other:?}\n\n{}", usage()),
    }
}

fn base_config(args: &Args) -> Result<Config> {
    let cfg = match args.get("config") {
        Some(path) => Config::from_json_file(Path::new(path))?,
        None => Config::default(),
    };
    cfg.apply_args(args).map_err(Into::into)
}

/// Generate (or load the cached copy of) the synthetic embedding set.
fn load_store(cfg: &Config) -> Result<EmbeddingStore> {
    let dir = PathBuf::from(&cfg.out_dir);
    std::fs::create_dir_all(&dir).ok();
    let cache = dir.join(format!("emb_n{}_d{}_s{}.bin", cfg.n, cfg.d, cfg.seed));
    if cache.exists() {
        log::info!("loading cached embeddings from {cache:?}");
        return EmbeddingStore::load(&cache);
    }
    log::info!("generating synthetic embeddings N={} d={}", cfg.n, cfg.d);
    let store = generate(&synth_cfg(cfg));
    store.save(&cache).context("cache embeddings")?;
    Ok(store)
}

fn synth_cfg(cfg: &Config) -> SynthConfig {
    SynthConfig {
        n: cfg.n,
        d: cfg.d,
        seed: cfg.seed,
        ..Default::default()
    }
}

fn write_result(cfg: &Config, name: &str, json: &Json) -> Result<()> {
    let dir = PathBuf::from(&cfg.out_dir);
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, json.to_string())?;
    println!("(result written to {})", path.display());
    Ok(())
}

fn cmd_gen_data(cfg: &Config, _args: &Args) -> Result<()> {
    let store = load_store(cfg)?;
    let norms = store.norms();
    println!(
        "N={} d={} norm[min,max]=[{:.2},{:.2}]",
        store.len(),
        store.dim(),
        norms.iter().copied().fold(f32::INFINITY, f32::min),
        norms.iter().copied().fold(0f32, f32::max),
    );
    Ok(())
}

fn cmd_estimate(cfg: &Config, args: &Args) -> Result<()> {
    use zest::estimators::{EstimateContext, Estimator};
    let store = load_store(cfg)?;
    let qi: usize = args.get_or("query-index", store.len() - 1);
    let q = store.row(qi).to_vec();
    let brute = zest::mips::brute::BruteIndex::new(&store);
    let z_true = brute.partition(&q);
    println!("query index {qi}: true Z = {z_true:.4}\n");
    let mut rng = zest::util::rng::Rng::seeded(cfg.seed);
    let mut table = zest::bench::harness::Table::new(&["estimator", "Z-hat", "err %", "scorings"]);
    let ests: Vec<Box<dyn Estimator>> = vec![
        Box::new(zest::estimators::uniform::Uniform::new(cfg.l)),
        Box::new(zest::estimators::nmimps::Nmimps::new(cfg.k)),
        Box::new(zest::estimators::mimps::Mimps::new(cfg.k, cfg.l)),
        Box::new(zest::estimators::mince::Mince::new(cfg.k, cfg.l)),
    ];
    for est in ests {
        let mut ctx = EstimateContext::new(&store, &brute, &mut rng);
        let z = est.estimate(&mut ctx, &q);
        table.row(vec![
            est.name(),
            format!("{z:.4}"),
            format!("{:.2}", zest::metrics::abs_rel_err_pct(z, z_true)),
            est.scorings(store.len()).to_string(),
        ]);
    }
    table.print();
    Ok(())
}

fn cmd_classify(cfg: &Config, args: &Args) -> Result<()> {
    use zest::estimators::{probability, EstimateContext};
    let store = load_store(cfg)?;
    let qi: usize = args.get_or("query-index", store.len() - 1);
    let q = store.row(qi).to_vec();
    let tree = zest::mips::kmeans_tree::KMeansTreeIndex::build(&store, Default::default());
    let mut rng = zest::util::rng::Rng::seeded(cfg.seed);
    let mut ctx = EstimateContext::new(&store, &tree, &mut rng);
    let r = probability::classify_with_probability(&mut ctx, &q, cfg.k, cfg.l)
        .context("empty store")?;
    println!(
        "query {qi}: class={} score={:.4} p̂={:.6} (Ẑ={:.4}, {} head items)",
        r.class, r.score, r.p, r.z_hat, r.head_len
    );
    let dist = probability::head_distribution(&mut ctx, &q, cfg.k, cfg.l, 10);
    println!("top-10 head distribution:");
    for (c, p) in dist {
        println!("  class {c:>8}  p̂ {p:.6}");
    }
    Ok(())
}

fn cmd_recall(cfg: &Config, args: &Args) -> Result<()> {
    let store = load_store(cfg)?;
    let queries: usize = args.get_or("recall-queries", 50);
    let rows = zest::experiments::ablations::index_ablation(&store, queries, cfg.seed);
    let mut t = zest::bench::harness::Table::new(&[
        "index", "recall@10", "top1", "mean probes", "build ms",
    ]);
    for r in &rows {
        t.row(vec![
            r.name.clone(),
            format!("{:.3}", r.recall_at_10),
            format!("{:.3}", r.top1_recall),
            format!("{:.0}", r.mean_probes),
            format!("{}", r.build_wall.as_millis()),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_serve(cfg: &Config, args: &Args) -> Result<()> {
    use std::sync::Arc;
    use zest::coordinator::*;
    use zest::estimators::EstimatorKind;
    let store = Arc::new(load_store(cfg)?);
    let requests: usize = args.get_or("requests", 500);
    let index: Arc<dyn zest::mips::MipsIndex> = Arc::new(
        zest::mips::kmeans_tree::KMeansTreeIndex::build(&store, Default::default()),
    );
    let svc = PartitionService::start(
        store.clone(),
        index,
        Router::new(zest::estimators::fmbe::FmbeConfig {
            p_features: cfg.fmbe_p.min(2000),
            ..Default::default()
        }),
        ServiceConfig::default(),
        None,
    );
    let t0 = std::time::Instant::now();
    let mut rng = zest::util::rng::Rng::seeded(cfg.seed);
    let rxs: Vec<_> = (0..requests)
        .map(|_| {
            let qi = rng.below(store.len());
            svc.submit(
                EstimateSpec::new(store.row(qi).to_vec())
                    .kind(EstimatorKind::Mimps)
                    .k(cfg.k)
                    .l(cfg.l),
            )
            .expect("submit")
        })
        .collect();
    for rx in rxs {
        rx.recv().unwrap();
    }
    let wall = t0.elapsed();
    println!(
        "{requests} requests in {wall:?} ({:.0} req/s)",
        requests as f64 / wall.as_secs_f64()
    );
    println!("{}", svc.metrics());
    svc.shutdown();
    Ok(())
}

fn cmd_train_lm(cfg: &Config, args: &Args) -> Result<()> {
    let dir = PathBuf::from(&cfg.artifacts_dir);
    let meta = zest::runtime::ArtifactsMeta::load(&dir)?;
    let lbl = zest::lm::LblConfig {
        vocab: meta.config_usize("vocab").context("meta vocab")?,
        d: meta.config_usize("lbl_d").context("meta lbl_d")?,
        ctx: meta.config_usize("ctx").context("meta ctx")?,
        seed: cfg.seed,
    };
    let nce = zest::lm::NceConfig {
        batch: meta.config_usize("lbl_batch").context("meta lbl_batch")?,
        noise_k: meta.config_usize("noise_k").context("meta noise_k")?,
        lr: args.get_or("lr", 0.3f32),
    };
    let steps: usize = args.get_or("steps", 600);
    let corpus = zest::data::corpus::generate(&zest::data::corpus::CorpusConfig {
        vocab: lbl.vocab,
        seed: cfg.seed,
        ..Default::default()
    });
    let (rt, join) = zest::runtime::spawn_runtime_thread(
        dir.clone(),
        Some(vec!["lbl_nce_step".to_string()]),
    )?;
    let (_params, report) = zest::lm::train(&corpus, lbl, nce, steps, &rt, &dir)?;
    println!(
        "trained {} steps in {:?}; loss {:.4} -> {:.4}",
        report.steps,
        report.wall,
        report.loss_curve.first().map(|x| x.1).unwrap_or(f64::NAN),
        report.final_loss
    );
    for (s, l) in &report.loss_curve {
        println!("  step {s:>6}  loss {l:.4}");
    }
    rt.shutdown();
    join.join().ok();
    Ok(())
}

fn cmd_figure1(cfg: &Config, _args: &Args) -> Result<()> {
    let store = load_store(cfg)?;
    let curves = zest::experiments::figure1::run(&store, &synth_cfg(cfg), cfg.threads);
    let mut t = zest::bench::harness::Table::new(&[
        "rank", "corpus freq", "n@50%", "n@80%", "n@90%", "n80 / N",
    ]);
    for c in &curves {
        t.row(vec![
            c.rank.to_string(),
            c.corpus_freq.to_string(),
            c.n50.to_string(),
            c.n80.to_string(),
            c.n90.to_string(),
            format!("{:.3}", c.n80 as f64 / store.len() as f64),
        ]);
    }
    t.print();
    write_result(cfg, "figure1", &zest::experiments::figure1::to_json(&curves))
}

fn cmd_table1(cfg: &Config, args: &Args) -> Result<()> {
    let store = load_store(cfg)?;
    let fmbe_ds = args.get_list::<usize>("fmbe-ds", &[10_000, 50_000]);
    let t = zest::experiments::table1::run(&store, cfg, &fmbe_ds);
    print!("{}", zest::experiments::table1::render(&t));
    write_result(cfg, "table1", &zest::experiments::table1::to_json(&t))
}

fn cmd_table2(cfg: &Config, args: &Args) -> Result<()> {
    let store = load_store(cfg)?;
    let fmbe_d: usize = args.get_or("fmbe-d", 50_000);
    let t = zest::experiments::table2::run(&store, cfg, fmbe_d);
    print!("{}", zest::experiments::table2::render(&t));
    write_result(cfg, "table2", &zest::experiments::table2::to_json(&t))
}

fn cmd_table3(cfg: &Config, _args: &Args) -> Result<()> {
    let store = load_store(cfg)?;
    let t = zest::experiments::table3::run(&store, cfg);
    print!("{}", zest::experiments::table3::render(&t));
    write_result(cfg, "table3", &zest::experiments::table3::to_json(&t))
}

fn cmd_table4(cfg: &Config, args: &Args) -> Result<()> {
    use zest::experiments::table4::*;
    let dir = PathBuf::from(&cfg.artifacts_dir);
    let meta = zest::runtime::ArtifactsMeta::load(&dir)?;
    let mut t4 = Table4Config {
        lbl: zest::lm::LblConfig {
            vocab: meta.config_usize("vocab").context("meta vocab")?,
            d: meta.config_usize("lbl_d").context("meta lbl_d")?,
            ctx: meta.config_usize("ctx").context("meta ctx")?,
            seed: cfg.seed,
        },
        nce: zest::lm::NceConfig {
            batch: meta.config_usize("lbl_batch").context("meta lbl_batch")?,
            noise_k: meta.config_usize("noise_k").context("meta noise_k")?,
            lr: args.get_or("lr", 0.3f32),
        },
        train_steps: args.get_or("steps", 600),
        contexts: args.get_or("contexts", 2000),
        threads: cfg.threads,
        ..Default::default()
    };
    t4.corpus.vocab = t4.lbl.vocab;
    t4.corpus.seed = cfg.seed;
    let (rt, join) = zest::runtime::spawn_runtime_thread(
        dir.clone(),
        Some(vec!["lbl_nce_step".to_string()]),
    )?;
    let t = run_table4(&t4, &rt, &dir)?;
    print!("{}", render(&t));
    rt.shutdown();
    join.join().ok();
    write_result(cfg, "table4", &to_json(&t))
}

use zest::experiments::table4::run as run_table4;

fn cmd_ablations(cfg: &Config, args: &Args) -> Result<()> {
    use zest::experiments::ablations::*;
    let store = load_store(cfg)?;
    let solver = solver_ablation(args.get_or("instances", 200), cfg.k, cfg.l, cfg.seed);
    println!(
        "solver ablation over {} instances: Newton {} iters / {:?}, Halley {} iters / {:?} (max disagreement {:.2e})",
        solver.instances,
        solver.newton_iters,
        solver.newton_wall,
        solver.halley_iters,
        solver.halley_wall,
        solver.max_disagreement
    );
    let index = index_ablation(&store, args.get_or("recall-queries", 30), cfg.seed);
    for r in &index {
        println!(
            "index {:<12} recall@10={:.3} top1={:.3} probes={:.0} build={:?}",
            r.name, r.recall_at_10, r.top1_recall, r.mean_probes, r.build_wall
        );
    }
    let budgets: Vec<usize> = args.get_list("budgets", &[256, 1024, 4096, 16384]);
    let pts = probe_budget_ablation(&store, cfg, &budgets);
    for p in &pts {
        println!("probes={:<8} MIMPS err={:.2}%", p.probes, p.mean_err_pct);
    }
    write_result(cfg, "ablations", &to_json(&solver, &index, &pts))
}
