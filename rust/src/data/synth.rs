//! Synthetic word2vec-like embeddings.
//!
//! The paper's oracle experiments run on the first 100k GoogleNews
//! word2vec vectors (300-d, unnormalized). That dataset is not available
//! here, so this module generates a synthetic embedding set that
//! reproduces the two geometric properties the paper's results depend on
//! (DESIGN.md §Substitutions):
//!
//! 1. **Norm/frequency correlation** — frequent ("common") tokens have
//!    small-norm, weakly clustered vectors, so as queries they induce a
//!    nearly flat `exp(u)` distribution over the vocabulary (paper Fig. 1:
//!    "The" needs ~80k neighbors to cover 80% of Z). Rare tokens have
//!    large-norm, strongly cluster-aligned vectors and induce peaked
//!    distributions (~1k neighbors suffice).
//! 2. **Cluster structure** — tokens live near one of `clusters` topic
//!    centroids, so the top of the inner-product order for a rare query is
//!    populated by its topical neighbors, exactly the structure MIPS
//!    indexes exploit.
//!
//! Token `i` has Zipf rank `i` (0 = most frequent). Its vector is
//! `norm(i) * (align(i) * c_{topic(i)} + sqrt(1-align(i)^2) * ε)` with
//! `ε` a random unit vector, `norm` and `align` increasing in rank.

use crate::data::embeddings::EmbeddingStore;
use crate::util::rng::{Rng, Zipf};

/// Parameters for the synthetic embedding generator.
#[derive(Clone, Debug)]
pub struct SynthConfig {
    /// Vocabulary size N (paper: 100_000).
    pub n: usize,
    /// Dimensionality d (paper: 300).
    pub d: usize,
    /// PRNG seed.
    pub seed: u64,
    /// Number of topic clusters.
    pub clusters: usize,
    /// Vector norm for the most frequent token.
    pub norm_lo: f32,
    /// Vector norm for the rarest token.
    pub norm_hi: f32,
    /// Cluster alignment for the most frequent token (0 = isotropic).
    pub align_lo: f32,
    /// Cluster alignment for the rarest token (→1 = on the centroid).
    pub align_hi: f32,
    /// Zipf exponent for the frequency model.
    pub zipf_s: f64,
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig {
            n: 100_000,
            d: 300,
            seed: 0,
            clusters: 256,
            norm_lo: 0.8,
            norm_hi: 5.0,
            align_lo: 0.05,
            align_hi: 0.9,
            zipf_s: 1.05,
        }
    }
}

impl SynthConfig {
    /// A small configuration for unit tests (fast to generate and score).
    pub fn tiny() -> Self {
        SynthConfig {
            n: 2_000,
            d: 32,
            clusters: 16,
            ..Default::default()
        }
    }
}

/// Rank-interpolation helper: log-spaced ramp from `lo` at rank 0 to `hi`
/// at rank n-1. Log spacing matches the Zipfian intuition that the first
/// few ranks differ the most.
fn ramp(lo: f32, hi: f32, rank: usize, n: usize) -> f32 {
    if n <= 1 {
        return lo;
    }
    let t = ((1 + rank) as f64).ln() / (n as f64).ln();
    lo + (hi - lo) * t as f32
}

/// Generate the synthetic embedding set.
pub fn generate(cfg: &SynthConfig) -> EmbeddingStore {
    let mut rng = Rng::seeded(cfg.seed);
    // Topic centroids: random unit vectors.
    let centers: Vec<Vec<f32>> = (0..cfg.clusters.max(1))
        .map(|_| rng.unit_vec(cfg.d))
        .collect();
    let mut data = vec![0f32; cfg.n * cfg.d];
    for i in 0..cfg.n {
        let topic = rng.below(centers.len());
        let align = ramp(cfg.align_lo, cfg.align_hi, i, cfg.n).clamp(0.0, 0.999);
        let nrm = ramp(cfg.norm_lo, cfg.norm_hi, i, cfg.n);
        let c = &centers[topic];
        // Noise direction orthogonalized against the centroid so the row
        // norm is exactly `nrm` (align² + ortho² = 1 with c ⟂ eps).
        let mut eps = rng.unit_vec(cfg.d);
        let proj = crate::linalg::dot(&eps, c);
        for j in 0..cfg.d {
            eps[j] -= proj * c[j];
        }
        let enorm = crate::linalg::norm(&eps).max(f32::MIN_POSITIVE);
        let ortho = (1.0 - align * align).sqrt() / enorm;
        let row = &mut data[i * cfg.d..(i + 1) * cfg.d];
        for j in 0..cfg.d {
            row[j] = nrm * (align * c[j] + ortho * eps[j]);
        }
    }
    EmbeddingStore::from_data(cfg.n, cfg.d, data).expect("consistent shape by construction")
}

/// The Zipf frequency model associated with a config (token i has rank i).
pub fn frequency_model(cfg: &SynthConfig) -> Zipf {
    Zipf::new(cfg.n, cfg.zipf_s)
}

/// Pseudo corpus frequency for token `i`, scaled to a corpus of
/// `corpus_tokens` tokens — used for Figure 1's legend annotations.
pub fn corpus_frequency(cfg: &SynthConfig, i: usize, corpus_tokens: f64) -> u64 {
    let z = frequency_model(cfg);
    (z.pmf(i) * corpus_tokens) as u64
}

/// Build noisy queries the way the paper does (§5.1): take data vectors and
/// add Gaussian noise with a controlled relative norm
/// (`|noise| / |q| = rel_noise`), so queries deviate from — but stay close
/// to — real category vectors.
pub fn noisy_queries(
    store: &EmbeddingStore,
    indices: &[usize],
    rel_noise: f32,
    rng: &mut Rng,
) -> Vec<Vec<f32>> {
    indices
        .iter()
        .map(|&i| {
            let base = store.row(i);
            if rel_noise <= 0.0 {
                return base.to_vec();
            }
            let target = crate::linalg::norm(base) * rel_noise;
            let dir = rng.unit_vec(store.dim());
            base.iter()
                .zip(&dir)
                .map(|(b, n)| b + target * n)
                .collect()
        })
        .collect()
}

/// Sample query indices: `count` tokens drawn by frequency rank strata so
/// the query set covers common, mid and rare tokens (the paper uses 10k
/// items "from across" the 100k vocabulary).
pub fn stratified_query_indices(n: usize, count: usize, rng: &mut Rng) -> Vec<usize> {
    let count = count.min(n);
    if count == 0 {
        return vec![];
    }
    // Split into `count` equal strata and pick one index per stratum.
    let mut out = Vec::with_capacity(count);
    let stride = n as f64 / count as f64;
    for s in 0..count {
        let lo = (s as f64 * stride) as usize;
        let hi = (((s + 1) as f64) * stride) as usize;
        let hi = hi.max(lo + 1).min(n);
        out.push(rng.range(lo, hi));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg;

    #[test]
    fn shapes_and_determinism() {
        let cfg = SynthConfig::tiny();
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.len(), cfg.n);
        assert_eq!(a.dim(), cfg.d);
        assert_eq!(a, b, "same seed must generate identical data");
        let c = generate(&SynthConfig {
            seed: 1,
            ..SynthConfig::tiny()
        });
        assert_ne!(a, c, "different seed must differ");
    }

    #[test]
    fn norms_increase_with_rank() {
        let cfg = SynthConfig::tiny();
        let s = generate(&cfg);
        let head_norm = linalg::norm(s.row(0));
        let tail_norm = linalg::norm(s.row(cfg.n - 1));
        assert!(
            tail_norm > head_norm * 2.0,
            "rare-token norm {tail_norm} should dominate common-token norm {head_norm}"
        );
        // Endpoints ≈ configured norms.
        assert!((head_norm - cfg.norm_lo).abs() / cfg.norm_lo < 0.05);
        assert!((tail_norm - cfg.norm_hi).abs() / cfg.norm_hi < 0.05);
    }

    /// The property Figure 1 depends on: a common token as query induces a
    /// much flatter distribution than a rare token — measured by how many
    /// top categories are needed to reach 80% of Z.
    #[test]
    fn common_queries_flatter_than_rare() {
        let cfg = SynthConfig::tiny();
        let s = generate(&cfg);
        let need = |qi: usize| -> usize {
            let q = s.row(qi);
            let mut scores = vec![0f32; s.len()];
            linalg::gemv_blocked(s.data(), s.len(), s.dim(), q, &mut scores);
            let mut e: Vec<f64> = scores.iter().map(|&x| (x as f64).exp()).collect();
            e.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let z: f64 = e.iter().sum();
            let mut acc = 0.0;
            for (i, v) in e.iter().enumerate() {
                acc += v;
                if acc >= 0.8 * z {
                    return i + 1;
                }
            }
            e.len()
        };
        let common = need(0);
        let rare = need(cfg.n - 1);
        assert!(
            common > rare * 5,
            "common query should need many more neighbors: common={common} rare={rare}"
        );
    }

    #[test]
    fn noisy_queries_have_requested_relative_norm() {
        let cfg = SynthConfig::tiny();
        let s = generate(&cfg);
        let mut rng = Rng::seeded(7);
        let qs = noisy_queries(&s, &[100, 200], 0.2, &mut rng);
        for (qi, &idx) in [100usize, 200].iter().enumerate() {
            let diff: Vec<f32> = qs[qi]
                .iter()
                .zip(s.row(idx))
                .map(|(a, b)| a - b)
                .collect();
            let rel = linalg::norm(&diff) / linalg::norm(s.row(idx));
            assert!((rel - 0.2).abs() < 1e-4, "rel noise {rel}");
        }
    }

    #[test]
    fn zero_noise_returns_original() {
        let cfg = SynthConfig::tiny();
        let s = generate(&cfg);
        let mut rng = Rng::seeded(7);
        let qs = noisy_queries(&s, &[5], 0.0, &mut rng);
        assert_eq!(qs[0].as_slice(), s.row(5));
    }

    #[test]
    fn stratified_indices_cover_range() {
        let mut rng = Rng::seeded(11);
        let idx = stratified_query_indices(1000, 10, &mut rng);
        assert_eq!(idx.len(), 10);
        assert!(idx[0] < 100);
        assert!(idx[9] >= 900);
        for w in idx.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn corpus_frequency_decreasing() {
        let cfg = SynthConfig::tiny();
        let f0 = corpus_frequency(&cfg, 0, 1e9);
        let f100 = corpus_frequency(&cfg, 100, 1e9);
        assert!(f0 > f100);
    }
}
