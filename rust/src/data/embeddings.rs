//! `EmbeddingStore`: the N×d row-major matrix of category weight vectors
//! `v_i`, with a compact binary on-disk format (magic + dims + raw f32 LE)
//! so experiments can generate once and reuse across benches.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"ZESTEMB1";

/// Row-major dense matrix of `n` category vectors in `R^d`.
#[derive(Clone, Debug, PartialEq)]
pub struct EmbeddingStore {
    n: usize,
    d: usize,
    data: Vec<f32>,
}

impl EmbeddingStore {
    /// Build from raw row-major data.
    pub fn from_data(n: usize, d: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != n * d {
            bail!("data length {} != n*d = {}", data.len(), n * d);
        }
        Ok(EmbeddingStore { n, d, data })
    }

    /// Number of categories N.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Dimensionality d.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// The i-th category vector.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    /// Raw row-major backing data.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// A contiguous block of rows `[lo, hi)` (used by chunked scoring).
    pub fn rows(&self, lo: usize, hi: usize) -> &[f32] {
        &self.data[lo * self.d..hi * self.d]
    }

    /// Restrict to the first `n` rows (the paper uses the first 100k of
    /// 3M). Takes `self` and shrinks the backing `Vec` in place — no
    /// copy of the retained prefix (at `ZEST_SCALE=paper` the old
    /// clone-the-prefix version copied 100k×300 f32s); callers that need
    /// to keep the full store borrow a prefix view through
    /// [`crate::store::StoreView`] instead.
    pub fn truncate(mut self, n: usize) -> EmbeddingStore {
        let n = n.min(self.n);
        self.data.truncate(n * self.d);
        self.n = n;
        self
    }

    /// Per-row L2 norms.
    pub fn norms(&self) -> Vec<f32> {
        (0..self.n)
            .map(|i| crate::linalg::norm(self.row(i)))
            .collect()
    }

    /// Serialize to the binary format.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("create {path:?}"))?,
        );
        f.write_all(MAGIC)?;
        f.write_all(&(self.n as u64).to_le_bytes())?;
        f.write_all(&(self.d as u64).to_le_bytes())?;
        // Bulk-write the raw f32 data as LE bytes.
        let bytes: Vec<u8> = self.data.iter().flat_map(|x| x.to_le_bytes()).collect();
        f.write_all(&bytes)?;
        Ok(())
    }

    /// Load from the binary format.
    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {path:?}"))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("bad magic in {path:?}: not a zest embedding file");
        }
        let mut u = [0u8; 8];
        f.read_exact(&mut u)?;
        let n = u64::from_le_bytes(u) as usize;
        f.read_exact(&mut u)?;
        let d = u64::from_le_bytes(u) as usize;
        let mut bytes = Vec::new();
        f.read_to_end(&mut bytes)?;
        if bytes.len() != n * d * 4 {
            bail!(
                "truncated embedding file: have {} bytes, want {}",
                bytes.len(),
                n * d * 4
            );
        }
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(EmbeddingStore { n, d, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_store() -> EmbeddingStore {
        EmbeddingStore::from_data(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap()
    }

    #[test]
    fn row_access() {
        let s = small_store();
        assert_eq!(s.row(0), &[1.0, 2.0]);
        assert_eq!(s.row(2), &[5.0, 6.0]);
        assert_eq!(s.rows(1, 3), &[3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(EmbeddingStore::from_data(2, 3, vec![0.0; 5]).is_err());
    }

    #[test]
    fn truncate_keeps_prefix_without_copying() {
        let full = small_store();
        let ptr = full.data().as_ptr();
        let s = full.truncate(2);
        assert_eq!(s.len(), 2);
        assert_eq!(s.row(1), &[3.0, 4.0]);
        assert_eq!(s.data().as_ptr(), ptr, "backing allocation is reused");
        assert_eq!(small_store().truncate(99).len(), 3, "clamped to n");
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("zest_test_emb");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.bin");
        let s = small_store();
        s.save(&path).unwrap();
        let l = EmbeddingStore::load(&path).unwrap();
        assert_eq!(s, l);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_bad_magic() {
        let dir = std::env::temp_dir().join("zest_test_emb");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOTMAGIC00000000").unwrap();
        assert!(EmbeddingStore::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn norms_computed_per_row() {
        let s = EmbeddingStore::from_data(2, 2, vec![3.0, 4.0, 0.0, 1.0]).unwrap();
        let n = s.norms();
        assert!((n[0] - 5.0).abs() < 1e-6);
        assert!((n[1] - 1.0).abs() < 1e-6);
    }
}
