//! Synthetic Zipfian bigram corpus — the Penn Treebank stand-in for the
//! paper's §5.2 language-modeling experiment (DESIGN.md §Substitutions).
//!
//! A hidden bigram transition model is sampled once (per seed): each word
//! type gets a sparse successor distribution mixing (a) a Zipfian unigram
//! background and (b) a handful of strongly preferred successors. Token
//! sequences sampled from this chain have realistic frequency structure:
//! Zipfian unigrams, bursty local co-occurrence — which is what drives the
//! head/tail split of the partition function that Table 4 probes.

use crate::util::rng::{Rng, Zipf};

/// Configuration for the synthetic corpus.
#[derive(Clone, Debug)]
pub struct CorpusConfig {
    /// Vocabulary size (paper PTB §0–20 vocab ≈ 10k).
    pub vocab: usize,
    /// Training tokens to sample.
    pub train_tokens: usize,
    /// Test tokens to sample (PTB §21–22 gives ~10k contexts).
    pub test_tokens: usize,
    /// Zipf exponent for the unigram background.
    pub zipf_s: f64,
    /// Number of preferred successors per word type.
    pub links: usize,
    /// Mixture weight of the preferred-successor component.
    pub link_weight: f64,
    /// Seed.
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            vocab: 10_000,
            train_tokens: 800_000,
            test_tokens: 40_000,
            zipf_s: 1.05,
            links: 8,
            link_weight: 0.45,
            seed: 0,
        }
    }
}

impl CorpusConfig {
    pub fn tiny() -> Self {
        CorpusConfig {
            vocab: 500,
            train_tokens: 20_000,
            test_tokens: 2_000,
            ..Default::default()
        }
    }
}

/// A generated corpus: token id sequences plus the frequency model.
#[derive(Clone, Debug)]
pub struct Corpus {
    pub train: Vec<u32>,
    pub test: Vec<u32>,
    pub vocab: usize,
    /// The hidden preferred-successor table (per word type) the sampler
    /// used — exposed for tests and diagnostics.
    pub links: Vec<Vec<u32>>,
}

/// Sample the corpus for a config.
pub fn generate(cfg: &CorpusConfig) -> Corpus {
    let mut rng = Rng::seeded(cfg.seed.wrapping_mul(0x9E37_79B9).wrapping_add(17));
    let unigram = Zipf::new(cfg.vocab, cfg.zipf_s);
    // Preferred successors: word w prefers links[w] (biased toward
    // mid-frequency words so the links carry real signal).
    let links: Vec<Vec<u32>> = (0..cfg.vocab)
        .map(|_| {
            (0..cfg.links)
                .map(|_| {
                    // Bias: sample two Zipf draws, keep the rarer one.
                    let a = unigram.sample(&mut rng);
                    let b = unigram.sample(&mut rng);
                    a.max(b) as u32
                })
                .collect()
        })
        .collect();

    let sample_stream = |tokens: usize, rng: &mut Rng| -> Vec<u32> {
        let mut out = Vec::with_capacity(tokens);
        let mut prev = unigram.sample(rng) as u32;
        out.push(prev);
        while out.len() < tokens {
            let next = if rng.f64() < cfg.link_weight {
                let ls = &links[prev as usize];
                ls[rng.below(ls.len())]
            } else {
                unigram.sample(rng) as u32
            };
            out.push(next);
            prev = next;
        }
        out
    };

    let train = sample_stream(cfg.train_tokens, &mut rng);
    let test = sample_stream(cfg.test_tokens, &mut rng);
    Corpus {
        train,
        test,
        vocab: cfg.vocab,
        links,
    }
}

impl Corpus {
    /// Iterate (context, target) pairs with a fixed-size context window
    /// over a token stream. Contexts shorter than `ctx` at the start are
    /// left-padded with token 0 (the most frequent type, as PTB LMs pad
    /// with a boundary symbol).
    pub fn windows(stream: &[u32], ctx: usize) -> impl Iterator<Item = (Vec<u32>, u32)> + '_ {
        (0..stream.len().saturating_sub(1)).map(move |t| {
            let target = stream[t + 1];
            let mut c = Vec::with_capacity(ctx);
            for j in 0..ctx {
                let pos = t as i64 - (ctx - 1 - j) as i64;
                c.push(if pos < 0 { 0 } else { stream[pos as usize] });
            }
            (c, target)
        })
    }

    /// Empirical unigram counts over the training split.
    pub fn unigram_counts(&self) -> Vec<u64> {
        let mut c = vec![0u64; self.vocab];
        for &t in &self.train {
            c[t as usize] += 1;
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_sized() {
        let cfg = CorpusConfig::tiny();
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.train, b.train);
        assert_eq!(a.train.len(), cfg.train_tokens);
        assert_eq!(a.test.len(), cfg.test_tokens);
        assert!(a.train.iter().all(|&t| (t as usize) < cfg.vocab));
    }

    #[test]
    fn unigrams_are_zipfian() {
        let cfg = CorpusConfig::tiny();
        let c = generate(&cfg);
        let counts = c.unigram_counts();
        // Head rank should dominate a mid rank by a large factor.
        assert!(counts[0] > counts[100].max(1) * 5, "head {} mid {}", counts[0], counts[100]);
    }

    #[test]
    fn bigram_links_create_burstiness() {
        let cfg = CorpusConfig::tiny();
        let c = generate(&cfg);
        // Transitions out of a frequent word should land in its preferred
        // successor set at roughly the configured link_weight rate — far
        // above what the unigram background alone would produce.
        let word = 1u32;
        let link_set: std::collections::HashSet<u32> =
            c.links[word as usize].iter().copied().collect();
        let (mut in_links, mut total) = (0u64, 0u64);
        for w in c.train.windows(2) {
            if w[0] == word {
                total += 1;
                if link_set.contains(&w[1]) {
                    in_links += 1;
                }
            }
        }
        assert!(total >= 50, "word 1 should be frequent, saw {total}");
        let share = in_links as f64 / total as f64;
        assert!(
            share > cfg.link_weight * 0.7,
            "preferred-successor share {share} too low vs link_weight {}",
            cfg.link_weight
        );
    }

    #[test]
    fn windows_pad_and_align() {
        let stream = vec![5u32, 6, 7, 8];
        let w: Vec<_> = Corpus::windows(&stream, 3).collect();
        assert_eq!(w.len(), 3);
        assert_eq!(w[0], (vec![0, 0, 5], 6));
        assert_eq!(w[1], (vec![0, 5, 6], 7));
        assert_eq!(w[2], (vec![5, 6, 7], 8));
    }

    #[test]
    fn different_seeds_differ() {
        let a = generate(&CorpusConfig::tiny());
        let b = generate(&CorpusConfig {
            seed: 9,
            ..CorpusConfig::tiny()
        });
        assert_ne!(a.train, b.train);
    }
}
