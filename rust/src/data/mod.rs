//! Datasets: the embedding store (category weight vectors `v_i`), the
//! synthetic word2vec-like generator that stands in for the GoogleNews
//! vectors, and the synthetic Zipfian corpus that stands in for the Penn
//! Treebank (see DESIGN.md §Substitutions).

pub mod corpus;
pub mod embeddings;
pub mod synth;
