//! Datasets: the embedding store (category weight vectors `v_i`), the
//! synthetic word2vec-like generator that stands in for the GoogleNews
//! vectors, and the synthetic Zipfian corpus that stands in for the Penn
//! Treebank (see DESIGN.md §Substitutions).

pub mod corpus;
pub mod embeddings;
pub mod synth;

/// Shared `--data <file>` / `--synth n,d,seed` loader for the serving
/// binaries (`zest-server`, `zest-shard-worker`). Returns `Ok(None)`
/// when neither flag is present so each binary can report its own
/// usage error.
pub fn rows_from_cli(
    args: &crate::util::cli::Args,
) -> anyhow::Result<Option<embeddings::EmbeddingStore>> {
    use anyhow::Context as _;
    if let Some(path) = args.get("data") {
        let store = embeddings::EmbeddingStore::load(std::path::Path::new(path))
            .with_context(|| format!("load {path}"))?;
        return Ok(Some(store));
    }
    if args.has("synth") {
        let spec: Vec<u64> = args.get_list("synth", &[]);
        anyhow::ensure!(spec.len() == 3, "--synth wants n,d,seed");
        return Ok(Some(synth::generate(&synth::SynthConfig {
            n: spec[0] as usize,
            d: spec[1] as usize,
            seed: spec[2],
            ..Default::default()
        })));
    }
    Ok(None)
}
