//! [`ShardedStore`]: N categories partitioned into S contiguous shards.
//!
//! Global ids are **stable within a snapshot**: shard `s` owns the
//! half-open global range `[offset_s, offset_s + len_s)` and maps global
//! id `i` to local row `i − offset_s`. Shards in global order are
//! exactly the category set in order, so exp-sums, top-k merges and
//! tail sampling over the sharded view are the same mathematical objects
//! as over the monolithic matrix (Spring & Shrivastava 2017: partition
//! estimators compose across independent partitions — exp-sums are
//! additive, top-k merges by heap).
//!
//! Shards hold `Arc<EmbeddingStore>` so snapshot mutations
//! (`add_categories` / `remove_categories`) reuse every untouched
//! shard's storage (and its index) by reference.

use super::StoreView;
use crate::data::embeddings::EmbeddingStore;
use anyhow::{bail, Result};
use std::sync::Arc;

/// One contiguous shard: global rows `[offset, offset + store.len())`.
#[derive(Clone)]
pub struct Shard {
    offset: usize,
    store: Arc<EmbeddingStore>,
}

impl Shard {
    /// Global id of this shard's first row.
    pub fn offset(&self) -> usize {
        self.offset
    }

    /// Rows owned by this shard.
    pub fn len(&self) -> usize {
        self.store.len()
    }

    pub fn is_empty(&self) -> bool {
        self.store.is_empty()
    }

    /// The shard's backing store (local row-major matrix).
    pub fn store(&self) -> &Arc<EmbeddingStore> {
        &self.store
    }
}

/// S contiguous, non-empty shards covering `[0, len)` in global order.
#[derive(Clone)]
pub struct ShardedStore {
    shards: Vec<Shard>,
    len: usize,
    dim: usize,
}

impl ShardedStore {
    /// Partition `store` into `s` contiguous shards of near-equal size
    /// (the first `n mod s` shards get one extra row). `s` is clamped to
    /// `[1, n]` so every shard is non-empty.
    pub fn split(store: &EmbeddingStore, s: usize) -> ShardedStore {
        let n = store.len();
        let d = store.dim();
        let s = s.clamp(1, n.max(1));
        let base = n / s;
        let extra = n % s;
        let mut shards = Vec::with_capacity(s);
        let mut offset = 0usize;
        for i in 0..s {
            let rows = base + usize::from(i < extra);
            if rows == 0 {
                continue;
            }
            let shard_store =
                EmbeddingStore::from_data(rows, d, store.rows(offset, offset + rows).to_vec())
                    .expect("contiguous slice has exact n*d length");
            shards.push(Shard {
                offset,
                store: Arc::new(shard_store),
            });
            offset += rows;
        }
        ShardedStore {
            shards,
            len: n,
            dim: d,
        }
    }

    /// Assemble from per-shard stores (in global order). Empty shards are
    /// dropped; all non-empty shards must share one dimensionality.
    pub fn from_stores(stores: Vec<Arc<EmbeddingStore>>) -> Result<ShardedStore> {
        let mut dim = None;
        let mut shards = Vec::with_capacity(stores.len());
        let mut offset = 0usize;
        for s in stores {
            if s.is_empty() {
                continue;
            }
            match dim {
                None => dim = Some(s.dim()),
                Some(d) if d != s.dim() => {
                    bail!("shard dimensionality mismatch: {} != {}", s.dim(), d)
                }
                _ => {}
            }
            let rows = s.len();
            shards.push(Shard { offset, store: s });
            offset += rows;
        }
        let Some(dim) = dim else {
            bail!("sharded store needs at least one non-empty shard");
        };
        Ok(ShardedStore {
            shards,
            len: offset,
            dim,
        })
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    pub fn shard(&self, s: usize) -> &Shard {
        &self.shards[s]
    }

    /// Locate global id `i`: `(shard_index, local_row)`.
    pub fn shard_of(&self, i: usize) -> (usize, usize) {
        assert!(i < self.len, "row {i} out of bounds (len {})", self.len);
        // partition_point: first shard whose range starts past i, minus 1.
        let s = self.shards.partition_point(|sh| sh.offset <= i) - 1;
        (s, i - self.shards[s].offset)
    }

    /// Copy the sharded view back into one contiguous store (tests,
    /// export paths).
    pub fn to_monolithic(&self) -> EmbeddingStore {
        let mut data = Vec::with_capacity(self.len * self.dim);
        for sh in &self.shards {
            data.extend_from_slice(sh.store.data());
        }
        EmbeddingStore::from_data(self.len, self.dim, data).expect("shards tile the range")
    }
}

impl StoreView for ShardedStore {
    fn len(&self) -> usize {
        self.len
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn chunk_at(&self, i: usize) -> (usize, &[f32]) {
        let (s, _) = self.shard_of(i);
        let sh = &self.shards[s];
        (sh.offset, sh.store.data())
    }

    fn as_sharded(&self) -> Option<&ShardedStore> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::store::StoreView;

    fn store(n: usize) -> EmbeddingStore {
        generate(&SynthConfig {
            n,
            d: 8,
            ..SynthConfig::tiny()
        })
    }

    #[test]
    fn split_sizes_cover_exactly() {
        let s = store(103);
        for count in [1usize, 2, 4, 7, 103, 500] {
            let sh = ShardedStore::split(&s, count);
            assert_eq!(StoreView::len(&sh), 103);
            assert_eq!(sh.num_shards(), count.min(103));
            let total: usize = sh.shards().iter().map(|x| x.len()).sum();
            assert_eq!(total, 103);
            // Contiguous offsets and near-equal sizes (±1).
            let mut expect = 0usize;
            let (mut lo, mut hi) = (usize::MAX, 0usize);
            for x in sh.shards() {
                assert_eq!(x.offset(), expect);
                expect += x.len();
                lo = lo.min(x.len());
                hi = hi.max(x.len());
            }
            assert!(hi - lo <= 1, "balanced split: {lo}..{hi}");
        }
    }

    #[test]
    fn rows_match_monolithic_everywhere() {
        let s = store(97);
        let sh = ShardedStore::split(&s, 5);
        for i in 0..97 {
            assert_eq!(StoreView::row(&sh, i), s.row(i), "row {i}");
        }
        assert_eq!(sh.to_monolithic(), s);
    }

    #[test]
    fn shard_of_maps_boundaries() {
        let s = store(10);
        let sh = ShardedStore::split(&s, 3); // sizes 4, 3, 3
        assert_eq!(sh.shard_of(0), (0, 0));
        assert_eq!(sh.shard_of(3), (0, 3));
        assert_eq!(sh.shard_of(4), (1, 0));
        assert_eq!(sh.shard_of(6), (1, 2));
        assert_eq!(sh.shard_of(7), (2, 0));
        assert_eq!(sh.shard_of(9), (2, 2));
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn shard_of_rejects_out_of_range() {
        let s = store(10);
        ShardedStore::split(&s, 2).shard_of(10);
    }

    #[test]
    fn chunks_tile_across_shard_boundaries() {
        let s = store(30);
        let sh = ShardedStore::split(&s, 4); // sizes 8, 8, 7, 7
        let mut covered = Vec::new();
        sh.for_each_chunk(5, 27, &mut |start, rows| {
            covered.push((start, rows.len() / 8));
        });
        assert_eq!(covered, vec![(5, 3), (8, 8), (16, 7), (23, 4)]);
    }

    #[test]
    fn from_stores_requires_equal_dims() {
        let a = Arc::new(EmbeddingStore::from_data(2, 3, vec![0.0; 6]).unwrap());
        let b = Arc::new(EmbeddingStore::from_data(2, 4, vec![0.0; 8]).unwrap());
        assert!(ShardedStore::from_stores(vec![a.clone(), b]).is_err());
        assert!(ShardedStore::from_stores(vec![]).is_err());
        let ok = ShardedStore::from_stores(vec![a.clone(), a]).unwrap();
        assert_eq!(StoreView::len(&ok), 4);
    }

    #[test]
    fn exp_sum_bit_identical_to_monolithic() {
        let s = store(700);
        let q = s.row(17).to_vec();
        let want = crate::store::exp_sum_view(&s, &q);
        for count in [1usize, 2, 4, 7, 64] {
            let sh = ShardedStore::split(&s, count);
            let got = crate::store::exp_sum_view(&sh, &q);
            assert_eq!(got.to_bits(), want.to_bits(), "shards={count}");
        }
    }
}
