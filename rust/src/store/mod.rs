//! The storage layer behind every scoring path: a [`StoreView`] trait
//! abstracting "N×d row-major category matrix" so consumers (estimators,
//! indexes, the coordinator) no longer assume one monolithic
//! [`EmbeddingStore`], plus:
//!
//! * [`sharded::ShardedStore`] — N categories partitioned into S
//!   contiguous shards with stable global ids (global id = shard offset +
//!   local row), the scaling axis after PR 1's batching: each shard gets
//!   its own index build and its own slice of every scoring pass.
//! * [`snapshot::SnapshotHandle`] — an epoch-stamped, `Arc`-swap style
//!   published view `{epoch, store, per-shard indexes}` supporting
//!   `add_categories` / `remove_categories` without pausing readers:
//!   in-flight work keeps the `Arc<Snapshot>` it pinned, new work sees
//!   the new epoch.
//!
//! ## Bit-stability contract
//!
//! [`exp_sum_view`] / [`exp_sum_view_batch`] stream *any* view through
//! the same global row tiling that `linalg::exp_sum_gemv` /
//! `linalg::exp_sum_gemm` use on a contiguous matrix (tiles of
//! [`EXP_SUM_TILE`] / [`EXP_SUM_BATCH_TILE`] rows aligned to row 0, one
//! sequential f64 accumulator per query). Tiles that cross a shard
//! boundary are staged into a scratch buffer — same bytes, same kernel
//! calls, same accumulation order — so `Exact` over a `ShardedStore` is
//! **bit-identical** to the unsharded answer for every shard layout, on
//! both the AVX2 and scalar backends. `rust/tests/sharding.rs` pins this.

pub mod sharded;
pub mod snapshot;

pub use sharded::{Shard, ShardedStore};
pub use snapshot::{PendingEpoch, ShardIndexBuilder, Snapshot, SnapshotHandle};

use crate::data::embeddings::EmbeddingStore;
use crate::linalg;

/// Read-only view of an N×d row-major category matrix. Implemented by
/// the monolithic [`EmbeddingStore`] (one chunk) and by [`ShardedStore`]
/// (one chunk per shard).
pub trait StoreView: Send + Sync {
    /// Number of categories N.
    fn len(&self) -> usize;

    /// Dimensionality d.
    fn dim(&self) -> usize;

    /// The contiguous storage block containing global row `i`:
    /// `(block_first_row, block_rows)` where `block_rows` is row-major
    /// (`block_len × d`) and `block_first_row ≤ i <
    /// block_first_row + block_len`. One block for a monolithic store;
    /// the owning shard's block for a sharded store.
    fn chunk_at(&self, i: usize) -> (usize, &[f32]);

    /// The i-th category vector (global id).
    fn row(&self, i: usize) -> &[f32] {
        let d = self.dim();
        let (start, rows) = self.chunk_at(i);
        &rows[(i - start) * d..(i - start + 1) * d]
    }

    /// Visit the contiguous row blocks covering `[lo, hi)` in global row
    /// order: `f(block_start, rows)` with `rows` row-major
    /// (`block_len × d`). Blocks are non-empty and tile `[lo, hi)`
    /// exactly.
    fn for_each_chunk(&self, lo: usize, hi: usize, f: &mut dyn FnMut(usize, &[f32])) {
        let d = self.dim();
        let mut pos = lo;
        while pos < hi {
            let (start, rows) = self.chunk_at(pos);
            let chunk_end = start + rows.len() / d;
            let take_hi = hi.min(chunk_end);
            f(pos, &rows[(pos - start) * d..(take_hi - start) * d]);
            pos = take_hi;
        }
    }

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Downcast hook for shard-aware consumers (stratified tail
    /// sampling, per-shard metrics). `None` for monolithic stores.
    fn as_sharded(&self) -> Option<&ShardedStore> {
        None
    }
}

impl StoreView for EmbeddingStore {
    fn len(&self) -> usize {
        EmbeddingStore::len(self)
    }

    fn dim(&self) -> usize {
        EmbeddingStore::dim(self)
    }

    fn chunk_at(&self, i: usize) -> (usize, &[f32]) {
        assert!(i < EmbeddingStore::len(self), "row {i} out of bounds");
        (0, self.data())
    }

    fn row(&self, i: usize) -> &[f32] {
        EmbeddingStore::row(self, i)
    }
}

/// Row tiles of the streaming exp-sums — shared with the fused linalg
/// kernels so the bit-stability contract is structural, not by
/// convention.
pub use crate::linalg::{EXP_SUM_BATCH_TILE, EXP_SUM_TILE};

/// Rows `[lo, hi)` of `view` as one contiguous block: borrowed straight
/// from the owning chunk when the range does not cross a chunk boundary,
/// staged into `buf` otherwise.
fn gather_rows<'a>(
    view: &'a dyn StoreView,
    lo: usize,
    hi: usize,
    buf: &'a mut Vec<f32>,
) -> &'a [f32] {
    let d = view.dim();
    let (start, rows) = view.chunk_at(lo);
    let chunk_end = start + rows.len() / d;
    if chunk_end >= hi {
        return &rows[(lo - start) * d..(hi - start) * d];
    }
    buf.clear();
    view.for_each_chunk(lo, hi, &mut |_, r| buf.extend_from_slice(r));
    debug_assert_eq!(buf.len(), (hi - lo) * d);
    buf
}

/// Σ exp(row · q) over every row of `view`, streamed through the same
/// global [`EXP_SUM_TILE`]-row tiling and sequential f64 accumulation as
/// `linalg::exp_sum_gemv` on a contiguous matrix — bit-identical for any
/// shard layout (see module docs).
pub fn exp_sum_view(view: &dyn StoreView, q: &[f32]) -> f64 {
    exp_sum_view_chain(view, q, 0.0)
}

/// [`exp_sum_view`] continued from an initial accumulator: returns
/// `acc0 + Σ exp(row · q)` with the accumulation order picking up exactly
/// where a previous segment of a larger row range left off. This is the
/// cross-process seam for distributed `Exact`: each shard worker extends
/// the running f64 sum over its own rows in strict global row order, so a
/// chain of workers reproduces the single-process sequential accumulation
/// (see `net::remote` for the row-alignment contract that makes the
/// per-row score bits match too).
pub fn exp_sum_view_chain(view: &dyn StoreView, q: &[f32], acc0: f64) -> f64 {
    let n = view.len();
    let d = view.dim();
    assert_eq!(q.len(), d, "query dimensionality mismatch");
    let mut stage: Vec<f32> = Vec::new();
    let mut tile = [0f32; EXP_SUM_TILE];
    let mut acc = acc0;
    let mut lo = 0usize;
    while lo < n {
        let hi = (lo + EXP_SUM_TILE).min(n);
        let nrows = hi - lo;
        let rows = gather_rows(view, lo, hi, &mut stage);
        linalg::gemv_blocked(rows, nrows, d, q, &mut tile[..nrows]);
        for &s in &tile[..nrows] {
            acc += (s as f64).exp();
        }
        lo = hi;
    }
    acc
}

/// Batched streaming exp-sum: `zs[j] += Σ_rows exp(row · q_j)` with the
/// same [`EXP_SUM_BATCH_TILE`]-row tiling and per-tile accumulation
/// order as `linalg::exp_sum_gemm` — bit-identical for any shard layout.
/// Because it accumulates **into** `zs`, it doubles as the batched chain
/// kernel (cf. [`exp_sum_view_chain`]): seed `zs` with the partial sums
/// of the preceding global rows and the per-query accumulation continues
/// in strict row order.
pub fn exp_sum_view_batch(view: &dyn StoreView, qs_flat: &[f32], nq: usize, zs: &mut [f64]) {
    let n = view.len();
    let d = view.dim();
    assert_eq!(qs_flat.len(), nq * d);
    assert_eq!(zs.len(), nq);
    if n == 0 || nq == 0 {
        return;
    }
    let mut stage: Vec<f32> = Vec::new();
    let mut tile = vec![0f32; EXP_SUM_BATCH_TILE * nq];
    let mut lo = 0usize;
    while lo < n {
        let hi = (lo + EXP_SUM_BATCH_TILE).min(n);
        let nrows = hi - lo;
        let rows = gather_rows(view, lo, hi, &mut stage);
        linalg::gemm(rows, nrows, d, qs_flat, nq, &mut tile[..nrows * nq]);
        for r in 0..nrows {
            for (qi, z) in zs.iter_mut().enumerate() {
                *z += (tile[r * nq + qi] as f64).exp();
            }
        }
        lo = hi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};

    fn store(n: usize, d: usize) -> EmbeddingStore {
        generate(&SynthConfig {
            n,
            d,
            ..SynthConfig::tiny()
        })
    }

    #[test]
    fn monolithic_chunk_covers_range() {
        let s = store(300, 16);
        let mut seen = Vec::new();
        StoreView::for_each_chunk(&s, 10, 200, &mut |start, rows| {
            seen.push((start, rows.len()));
        });
        assert_eq!(seen, vec![(10, 190 * 16)]);
        assert_eq!(StoreView::row(&s, 7), EmbeddingStore::row(&s, 7));
    }

    /// The view streaming kernel over a monolithic store must reproduce
    /// the fused linalg kernel bit for bit (same tiles, same calls).
    #[test]
    fn exp_sum_view_bit_matches_linalg_on_monolithic() {
        for n in [1usize, 255, 256, 257, 700] {
            let s = store(n, 17);
            let q: Vec<f32> = (0..17).map(|j| (j as f32 * 0.37).sin()).collect();
            let got = exp_sum_view(&s, &q);
            let want = linalg::exp_sum_gemv(s.data(), s.len(), 17, &q);
            assert_eq!(got.to_bits(), want.to_bits(), "n={n}: {got} vs {want}");
        }
    }

    #[test]
    fn exp_sum_view_empty_store_is_zero() {
        let s = EmbeddingStore::from_data(0, 4, vec![]).unwrap();
        assert_eq!(exp_sum_view(&s, &[0.0; 4]), 0.0);
    }

    /// Chaining per-segment sums in global row order reproduces the
    /// one-shot accumulation bit for bit when every segment boundary is
    /// 4-row aligned (the quad-alignment contract `net::remote` relies
    /// on: each gemv call then scores every row through the same blocked
    /// quad path as the global tiling).
    #[test]
    fn exp_sum_view_chain_matches_one_shot_on_aligned_segments() {
        let s = store(600, 12);
        let q: Vec<f32> = (0..12).map(|j| (j as f32 * 0.21).cos()).collect();
        let want = exp_sum_view(&s, &q);
        for cut in [4usize, 256, 320, 400] {
            let head = EmbeddingStore::from_data(cut, 12, s.rows(0, cut).to_vec()).unwrap();
            let tail =
                EmbeddingStore::from_data(600 - cut, 12, s.rows(cut, 600).to_vec()).unwrap();
            let acc = exp_sum_view_chain(&head, &q, 0.0);
            let got = exp_sum_view_chain(&tail, &q, acc);
            assert_eq!(got.to_bits(), want.to_bits(), "cut={cut}: {got} vs {want}");
        }
    }

    /// The batched kernel accumulates into `zs`, so seeding it with the
    /// previous segment's partial sums chains the same way.
    #[test]
    fn exp_sum_view_batch_chains_on_aligned_segments() {
        let s = store(512, 16);
        let qs: Vec<Vec<f32>> = (0..3).map(|i| s.row(i * 100 + 1).to_vec()).collect();
        let qs_flat = linalg::flatten_queries(&qs, 16);
        let mut want = vec![0f64; qs.len()];
        exp_sum_view_batch(&s, &qs_flat, qs.len(), &mut want);
        let head = EmbeddingStore::from_data(256, 16, s.rows(0, 256).to_vec()).unwrap();
        let tail = EmbeddingStore::from_data(256, 16, s.rows(256, 512).to_vec()).unwrap();
        let mut got = vec![0f64; qs.len()];
        exp_sum_view_batch(&head, &qs_flat, qs.len(), &mut got);
        exp_sum_view_batch(&tail, &qs_flat, qs.len(), &mut got);
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.to_bits(), w.to_bits(), "{g} vs {w}");
        }
    }

    #[test]
    fn exp_sum_view_batch_bit_matches_linalg_on_monolithic() {
        let s = store(321, 19);
        let qs: Vec<Vec<f32>> = (0..5).map(|i| s.row(i * 60).to_vec()).collect();
        let qs_flat = linalg::flatten_queries(&qs, 19);
        let mut got = vec![0f64; qs.len()];
        exp_sum_view_batch(&s, &qs_flat, qs.len(), &mut got);
        let mut want = vec![0f64; qs.len()];
        linalg::exp_sum_gemm(s.data(), s.len(), 19, &qs_flat, qs.len(), &mut want);
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.to_bits(), w.to_bits(), "{g} vs {w}");
        }
    }
}
