//! Epoch snapshots over a [`ShardedStore`]: live category insertion and
//! removal without pausing readers.
//!
//! A [`Snapshot`] is an immutable `{epoch, store, index}` triple; the
//! [`SnapshotHandle`] publishes the current one behind an `RwLock<Arc<…>>`
//! (`Arc`-swap style: the write lock is held only for the pointer swap,
//! never during index builds). Readers call [`SnapshotHandle::load`] once
//! per unit of work and keep using the pinned `Arc<Snapshot>` for its
//! whole duration — a concurrent `add_categories` /
//! `remove_categories` publishes epoch `e+1` while in-flight work keeps
//! answering from epoch `e`. Per-shard stores and indexes are
//! `Arc`-shared across epochs, so a mutation rebuilds only the shards it
//! touches: `add_categories` appends one new shard (and builds one new
//! sub-index); `remove_categories` rebuilds exactly the shards that lost
//! rows.
//!
//! Mutations come in two shapes: the one-shot `add_categories` /
//! `remove_categories` (prepare + commit under the writer lock), and an
//! explicit two-phase `prepare_*` → [`PendingEpoch`] →
//! [`SnapshotHandle::commit`] split for coordinated cross-process swaps
//! (`net::remote` prepares on every shard worker, then commits
//! everywhere; a preparation invalidated by a concurrent commit fails
//! with a stale-epoch error instead of publishing over it).
//!
//! Id semantics: global ids are positional **within a snapshot**.
//! `add_categories` extends the id range (existing ids are unchanged);
//! `remove_categories` compacts ids, shifting rows after a removed
//! position down — consumers that need cross-epoch identity must track
//! their own label→id map per epoch.

use super::sharded::ShardedStore;
use super::StoreView;
use crate::data::embeddings::EmbeddingStore;
use crate::mips::sharded::{proportional_threads, ShardedIndex};
use crate::mips::MipsIndex;
use anyhow::{bail, Result};
use std::sync::{Arc, Mutex, RwLock};

/// One immutable published epoch: the sharded category set plus the
/// per-shard index set serving it.
pub struct Snapshot {
    pub epoch: u64,
    pub store: Arc<ShardedStore>,
    pub index: Arc<ShardedIndex>,
}

/// How to index one (new or rebuilt) shard. The `usize` is the
/// suggested scoring-thread budget for that shard — its
/// size-proportional share ([`proportional_threads`]) of the snapshot
/// being built — so per-shard indexes stay fair as epochs add, drop or
/// shrink shards.
pub type ShardIndexBuilder =
    Arc<dyn Fn(&Arc<EmbeddingStore>, usize) -> Arc<dyn MipsIndex> + Send + Sync>;

/// A fully built but **unpublished** next epoch: the output of the
/// `prepare_*` half of a two-phase publish. Holds the next epoch's store
/// and index (untouched shards reused by `Arc`); [`SnapshotHandle::commit`]
/// swaps it in iff the handle is still at the epoch the preparation was
/// based on. Used by cross-process epoch swaps (`net::remote`): the
/// coordinator prepares on every shard worker, and only when all of them
/// staged successfully does it commit everywhere.
pub struct PendingEpoch {
    base_epoch: u64,
    store: ShardedStore,
    index: ShardedIndex,
}

impl PendingEpoch {
    /// The published epoch this preparation was built from.
    pub fn base_epoch(&self) -> u64 {
        self.base_epoch
    }

    /// The epoch this preparation will publish as.
    pub fn epoch(&self) -> u64 {
        self.base_epoch + 1
    }

    /// Rows the prepared snapshot will serve.
    pub fn len(&self) -> usize {
        StoreView::len(&self.store)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Publisher of epoch snapshots.
pub struct SnapshotHandle {
    current: RwLock<Arc<Snapshot>>,
    /// Serializes mutators (read-modify-write) without blocking `load`.
    writer: Mutex<()>,
    builder: ShardIndexBuilder,
}

impl SnapshotHandle {
    /// Publish epoch 0 of `store`, indexing every shard with `builder`
    /// at its size-proportional thread share.
    pub fn new(store: ShardedStore, builder: ShardIndexBuilder) -> SnapshotHandle {
        let index = ShardedIndex::build(
            &store,
            crate::util::threadpool::default_threads(),
            builder.as_ref(),
        );
        SnapshotHandle {
            current: RwLock::new(Arc::new(Snapshot {
                epoch: 0,
                store: Arc::new(store),
                index: Arc::new(index),
            })),
            writer: Mutex::new(()),
            builder,
        }
    }

    /// Convenience: exact (brute-force) per-shard indexes, each built
    /// with the size-proportional thread budget the handle passes for
    /// the snapshot being published ([`proportional_threads`]), so the
    /// cross-shard scatter does not oversubscribe the machine as epochs
    /// add, drop or shrink shards.
    pub fn brute(store: ShardedStore) -> SnapshotHandle {
        Self::new(
            store,
            Arc::new(|s: &Arc<EmbeddingStore>, threads: usize| {
                Arc::new(crate::mips::brute::BruteIndex::from_arc_with_threads(
                    s.clone(),
                    threads,
                )) as Arc<dyn MipsIndex>
            }),
        )
    }

    /// Pin the current snapshot. Cheap (one `Arc` clone under a read
    /// lock); hold the returned `Arc` for the whole unit of work.
    pub fn load(&self) -> Arc<Snapshot> {
        self.current.read().unwrap().clone()
    }

    /// The currently published epoch.
    pub fn epoch(&self) -> u64 {
        self.current.read().unwrap().epoch
    }

    /// Append `rows` as one new shard and publish the next epoch.
    /// Existing global ids are unchanged; the new categories take ids
    /// `[old_len, old_len + rows.len())`. Every existing shard's store
    /// and index are reused by reference. Returns the new epoch.
    pub fn add_categories(&self, rows: EmbeddingStore) -> Result<u64> {
        let _w = self.writer.lock().unwrap();
        let pending = self.prepare_add(rows)?;
        self.commit_locked(pending)
    }

    /// Remove the categories at the given global ids (of the **current**
    /// snapshot) and publish the next epoch. Only shards that lost rows
    /// are rebuilt (store + index); untouched shards are reused by
    /// reference at their shifted offsets. Remaining ids compact
    /// downward. Returns the new epoch.
    pub fn remove_categories(&self, ids: &[usize]) -> Result<u64> {
        if ids.is_empty() {
            bail!("remove_categories: empty id set");
        }
        let _w = self.writer.lock().unwrap();
        let pending = self.prepare_remove(ids)?;
        self.commit_locked(pending)
    }

    /// First half of a two-phase append: build (but do not publish) the
    /// snapshot that adds `rows` as one new shard. Does **not** take the
    /// writer lock — a concurrent mutation invalidates the preparation,
    /// which [`SnapshotHandle::commit`] detects by epoch.
    pub fn prepare_add(&self, rows: EmbeddingStore) -> Result<PendingEpoch> {
        if rows.is_empty() {
            bail!("add_categories: empty row set");
        }
        let cur = self.load();
        if rows.dim() != StoreView::dim(cur.store.as_ref()) {
            bail!(
                "add_categories: dim {} != store dim {}",
                rows.dim(),
                StoreView::dim(cur.store.as_ref())
            );
        }
        let new_shard = Arc::new(rows);
        let mut stores: Vec<Arc<EmbeddingStore>> = cur
            .store
            .shards()
            .iter()
            .map(|sh| sh.store().clone())
            .collect();
        stores.push(new_shard.clone());
        let store = ShardedStore::from_stores(stores)?;
        // Reuse every existing sub-index; build one for the new shard at
        // its size-proportional thread share of the new layout.
        let mut parts: Vec<(usize, Arc<dyn MipsIndex>)> = (0..cur.index.num_shards())
            .map(|s| (cur.index.shard_offset(s), cur.index.shard_index(s).clone()))
            .collect();
        let lens: Vec<usize> = store.shards().iter().map(|sh| sh.len()).collect();
        let budgets = proportional_threads(&lens, crate::util::threadpool::default_threads());
        parts.push((
            StoreView::len(cur.store.as_ref()),
            (self.builder)(&new_shard, *budgets.last().expect("non-empty layout")),
        ));
        let index = ShardedIndex::from_parts(parts);
        Ok(PendingEpoch {
            base_epoch: cur.epoch,
            store,
            index,
        })
    }

    /// First half of a two-phase removal: build (but do not publish) the
    /// snapshot that drops the given global ids. An **empty** id set is
    /// a pure epoch bump ("touch"): every shard's store and index are
    /// reused by reference — that is how workers without local changes
    /// participate in a cluster-wide two-phase publish and keep their
    /// epoch in lockstep.
    pub fn prepare_remove(&self, ids: &[usize]) -> Result<PendingEpoch> {
        let cur = self.load();
        if ids.is_empty() {
            return Ok(self.prepare_touch_from(&cur));
        }
        let n = StoreView::len(cur.store.as_ref());
        let mut sorted: Vec<usize> = ids.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        if let Some(&bad) = sorted.last() {
            if bad >= n {
                bail!("remove_categories: id {bad} out of range (len {n})");
            }
        }
        let d = StoreView::dim(cur.store.as_ref());
        // First pass: which local rows each shard loses, and the new
        // layout's row counts (for the proportional thread budgets).
        let mut drops_per_shard: Vec<Vec<usize>> = Vec::with_capacity(cur.store.num_shards());
        let mut drop_iter = sorted.iter().peekable();
        for sh in cur.store.shards() {
            let lo = sh.offset();
            let hi = lo + sh.len();
            let mut local_drops: Vec<usize> = Vec::new();
            while let Some(&&g) = drop_iter.peek() {
                if g >= hi {
                    break;
                }
                local_drops.push(g - lo);
                drop_iter.next();
            }
            drops_per_shard.push(local_drops);
        }
        let new_lens: Vec<usize> = cur
            .store
            .shards()
            .iter()
            .zip(&drops_per_shard)
            .map(|(sh, drops)| sh.len() - drops.len())
            .filter(|&keep| keep > 0)
            .collect();
        let budgets = proportional_threads(&new_lens, crate::util::threadpool::default_threads());
        // Second pass: rebuild exactly the shards that lost rows.
        let mut stores: Vec<Arc<EmbeddingStore>> = Vec::new();
        let mut parts: Vec<(usize, Arc<dyn MipsIndex>)> = Vec::new();
        let mut offset = 0usize;
        let mut kept = 0usize;
        for (s, sh) in cur.store.shards().iter().enumerate() {
            let local_drops = &drops_per_shard[s];
            if local_drops.is_empty() {
                // Untouched: reuse store + index at the shifted offset.
                stores.push(sh.store().clone());
                parts.push((offset, cur.index.shard_index(s).clone()));
                offset += sh.len();
                kept += 1;
                continue;
            }
            let keep = sh.len() - local_drops.len();
            if keep == 0 {
                continue; // whole shard removed
            }
            let mut data = Vec::with_capacity(keep * d);
            let mut next_drop = local_drops.iter().peekable();
            for r in 0..sh.len() {
                if next_drop.peek() == Some(&&r) {
                    next_drop.next();
                    continue;
                }
                data.extend_from_slice(sh.store().row(r));
            }
            let rebuilt = Arc::new(EmbeddingStore::from_data(keep, d, data)?);
            parts.push((offset, (self.builder)(&rebuilt, budgets[kept])));
            stores.push(rebuilt);
            offset += keep;
            kept += 1;
        }
        let store = ShardedStore::from_stores(stores)?;
        let index = ShardedIndex::from_parts(parts);
        Ok(PendingEpoch {
            base_epoch: cur.epoch,
            store,
            index,
        })
    }

    /// Prepare a pure epoch bump: the next epoch serves the same shard
    /// set, every store and index reused by `Arc`.
    pub fn prepare_touch(&self) -> PendingEpoch {
        let cur = self.load();
        self.prepare_touch_from(&cur)
    }

    fn prepare_touch_from(&self, cur: &Snapshot) -> PendingEpoch {
        let store = cur.store.as_ref().clone();
        let parts: Vec<(usize, Arc<dyn MipsIndex>)> = (0..cur.index.num_shards())
            .map(|s| (cur.index.shard_offset(s), cur.index.shard_index(s).clone()))
            .collect();
        PendingEpoch {
            base_epoch: cur.epoch,
            store,
            index: ShardedIndex::from_parts(parts),
        }
    }

    /// Second half of a two-phase publish: atomically swap `pending` in.
    /// Fails — leaving the published snapshot untouched — when another
    /// mutation committed since the preparation was built (the epoch
    /// moved past `pending.base_epoch()`).
    pub fn commit(&self, pending: PendingEpoch) -> Result<u64> {
        let _w = self.writer.lock().unwrap();
        self.commit_locked(pending)
    }

    fn commit_locked(&self, pending: PendingEpoch) -> Result<u64> {
        let cur = self.load();
        if cur.epoch != pending.base_epoch {
            bail!(
                "stale prepare: built from epoch {}, but epoch {} is published",
                pending.base_epoch,
                cur.epoch
            );
        }
        Ok(self.publish(&cur, pending.store, pending.index))
    }

    /// Swap in the next epoch (write lock held only for the swap).
    fn publish(&self, cur: &Snapshot, store: ShardedStore, index: ShardedIndex) -> u64 {
        let epoch = cur.epoch + 1;
        let next = Arc::new(Snapshot {
            epoch,
            store: Arc::new(store),
            index: Arc::new(index),
        });
        *self.current.write().unwrap() = next;
        epoch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::store::exp_sum_view;

    fn handle(n: usize, shards: usize) -> (SnapshotHandle, EmbeddingStore) {
        let s = generate(&SynthConfig {
            n,
            d: 8,
            ..SynthConfig::tiny()
        });
        (SnapshotHandle::brute(ShardedStore::split(&s, shards)), s)
    }

    fn extra_rows(d: usize, n: usize, seed: u64) -> EmbeddingStore {
        generate(&SynthConfig {
            n,
            d,
            seed,
            ..SynthConfig::tiny()
        })
    }

    #[test]
    fn add_publishes_next_epoch_and_keeps_old_ids() {
        let (h, s) = handle(60, 3);
        assert_eq!(h.epoch(), 0);
        let added = extra_rows(8, 10, 7);
        let e = h.add_categories(added.clone()).unwrap();
        assert_eq!(e, 1);
        let snap = h.load();
        assert_eq!(StoreView::len(snap.store.as_ref()), 70);
        for i in 0..60 {
            assert_eq!(StoreView::row(snap.store.as_ref(), i), s.row(i));
        }
        for i in 0..10 {
            assert_eq!(StoreView::row(snap.store.as_ref(), 60 + i), added.row(i));
        }
    }

    #[test]
    fn pinned_snapshot_survives_swap() {
        let (h, s) = handle(50, 2);
        let pinned = h.load();
        let q = s.row(3).to_vec();
        let z_before = exp_sum_view(pinned.store.as_ref(), &q);
        h.add_categories(extra_rows(8, 20, 9)).unwrap();
        // The pinned epoch still answers from the old category set.
        assert_eq!(
            exp_sum_view(pinned.store.as_ref(), &q).to_bits(),
            z_before.to_bits()
        );
        assert_eq!(pinned.epoch, 0);
        let fresh = h.load();
        assert_eq!(fresh.epoch, 1);
        assert!(exp_sum_view(fresh.store.as_ref(), &q) > z_before);
    }

    #[test]
    fn add_reuses_existing_shard_indexes() {
        let (h, _) = handle(40, 4);
        let before = h.load();
        h.add_categories(extra_rows(8, 5, 3)).unwrap();
        let after = h.load();
        assert_eq!(after.index.num_shards(), 5);
        for s in 0..4 {
            assert!(
                Arc::ptr_eq(before.index.shard_index(s), after.index.shard_index(s)),
                "shard {s} index must be reused"
            );
        }
    }

    #[test]
    fn remove_compacts_ids_and_rebuilds_only_touched_shards() {
        let (h, s) = handle(40, 4); // shards of 10
        let before = h.load();
        // Remove two rows from shard 1 only.
        let e = h.remove_categories(&[12, 17]).unwrap();
        assert_eq!(e, 1);
        let after = h.load();
        assert_eq!(StoreView::len(after.store.as_ref()), 38);
        // Shard 0 untouched (same offset), shards 2/3 shifted but reused.
        assert!(Arc::ptr_eq(before.index.shard_index(0), after.index.shard_index(0)));
        assert!(!Arc::ptr_eq(before.index.shard_index(1), after.index.shard_index(1)));
        assert!(Arc::ptr_eq(before.index.shard_index(2), after.index.shard_index(2)));
        assert!(Arc::ptr_eq(before.index.shard_index(3), after.index.shard_index(3)));
        // Ids compact: old row 13 is now id 12, old row 20 is now id 18.
        assert_eq!(StoreView::row(after.store.as_ref(), 12), s.row(13));
        assert_eq!(StoreView::row(after.store.as_ref(), 18), s.row(20));
    }

    #[test]
    fn remove_whole_shard_drops_it() {
        let (h, _) = handle(20, 2); // shards of 10
        let ids: Vec<usize> = (10..20).collect();
        h.remove_categories(&ids).unwrap();
        let after = h.load();
        assert_eq!(after.store.num_shards(), 1);
        assert_eq!(StoreView::len(after.store.as_ref()), 10);
    }

    #[test]
    fn invalid_mutations_are_rejected_and_do_not_advance() {
        let (h, _) = handle(10, 2);
        assert!(h
            .add_categories(EmbeddingStore::from_data(2, 5, vec![0.0; 10]).unwrap())
            .is_err());
        assert!(h
            .add_categories(EmbeddingStore::from_data(0, 8, vec![]).unwrap())
            .is_err());
        assert!(h.remove_categories(&[99]).is_err());
        assert!(h.remove_categories(&[]).is_err());
        let all: Vec<usize> = (0..10).collect();
        assert!(h.remove_categories(&all).is_err(), "cannot empty the store");
        assert_eq!(h.epoch(), 0, "failed mutations must not advance the epoch");
    }

    #[test]
    fn two_phase_prepare_then_commit_publishes() {
        let (h, _) = handle(40, 2);
        let pending = h.prepare_add(extra_rows(8, 6, 5)).unwrap();
        assert_eq!(pending.base_epoch(), 0);
        assert_eq!(pending.epoch(), 1);
        assert_eq!(pending.len(), 46);
        // Nothing published until commit.
        assert_eq!(h.epoch(), 0);
        assert_eq!(h.commit(pending).unwrap(), 1);
        assert_eq!(StoreView::len(h.load().store.as_ref()), 46);
    }

    #[test]
    fn stale_prepare_is_rejected_at_commit() {
        let (h, _) = handle(40, 2);
        let pending = h.prepare_add(extra_rows(8, 6, 5)).unwrap();
        // A concurrent mutation lands first.
        h.add_categories(extra_rows(8, 3, 6)).unwrap();
        let err = h.commit(pending).unwrap_err();
        assert!(err.to_string().contains("stale prepare"), "{err}");
        // The interleaved epoch survives untouched.
        assert_eq!(h.epoch(), 1);
        assert_eq!(StoreView::len(h.load().store.as_ref()), 43);
    }

    #[test]
    fn prepare_touch_bumps_epoch_reusing_every_shard() {
        let (h, _) = handle(30, 3);
        let before = h.load();
        let pending = h.prepare_touch();
        assert_eq!(h.commit(pending).unwrap(), 1);
        let after = h.load();
        assert_eq!(StoreView::len(after.store.as_ref()), 30);
        for s in 0..3 {
            assert!(
                Arc::ptr_eq(before.store.shard(s).store(), after.store.shard(s).store()),
                "touch must reuse shard {s} store"
            );
            assert!(
                Arc::ptr_eq(before.index.shard_index(s), after.index.shard_index(s)),
                "touch must reuse shard {s} index"
            );
        }
        // prepare_remove(&[]) is the same touch (cluster lockstep path).
        let pending = h.prepare_remove(&[]).unwrap();
        assert_eq!(pending.len(), 30);
        assert_eq!(h.commit(pending).unwrap(), 2);
    }

    #[test]
    fn concurrent_adds_serialize() {
        let (h, _) = handle(30, 3);
        let h = Arc::new(h);
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let h = h.clone();
            joins.push(std::thread::spawn(move || {
                h.add_categories(extra_rows(8, 3, t + 100)).unwrap()
            }));
        }
        let mut epochs: Vec<u64> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        epochs.sort_unstable();
        assert_eq!(epochs, vec![1, 2, 3, 4], "each mutation gets its own epoch");
        assert_eq!(StoreView::len(h.load().store.as_ref()), 42);
    }
}
