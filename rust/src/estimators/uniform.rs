//! Uniform importance sampling — the k=0 baseline row of Table 1:
//! `Ẑ = N/l · Σ_{u∈U_l} exp(u·q)` with `U_l` drawn uniformly.
//!
//! The paper (§2) notes this estimator is "marred by the high variance":
//! the summands are log-normal with heavy tails, so a small uniform
//! sample almost always misses the head and ~100% error results. Table 1
//! reproduces exactly that.

use super::{tail, EstimateContext, Estimator};

/// Uniform importance-sampling estimator with `l` samples.
#[derive(Clone, Copy, Debug)]
pub struct Uniform {
    pub l: usize,
}

impl Uniform {
    pub fn new(l: usize) -> Self {
        Uniform { l }
    }
}

impl Estimator for Uniform {
    fn name(&self) -> String {
        format!("Uniform(l={})", self.l)
    }

    fn estimate(&self, ctx: &mut EstimateContext<'_>, q: &[f32]) -> f64 {
        let n = ctx.store.len();
        tail::sample_tail_into(ctx.store, &[], self.l, q, ctx.rng, &mut ctx.scratch);
        let drawn = ctx.scratch.indices.len();
        if drawn == 0 {
            return 0.0;
        }
        let mean: f64 = ctx.scratch.exp_scores.iter().sum::<f64>() / drawn as f64;
        n as f64 * mean
    }

    fn scorings(&self, _n: usize) -> usize {
        self.l
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::metrics::abs_rel_err_pct;
    use crate::mips::brute::BruteIndex;
    use crate::util::rng::Rng;

    #[test]
    fn exact_when_l_equals_n() {
        let s = generate(&SynthConfig {
            n: 200,
            d: 8,
            ..SynthConfig::tiny()
        });
        let brute = BruteIndex::new(&s);
        let q = s.row(0).to_vec();
        let mut rng = Rng::seeded(1);
        let mut ctx = EstimateContext::new(&s, &brute, &mut rng);
        let z = Uniform::new(200).estimate(&mut ctx, &q);
        let want = brute.partition(&q);
        assert!(
            (z - want).abs() < 1e-9 * want,
            "sampling all N without replacement is exact: {z} vs {want}"
        );
    }

    #[test]
    fn unbiased_over_many_runs_on_flat_data() {
        // On a *flat* query (all scores similar) uniform sampling works;
        // bias should vanish in the average over repetitions.
        let s = generate(&SynthConfig {
            n: 500,
            d: 8,
            norm_lo: 0.5,
            norm_hi: 0.6,
            ..SynthConfig::tiny()
        });
        let brute = BruteIndex::new(&s);
        let q = s.row(0).to_vec();
        let want = brute.partition(&q);
        let mut rng = Rng::seeded(2);
        let est = Uniform::new(50);
        let mut acc = 0f64;
        let reps = 200;
        for _ in 0..reps {
            let mut ctx = EstimateContext::new(&s, &brute, &mut rng);
            acc += est.estimate(&mut ctx, &q);
        }
        let mean = acc / reps as f64;
        assert!(
            abs_rel_err_pct(mean, want) < 5.0,
            "mean of repeated estimates should approach Z: {mean} vs {want}"
        );
    }

    #[test]
    fn high_error_on_peaked_query() {
        // A rare (peaked) query: a single uniform draw of l=10 almost
        // surely misses the head → large error, as in Table 1.
        let s = generate(&SynthConfig::tiny());
        let brute = BruteIndex::new(&s);
        let q = s.row(s.len() - 1).to_vec();
        let want = brute.partition(&q);
        let mut rng = Rng::seeded(3);
        let mut ctx = EstimateContext::new(&s, &brute, &mut rng);
        let z = Uniform::new(10).estimate(&mut ctx, &q);
        assert!(
            abs_rel_err_pct(z, want) > 30.0,
            "uniform sampling should fail on peaked distributions"
        );
    }
}
