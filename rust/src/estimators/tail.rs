//! Tail sampling shared by MIMPS, MINCE and Uniform: draw `l` distinct
//! categories uniformly from the complement of the retrieved head `S_k`
//! and score them exactly against the query.
//!
//! The hot path ([`sample_tail_into`]) writes into a reusable
//! [`TailScratch`] owned by the `EstimateContext`, so repeated estimates
//! perform no per-query allocation: membership is tracked in a word-packed
//! bitset that is cleared sparsely (only the words actually touched),
//! and the index/score buffers keep their capacity across calls. The
//! allocating [`sample_tail`] wrapper remains for one-off callers.
//!
//! Sampling is over **global** ids of a [`StoreView`], so the same draw
//! sequence serves monolithic and sharded stores — that is what makes
//! sampler estimates shard-layout-invariant under a fixed seed. The
//! shard-aware alternative, [`stratified_tail_z`], allocates the budget
//! across shards proportionally to their complement sizes (one uniform
//! stratum per shard) — same expectation, lower variance when shards
//! have heterogeneous score ranges, at the cost of draw sequences that
//! depend on the shard layout.

use crate::linalg;
use crate::mips::Hit;
use crate::store::{ShardedStore, StoreView};
use crate::util::rng::Rng;

/// A scored uniform tail sample (owning variant, see [`sample_tail`]).
pub struct TailSample {
    /// Category indices sampled (distinct, disjoint from the head).
    pub indices: Vec<usize>,
    /// exp(u_i · q) for each sampled index, in f64.
    pub exp_scores: Vec<f64>,
}

/// Reusable tail-sampling scratch: a lazily sized membership bitset plus
/// the sample output buffers. One instance lives in `EstimateContext`;
/// every [`sample_tail_into`] call reuses its allocations.
#[derive(Default)]
pub struct TailScratch {
    /// Word-packed membership bits over `[0, n)` (head ∪ already-drawn).
    bits: Vec<u64>,
    /// Words with at least one set bit — cleared sparsely between calls.
    touched: Vec<usize>,
    /// Category indices sampled by the last call.
    pub indices: Vec<usize>,
    /// exp(u_i · q) for each sampled index, in f64.
    pub exp_scores: Vec<f64>,
}

impl TailScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reset the sample buffers and clear only the bitset words that the
    /// previous call set.
    fn reset(&mut self, n: usize) {
        for &w in &self.touched {
            self.bits[w] = 0;
        }
        self.touched.clear();
        self.indices.clear();
        self.exp_scores.clear();
        let words = n.div_ceil(64);
        if self.bits.len() < words {
            self.bits.resize(words, 0);
        }
    }

    /// Mark `i`; returns false if it was already marked.
    #[inline]
    fn insert(&mut self, i: usize) -> bool {
        let (w, b) = (i / 64, 1u64 << (i % 64));
        let old = self.bits[w];
        if old & b != 0 {
            return false;
        }
        if old == 0 {
            self.touched.push(w);
        }
        self.bits[w] = old | b;
        true
    }

    #[inline]
    fn contains(&self, i: usize) -> bool {
        self.bits[i / 64] & (1u64 << (i % 64)) != 0
    }
}

/// Draw `l` distinct indices uniformly from `[0, n) \ head` into
/// `scratch.indices` **without scoring them**. This is the draw half of
/// [`sample_tail_into`] — exposed so shard-transparent consumers that
/// score elsewhere (the remote tail path in `net::remote` ships the
/// drawn ids to shard workers) consume the RNG in exactly the same
/// sequence as the in-process estimators.
pub fn sample_tail_ids(
    n: usize,
    head: &[Hit],
    l: usize,
    rng: &mut Rng,
    scratch: &mut TailScratch,
) {
    scratch.reset(n);
    if n == 0 {
        return;
    }
    let mut excluded = 0usize;
    for h in head {
        // Out-of-range hits (possible from a fault-injected index) are
        // ignored rather than sized into the bitset.
        if h.idx < n && scratch.insert(h.idx) {
            excluded += 1;
        }
    }
    let l = l.min(n - excluded);
    if l == 0 {
        return;
    }
    // Rejection-sample while the expected acceptance rate stays ≥ 3/4
    // (the bitset doubles as the seen-set); otherwise do an exact partial
    // Fisher–Yates over the materialized complement.
    if (excluded + l) * 4 <= n {
        while scratch.indices.len() < l {
            let i = rng.below(n);
            if scratch.insert(i) {
                scratch.indices.push(i);
            }
        }
    } else {
        let mut pool: Vec<usize> = (0..n).filter(|&i| !scratch.contains(i)).collect();
        for i in 0..l {
            let j = rng.range(i, pool.len());
            pool.swap(i, j);
            scratch.indices.push(pool[i]);
        }
    }
}

/// Draw `l` distinct indices uniformly from `[0, n) \ head`, score them,
/// and leave the result in `scratch.indices` / `scratch.exp_scores`.
pub fn sample_tail_into(
    store: &dyn StoreView,
    head: &[Hit],
    l: usize,
    q: &[f32],
    rng: &mut Rng,
    scratch: &mut TailScratch,
) {
    sample_tail_ids(store.len(), head, l, rng, scratch);
    for &i in &scratch.indices {
        scratch
            .exp_scores
            .push((linalg::dot(store.row(i), q) as f64).exp());
    }
}

/// Allocating wrapper around [`sample_tail_into`] for one-off callers.
pub fn sample_tail(
    store: &dyn StoreView,
    head: &[Hit],
    l: usize,
    q: &[f32],
    rng: &mut Rng,
) -> TailSample {
    let mut scratch = TailScratch::new();
    sample_tail_into(store, head, l, q, rng, &mut scratch);
    TailSample {
        indices: scratch.indices,
        exp_scores: scratch.exp_scores,
    }
}

/// Stratified tail estimate over a sharded store: an unbiased estimate of
/// `Σ_{u ∉ head} exp(u·q)` with one uniform stratum per shard.
///
/// Per shard `s` with complement size `C_s` (shard rows not in the head)
/// the budget share is `l_s ∝ C_s` (D'Hondt rounding, every non-empty
/// stratum gets ≥ 1), and the stratum contributes `(C_s / l_s) · Σ exp`
/// over its `l_s` distinct draws. Expectation telescopes to the true
/// tail sum per stratum, so the total stays unbiased; variance drops
/// when shards have heterogeneous tail ranges because no stratum can be
/// missed entirely. When `l` cannot cover every non-empty stratum the
/// function falls back to one global uniform stratum (still unbiased).
///
/// Draws land in `scratch.indices` / `scratch.exp_scores` (global ids),
/// like [`sample_tail_into`].
pub fn stratified_tail_z(
    store: &ShardedStore,
    head: &[Hit],
    l: usize,
    q: &[f32],
    rng: &mut Rng,
    scratch: &mut TailScratch,
) -> f64 {
    let n = StoreView::len(store);
    scratch.reset(n);
    if n == 0 || l == 0 {
        return 0.0;
    }
    // Mark the head once, counting exclusions per shard.
    let num_shards = store.num_shards();
    let mut head_in = vec![0usize; num_shards];
    let mut excluded = 0usize;
    for h in head {
        if h.idx < n && scratch.insert(h.idx) {
            head_in[store.shard_of(h.idx).0] += 1;
            excluded += 1;
        }
    }
    let caps: Vec<usize> = (0..num_shards)
        .map(|s| store.shard(s).len() - head_in[s])
        .collect();
    let c_total: usize = caps.iter().sum();
    if c_total == 0 {
        return 0.0;
    }
    let l = l.min(c_total);
    let strata = caps.iter().filter(|&&c| c > 0).count();
    if l < strata {
        // Too few draws to cover every stratum: one global stratum.
        drain_shard_sample(store, 0, n, excluded, l, q, rng, scratch);
        let sum: f64 = scratch.exp_scores.iter().sum();
        return c_total as f64 * sum / l as f64;
    }
    // Proportional allocation: seed every non-empty stratum with one
    // draw, then hand out the rest by D'Hondt quotients (cap-aware).
    let mut alloc: Vec<usize> = caps.iter().map(|&c| usize::from(c > 0)).collect();
    let mut rem = l - strata;
    while rem > 0 {
        let mut best = usize::MAX;
        let mut best_q = f64::NEG_INFINITY;
        for (s, (&c, &a)) in caps.iter().zip(&alloc).enumerate() {
            if a >= c {
                continue;
            }
            let quot = c as f64 / (a + 1) as f64;
            if quot > best_q {
                best_q = quot;
                best = s;
            }
        }
        debug_assert_ne!(best, usize::MAX, "l ≤ C_total guarantees spare capacity");
        alloc[best] += 1;
        rem -= 1;
    }
    // Sample each stratum and accumulate its weighted mass. The bitset
    // already holds the head; per-shard draws extend it.
    let mut z = 0f64;
    for s in 0..num_shards {
        if alloc[s] == 0 {
            continue;
        }
        let lo = store.shard(s).offset();
        let first = scratch.indices.len();
        drain_shard_sample(
            store,
            lo,
            store.shard(s).len(),
            head_in[s],
            alloc[s],
            q,
            rng,
            scratch,
        );
        let sum: f64 = scratch.exp_scores[first..].iter().sum();
        z += caps[s] as f64 * sum / alloc[s] as f64;
    }
    z
}

/// Draw `take` distinct unmarked global ids from `[lo, lo + len)`, score
/// them, and append to the scratch buffers. `marked` is the number of
/// already-set bits in the range (the caller tracked it while marking
/// the head — strata are visited once each, so no rescan is needed and
/// the draw stays O(k + l), not O(N)). Same rejection-vs-partial-
/// Fisher–Yates policy as [`sample_tail_into`], per stratum.
#[allow(clippy::too_many_arguments)]
fn drain_shard_sample(
    store: &ShardedStore,
    lo: usize,
    len: usize,
    marked: usize,
    take: usize,
    q: &[f32],
    rng: &mut Rng,
    scratch: &mut TailScratch,
) {
    let first = scratch.indices.len();
    if (marked + take) * 4 <= len {
        while scratch.indices.len() - first < take {
            let i = lo + rng.below(len);
            if scratch.insert(i) {
                scratch.indices.push(i);
            }
        }
    } else {
        let mut pool: Vec<usize> = (lo..lo + len).filter(|&i| !scratch.contains(i)).collect();
        let take = take.min(pool.len());
        for i in 0..take {
            let j = rng.range(i, pool.len());
            pool.swap(i, j);
            scratch.insert(pool[i]);
            scratch.indices.push(pool[i]);
        }
    }
    for pos in first..scratch.indices.len() {
        let i = scratch.indices[pos];
        scratch
            .exp_scores
            .push((linalg::dot(store.row(i), q) as f64).exp());
    }
}

/// Σ exp over the head hits, in f64 — the first term of eq. (4)/(5).
pub fn head_sum(head: &[Hit]) -> f64 {
    head.iter().map(|h| (h.score as f64).exp()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::mips::brute::BruteIndex;
    use crate::mips::MipsIndex;
    use std::collections::HashSet;

    #[test]
    fn tail_disjoint_from_head_and_distinct() {
        let s = generate(&SynthConfig {
            n: 300,
            d: 8,
            ..SynthConfig::tiny()
        });
        let idx = BruteIndex::new(&s);
        let q = s.row(0).to_vec();
        let head = idx.top_k(&q, 50);
        let mut rng = Rng::seeded(1);
        let tail = sample_tail(&s, &head, 100, &q, &mut rng);
        assert_eq!(tail.indices.len(), 100);
        let head_set: HashSet<usize> = head.iter().map(|h| h.idx).collect();
        let tail_set: HashSet<usize> = tail.indices.iter().copied().collect();
        assert_eq!(tail_set.len(), 100, "distinct");
        assert!(head_set.is_disjoint(&tail_set), "disjoint from head");
    }

    #[test]
    fn l_clamped_when_exceeding_complement() {
        let s = generate(&SynthConfig {
            n: 100,
            d: 8,
            ..SynthConfig::tiny()
        });
        let idx = BruteIndex::new(&s);
        let q = s.row(0).to_vec();
        let head = idx.top_k(&q, 90);
        let mut rng = Rng::seeded(2);
        let tail = sample_tail(&s, &head, 50, &q, &mut rng);
        assert_eq!(tail.indices.len(), 10, "only 10 non-head items exist");
    }

    #[test]
    fn scores_match_direct_computation() {
        let s = generate(&SynthConfig {
            n: 200,
            d: 8,
            ..SynthConfig::tiny()
        });
        let q = s.row(3).to_vec();
        let mut rng = Rng::seeded(3);
        let tail = sample_tail(&s, &[], 20, &q, &mut rng);
        for (i, &idx) in tail.indices.iter().enumerate() {
            let want = (linalg::dot(s.row(idx), &q) as f64).exp();
            assert!((tail.exp_scores[i] - want).abs() < 1e-12 * want);
        }
    }

    /// The scratch must fully reset between calls: a second sample with a
    /// different head must be disjoint from *its* head only, and the
    /// buffers must not accumulate across calls.
    #[test]
    fn scratch_reuse_is_clean_across_calls() {
        let s = generate(&SynthConfig {
            n: 400,
            d: 8,
            ..SynthConfig::tiny()
        });
        let idx = BruteIndex::new(&s);
        let q = s.row(1).to_vec();
        let mut rng = Rng::seeded(11);
        let mut scratch = TailScratch::new();
        let head_a = idx.top_k(&q, 40);
        sample_tail_into(&s, &head_a, 60, &q, &mut rng, &mut scratch);
        let first: HashSet<usize> = scratch.indices.iter().copied().collect();
        assert_eq!(first.len(), 60);

        let head_b = idx.top_k(&q, 5);
        sample_tail_into(&s, &head_b, 300, &q, &mut rng, &mut scratch);
        assert_eq!(scratch.indices.len(), 300, "buffers reset, not appended");
        assert_eq!(scratch.exp_scores.len(), 300);
        let head_b_set: HashSet<usize> = head_b.iter().map(|h| h.idx).collect();
        let second: HashSet<usize> = scratch.indices.iter().copied().collect();
        assert_eq!(second.len(), 300, "distinct within the call");
        assert!(head_b_set.is_disjoint(&second), "disjoint from current head");
        // Indices excluded in call 1 (head_a beyond head_b) must be
        // samplable again in call 2.
        assert!(
            second.iter().any(|i| !first.contains(i)),
            "new draws appear after reset"
        );
    }

    /// Matches the allocating wrapper draw-for-draw for the same seed.
    #[test]
    fn scratch_and_wrapper_agree_for_same_seed() {
        let s = generate(&SynthConfig {
            n: 500,
            d: 8,
            ..SynthConfig::tiny()
        });
        let idx = BruteIndex::new(&s);
        let q = s.row(7).to_vec();
        let head = idx.top_k(&q, 30);
        let a = {
            let mut rng = Rng::seeded(21);
            sample_tail(&s, &head, 50, &q, &mut rng)
        };
        let mut rng = Rng::seeded(21);
        let mut scratch = TailScratch::new();
        sample_tail_into(&s, &head, 50, &q, &mut rng, &mut scratch);
        assert_eq!(a.indices, scratch.indices);
        assert_eq!(a.exp_scores, scratch.exp_scores);
    }

    #[test]
    fn head_sum_exponentiates() {
        let head = vec![
            Hit { idx: 0, score: 0.0 },
            Hit { idx: 1, score: 1.0 },
        ];
        let want = 1.0 + std::f64::consts::E;
        assert!((head_sum(&head) - want).abs() < 1e-6);
    }
}
