//! Tail sampling shared by MIMPS, MINCE and Uniform: draw `l` distinct
//! categories uniformly from the complement of the retrieved head `S_k`
//! and score them exactly against the query.

use crate::data::embeddings::EmbeddingStore;
use crate::linalg;
use crate::mips::Hit;
use crate::util::rng::Rng;
use std::collections::HashSet;

/// A scored uniform tail sample.
pub struct TailSample {
    /// Category indices sampled (distinct, disjoint from the head).
    pub indices: Vec<usize>,
    /// exp(u_i · q) for each sampled index, in f64.
    pub exp_scores: Vec<f64>,
}

/// Draw `l` distinct indices uniformly from `[0, n) \ head` and score them.
pub fn sample_tail(
    store: &EmbeddingStore,
    head: &[Hit],
    l: usize,
    q: &[f32],
    rng: &mut Rng,
) -> TailSample {
    let head_set: HashSet<usize> = head.iter().map(|h| h.idx).collect();
    let n = store.len();
    let l = l.min(n.saturating_sub(head_set.len()));
    let indices = rng.sample_distinct_excluding(n, l, |i| head_set.contains(&i));
    let exp_scores = indices
        .iter()
        .map(|&i| (linalg::dot(store.row(i), q) as f64).exp())
        .collect();
    TailSample {
        indices,
        exp_scores,
    }
}

/// Σ exp over the head hits, in f64 — the first term of eq. (4)/(5).
pub fn head_sum(head: &[Hit]) -> f64 {
    head.iter().map(|h| (h.score as f64).exp()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::mips::brute::BruteIndex;
    use crate::mips::MipsIndex;

    #[test]
    fn tail_disjoint_from_head_and_distinct() {
        let s = generate(&SynthConfig {
            n: 300,
            d: 8,
            ..SynthConfig::tiny()
        });
        let idx = BruteIndex::new(&s);
        let q = s.row(0).to_vec();
        let head = idx.top_k(&q, 50);
        let mut rng = Rng::seeded(1);
        let tail = sample_tail(&s, &head, 100, &q, &mut rng);
        assert_eq!(tail.indices.len(), 100);
        let head_set: HashSet<usize> = head.iter().map(|h| h.idx).collect();
        let tail_set: HashSet<usize> = tail.indices.iter().copied().collect();
        assert_eq!(tail_set.len(), 100, "distinct");
        assert!(head_set.is_disjoint(&tail_set), "disjoint from head");
    }

    #[test]
    fn l_clamped_when_exceeding_complement() {
        let s = generate(&SynthConfig {
            n: 100,
            d: 8,
            ..SynthConfig::tiny()
        });
        let idx = BruteIndex::new(&s);
        let q = s.row(0).to_vec();
        let head = idx.top_k(&q, 90);
        let mut rng = Rng::seeded(2);
        let tail = sample_tail(&s, &head, 50, &q, &mut rng);
        assert_eq!(tail.indices.len(), 10, "only 10 non-head items exist");
    }

    #[test]
    fn scores_match_direct_computation() {
        let s = generate(&SynthConfig {
            n: 200,
            d: 8,
            ..SynthConfig::tiny()
        });
        let q = s.row(3).to_vec();
        let mut rng = Rng::seeded(3);
        let tail = sample_tail(&s, &[], 20, &q, &mut rng);
        for (i, &idx) in tail.indices.iter().enumerate() {
            let want = (linalg::dot(s.row(idx), &q) as f64).exp();
            assert!((tail.exp_scores[i] - want).abs() < 1e-12 * want);
        }
    }

    #[test]
    fn head_sum_exponentiates() {
        let head = vec![
            Hit { idx: 0, score: 0.0 },
            Hit { idx: 1, score: 1.0 },
        ];
        let want = 1.0 + std::f64::consts::E;
        assert!((head_sum(&head) - want).abs() < 1e-6);
    }
}
