//! Tail sampling shared by MIMPS, MINCE and Uniform: draw `l` distinct
//! categories uniformly from the complement of the retrieved head `S_k`
//! and score them exactly against the query.
//!
//! The hot path ([`sample_tail_into`]) writes into a reusable
//! [`TailScratch`] owned by the `EstimateContext`, so repeated estimates
//! perform no per-query allocation: membership is tracked in a word-packed
//! bitset that is cleared sparsely (only the words actually touched),
//! and the index/score buffers keep their capacity across calls. The
//! allocating [`sample_tail`] wrapper remains for one-off callers.

use crate::data::embeddings::EmbeddingStore;
use crate::linalg;
use crate::mips::Hit;
use crate::util::rng::Rng;

/// A scored uniform tail sample (owning variant, see [`sample_tail`]).
pub struct TailSample {
    /// Category indices sampled (distinct, disjoint from the head).
    pub indices: Vec<usize>,
    /// exp(u_i · q) for each sampled index, in f64.
    pub exp_scores: Vec<f64>,
}

/// Reusable tail-sampling scratch: a lazily sized membership bitset plus
/// the sample output buffers. One instance lives in `EstimateContext`;
/// every [`sample_tail_into`] call reuses its allocations.
#[derive(Default)]
pub struct TailScratch {
    /// Word-packed membership bits over `[0, n)` (head ∪ already-drawn).
    bits: Vec<u64>,
    /// Words with at least one set bit — cleared sparsely between calls.
    touched: Vec<usize>,
    /// Category indices sampled by the last call.
    pub indices: Vec<usize>,
    /// exp(u_i · q) for each sampled index, in f64.
    pub exp_scores: Vec<f64>,
}

impl TailScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reset the sample buffers and clear only the bitset words that the
    /// previous call set.
    fn reset(&mut self, n: usize) {
        for &w in &self.touched {
            self.bits[w] = 0;
        }
        self.touched.clear();
        self.indices.clear();
        self.exp_scores.clear();
        let words = n.div_ceil(64);
        if self.bits.len() < words {
            self.bits.resize(words, 0);
        }
    }

    /// Mark `i`; returns false if it was already marked.
    #[inline]
    fn insert(&mut self, i: usize) -> bool {
        let (w, b) = (i / 64, 1u64 << (i % 64));
        let old = self.bits[w];
        if old & b != 0 {
            return false;
        }
        if old == 0 {
            self.touched.push(w);
        }
        self.bits[w] = old | b;
        true
    }

    #[inline]
    fn contains(&self, i: usize) -> bool {
        self.bits[i / 64] & (1u64 << (i % 64)) != 0
    }
}

/// Draw `l` distinct indices uniformly from `[0, n) \ head`, score them,
/// and leave the result in `scratch.indices` / `scratch.exp_scores`.
pub fn sample_tail_into(
    store: &EmbeddingStore,
    head: &[Hit],
    l: usize,
    q: &[f32],
    rng: &mut Rng,
    scratch: &mut TailScratch,
) {
    let n = store.len();
    scratch.reset(n);
    if n == 0 {
        return;
    }
    let mut excluded = 0usize;
    for h in head {
        // Out-of-range hits (possible from a fault-injected index) are
        // ignored rather than sized into the bitset.
        if h.idx < n && scratch.insert(h.idx) {
            excluded += 1;
        }
    }
    let l = l.min(n - excluded);
    if l == 0 {
        return;
    }
    // Rejection-sample while the expected acceptance rate stays ≥ 3/4
    // (the bitset doubles as the seen-set); otherwise do an exact partial
    // Fisher–Yates over the materialized complement.
    if (excluded + l) * 4 <= n {
        while scratch.indices.len() < l {
            let i = rng.below(n);
            if scratch.insert(i) {
                scratch.indices.push(i);
            }
        }
    } else {
        let mut pool: Vec<usize> = (0..n).filter(|&i| !scratch.contains(i)).collect();
        for i in 0..l {
            let j = rng.range(i, pool.len());
            pool.swap(i, j);
            scratch.indices.push(pool[i]);
        }
    }
    for &i in &scratch.indices {
        scratch
            .exp_scores
            .push((linalg::dot(store.row(i), q) as f64).exp());
    }
}

/// Allocating wrapper around [`sample_tail_into`] for one-off callers.
pub fn sample_tail(
    store: &EmbeddingStore,
    head: &[Hit],
    l: usize,
    q: &[f32],
    rng: &mut Rng,
) -> TailSample {
    let mut scratch = TailScratch::new();
    sample_tail_into(store, head, l, q, rng, &mut scratch);
    TailSample {
        indices: scratch.indices,
        exp_scores: scratch.exp_scores,
    }
}

/// Σ exp over the head hits, in f64 — the first term of eq. (4)/(5).
pub fn head_sum(head: &[Hit]) -> f64 {
    head.iter().map(|h| (h.score as f64).exp()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::mips::brute::BruteIndex;
    use crate::mips::MipsIndex;
    use std::collections::HashSet;

    #[test]
    fn tail_disjoint_from_head_and_distinct() {
        let s = generate(&SynthConfig {
            n: 300,
            d: 8,
            ..SynthConfig::tiny()
        });
        let idx = BruteIndex::new(&s);
        let q = s.row(0).to_vec();
        let head = idx.top_k(&q, 50);
        let mut rng = Rng::seeded(1);
        let tail = sample_tail(&s, &head, 100, &q, &mut rng);
        assert_eq!(tail.indices.len(), 100);
        let head_set: HashSet<usize> = head.iter().map(|h| h.idx).collect();
        let tail_set: HashSet<usize> = tail.indices.iter().copied().collect();
        assert_eq!(tail_set.len(), 100, "distinct");
        assert!(head_set.is_disjoint(&tail_set), "disjoint from head");
    }

    #[test]
    fn l_clamped_when_exceeding_complement() {
        let s = generate(&SynthConfig {
            n: 100,
            d: 8,
            ..SynthConfig::tiny()
        });
        let idx = BruteIndex::new(&s);
        let q = s.row(0).to_vec();
        let head = idx.top_k(&q, 90);
        let mut rng = Rng::seeded(2);
        let tail = sample_tail(&s, &head, 50, &q, &mut rng);
        assert_eq!(tail.indices.len(), 10, "only 10 non-head items exist");
    }

    #[test]
    fn scores_match_direct_computation() {
        let s = generate(&SynthConfig {
            n: 200,
            d: 8,
            ..SynthConfig::tiny()
        });
        let q = s.row(3).to_vec();
        let mut rng = Rng::seeded(3);
        let tail = sample_tail(&s, &[], 20, &q, &mut rng);
        for (i, &idx) in tail.indices.iter().enumerate() {
            let want = (linalg::dot(s.row(idx), &q) as f64).exp();
            assert!((tail.exp_scores[i] - want).abs() < 1e-12 * want);
        }
    }

    /// The scratch must fully reset between calls: a second sample with a
    /// different head must be disjoint from *its* head only, and the
    /// buffers must not accumulate across calls.
    #[test]
    fn scratch_reuse_is_clean_across_calls() {
        let s = generate(&SynthConfig {
            n: 400,
            d: 8,
            ..SynthConfig::tiny()
        });
        let idx = BruteIndex::new(&s);
        let q = s.row(1).to_vec();
        let mut rng = Rng::seeded(11);
        let mut scratch = TailScratch::new();
        let head_a = idx.top_k(&q, 40);
        sample_tail_into(&s, &head_a, 60, &q, &mut rng, &mut scratch);
        let first: HashSet<usize> = scratch.indices.iter().copied().collect();
        assert_eq!(first.len(), 60);

        let head_b = idx.top_k(&q, 5);
        sample_tail_into(&s, &head_b, 300, &q, &mut rng, &mut scratch);
        assert_eq!(scratch.indices.len(), 300, "buffers reset, not appended");
        assert_eq!(scratch.exp_scores.len(), 300);
        let head_b_set: HashSet<usize> = head_b.iter().map(|h| h.idx).collect();
        let second: HashSet<usize> = scratch.indices.iter().copied().collect();
        assert_eq!(second.len(), 300, "distinct within the call");
        assert!(head_b_set.is_disjoint(&second), "disjoint from current head");
        // Indices excluded in call 1 (head_a beyond head_b) must be
        // samplable again in call 2.
        assert!(
            second.iter().any(|i| !first.contains(i)),
            "new draws appear after reset"
        );
    }

    /// Matches the allocating wrapper draw-for-draw for the same seed.
    #[test]
    fn scratch_and_wrapper_agree_for_same_seed() {
        let s = generate(&SynthConfig {
            n: 500,
            d: 8,
            ..SynthConfig::tiny()
        });
        let idx = BruteIndex::new(&s);
        let q = s.row(7).to_vec();
        let head = idx.top_k(&q, 30);
        let a = {
            let mut rng = Rng::seeded(21);
            sample_tail(&s, &head, 50, &q, &mut rng)
        };
        let mut rng = Rng::seeded(21);
        let mut scratch = TailScratch::new();
        sample_tail_into(&s, &head, 50, &q, &mut rng, &mut scratch);
        assert_eq!(a.indices, scratch.indices);
        assert_eq!(a.exp_scores, scratch.exp_scores);
    }

    #[test]
    fn head_sum_exponentiates() {
        let head = vec![
            Hit { idx: 0, score: 0.0 },
            Hit { idx: 1, score: 1.0 },
        ];
        let want = 1.0 + std::f64::consts::E;
        assert!((head_sum(&head) - want).abs() < 1e-6);
    }
}
