//! The paper's partition-function estimators (Section 4) plus baselines.
//!
//! All estimators implement [`Estimator`]: given the category matrix, a
//! MIPS index, and a query, produce Ẑ(q). The estimators differ in what
//! they retrieve and how they extrapolate the tail:
//!
//! | estimator | head | tail | paper |
//! |---|---|---|---|
//! | [`exact::Exact`] | all N | — | eq. (1), ground truth |
//! | [`uniform::Uniform`] | — | `N/l · Σ exp(u·q)` over `U_l` | §2 importance sampling, k=0 |
//! | [`nmimps::Nmimps`] | `Σ exp(s·q)` over `S_k` | — | eq. (4) |
//! | [`mimps::Mimps`] | `Σ exp(s·q)` over `S_k` | `(N−k)/l · Σ exp(u·q)` | eq. (5) |
//! | [`mince::Mince`] | `S_k` as "data" samples | `U_l` as noise | eq. (6)/(7), Newton/Halley |
//! | [`fmbe::Fmbe`] | — (no retrieval) | random feature maps | eq. (8)–(10) |

pub mod exact;
pub mod fmbe;
pub mod mimps;
pub mod mince;
pub mod nmimps;
pub mod powerlaw;
pub mod probability;
pub mod tail;
pub mod uniform;

use crate::mips::MipsIndex;
use crate::store::StoreView;
use crate::util::rng::Rng;

/// Everything an estimator may consult for one query (or query batch).
///
/// The category matrix is a [`StoreView`], so the same estimator code
/// serves a monolithic `EmbeddingStore` and an epoch-pinned
/// [`crate::store::ShardedStore`] — global ids, row access and exp-sum
/// streaming are shard-transparent (see `store` module docs for the
/// bit-stability contract).
pub struct EstimateContext<'a> {
    pub store: &'a dyn StoreView,
    pub index: &'a dyn MipsIndex,
    pub rng: &'a mut Rng,
    /// Reusable tail-sampling scratch (bitset + sample buffers) so the
    /// MIMPS/MINCE hot path allocates nothing per query after warmup.
    pub scratch: tail::TailScratch,
}

impl<'a> EstimateContext<'a> {
    pub fn new(store: &'a dyn StoreView, index: &'a dyn MipsIndex, rng: &'a mut Rng) -> Self {
        EstimateContext {
            store,
            index,
            rng,
            scratch: tail::TailScratch::new(),
        }
    }
}

/// A partition-function estimator.
pub trait Estimator: Send + Sync {
    /// Human-readable name with hyper-parameters, e.g. `MIMPS(k=100,l=10)`.
    fn name(&self) -> String;

    /// Estimate Ẑ(q).
    fn estimate(&self, ctx: &mut EstimateContext<'_>, q: &[f32]) -> f64;

    /// Estimate Ẑ for every query in `qs`, in order. The default loops
    /// over [`Estimator::estimate`]; batch-aware estimators (`Exact`,
    /// `Mimps`, `Fmbe`) override it to share one batched retrieval /
    /// scoring pass across the whole block, which is what the
    /// coordinator's dynamic batcher executes per drained batch.
    fn estimate_batch(&self, ctx: &mut EstimateContext<'_>, qs: &[Vec<f32>]) -> Vec<f64> {
        qs.iter().map(|q| self.estimate(ctx, q)).collect()
    }

    /// Number of category-vector scorings one estimate performs (index
    /// probes + tail samples) — the sublinearity measure that Table 4's
    /// Speedup compares against N.
    fn scorings(&self, n: usize) -> usize;
}

/// Registry of estimator kinds for CLI/service routing.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EstimatorKind {
    Exact,
    Uniform,
    Nmimps,
    Mimps,
    Mince,
    Fmbe,
}

/// Error of [`EstimatorKind::from_str`]: the name matched no kind.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct UnknownEstimatorKind(String);

impl std::fmt::Display for UnknownEstimatorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "unknown estimator kind {:?} (want one of exact, uniform, nmimps, mimps, mince, fmbe)",
            self.0
        )
    }
}

impl std::error::Error for UnknownEstimatorKind {}

impl std::str::FromStr for EstimatorKind {
    type Err = UnknownEstimatorKind;

    /// Case-insensitive kind name, e.g. `"mimps".parse::<EstimatorKind>()`.
    fn from_str(s: &str) -> Result<EstimatorKind, UnknownEstimatorKind> {
        match s.to_ascii_lowercase().as_str() {
            "exact" => Ok(EstimatorKind::Exact),
            "uniform" => Ok(EstimatorKind::Uniform),
            "nmimps" => Ok(EstimatorKind::Nmimps),
            "mimps" => Ok(EstimatorKind::Mimps),
            "mince" => Ok(EstimatorKind::Mince),
            "fmbe" => Ok(EstimatorKind::Fmbe),
            _ => Err(UnknownEstimatorKind(s.to_string())),
        }
    }
}

impl EstimatorKind {
    /// `Option`-shaped wrapper around the [`std::str::FromStr`] impl.
    pub fn parse(s: &str) -> Option<EstimatorKind> {
        s.parse().ok()
    }

    pub fn all() -> &'static [EstimatorKind] {
        &[
            EstimatorKind::Exact,
            EstimatorKind::Uniform,
            EstimatorKind::Nmimps,
            EstimatorKind::Mimps,
            EstimatorKind::Mince,
            EstimatorKind::Fmbe,
        ]
    }
}

impl std::fmt::Display for EstimatorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for k in EstimatorKind::all() {
            let s = k.to_string();
            assert_eq!(EstimatorKind::parse(&s), Some(*k), "{s}");
            assert_eq!(s.parse::<EstimatorKind>(), Ok(*k), "{s}");
            assert_eq!(s.to_ascii_uppercase().parse::<EstimatorKind>(), Ok(*k));
        }
        assert_eq!(EstimatorKind::parse("bogus"), None);
        let err = "bogus".parse::<EstimatorKind>().unwrap_err();
        assert!(err.to_string().contains("bogus"), "{err}");
    }
}
