//! Naive MIMPS (paper eq. 4): head-only sum over the retrieved `S_k(q)`.
//!
//! The paper's Figure 1 shows why this estimator "requires k to be very
//! high and is not realistic": common-word queries induce flat
//! distributions where the top-1000 categories carry only a small
//! fraction of Z. It is kept as a baseline and as the head term shared
//! with full MIMPS.

use super::{tail, EstimateContext, Estimator};

/// Head-only estimator with head size `k`.
#[derive(Clone, Copy, Debug)]
pub struct Nmimps {
    pub k: usize,
}

impl Nmimps {
    pub fn new(k: usize) -> Self {
        Nmimps { k }
    }
}

impl Estimator for Nmimps {
    fn name(&self) -> String {
        format!("NMIMPS(k={})", self.k)
    }

    fn estimate(&self, ctx: &mut EstimateContext<'_>, q: &[f32]) -> f64 {
        let head = ctx.index.top_k(q, self.k);
        tail::head_sum(&head)
    }

    fn scorings(&self, n: usize) -> usize {
        self.k.min(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::mips::brute::BruteIndex;
    use crate::util::rng::Rng;

    #[test]
    fn k_equals_n_is_exact() {
        let s = generate(&SynthConfig {
            n: 300,
            d: 8,
            ..SynthConfig::tiny()
        });
        let brute = BruteIndex::new(&s);
        let q = s.row(5).to_vec();
        let mut rng = Rng::seeded(0);
        let mut ctx = EstimateContext::new(&s, &brute, &mut rng);
        let z = Nmimps::new(300).estimate(&mut ctx, &q);
        let want = brute.partition(&q);
        assert!((z - want).abs() < 1e-6 * want);
    }

    #[test]
    fn always_underestimates() {
        let s = generate(&SynthConfig::tiny());
        let brute = BruteIndex::new(&s);
        let mut rng = Rng::seeded(1);
        for qi in [0usize, 500, 1999] {
            let q = s.row(qi).to_vec();
            let mut ctx = EstimateContext::new(&s, &brute, &mut rng);
            let z = Nmimps::new(50).estimate(&mut ctx, &q);
            let want = brute.partition(&q);
            assert!(z < want, "head-only sum must underestimate Z");
            assert!(z > 0.0);
        }
    }

    #[test]
    fn monotone_in_k() {
        let s = generate(&SynthConfig::tiny());
        let brute = BruteIndex::new(&s);
        let q = s.row(100).to_vec();
        let mut rng = Rng::seeded(2);
        let mut prev = 0.0;
        for k in [1usize, 10, 100, 1000] {
            let mut ctx = EstimateContext::new(&s, &brute, &mut rng);
            let z = Nmimps::new(k).estimate(&mut ctx, &q);
            assert!(z >= prev, "head sum must grow with k");
            prev = z;
        }
    }
}
