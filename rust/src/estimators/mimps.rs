//! MIMPS — MIPS-based importance sampling, the paper's main estimator
//! (eq. 5):
//!
//! ```text
//! Ẑ = Σ_{s∈S_k(q)} exp(s·q)  +  (N−k)/l · Σ_{u∈U_l} exp(u·q)
//! ```
//!
//! The head is summed exactly from the top-k retrieval; the tail is
//! corrected by a uniform sample over the `N−k` remaining categories —
//! "in effect we are assuming that the values at the tail end of the
//! probability distribution lie in a small range and thus a small sample
//! size still has a small variance."
//!
//! `Ẑ` is unbiased whenever the retrieval is exact: the head term is
//! deterministic and the tail term is a uniform-sample mean scaled by the
//! tail population size (tested in `unbiased_tail_correction`).

use super::{tail, EstimateContext, Estimator};
use crate::mips::Hit;

/// MIMPS estimator with head size `k` and tail sample size `l`.
///
/// `stratified` switches the tail correction to per-shard stratified
/// sampling ([`tail::stratified_tail_z`]) when the context's store is a
/// [`crate::store::ShardedStore`]: the `l` budget is split across shards
/// proportionally to their complement sizes, so no shard's tail mass can
/// be missed entirely. Same expectation as the global draw (unbiased),
/// lower variance on heterogeneous shards — but the draw sequence then
/// depends on the shard layout, so only the default global mode is
/// shard-count-invariant under a fixed seed.
#[derive(Clone, Copy, Debug)]
pub struct Mimps {
    pub k: usize,
    pub l: usize,
    pub stratified: bool,
}

impl Mimps {
    pub fn new(k: usize, l: usize) -> Self {
        Mimps {
            k,
            l,
            stratified: false,
        }
    }

    /// Shard-stratified tail sampling (falls back to the global draw on
    /// monolithic stores).
    pub fn stratified(k: usize, l: usize) -> Self {
        Mimps {
            k,
            l,
            stratified: true,
        }
    }

    /// Head-sum + sampled tail correction for one already-retrieved head.
    /// Shared by the single and batched paths so both consume the RNG
    /// identically and batch-vs-single results agree.
    fn finish(&self, ctx: &mut EstimateContext<'_>, q: &[f32], head: &[Hit]) -> f64 {
        let n = ctx.store.len();
        let head_z = tail::head_sum(head);
        let k_eff = head.len();
        if k_eff >= n || self.l == 0 {
            return head_z;
        }
        if self.stratified {
            let store = ctx.store;
            if let Some(sharded) = store.as_sharded() {
                let tail_z =
                    tail::stratified_tail_z(sharded, head, self.l, q, ctx.rng, &mut ctx.scratch);
                return head_z + tail_z;
            }
        }
        tail::sample_tail_into(ctx.store, head, self.l, q, ctx.rng, &mut ctx.scratch);
        let drawn = ctx.scratch.indices.len();
        if drawn == 0 {
            return head_z;
        }
        let tail_mean: f64 = ctx.scratch.exp_scores.iter().sum::<f64>() / drawn as f64;
        head_z + (n - k_eff) as f64 * tail_mean
    }
}

impl Estimator for Mimps {
    fn name(&self) -> String {
        format!("MIMPS(k={},l={})", self.k, self.l)
    }

    fn estimate(&self, ctx: &mut EstimateContext<'_>, q: &[f32]) -> f64 {
        let head = ctx.index.top_k(q, self.k);
        self.finish(ctx, q, &head)
    }

    /// Batched MIMPS: one `top_k_batch` retrieval pass (the multi-query
    /// GEMM on batch-aware indexes) shared by the whole block, then the
    /// per-query tail correction in submission order.
    fn estimate_batch(&self, ctx: &mut EstimateContext<'_>, qs: &[Vec<f32>]) -> Vec<f64> {
        let heads = ctx.index.top_k_batch(qs, self.k);
        qs.iter()
            .zip(&heads)
            .map(|(q, head)| self.finish(ctx, q, head))
            .collect()
    }

    fn scorings(&self, n: usize) -> usize {
        (self.k + self.l).min(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::metrics::abs_rel_err_pct;
    use crate::mips::brute::BruteIndex;
    use crate::util::rng::Rng;

    fn setup() -> (crate::data::embeddings::EmbeddingStore, BruteIndex) {
        let s = generate(&SynthConfig::tiny());
        let b = BruteIndex::new(&s);
        (s, b)
    }

    #[test]
    fn exact_when_k_plus_l_covers_n() {
        let s = generate(&SynthConfig {
            n: 200,
            d: 8,
            ..SynthConfig::tiny()
        });
        let brute = BruteIndex::new(&s);
        let q = s.row(11).to_vec();
        let mut rng = Rng::seeded(0);
        let mut ctx = EstimateContext::new(&s, &brute, &mut rng);
        // k + l = N → the tail sample is the whole complement → exact.
        let z = Mimps::new(120, 80).estimate(&mut ctx, &q);
        let want = brute.partition(&q);
        assert!((z - want).abs() < 1e-9 * want, "{z} vs {want}");
    }

    #[test]
    fn unbiased_tail_correction() {
        // Average over many reruns approaches Z (the estimator is unbiased
        // given exact retrieval).
        let (s, brute) = setup();
        let q = s.row(1500).to_vec();
        let want = brute.partition(&q);
        let est = Mimps::new(100, 50);
        let mut rng = Rng::seeded(5);
        let mut acc = 0f64;
        let reps = 300;
        for _ in 0..reps {
            let mut ctx = EstimateContext::new(&s, &brute, &mut rng);
            acc += est.estimate(&mut ctx, &q);
        }
        let mean = acc / reps as f64;
        assert!(
            abs_rel_err_pct(mean, want) < 3.0,
            "MIMPS mean {mean} should be ≈ Z {want}"
        );
    }

    #[test]
    fn beats_uniform_on_peaked_queries() {
        let (s, brute) = setup();
        let est_m = Mimps::new(100, 100);
        let est_u = super::super::uniform::Uniform::new(200);
        let mut rng = Rng::seeded(7);
        let mut err_m = 0f64;
        let mut err_u = 0f64;
        // Rare tokens → peaked distributions (the paper's main regime).
        for qi in (1600..1900).step_by(30) {
            let q = s.row(qi).to_vec();
            let want = brute.partition(&q);
            let mut ctx = EstimateContext::new(&s, &brute, &mut rng);
            err_m += abs_rel_err_pct(est_m.estimate(&mut ctx, &q), want);
            let mut ctx = EstimateContext::new(&s, &brute, &mut rng);
            err_u += abs_rel_err_pct(est_u.estimate(&mut ctx, &q), want);
        }
        assert!(
            err_m < err_u / 3.0,
            "MIMPS ({err_m}) must beat Uniform ({err_u}) at equal budget"
        );
    }

    #[test]
    fn l_zero_degrades_to_nmimps() {
        let (s, brute) = setup();
        let q = s.row(42).to_vec();
        let mut rng = Rng::seeded(9);
        let mut ctx = EstimateContext::new(&s, &brute, &mut rng);
        let a = Mimps::new(64, 0).estimate(&mut ctx, &q);
        let mut ctx = EstimateContext::new(&s, &brute, &mut rng);
        let b = super::super::nmimps::Nmimps::new(64).estimate(&mut ctx, &q);
        assert_eq!(a, b);
    }

    #[test]
    fn scorings_reflect_budget() {
        assert_eq!(Mimps::new(100, 50).scorings(10_000), 150);
        assert_eq!(Mimps::new(100, 50).scorings(120), 120);
    }

    /// Batch and single paths share `finish()` and consume the RNG in the
    /// same order, so with identical seeds they must agree (tolerance
    /// covers last-ulp GEMM-vs-GEMV head-score differences on the scalar
    /// fallback).
    #[test]
    fn batch_matches_single_with_same_seed() {
        let (s, brute) = setup();
        let est = Mimps::new(60, 40);
        let qs: Vec<Vec<f32>> = (0..6).map(|i| s.row(300 * i + 11).to_vec()).collect();
        let singles: Vec<f64> = {
            let mut rng = Rng::seeded(77);
            let mut ctx = EstimateContext::new(&s, &brute, &mut rng);
            qs.iter().map(|q| est.estimate(&mut ctx, q)).collect()
        };
        let mut rng = Rng::seeded(77);
        let mut ctx = EstimateContext::new(&s, &brute, &mut rng);
        let batched = est.estimate_batch(&mut ctx, &qs);
        for (a, b) in singles.iter().zip(&batched) {
            assert!((a - b).abs() <= 1e-3 * a.abs(), "single {a} vs batched {b}");
        }
    }
}
