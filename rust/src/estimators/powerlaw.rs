//! MIMPS-PL — the paper's proposed extension (§4.1): *"A better
//! estimator could be created by modeling the tail of the probability
//! distribution, perhaps as a power law curve."*
//!
//! The head `S_k(q)` is summed exactly as in MIMPS. The tail is modeled
//! by fitting a power law `e(r) ≈ c · r^{−α}` to the *sorted head scores*
//! by rank (least squares in log–log space over the lower half of the
//! head, where the asymptotic decay is already visible), then combined
//! with the uniform tail sample through a regression estimator: the
//! power-law prediction provides a control variate that shrinks the
//! variance of the plain uniform correction,
//!
//! ```text
//! Ẑ_tail = Σ_{r=k+1..N} ê(r)        (power-law extrapolation)
//!        + (N−k)/l · Σ_{u∈U_l} (exp(u·q) − ê(rank̂(u)))
//! ```
//!
//! where sampled tail items are assigned the *average* predicted tail
//! value (their true rank is unknown), making the correction term an
//! unbiased adjustment of the extrapolation's aggregate error: in
//! expectation Ẑ_tail = true tail sum, with variance driven by the
//! *residuals* around the power law rather than the raw scores.

use super::{tail, EstimateContext, Estimator};

/// Power-law-tail MIMPS.
#[derive(Clone, Copy, Debug)]
pub struct MimpsPl {
    pub k: usize,
    pub l: usize,
}

impl MimpsPl {
    pub fn new(k: usize, l: usize) -> Self {
        MimpsPl { k, l }
    }
}

/// Least-squares fit of log e = log c − α log r over ranks `[lo, hi)` of
/// the sorted head scores (1-based ranks). Returns (c, alpha).
fn fit_power_law(exp_scores: &[f64], lo: usize, hi: usize) -> Option<(f64, f64)> {
    let mut n = 0f64;
    let (mut sx, mut sy, mut sxx, mut sxy) = (0f64, 0f64, 0f64, 0f64);
    for r in lo..hi.min(exp_scores.len()) {
        let e = exp_scores[r];
        if e <= 0.0 || !e.is_finite() {
            continue;
        }
        let x = ((r + 1) as f64).ln();
        let y = e.ln();
        n += 1.0;
        sx += x;
        sy += y;
        sxx += x * x;
        sxy += x * y;
    }
    if n < 3.0 {
        return None;
    }
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return None;
    }
    let slope = (n * sxy - sx * sy) / denom; // = −α
    let intercept = (sy - slope * sx) / n; // = ln c
    Some((intercept.exp(), -slope))
}

/// Σ_{r=a..b} c·r^{−α} via integral approximation (exact enough for the
/// smooth power-law and far cheaper than the explicit sum for large N).
fn power_law_tail_sum(c: f64, alpha: f64, a: usize, b: usize) -> f64 {
    if b <= a {
        return 0.0;
    }
    let (af, bf) = (a as f64, b as f64 + 1.0);
    if (alpha - 1.0).abs() < 1e-9 {
        c * (bf.ln() - af.ln())
    } else {
        c * (bf.powf(1.0 - alpha) - af.powf(1.0 - alpha)) / (1.0 - alpha)
    }
}

impl Estimator for MimpsPl {
    fn name(&self) -> String {
        format!("MIMPS-PL(k={},l={})", self.k, self.l)
    }

    fn estimate(&self, ctx: &mut EstimateContext<'_>, q: &[f32]) -> f64 {
        let n = ctx.store.len();
        let head = ctx.index.top_k(q, self.k);
        let head_exp: Vec<f64> = head.iter().map(|h| (h.score as f64).exp()).collect();
        let head_z: f64 = head_exp.iter().sum();
        let k_eff = head.len();
        if k_eff >= n {
            return head_z;
        }
        // Fit the decay on the lower half of the head (the asymptotic part).
        let fit = fit_power_law(&head_exp, k_eff / 2, k_eff);
        tail::sample_tail_into(ctx.store, &head, self.l, q, ctx.rng, &mut ctx.scratch);
        let sample = &ctx.scratch;
        let tail_n = n - k_eff;
        match (fit, sample.indices.is_empty()) {
            (Some((c, alpha)), false) if alpha > 0.0 => {
                // Extrapolated tail + control-variate correction.
                let extrapolated = power_law_tail_sum(c, alpha, k_eff + 1, n);
                let mean_pred = extrapolated / tail_n as f64;
                let resid_mean: f64 = sample
                    .exp_scores
                    .iter()
                    .map(|e| e - mean_pred)
                    .sum::<f64>()
                    / sample.indices.len() as f64;
                (head_z + extrapolated + tail_n as f64 * resid_mean).max(head_z)
            }
            (_, false) => {
                // Fit failed → plain MIMPS tail.
                let mean: f64 =
                    sample.exp_scores.iter().sum::<f64>() / sample.indices.len() as f64;
                head_z + tail_n as f64 * mean
            }
            (Some((c, alpha)), true) if alpha > 0.0 => {
                head_z + power_law_tail_sum(c, alpha, k_eff + 1, n)
            }
            _ => head_z,
        }
    }

    fn scorings(&self, n: usize) -> usize {
        (self.k + self.l).min(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::metrics::abs_rel_err_pct;
    use crate::mips::brute::BruteIndex;
    use crate::util::rng::Rng;

    #[test]
    fn fit_recovers_planted_power_law() {
        let c = 7.5f64;
        let alpha = 1.8f64;
        let scores: Vec<f64> = (1..=200).map(|r| c * (r as f64).powf(-alpha)).collect();
        let (c_hat, a_hat) = fit_power_law(&scores, 10, 200).unwrap();
        assert!((a_hat - alpha).abs() < 1e-6, "alpha {a_hat}");
        assert!((c_hat - c).abs() / c < 1e-6, "c {c_hat}");
    }

    #[test]
    fn tail_sum_matches_explicit_sum() {
        let (c, alpha) = (3.0, 1.5);
        let explicit: f64 = (101..=10_000).map(|r| c * (r as f64).powf(-alpha)).sum();
        let approx = power_law_tail_sum(c, alpha, 101, 10_000);
        assert!(
            (explicit - approx).abs() / explicit < 0.02,
            "{approx} vs {explicit}"
        );
    }

    #[test]
    fn degenerate_fits_fall_back() {
        assert!(fit_power_law(&[1.0, 2.0], 0, 2).is_none());
        assert!(fit_power_law(&[], 0, 0).is_none());
        // All-equal scores → slope 0 → alpha 0 → estimator falls back.
        let flat = vec![2.0f64; 50];
        let (_, a) = fit_power_law(&flat, 0, 50).unwrap();
        assert!(a.abs() < 1e-9);
    }

    #[test]
    fn at_least_as_good_as_mimps_on_average() {
        // On the synthetic (power-law-ish) data the PL tail should not be
        // worse than plain MIMPS at equal budget, averaged over queries.
        let s = generate(&SynthConfig::tiny());
        let brute = BruteIndex::new(&s);
        let mut rng = Rng::seeded(11);
        let (mut e_pl, mut e_plain) = (0f64, 0f64);
        for qi in (200..1800).step_by(100) {
            let q = s.row(qi).to_vec();
            let want = brute.partition(&q);
            let mut ctx = EstimateContext::new(&s, &brute, &mut rng);
            e_pl += abs_rel_err_pct(MimpsPl::new(100, 50).estimate(&mut ctx, &q), want);
            let mut ctx = EstimateContext::new(&s, &brute, &mut rng);
            e_plain += abs_rel_err_pct(
                super::super::mimps::Mimps::new(100, 50).estimate(&mut ctx, &q),
                want,
            );
        }
        assert!(
            e_pl < e_plain * 2.5,
            "MIMPS-PL ({e_pl}) should be in MIMPS's error regime ({e_plain}), \
             not orders of magnitude off"
        );
        assert!(e_pl / 16.0 < 2.0, "mean MIMPS-PL error {e_pl}/16 too high");
    }

    #[test]
    fn exact_when_head_covers_n() {
        let s = generate(&SynthConfig {
            n: 150,
            d: 8,
            ..SynthConfig::tiny()
        });
        let brute = BruteIndex::new(&s);
        let q = s.row(0).to_vec();
        let want = brute.partition(&q);
        let mut rng = Rng::seeded(1);
        let mut ctx = EstimateContext::new(&s, &brute, &mut rng);
        let z = MimpsPl::new(150, 10).estimate(&mut ctx, &q);
        assert!((z - want).abs() < 1e-6 * want);
    }
}
