//! MINCE — MIPS-based Noise-Contrastive Estimation (paper §4.2).
//!
//! `Z` is treated as the single free parameter of the unnormalized head
//! distribution: the retrieved `S_k(q)` plays the role of "data" samples
//! and a uniform draw `U_l` over the complement plays the noise. With
//! noise density `1/(N−k)` and noise ratio `ν = l/k`, the NCE objective
//! (paper eq. 6) simplifies to eq. (7):
//!
//! ```text
//! −J(Z) = Σ_{i∈S_k} log(Z/a_i + 1) + Σ_{j∈U_l} log(b_j/Z + 1)
//! a_i = exp(s_i·q)·k(N−k)/l      b_j = exp(u_j·q)·k(N−k)/l
//! ```
//!
//! The minimizer is found by safeguarded Newton or **Halley** iterations
//! on `f'(Z) = 0` — the paper notes "efficient computation of the third
//! derivative utilized through Halley's method leads to considerable
//! speedup during optimization compared to ... Newton's method", which
//! the `ablations` bench quantifies.
//!
//! The paper's empirical finding — MINCE errors of 10²–10⁵% that *worsen*
//! with k at large l (Table 1) — is a property of using top-k sets as
//! "data samples" (they are not samples from the model distribution);
//! the reproduction exhibits the same failure mode.

use super::{tail, EstimateContext, Estimator};

/// Root-finding method for the NCE objective.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Solver {
    Newton,
    Halley,
}

/// MINCE estimator with head size `k`, noise size `l`, and solver choice.
#[derive(Clone, Copy, Debug)]
pub struct Mince {
    pub k: usize,
    pub l: usize,
    pub solver: Solver,
}

impl Mince {
    pub fn new(k: usize, l: usize) -> Self {
        Mince {
            k,
            l,
            solver: Solver::Halley,
        }
    }

    pub fn with_solver(k: usize, l: usize, solver: Solver) -> Self {
        Mince { k, l, solver }
    }
}

/// First three derivatives of f(Z) = Σ log(Z/a_i + 1) + Σ log(b_j/Z + 1).
/// Returns (f', f'', f''').
fn derivatives(z: f64, a: &[f64], b: &[f64]) -> (f64, f64, f64) {
    let mut g = 0f64; // f'
    let mut g1 = 0f64; // f''
    let mut g2 = 0f64; // f'''
    for &ai in a {
        let t = 1.0 / (z + ai);
        g += t;
        g1 -= t * t;
        g2 += 2.0 * t * t * t;
    }
    let inv_z = 1.0 / z;
    let (mut s0, mut s1, mut s2) = (0f64, 0f64, 0f64);
    for &bj in b {
        let t = 1.0 / (z + bj);
        // d/dZ log(b/Z + 1) = 1/(Z+b) − 1/Z
        s0 += t - inv_z;
        s1 += -t * t + inv_z * inv_z;
        s2 += 2.0 * t * t * t - 2.0 * inv_z * inv_z * inv_z;
    }
    (g + s0, g1 + s1, g2 + s2)
}

/// Objective value (for safeguarding / tests).
#[cfg_attr(not(test), allow(dead_code))]
fn objective(z: f64, a: &[f64], b: &[f64]) -> f64 {
    let mut f = 0f64;
    for &ai in a {
        f += (z / ai + 1.0).ln();
    }
    for &bj in b {
        f += (bj / z + 1.0).ln();
    }
    f
}

/// Result of one solve: the estimate plus iteration count (for the
/// Halley-vs-Newton ablation).
#[derive(Clone, Copy, Debug)]
pub struct SolveResult {
    pub z: f64,
    pub iterations: usize,
}

/// Safeguarded root-find of f'(Z)=0 on Z>0: bracket the root, then run
/// Newton/Halley with bisection fallback when a step leaves the bracket.
pub fn solve(a: &[f64], b: &[f64], z0: f64, solver: Solver) -> SolveResult {
    assert!(!a.is_empty() && !b.is_empty(), "MINCE needs data and noise");
    // Bracket: f'(Z) < 0 for small Z (noise term ~ −l/Z) and > 0 for large
    // Z (data term ~ k/Z dominates). Expand geometrically from z0.
    let mut lo = z0.max(1e-300);
    let mut iters = 0usize;
    while derivatives(lo, a, b).0 > 0.0 && lo > 1e-280 {
        lo *= 0.125;
        iters += 1;
        if iters > 400 {
            break;
        }
    }
    let mut hi = z0.max(lo * 2.0);
    while derivatives(hi, a, b).0 < 0.0 && hi < 1e280 {
        hi *= 8.0;
        iters += 1;
        if iters > 800 {
            break;
        }
    }
    let mut z = (lo * hi).sqrt().clamp(lo, hi);
    for _ in 0..100 {
        iters += 1;
        let (g, g1, g2) = derivatives(z, a, b);
        if g.abs() < 1e-12 * (1.0 + z.abs()) {
            break;
        }
        // Maintain the bracket.
        if g < 0.0 {
            lo = z;
        } else {
            hi = z;
        }
        let step = match solver {
            Solver::Newton => {
                if g1.abs() < f64::MIN_POSITIVE {
                    f64::NAN
                } else {
                    -g / g1
                }
            }
            Solver::Halley => {
                let denom = 2.0 * g1 * g1 - g * g2;
                if denom.abs() < f64::MIN_POSITIVE {
                    f64::NAN
                } else {
                    -2.0 * g * g1 / denom
                }
            }
        };
        let cand = z + step;
        let next = if cand.is_finite() && cand > lo && cand < hi {
            cand
        } else {
            // Bisect (geometric mean keeps scale-invariance on (0,∞)).
            (lo * hi).sqrt()
        };
        if (next - z).abs() < 1e-14 * (1.0 + z.abs()) {
            z = next;
            break;
        }
        z = next;
    }
    SolveResult { z, iterations: iters }
}

impl Estimator for Mince {
    fn name(&self) -> String {
        format!("MINCE(k={},l={})", self.k, self.l)
    }

    fn estimate(&self, ctx: &mut EstimateContext<'_>, q: &[f32]) -> f64 {
        let n = ctx.store.len();
        let head = ctx.index.top_k(q, self.k);
        let k_eff = head.len().max(1);
        tail::sample_tail_into(ctx.store, &head, self.l, q, ctx.rng, &mut ctx.scratch);
        if ctx.scratch.indices.is_empty() {
            // Degenerate: no complement to sample; fall back to head sum.
            return tail::head_sum(&head);
        }
        let l_eff = ctx.scratch.indices.len();
        // a_i, b_j with the k(N−k)/l scaling from eq. (7).
        let scale = k_eff as f64 * (n - k_eff) as f64 / l_eff as f64;
        let a: Vec<f64> = head
            .iter()
            .map(|h| (h.score as f64).exp() * scale)
            .collect();
        let b: Vec<f64> = ctx.scratch.exp_scores.iter().map(|e| e * scale).collect();
        let z0 = tail::head_sum(&head).max(1e-12);
        solve(&a, &b, z0, self.solver).z
    }

    fn scorings(&self, n: usize) -> usize {
        (self.k + self.l).min(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::mips::brute::BruteIndex;
    use crate::util::rng::Rng;

    /// The solver must find a stationary point of the objective.
    #[test]
    fn solver_reaches_stationary_point() {
        let a = vec![100.0, 80.0, 60.0, 40.0];
        let b = vec![1.0, 2.0, 0.5, 1.5, 0.8];
        for solver in [Solver::Newton, Solver::Halley] {
            let r = solve(&a, &b, 50.0, solver);
            let (g, _, _) = derivatives(r.z, &a, &b);
            assert!(
                g.abs() < 1e-6,
                "{solver:?}: f'({}) = {g} not ~0 after {} iters",
                r.z,
                r.iterations
            );
            // Local minimum: f is larger on either side.
            let f = objective(r.z, &a, &b);
            assert!(objective(r.z * 1.01, &a, &b) >= f - 1e-12);
            assert!(objective(r.z * 0.99, &a, &b) >= f - 1e-12);
        }
    }

    #[test]
    fn newton_and_halley_agree() {
        let a = vec![250.0, 90.0, 30.0];
        let b = vec![3.0, 9.0, 1.0, 2.0];
        let zn = solve(&a, &b, 100.0, Solver::Newton).z;
        let zh = solve(&a, &b, 100.0, Solver::Halley).z;
        assert!(
            (zn - zh).abs() < 1e-6 * zn.max(zh),
            "Newton {zn} vs Halley {zh}"
        );
    }

    #[test]
    fn halley_no_slower_than_newton() {
        // Averaged over random instances, Halley's cubic convergence needs
        // no more iterations than Newton (usually fewer).
        let mut rng = Rng::seeded(4);
        let (mut tn, mut th) = (0usize, 0usize);
        for _ in 0..50 {
            let a: Vec<f64> = (0..20).map(|_| (rng.normal() * 2.0).exp() * 50.0).collect();
            let b: Vec<f64> = (0..40).map(|_| (rng.normal()).exp()).collect();
            tn += solve(&a, &b, 10.0, Solver::Newton).iterations;
            th += solve(&a, &b, 10.0, Solver::Halley).iterations;
        }
        assert!(
            th <= tn,
            "Halley total iters {th} should not exceed Newton {tn}"
        );
    }

    #[test]
    fn solver_robust_to_extreme_scales() {
        // Huge data scores, tiny noise scores — bracket expansion must cope.
        let a = vec![1e12, 5e11];
        let b = vec![1e-9, 2e-9, 5e-10];
        let r = solve(&a, &b, 1.0, Solver::Halley);
        assert!(r.z.is_finite() && r.z > 0.0);
        let (g, _, _) = derivatives(r.z, &a, &b);
        assert!(g.abs() < 1e-9, "f' = {g} at z = {}", r.z);
    }

    #[test]
    fn estimate_runs_and_is_positive() {
        let s = generate(&SynthConfig {
            n: 1000,
            d: 16,
            ..SynthConfig::tiny()
        });
        let brute = BruteIndex::new(&s);
        let mut rng = Rng::seeded(6);
        let q = s.row(900).to_vec();
        let mut ctx = EstimateContext::new(&s, &brute, &mut rng);
        let z = Mince::new(10, 100).estimate(&mut ctx, &q);
        assert!(z.is_finite() && z > 0.0);
    }

    /// Reproduce the qualitative Table 1 finding: MINCE is far worse than
    /// MIMPS at the same budget.
    #[test]
    fn mince_worse_than_mimps() {
        use crate::metrics::abs_rel_err_pct;
        let s = generate(&SynthConfig::tiny());
        let brute = BruteIndex::new(&s);
        let mut rng = Rng::seeded(8);
        let (mut e_mince, mut e_mimps) = (0f64, 0f64);
        for qi in (100..1900).step_by(200) {
            let q = s.row(qi).to_vec();
            let want = brute.partition(&q);
            let mut ctx = EstimateContext::new(&s, &brute, &mut rng);
            e_mince += abs_rel_err_pct(Mince::new(100, 100).estimate(&mut ctx, &q), want);
            let mut ctx = EstimateContext::new(&s, &brute, &mut rng);
            e_mimps += abs_rel_err_pct(
                super::super::mimps::Mimps::new(100, 100).estimate(&mut ctx, &q),
                want,
            );
        }
        assert!(
            e_mince > 2.0 * e_mimps,
            "expected MINCE ({e_mince}) ≫ MIMPS ({e_mimps}) as in Table 1"
        );
    }
}
