//! Exact partition function — the ground truth every table's error is
//! measured against, and the brute-force baseline for Speedup.

use super::{EstimateContext, Estimator};
use crate::linalg;
use crate::store;

/// Ẑ = Z: full O(N·d) sum (eq. 1).
#[derive(Clone, Copy, Debug, Default)]
pub struct Exact;

impl Estimator for Exact {
    fn name(&self) -> String {
        "Exact".to_string()
    }

    /// Streams the category matrix through [`store::exp_sum_view`]: for
    /// any shard layout of the view this reproduces the monolithic fused
    /// kernel's tiling and accumulation order, so the sharded answer is
    /// bit-identical to the unsharded one (tested in
    /// `tests/sharding.rs`).
    fn estimate(&self, ctx: &mut EstimateContext<'_>, q: &[f32]) -> f64 {
        store::exp_sum_view(ctx.store, q)
    }

    /// Batched exact: stream the category matrix once through the fused
    /// multi-query exp-sum GEMM so each streamed row is reused across
    /// all `qs` instead of re-read per query. Runs on the caller's
    /// thread — request-level parallelism comes from the coordinator's
    /// worker pool (`BruteIndex::partition_batch` is the data-parallel
    /// variant).
    fn estimate_batch(&self, ctx: &mut EstimateContext<'_>, qs: &[Vec<f32>]) -> Vec<f64> {
        let view = ctx.store;
        let nq = qs.len();
        if nq == 0 {
            return vec![];
        }
        let qs_flat = linalg::flatten_queries(qs, view.dim());
        let mut zs = vec![0f64; nq];
        store::exp_sum_view_batch(view, &qs_flat, nq, &mut zs);
        zs
    }

    fn scorings(&self, n: usize) -> usize {
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::mips::brute::BruteIndex;
    use crate::util::rng::Rng;

    #[test]
    fn matches_brute_partition() {
        let s = generate(&SynthConfig {
            n: 500,
            d: 16,
            ..SynthConfig::tiny()
        });
        let brute = BruteIndex::new(&s);
        let mut rng = Rng::seeded(0);
        let q = s.row(17).to_vec();
        let mut ctx = EstimateContext::new(&s, &brute, &mut rng);
        let z = Exact.estimate(&mut ctx, &q);
        let want = brute.partition(&q);
        assert!((z - want).abs() < 1e-9 * want);
    }

    /// The batched GEMM path must agree with per-query estimates (scores
    /// are bit-identical per row on AVX2; tolerance covers the scalar
    /// fallback's different accumulation order).
    #[test]
    fn batch_matches_single_queries() {
        let s = generate(&SynthConfig {
            n: 333,
            d: 17,
            ..SynthConfig::tiny()
        });
        let brute = BruteIndex::new(&s);
        let qs: Vec<Vec<f32>> = (0..7).map(|i| s.row(i * 40).to_vec()).collect();
        let mut rng = Rng::seeded(1);
        let mut ctx = EstimateContext::new(&s, &brute, &mut rng);
        let batched = Exact.estimate_batch(&mut ctx, &qs);
        assert_eq!(batched.len(), qs.len());
        for (q, zb) in qs.iter().zip(&batched) {
            let zs = Exact.estimate(&mut ctx, q);
            assert!(
                (zb - zs).abs() < 1e-6 * zs,
                "batched {zb} vs single {zs}"
            );
        }
    }

    #[test]
    fn batch_empty_is_empty() {
        let s = generate(&SynthConfig {
            n: 10,
            d: 4,
            ..SynthConfig::tiny()
        });
        let brute = BruteIndex::new(&s);
        let mut rng = Rng::seeded(2);
        let mut ctx = EstimateContext::new(&s, &brute, &mut rng);
        assert!(Exact.estimate_batch(&mut ctx, &[]).is_empty());
    }
}
