//! Exact partition function — the ground truth every table's error is
//! measured against, and the brute-force baseline for Speedup.

use super::{EstimateContext, Estimator};
use crate::linalg;

/// Ẑ = Z: full O(N·d) sum (eq. 1).
#[derive(Clone, Copy, Debug, Default)]
pub struct Exact;

impl Estimator for Exact {
    fn name(&self) -> String {
        "Exact".to_string()
    }

    fn estimate(&self, ctx: &mut EstimateContext<'_>, q: &[f32]) -> f64 {
        let store = ctx.store;
        let mut z = 0f64;
        for i in 0..store.len() {
            z += (linalg::dot(store.row(i), q) as f64).exp();
        }
        z
    }

    fn scorings(&self, n: usize) -> usize {
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::mips::brute::BruteIndex;
    use crate::util::rng::Rng;

    #[test]
    fn matches_brute_partition() {
        let s = generate(&SynthConfig {
            n: 500,
            d: 16,
            ..SynthConfig::tiny()
        });
        let brute = BruteIndex::new(&s);
        let mut rng = Rng::seeded(0);
        let q = s.row(17).to_vec();
        let mut ctx = EstimateContext {
            store: &s,
            index: &brute,
            rng: &mut rng,
        };
        let z = Exact.estimate(&mut ctx, &q);
        let want = brute.partition(&q);
        assert!((z - want).abs() < 1e-9 * want);
    }
}
