//! FMBE — Feature-Map-Based Estimation (paper §4.3).
//!
//! The `exp` dot-product kernel is linearized with Kar & Karnick (2012)
//! random feature maps:
//!
//! ```text
//! φ_j(x) = sqrt(a_M · p^{M+1} / P) · Π_{r=1..M} ω_r·x
//! exp(x·y) ≈ Σ_{j=1..P} φ_j(x)·φ_j(y)
//! ```
//!
//! with `M ~ P[M=m] = 1/p^{m+1}` (p = 2), `a_m = 1/m!` the Taylor
//! coefficients of exp, and `ω_r` Rademacher vectors. Unbiasedness:
//! `E[(ω·x)(ω·y)] = x·y`, so `E[φ_j(x)φ_j(y)] = Σ_m a_m (x·y)^m / P`.
//!
//! The partition sum collapses by precomputing (eq. 8)
//! `λ̃_j = φ_j-coefficient · Σ_i Π_r (v_i·ω_r)` once at build time; a
//! query then costs `O(P·E[M]·d)` instead of `O(N·d)`.
//!
//! The paper reports FMBE needs "far higher number of dimensions ...
//! before giving reasonable results" (μ = 100 at D = 10k, 83.8 at D = 50k)
//! — the heavy-tailed Rademacher products converge slowly for the large
//! `x·y` values real embeddings produce. The reproduction shows the same.

use super::{EstimateContext, Estimator};
use crate::linalg;
use crate::store::StoreView;
use crate::util::rng::Rng;
use crate::util::threadpool;

/// FMBE build configuration.
#[derive(Clone, Debug)]
pub struct FmbeConfig {
    /// Number of random features P (the paper's D).
    pub p_features: usize,
    /// Geometric parameter p ("usually taken to be 2").
    pub p_geom: f64,
    pub seed: u64,
    pub threads: usize,
}

impl Default for FmbeConfig {
    fn default() -> Self {
        FmbeConfig {
            p_features: 10_000,
            p_geom: 2.0,
            seed: 0,
            threads: threadpool::default_threads(),
        }
    }
}

/// One random feature: a degree and its Rademacher projection vectors.
struct Feature {
    /// Flattened (degree × d) Rademacher matrix; degree may be 0.
    omegas: Vec<f32>,
    degree: usize,
    /// c_m² · Σ_i Π_r (v_i·ω_r) — the precomputed λ̃ with both coefficient
    /// factors folded in, so a query contributes λ̃ · Π_r (q·ω_r).
    lambda: f64,
}

/// The fitted FMBE estimator.
pub struct Fmbe {
    features: Vec<Feature>,
    d: usize,
    cfg: FmbeConfig,
}

/// log(m!) via lgamma-free accumulation (m ≤ 64 in practice).
fn ln_factorial(m: usize) -> f64 {
    (1..=m).map(|i| (i as f64).ln()).sum()
}

/// Degree + flattened Rademacher matrix of one feature, before any λ̃
/// precomputation — the part of the fit that depends only on
/// `(seed, d)`, never on the store.
type Proto = (usize, Vec<f32>);

/// The seed-deterministic feature draw shared by [`Fmbe::fit`] and
/// [`Fmbe::from_lambdas`]: every fitter given the same `(seed, d,
/// p_features, p_geom)` draws byte-identical degrees and ω vectors,
/// which is what makes per-shard λ̃ vectors additive across workers.
fn draw_protos(d: usize, cfg: &FmbeConfig) -> Vec<Proto> {
    let mut rng = Rng::seeded(cfg.seed ^ 0xF3BE);
    (0..cfg.p_features)
        .map(|_| {
            let m = rng.geometric_kar(cfg.p_geom);
            let omegas: Vec<f32> = (0..m * d).map(|_| rng.rademacher()).collect();
            (m, omegas)
        })
        .collect()
}

/// c_m² = a_m · p^{m+1} / P — the squared feature coefficient with both
/// sides of the kernel folded in.
fn coeff_sq(m: usize, cfg: &FmbeConfig) -> f64 {
    ((cfg.p_geom.ln() * (m + 1) as f64) - ln_factorial(m)).exp() / cfg.p_features as f64
}

impl Fmbe {
    /// Draw the random features and precompute λ̃ over the store. The
    /// feature draw depends only on `(seed, d)` and the λ̃ sums stream
    /// global rows in order, so a sharded view fits to exactly the same
    /// estimator as the monolithic matrix (exp-sums are additive across
    /// shards; `tests/sharding.rs` pins seed-equality).
    pub fn fit(store: &dyn StoreView, cfg: FmbeConfig) -> Fmbe {
        let d = store.dim();
        let n = store.len();
        // Sample degrees + omegas up-front (cheap), precompute in parallel.
        let protos = draw_protos(d, &cfg);
        let features: Vec<Feature> = threadpool::par_map(protos.len(), cfg.threads, |j| {
            let (m, ref omegas) = protos[j];
            let c_sq = coeff_sq(m, &cfg);
            // Σ_i Π_r (v_i·ω_r): stream contiguous row blocks once per
            // projection (per-row shard lookups through `row(i)` would
            // cost a binary search each on sharded views; the chunk walk
            // touches each shard's block directly). Per-row dot order is
            // unchanged, so λ̃ stays bit-identical across layouts.
            let mut prod = vec![1f64; n];
            for r in 0..m {
                let w = &omegas[r * d..(r + 1) * d];
                store.for_each_chunk(0, n, &mut |start, rows| {
                    let nrows = rows.len() / d;
                    for (j, pi) in prod[start..start + nrows].iter_mut().enumerate() {
                        *pi *= linalg::dot(&rows[j * d..(j + 1) * d], w) as f64;
                    }
                });
            }
            let total: f64 = prod.iter().sum();
            Feature {
                omegas: omegas.clone(),
                degree: m,
                lambda: c_sq * total,
            }
        });
        Fmbe {
            features,
            d,
            cfg,
        }
    }

    /// Rebuild an estimator from externally computed λ̃ values — the
    /// remote-shard fit path (`net::remote`): each shard worker fits
    /// [`Fmbe::fit`] over its local rows with the same `(seed,
    /// p_features)`, the cluster sums the per-shard λ̃ vectors
    /// element-wise (λ̃ is additive over a partition of the rows: each
    /// entry is `c_m² · Σ_i Π_r (v_i·ω_r)` and the feature draw is
    /// seed-deterministic), and this constructor re-draws the identical
    /// feature maps and installs the summed λ̃. The result answers
    /// queries exactly like a monolithic fit, up to the f64 summation
    /// order of the per-shard partials (bit-identical for one shard).
    ///
    /// `lambdas.len()` must equal `cfg.p_features` (the per-feature λ̃
    /// in draw order).
    pub fn from_lambdas(d: usize, cfg: FmbeConfig, lambdas: Vec<f64>) -> Fmbe {
        assert_eq!(
            lambdas.len(),
            cfg.p_features,
            "λ̃ vector length must equal p_features"
        );
        let features: Vec<Feature> = draw_protos(d, &cfg)
            .into_iter()
            .zip(lambdas)
            .map(|((degree, omegas), lambda)| Feature {
                omegas,
                degree,
                lambda,
            })
            .collect();
        Fmbe { features, d, cfg }
    }

    /// The per-feature λ̃ values in draw order (what
    /// [`Fmbe::from_lambdas`] consumes; coefficients folded in).
    pub fn lambdas(&self) -> Vec<f64> {
        self.features.iter().map(|f| f.lambda).collect()
    }

    /// Ẑ(q) = Σ_j λ̃_j · Π_r (q·ω_r) — O(P·E[M]·d), no retrieval.
    pub fn estimate_query(&self, q: &[f32]) -> f64 {
        assert_eq!(q.len(), self.d);
        let mut z = 0f64;
        for f in &self.features {
            let mut prod = 1f64;
            for r in 0..f.degree {
                prod *= linalg::dot(&f.omegas[r * self.d..(r + 1) * self.d], q) as f64;
            }
            z += f.lambda * prod;
        }
        z
    }

    /// Batched Ẑ for a whole query block: per feature, all `Π_r (q·ω_r)`
    /// projection products are produced by one multi-query GEMM over the
    /// (degree × d) Rademacher matrix, so each ω row is streamed once per
    /// batch instead of once per query.
    pub fn estimate_queries(&self, qs: &[Vec<f32>]) -> Vec<f64> {
        let nq = qs.len();
        if nq == 0 {
            return vec![];
        }
        let d = self.d;
        let qs_flat = linalg::flatten_queries(qs, d);
        let mut zs = vec![0f64; nq];
        let mut proj: Vec<f32> = Vec::new();
        for f in &self.features {
            if f.degree == 0 {
                for z in zs.iter_mut() {
                    *z += f.lambda;
                }
                continue;
            }
            proj.clear();
            proj.resize(f.degree * nq, 0.0);
            linalg::gemm(&f.omegas, f.degree, d, &qs_flat, nq, &mut proj);
            for (qi, z) in zs.iter_mut().enumerate() {
                let mut prod = 1f64;
                for r in 0..f.degree {
                    prod *= proj[r * nq + qi] as f64;
                }
                *z += f.lambda * prod;
            }
        }
        zs
    }

    /// Mean degree of the drawn features (≈ 1/(p−1) for geometric p).
    pub fn mean_degree(&self) -> f64 {
        self.features.iter().map(|f| f.degree as f64).sum::<f64>() / self.features.len() as f64
    }

    pub fn num_features(&self) -> usize {
        self.features.len()
    }

    pub fn config(&self) -> &FmbeConfig {
        &self.cfg
    }
}

impl Estimator for Fmbe {
    fn name(&self) -> String {
        format!("FMBE(P={})", self.cfg.p_features)
    }

    fn estimate(&self, _ctx: &mut EstimateContext<'_>, q: &[f32]) -> f64 {
        self.estimate_query(q)
    }

    fn estimate_batch(&self, _ctx: &mut EstimateContext<'_>, qs: &[Vec<f32>]) -> Vec<f64> {
        self.estimate_queries(qs)
    }

    fn scorings(&self, n: usize) -> usize {
        // Effective "scorings": P·E[M] projection dots of length d, i.e.
        // ~P·E[M] vector ops vs N for brute force.
        ((self.features.len() as f64 * self.mean_degree().max(1.0)) as usize).min(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::embeddings::EmbeddingStore;
    use crate::data::synth::{generate, SynthConfig};
    use crate::mips::brute::BruteIndex;

    fn small_norm_store(n: usize, d: usize) -> EmbeddingStore {
        // Small norms → fast Taylor convergence → FMBE can actually work,
        // which lets us test unbiasedness with modest P.
        generate(&SynthConfig {
            n,
            d,
            norm_lo: 0.3,
            norm_hi: 0.6,
            clusters: 4,
            ..SynthConfig::tiny()
        })
    }

    #[test]
    fn degree_distribution_matches_geometric() {
        let s = small_norm_store(50, 8);
        let f = Fmbe::fit(
            &s,
            FmbeConfig {
                p_features: 4000,
                ..Default::default()
            },
        );
        // E[M] = Σ m/2^{m+1} = 1 for p = 2.
        let md = f.mean_degree();
        assert!((md - 1.0).abs() < 0.15, "mean degree {md}");
        let zero_frac = f
            .features
            .iter()
            .filter(|x| x.degree == 0)
            .count() as f64
            / f.features.len() as f64;
        assert!((zero_frac - 0.5).abs() < 0.05, "P[M=0] ≈ 1/2, got {zero_frac}");
    }

    #[test]
    fn unbiased_on_small_norm_data() {
        // Average over independent feature draws → should approach Z.
        let s = small_norm_store(200, 8);
        let brute = BruteIndex::new(&s);
        let q = s.row(7).to_vec();
        let want = brute.partition(&q);
        let mut acc = 0f64;
        let reps = 12;
        for seed in 0..reps {
            let f = Fmbe::fit(
                &s,
                FmbeConfig {
                    p_features: 2000,
                    seed,
                    ..Default::default()
                },
            );
            acc += f.estimate_query(&q);
        }
        let mean = acc / reps as f64;
        let rel = ((mean - want) / want).abs();
        assert!(rel < 0.15, "FMBE mean {mean} vs Z {want} (rel {rel})");
    }

    #[test]
    fn poor_on_large_norm_data() {
        // The paper's regime: unnormalized embeddings with norms up to ~5
        // → FMBE at moderate P has large error (μ ≈ 100 in Table 1 text).
        let s = generate(&SynthConfig {
            n: 500,
            d: 16,
            ..SynthConfig::tiny()
        });
        let brute = BruteIndex::new(&s);
        let f = Fmbe::fit(
            &s,
            FmbeConfig {
                p_features: 1000,
                ..Default::default()
            },
        );
        let q = s.row(480).to_vec(); // rare, large-norm query
        let want = brute.partition(&q);
        let got = f.estimate_query(&q);
        let err = crate::metrics::abs_rel_err_pct(got, want);
        assert!(err > 20.0, "expected large FMBE error, got {err}%");
    }

    /// The batched per-feature GEMM path must agree with the per-query
    /// projection loop.
    #[test]
    fn batched_matches_single_queries() {
        let s = small_norm_store(80, 8);
        let f = Fmbe::fit(
            &s,
            FmbeConfig {
                p_features: 500,
                ..Default::default()
            },
        );
        let qs: Vec<Vec<f32>> = (0..5).map(|i| s.row(i * 13).to_vec()).collect();
        let batched = f.estimate_queries(&qs);
        for (q, zb) in qs.iter().zip(&batched) {
            let zs = f.estimate_query(q);
            assert!(
                (zb - zs).abs() <= 1e-3 * (1.0 + zs.abs()),
                "batched {zb} vs single {zs}"
            );
        }
        assert!(f.estimate_queries(&[]).is_empty());
    }

    /// `from_lambdas` must reconstruct a fit exactly: same feature
    /// draws, installed λ̃ — the contract the remote FMBE path
    /// (per-shard fits summed cluster-side) builds on.
    #[test]
    fn from_lambdas_reconstructs_fit() {
        let s = small_norm_store(90, 8);
        let cfg = FmbeConfig {
            p_features: 300,
            seed: 5,
            ..Default::default()
        };
        let fitted = Fmbe::fit(&s, cfg.clone());
        let rebuilt = Fmbe::from_lambdas(8, cfg, fitted.lambdas());
        let q = s.row(11).to_vec();
        assert_eq!(
            fitted.estimate_query(&q).to_bits(),
            rebuilt.estimate_query(&q).to_bits()
        );
        let qs: Vec<Vec<f32>> = (0..4).map(|i| s.row(i * 20).to_vec()).collect();
        assert_eq!(fitted.estimate_queries(&qs), rebuilt.estimate_queries(&qs));
    }

    /// Per-shard λ̃ vectors summed element-wise match a monolithic fit
    /// to f64 summation-order tolerance (additivity over row partitions).
    #[test]
    fn per_shard_lambdas_sum_to_monolithic() {
        use crate::data::embeddings::EmbeddingStore;
        let s = small_norm_store(120, 8);
        let cfg = FmbeConfig {
            p_features: 200,
            seed: 3,
            ..Default::default()
        };
        let whole = Fmbe::fit(&s, cfg.clone()).lambdas();
        let cut = 64usize; // 4-aligned row split, like a worker layout
        let a = EmbeddingStore::from_data(cut, 8, s.rows(0, cut).to_vec()).unwrap();
        let b =
            EmbeddingStore::from_data(120 - cut, 8, s.rows(cut, 120).to_vec()).unwrap();
        let la = Fmbe::fit(&a, cfg.clone()).lambdas();
        let lb = Fmbe::fit(&b, cfg).lambdas();
        for (j, ((w, x), y)) in whole.iter().zip(&la).zip(&lb).enumerate() {
            let sum = x + y;
            assert!(
                (sum - w).abs() <= 1e-9 * (1.0 + w.abs()),
                "feature {j}: {sum} vs {w}"
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let s = small_norm_store(60, 8);
        let a = Fmbe::fit(&s, FmbeConfig { p_features: 200, ..Default::default() });
        let b = Fmbe::fit(&s, FmbeConfig { p_features: 200, ..Default::default() });
        let q = s.row(3).to_vec();
        assert_eq!(a.estimate_query(&q), b.estimate_query(&q));
    }

    #[test]
    fn degree_zero_features_contribute_n() {
        // With P features of which ~half are degree 0, the degree-0 part of
        // Ẑ equals Σ_j c0² · N summed over those features ≈ (p/P)·(P/p)·N = N.
        let s = small_norm_store(100, 8);
        let f = Fmbe::fit(&s, FmbeConfig { p_features: 5000, ..Default::default() });
        let z0: f64 = f
            .features
            .iter()
            .filter(|x| x.degree == 0)
            .map(|x| x.lambda)
            .sum();
        assert!(
            (z0 - 100.0).abs() < 12.0,
            "degree-0 mass {z0} should be ≈ N = 100"
        );
    }
}
