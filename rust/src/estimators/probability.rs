//! The paper's motivating use-case (eq. 2–3): find the most probable
//! class via MIPS and convert its score to a probability with a
//! sublinearly estimated partition function,
//!
//! ```text
//! î = argmax_i u_i          p(î) = exp(u_î) / Ẑ(q)
//! ```
//!
//! One retrieval serves both: the MIPS head gives the argmax *and* the
//! exact head sum of the MIMPS estimator, so classification +
//! normalization together cost O((k + l)·d) instead of O(N·d).

use super::{tail, EstimateContext};
use crate::mips::Hit;

/// A classified query with its estimated probability.
#[derive(Clone, Copy, Debug)]
pub struct ClassifyResult {
    /// argmax class index î.
    pub class: usize,
    /// Raw score u_î.
    pub score: f32,
    /// Estimated partition function Ẑ(q).
    pub z_hat: f64,
    /// p̂(î) = exp(u_î)/Ẑ.
    pub p: f64,
    /// Head actually retrieved (for downstream top-k probability needs).
    pub head_len: usize,
}

/// Classify `q` and estimate its probability with MIMPS(k, l), reusing a
/// single retrieval for both the argmax and the head sum.
pub fn classify_with_probability(
    ctx: &mut EstimateContext<'_>,
    q: &[f32],
    k: usize,
    l: usize,
) -> Option<ClassifyResult> {
    let n = ctx.store.len();
    let head: Vec<Hit> = ctx.index.top_k(q, k.max(1));
    let best = *head.first()?;
    let head_z = tail::head_sum(&head);
    let z_hat = if head.len() >= n || l == 0 {
        head_z
    } else {
        tail::sample_tail_into(ctx.store, &head, l, q, ctx.rng, &mut ctx.scratch);
        let drawn = ctx.scratch.indices.len();
        if drawn == 0 {
            head_z
        } else {
            let mean: f64 = ctx.scratch.exp_scores.iter().sum::<f64>() / drawn as f64;
            head_z + (n - head.len()) as f64 * mean
        }
    };
    let p = (best.score as f64).exp() / z_hat;
    Some(ClassifyResult {
        class: best.idx,
        score: best.score,
        z_hat,
        p,
        head_len: head.len(),
    })
}

/// Top-m probability distribution over the retrieved head (each head
/// member normalized by the same Ẑ) — what a downstream consumer (e.g. a
/// beam decoder) would read.
pub fn head_distribution(
    ctx: &mut EstimateContext<'_>,
    q: &[f32],
    k: usize,
    l: usize,
    m: usize,
) -> Vec<(usize, f64)> {
    let Some(first) = classify_with_probability(ctx, q, k, l) else {
        return vec![];
    };
    let head = ctx.index.top_k(q, k.max(m).max(1));
    head.iter()
        .take(m)
        .map(|h| (h.idx, (h.score as f64).exp() / first.z_hat))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::mips::brute::BruteIndex;
    use crate::mips::MipsIndex;
    use crate::util::rng::Rng;

    fn setup() -> (crate::data::embeddings::EmbeddingStore, BruteIndex) {
        let s = generate(&SynthConfig {
            n: 1500,
            d: 16,
            ..SynthConfig::tiny()
        });
        let b = BruteIndex::new(&s);
        (s, b)
    }

    #[test]
    fn classifies_to_true_argmax_and_probability_close_to_truth() {
        let (s, b) = setup();
        let q = s.row(s.len() - 3).to_vec(); // rare → peaked
        let truth_top = b.top_k(&q, 1)[0];
        let z_true = b.partition(&q);
        let p_true = (truth_top.score as f64).exp() / z_true;
        let mut rng = Rng::seeded(0);
        let mut ctx = EstimateContext::new(&s, &b, &mut rng);
        let r = classify_with_probability(&mut ctx, &q, 100, 100).unwrap();
        assert_eq!(r.class, truth_top.idx);
        assert!(
            ((r.p - p_true) / p_true).abs() < 0.2,
            "p̂ {} vs p {p_true}",
            r.p
        );
        assert!(r.p > 0.0 && r.p <= 1.0 + 1e-9);
    }

    #[test]
    fn head_distribution_sums_below_one_and_ordered() {
        let (s, b) = setup();
        let q = s.row(700).to_vec();
        let mut rng = Rng::seeded(1);
        let mut ctx = EstimateContext::new(&s, &b, &mut rng);
        let dist = head_distribution(&mut ctx, &q, 100, 100, 10);
        assert_eq!(dist.len(), 10);
        let total: f64 = dist.iter().map(|(_, p)| p).sum();
        assert!(total <= 1.05, "head mass {total} cannot exceed 1");
        for w in dist.windows(2) {
            assert!(w[0].1 >= w[1].1, "probabilities must be sorted desc");
        }
    }

    #[test]
    fn zero_l_uses_head_only() {
        let (s, b) = setup();
        let q = s.row(10).to_vec();
        let mut rng = Rng::seeded(2);
        let mut ctx = EstimateContext::new(&s, &b, &mut rng);
        let r = classify_with_probability(&mut ctx, &q, 50, 0).unwrap();
        // head-only Ẑ underestimates → p̂ overestimates vs truth, but must
        // still be a valid probability for the head-normalized family.
        assert!(r.p > 0.0 && r.p <= 1.0 + 1e-9);
        assert_eq!(r.head_len, 50);
    }
}
