//! Log-bilinear language model (paper §5.2): parameters, NCE training
//! driven through the AOT `lbl_nce_step` artifact, and the Table 4
//! evaluation that compares MIMPS partition estimates against the
//! self-normalization (Z ≡ 1) heuristic the model was trained with.

pub mod lbl;
pub mod nce;
pub mod train;

pub use lbl::{LblConfig, LblParams};
pub use nce::{NceConfig, NoiseModel};
pub use train::{train, TrainReport};
