//! NCE machinery on the Rust side: the unigram noise model (with log-
//! probability lookups the training graph needs) and batch assembly from
//! corpus windows. The gradient math itself lives in the AOT
//! `lbl_nce_step` artifact (python/compile/model.py); Rust feeds it.

use crate::data::corpus::Corpus;
use crate::runtime::HostTensor;
use crate::util::rng::Rng;

/// NCE training hyper-parameters (shapes must match the exported artifact).
#[derive(Clone, Debug)]
pub struct NceConfig {
    pub batch: usize,
    /// Noise samples per data point (the artifact's K).
    pub noise_k: usize,
    pub lr: f32,
}

impl Default for NceConfig {
    fn default() -> Self {
        NceConfig {
            batch: 256,
            noise_k: 25,
            lr: 0.1,
        }
    }
}

/// Unigram noise distribution with add-one smoothing: alias-free CDF
/// sampling plus per-token ln P_n lookups.
pub struct NoiseModel {
    ln_pn: Vec<f32>,
    cdf: Vec<f64>,
}

impl NoiseModel {
    pub fn from_corpus(corpus: &Corpus) -> NoiseModel {
        let counts = corpus.unigram_counts();
        Self::from_counts(&counts)
    }

    pub fn from_counts(counts: &[u64]) -> NoiseModel {
        let total: f64 = counts.iter().map(|&c| c as f64 + 1.0).sum();
        let mut cdf = Vec::with_capacity(counts.len());
        let mut acc = 0f64;
        let mut ln_pn = Vec::with_capacity(counts.len());
        for &c in counts {
            let p = (c as f64 + 1.0) / total;
            acc += p;
            cdf.push(acc);
            ln_pn.push(p.ln() as f32);
        }
        NoiseModel { ln_pn, cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    pub fn ln_pn(&self, w: usize) -> f32 {
        self.ln_pn[w]
    }

    pub fn len(&self) -> usize {
        self.ln_pn.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ln_pn.is_empty()
    }
}

/// One assembled training batch, shaped for the artifact.
pub struct NceBatch {
    pub ctx: HostTensor,         // (B, ctx) i32
    pub tgt: HostTensor,         // (B,) i32
    pub noise: HostTensor,       // (B, K) i32
    pub ln_pn_tgt: HostTensor,   // (B,) f32
    pub ln_pn_noise: HostTensor, // (B, K) f32
}

/// Assemble a batch by sampling window positions uniformly from `stream`.
pub fn make_batch(
    stream: &[u32],
    ctx_len: usize,
    cfg: &NceConfig,
    noise: &NoiseModel,
    rng: &mut Rng,
) -> NceBatch {
    let b = cfg.batch;
    let k = cfg.noise_k;
    let mut ctx = Vec::with_capacity(b * ctx_len);
    let mut tgt = Vec::with_capacity(b);
    let mut nz = Vec::with_capacity(b * k);
    let mut ln_t = Vec::with_capacity(b);
    let mut ln_n = Vec::with_capacity(b * k);
    for _ in 0..b {
        // Position t predicts stream[t+1] from the ctx_len tokens ending at t.
        let t = rng.range(0, stream.len() - 1);
        for j in 0..ctx_len {
            let pos = t as i64 - (ctx_len - 1 - j) as i64;
            let w = if pos < 0 { 0 } else { stream[pos as usize] };
            ctx.push(w as i32);
        }
        let target = stream[t + 1] as usize;
        tgt.push(target as i32);
        ln_t.push(noise.ln_pn(target));
        for _ in 0..k {
            let nw = noise.sample(rng);
            nz.push(nw as i32);
            ln_n.push(noise.ln_pn(nw));
        }
    }
    NceBatch {
        ctx: HostTensor::i32(ctx, &[b, ctx_len]),
        tgt: HostTensor::i32(tgt, &[b]),
        noise: HostTensor::i32(nz, &[b, k]),
        ln_pn_tgt: HostTensor::f32(ln_t, &[b]),
        ln_pn_noise: HostTensor::f32(ln_n, &[b, k]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{generate, CorpusConfig};

    #[test]
    fn noise_model_matches_empirical_frequencies() {
        let c = generate(&CorpusConfig::tiny());
        let nm = NoiseModel::from_corpus(&c);
        let mut rng = Rng::seeded(1);
        let mut head = 0usize;
        let n = 20_000;
        for _ in 0..n {
            if nm.sample(&mut rng) == 0 {
                head += 1;
            }
        }
        let counts = c.unigram_counts();
        let want = (counts[0] as f64 + 1.0)
            / counts.iter().map(|&x| x as f64 + 1.0).sum::<f64>();
        let got = head as f64 / n as f64;
        assert!(
            (got - want).abs() < 0.02,
            "sampled head mass {got} vs true {want}"
        );
    }

    #[test]
    fn ln_pn_sums_to_one_in_prob_space() {
        let nm = NoiseModel::from_counts(&[5, 3, 2, 0]);
        let total: f64 = (0..4).map(|w| (nm.ln_pn(w) as f64).exp()).sum();
        assert!((total - 1.0).abs() < 1e-6);
    }

    #[test]
    fn batch_shapes_and_ranges() {
        let c = generate(&CorpusConfig::tiny());
        let nm = NoiseModel::from_corpus(&c);
        let cfg = NceConfig {
            batch: 32,
            noise_k: 7,
            lr: 0.1,
        };
        let mut rng = Rng::seeded(2);
        let b = make_batch(&c.train, 3, &cfg, &nm, &mut rng);
        assert_eq!(b.ctx.shape(), &[32, 3]);
        assert_eq!(b.noise.shape(), &[32, 7]);
        assert_eq!(b.ln_pn_noise.shape(), &[32, 7]);
        for &w in b.ctx.as_i32().unwrap() {
            assert!((w as usize) < c.vocab);
        }
        // ln_pn fields must be the exact lookups for the sampled ids.
        let tgt = b.tgt.as_i32().unwrap();
        let ln_t = b.ln_pn_tgt.as_f32().unwrap();
        for (w, lp) in tgt.iter().zip(ln_t) {
            assert_eq!(*lp, nm.ln_pn(*w as usize));
        }
    }
}
