//! Log-bilinear LM parameters (Mnih & Hinton 2008 scoring, Mnih & Teh
//! 2012 diagonal context matrices):
//!
//! ```text
//! q̂(w_1..w_ctx) = Σ_j c_j ⊙ r_{w_j}          (context projection)
//! s(w | ctx)    = q̂ · qt_w + b_w              (target score)
//! Z(ctx)        = Σ_w exp(s(w | ctx))          (the paper's quantity)
//! ```
//!
//! For MIPS-based partition estimation the (qt, b) table is exposed as an
//! `EmbeddingStore` over `R^{d+1}` with the bias as an extra coordinate
//! and queries lifted to `[q̂, 1]` — inner products then equal scores
//! exactly, so every estimator and index in the crate applies unchanged.

use crate::data::embeddings::EmbeddingStore;
use crate::util::rng::Rng;

/// Model dimensions.
#[derive(Clone, Debug)]
pub struct LblConfig {
    pub vocab: usize,
    /// Embedding dim (paper: 300; artifacts default to 100 for CPU speed —
    /// see DESIGN.md §Substitutions).
    pub d: usize,
    /// Context window (paper: 9; artifacts default 5).
    pub ctx: usize,
    pub seed: u64,
}

impl Default for LblConfig {
    fn default() -> Self {
        LblConfig {
            vocab: 10_000,
            d: 100,
            ctx: 5,
            seed: 0,
        }
    }
}

/// Dense parameters, row-major.
#[derive(Clone, Debug)]
pub struct LblParams {
    pub cfg: LblConfig,
    /// Context word embeddings (vocab × d).
    pub r: Vec<f32>,
    /// Target word embeddings (vocab × d).
    pub qt: Vec<f32>,
    /// Target biases (vocab).
    pub b: Vec<f32>,
    /// Per-position diagonal context weights (ctx × d).
    pub c: Vec<f32>,
}

impl LblParams {
    /// Small random init (0.1σ gaussians, zero biases, c ≈ 1/ctx so the
    /// initial projection is an average).
    pub fn init(cfg: LblConfig) -> LblParams {
        let mut rng = Rng::seeded(cfg.seed ^ 0x1b1);
        let scale = 0.1f32;
        let r: Vec<f32> = (0..cfg.vocab * cfg.d)
            .map(|_| rng.normal() as f32 * scale)
            .collect();
        let qt: Vec<f32> = (0..cfg.vocab * cfg.d)
            .map(|_| rng.normal() as f32 * scale)
            .collect();
        let b = vec![0f32; cfg.vocab];
        let c: Vec<f32> = (0..cfg.ctx * cfg.d)
            .map(|_| 1.0 / cfg.ctx as f32 + rng.normal() as f32 * 0.01)
            .collect();
        LblParams { cfg, r, qt, b, c }
    }

    /// Context projection q̂ for one context (native path, used at eval).
    pub fn qhat(&self, ctx_ids: &[u32]) -> Vec<f32> {
        assert_eq!(ctx_ids.len(), self.cfg.ctx);
        let d = self.cfg.d;
        let mut out = vec![0f32; d];
        for (j, &w) in ctx_ids.iter().enumerate() {
            let emb = &self.r[w as usize * d..(w as usize + 1) * d];
            let cj = &self.c[j * d..(j + 1) * d];
            for t in 0..d {
                out[t] += cj[t] * emb[t];
            }
        }
        out
    }

    /// Score of one target word given a projected context.
    pub fn score(&self, qhat: &[f32], w: usize) -> f32 {
        let d = self.cfg.d;
        crate::linalg::dot(&self.qt[w * d..(w + 1) * d], qhat) + self.b[w]
    }

    /// The (qt | b) table as an EmbeddingStore over R^{d+1} (bias fold).
    pub fn target_store(&self) -> EmbeddingStore {
        let d = self.cfg.d;
        let mut data = Vec::with_capacity(self.cfg.vocab * (d + 1));
        for w in 0..self.cfg.vocab {
            data.extend_from_slice(&self.qt[w * d..(w + 1) * d]);
            data.push(self.b[w]);
        }
        EmbeddingStore::from_data(self.cfg.vocab, d + 1, data).expect("consistent")
    }

    /// Lift a projected context into the bias-fold query space: [q̂, 1].
    pub fn lift_query(qhat: &[f32]) -> Vec<f32> {
        let mut q = Vec::with_capacity(qhat.len() + 1);
        q.extend_from_slice(qhat);
        q.push(1.0);
        q
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg;

    fn tiny() -> LblParams {
        LblParams::init(LblConfig {
            vocab: 50,
            d: 8,
            ctx: 3,
            seed: 1,
        })
    }

    #[test]
    fn qhat_is_weighted_sum() {
        let p = tiny();
        let ctx = [1u32, 2, 3];
        let qh = p.qhat(&ctx);
        // Manual computation.
        let d = p.cfg.d;
        for t in 0..d {
            let want: f32 = (0..3)
                .map(|j| p.c[j * d + t] * p.r[ctx[j] as usize * d + t])
                .sum();
            assert!((qh[t] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn bias_fold_preserves_scores() {
        let p = tiny();
        let qh = p.qhat(&[4, 5, 6]);
        let store = p.target_store();
        let lifted = LblParams::lift_query(&qh);
        for w in [0usize, 10, 49] {
            let direct = p.score(&qh, w);
            let via_store = linalg::dot(store.row(w), &lifted);
            assert!(
                (direct - via_store).abs() < 1e-5,
                "w={w}: {direct} vs {via_store}"
            );
        }
    }

    #[test]
    fn init_deterministic_per_seed() {
        let a = tiny();
        let b = tiny();
        assert_eq!(a.r, b.r);
        assert_eq!(a.c, b.c);
    }

    #[test]
    fn target_store_shape() {
        let p = tiny();
        let s = p.target_store();
        assert_eq!(s.len(), 50);
        assert_eq!(s.dim(), 9);
        assert_eq!(s.row(7)[8], p.b[7]);
    }
}
