//! Training driver: owns the parameter buffers in Rust, streams NCE/SGD
//! steps through the AOT `lbl_nce_step` artifact on the PJRT runtime
//! thread, and logs the loss curve. This is the end-to-end path that
//! Table 4 (and `examples/lm_partition.rs`) runs.

use super::lbl::{LblConfig, LblParams};
use super::nce::{make_batch, NceConfig, NoiseModel};
use crate::data::corpus::Corpus;
use crate::runtime::{HostTensor, RuntimeHandle};
use crate::util::rng::Rng;
use anyhow::{ensure, Context, Result};

/// Outcome of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub steps: usize,
    /// (step, loss) samples along the run.
    pub loss_curve: Vec<(usize, f64)>,
    pub final_loss: f64,
    pub wall: std::time::Duration,
}

/// Train an LBL model with NCE (partition clamped to 1) for `steps`
/// SGD steps. The artifact's shapes (vocab, d, ctx, batch, K) must match
/// `cfg`/`nce` — validated up front against meta.json.
pub fn train(
    corpus: &Corpus,
    cfg: LblConfig,
    nce: NceConfig,
    steps: usize,
    rt: &RuntimeHandle,
    artifacts_dir: &std::path::Path,
) -> Result<(LblParams, TrainReport)> {
    // Shape validation against the exporter's meta.
    let meta = crate::runtime::ArtifactsMeta::load(artifacts_dir)?;
    let (_, args) = meta
        .graphs
        .get("lbl_nce_step")
        .context("lbl_nce_step not exported — rerun `make artifacts`")?;
    ensure!(
        args[0].shape == vec![cfg.vocab, cfg.d],
        "artifact vocab×d {:?} != config {:?} — re-export with matching --vocab/--lbl-d",
        args[0].shape,
        (cfg.vocab, cfg.d)
    );
    ensure!(
        args[4].shape == vec![nce.batch, cfg.ctx],
        "artifact batch×ctx {:?} != config {:?}",
        args[4].shape,
        (nce.batch, cfg.ctx)
    );
    ensure!(
        args[6].shape == vec![nce.batch, nce.noise_k],
        "artifact noise shape {:?} != config {:?}",
        args[6].shape,
        (nce.batch, nce.noise_k)
    );

    let mut params = LblParams::init(cfg.clone());
    let noise = NoiseModel::from_corpus(corpus);
    let mut rng = Rng::seeded(cfg.seed ^ 0x7247);
    let mut loss_curve = Vec::new();
    let mut final_loss = f64::NAN;
    let t0 = std::time::Instant::now();
    let log_every = (steps / 20).max(1);

    for step in 0..steps {
        let batch = make_batch(&corpus.train, cfg.ctx, &nce, &noise, &mut rng);
        let out = rt.run(
            "lbl_nce_step",
            vec![
                HostTensor::f32(std::mem::take(&mut params.r), &[cfg.vocab, cfg.d]),
                HostTensor::f32(std::mem::take(&mut params.qt), &[cfg.vocab, cfg.d]),
                HostTensor::f32(std::mem::take(&mut params.b), &[cfg.vocab]),
                HostTensor::f32(std::mem::take(&mut params.c), &[cfg.ctx, cfg.d]),
                batch.ctx,
                batch.tgt,
                batch.noise,
                batch.ln_pn_tgt,
                batch.ln_pn_noise,
                HostTensor::scalar_f32(nce.lr),
            ],
        )?;
        ensure!(out.len() == 5, "lbl_nce_step returned {} outputs", out.len());
        let mut it = out.into_iter();
        params.r = match it.next().unwrap() {
            HostTensor::F32(d, _) => d,
            _ => anyhow::bail!("r not f32"),
        };
        params.qt = match it.next().unwrap() {
            HostTensor::F32(d, _) => d,
            _ => anyhow::bail!("qt not f32"),
        };
        params.b = match it.next().unwrap() {
            HostTensor::F32(d, _) => d,
            _ => anyhow::bail!("b not f32"),
        };
        params.c = match it.next().unwrap() {
            HostTensor::F32(d, _) => d,
            _ => anyhow::bail!("c not f32"),
        };
        let loss = it.next().unwrap().first_f64().context("loss")?;
        ensure!(loss.is_finite(), "loss diverged at step {step}: {loss}");
        final_loss = loss;
        if step % log_every == 0 || step + 1 == steps {
            loss_curve.push((step, loss));
            log::info!("lbl step {step}/{steps} loss {loss:.4}");
        }
    }
    Ok((
        params,
        TrainReport {
            steps,
            loss_curve,
            final_loss,
            wall: t0.elapsed(),
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{generate, CorpusConfig};
    use crate::runtime::spawn_runtime_thread;
    use std::path::PathBuf;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("meta.json").exists().then_some(dir)
    }

    /// End-to-end: a short training run through the real artifact must
    /// produce finite decreasing loss.
    #[test]
    fn short_training_run_reduces_loss() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let meta = crate::runtime::ArtifactsMeta::load(&dir).unwrap();
        let cfg = LblConfig {
            vocab: meta.config_usize("vocab").unwrap(),
            d: meta.config_usize("lbl_d").unwrap(),
            ctx: meta.config_usize("ctx").unwrap(),
            seed: 3,
        };
        let nce = NceConfig {
            batch: meta.config_usize("lbl_batch").unwrap(),
            noise_k: meta.config_usize("noise_k").unwrap(),
            lr: 0.3,
        };
        let corpus = generate(&CorpusConfig {
            vocab: cfg.vocab,
            train_tokens: 50_000,
            test_tokens: 1_000,
            ..Default::default()
        });
        let (rt, join) =
            spawn_runtime_thread(dir.clone(), Some(vec!["lbl_nce_step".to_string()])).unwrap();
        let (params, report) = train(&corpus, cfg, nce, 30, &rt, &dir).unwrap();
        assert_eq!(report.steps, 30);
        assert!(report.final_loss.is_finite());
        let first = report.loss_curve.first().unwrap().1;
        assert!(
            report.final_loss < first,
            "loss should fall: {first} -> {}",
            report.final_loss
        );
        // Parameters actually moved.
        let init = LblParams::init(params.cfg.clone());
        let moved = params
            .qt
            .iter()
            .zip(&init.qt)
            .map(|(a, b)| (a - b).abs() as f64)
            .sum::<f64>();
        assert!(moved > 0.0);
        rt.shutdown();
        join.join().unwrap();
    }
}
