//! Self-spawned cluster under test: synth store → shard workers ×
//! replicas (optionally behind fault proxies) → `ClusterBackend` →
//! `PartitionService` → a real wire front door.
//!
//! The chaos and publish legs of a load run need two things an
//! external `--server` target cannot offer: a handle on the
//! coordinator (`add_categories` / `remove_categories` must go through
//! the *serving* coordinator — a second coordinator publishing to the
//! same workers would trip the split-brain guards) and a handle on
//! each replica's network link (the proxies). So `zest-loadgen` spawns
//! the whole stack in-process, exactly like `zest-server --cluster`
//! wires it, and drives it over a real TCP socket — the load still
//! crosses the wire; only process boundaries are elided.

use crate::coordinator::{ClusterBackend, PartitionService, ServiceConfig};
use crate::data::embeddings::EmbeddingStore;
use crate::data::synth::{generate, SynthConfig};
use crate::net::client::ClientConfig;
use crate::net::remote::aligned_split;
use crate::net::server::{Server, ServerConfig, ServiceHandler};
use crate::net::shard::ShardWorker;
use crate::net::Addr;
use crate::coordinator::ServiceMetrics;
use crate::testing::fault::FaultProxy;
use std::sync::Arc;
use std::time::Duration;

/// Knobs for a self-spawned cluster.
#[derive(Clone, Debug)]
pub struct HarnessConfig {
    /// Synth categories.
    pub n: usize,
    /// Dimensionality.
    pub dim: usize,
    /// Shard workers.
    pub shards: usize,
    /// Replicas per shard (identical blocks).
    pub replicas: usize,
    /// Route replica 0 of every shard through a [`FaultProxy`]
    /// (chaos-under-load: kill/delay/cut that replica mid-run).
    pub proxied: bool,
    /// Store + service seed.
    pub seed: u64,
    /// Service ingress queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Service worker (batcher executor) threads.
    pub service_workers: usize,
    /// Hedge delay for replica `TopK` reads; `None` disables.
    pub hedge_delay: Option<Duration>,
    /// Front-door connection cap (size to the session count).
    pub max_connections: usize,
}

impl Default for HarnessConfig {
    fn default() -> Self {
        HarnessConfig {
            n: 4096,
            dim: 32,
            shards: 2,
            replicas: 2,
            proxied: false,
            seed: 1,
            queue_capacity: 4096,
            service_workers: 4,
            hedge_delay: None,
            max_connections: 512,
        }
    }
}

/// A live in-process cluster behind a real wire endpoint.
pub struct ClusterHarness {
    /// The serving coordinator — publish epochs through this handle.
    pub svc: Arc<PartitionService>,
    /// Front-door address clients connect to.
    pub addr: Addr,
    /// One proxy per shard fronting replica 0, in shard order; empty
    /// unless [`HarnessConfig::proxied`].
    pub proxies: Vec<FaultProxy>,
    dim: usize,
    front: Server,
    workers: Vec<Server>,
}

fn loopback() -> Addr {
    Addr::parse("tcp://127.0.0.1:0").expect("loopback addr parses")
}

impl ClusterHarness {
    /// Spawn the full stack. Everything binds TCP loopback port 0, so
    /// harnesses never collide.
    pub fn spawn(cfg: &HarnessConfig) -> anyhow::Result<ClusterHarness> {
        let store = generate(&SynthConfig {
            n: cfg.n,
            d: cfg.dim,
            seed: cfg.seed,
            ..SynthConfig::tiny()
        });
        let mut workers = Vec::new();
        let mut proxies = Vec::new();
        let mut groups: Vec<Vec<Addr>> = Vec::new();
        for block in aligned_split(&store, cfg.shards) {
            let mut group = Vec::new();
            for r in 0..cfg.replicas.max(1) {
                let metrics = Arc::new(ServiceMetrics::new());
                let server = Server::serve(
                    &loopback(),
                    Arc::new(ShardWorker::new(block.clone()).with_metrics(metrics.clone())),
                    ServerConfig::default(),
                    metrics,
                )?;
                let addr = server.local_addr().clone();
                workers.push(server);
                if r == 0 && cfg.proxied {
                    let proxy = FaultProxy::start(&loopback(), addr)?;
                    group.push(proxy.addr().clone());
                    proxies.push(proxy);
                } else {
                    group.push(addr);
                }
            }
            groups.push(group);
        }
        let backend = ClusterBackend::connect_groups(&groups, ClientConfig::default())
            .map_err(|e| anyhow::anyhow!("connect harness workers: {e}"))?;
        let cluster = backend.cluster().clone();
        if let Some(delay) = cfg.hedge_delay {
            cluster.set_hedge_delay(delay);
        }
        let svc = Arc::new(PartitionService::start_with_backend(
            backend,
            ServiceConfig {
                workers: cfg.service_workers,
                queue_capacity: cfg.queue_capacity,
                seed: cfg.seed,
                ..Default::default()
            },
        ));
        cluster.set_metrics(svc.metrics_handle());
        let metrics = svc.metrics_handle();
        let front = Server::serve(
            &loopback(),
            Arc::new(ServiceHandler::new(svc.clone())),
            ServerConfig {
                max_connections: cfg.max_connections,
                ..ServerConfig::default()
            },
            metrics,
        )?;
        let addr = front.local_addr().clone();
        Ok(ClusterHarness {
            svc,
            addr,
            proxies,
            dim: cfg.dim,
            front,
            workers,
        })
    }

    /// Dimensionality the cluster serves.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Publish `rows` fresh synth categories (epoch bump); returns the
    /// new epoch. The rows derive from `seed` so publish waves are
    /// replayable.
    pub fn publish_add(&self, rows: usize, seed: u64) -> anyhow::Result<u64> {
        let fresh = generate(&SynthConfig {
            n: rows,
            d: self.dim,
            seed: seed ^ 0x9B11_5EED,
            ..SynthConfig::tiny()
        });
        self.svc
            .add_categories(fresh)
            .map_err(|e| anyhow::anyhow!("publish add: {e}"))
    }

    /// Remove the `rows` highest-id categories (epoch bump); returns
    /// the new epoch. Paired with [`ClusterHarness::publish_add`] this
    /// keeps the serving set's size stable across a run.
    pub fn publish_remove_tail(&self, rows: usize) -> anyhow::Result<u64> {
        let (len, _) = self.svc.serving_info();
        if rows == 0 || rows >= len {
            anyhow::bail!("cannot remove {rows} of {len} categories");
        }
        let ids: Vec<usize> = (len - rows..len).collect();
        self.svc
            .remove_categories(&ids)
            .map_err(|e| anyhow::anyhow!("publish remove: {e}"))
    }

    /// Tear the stack down (front door first so clients see clean
    /// closes, then workers).
    pub fn shutdown(self) {
        self.front.shutdown();
        drop(self.proxies);
        for w in self.workers {
            w.shutdown();
        }
        // `svc` threads drain on drop of the last Arc.
        drop(self.svc);
    }
}
