//! Run reports: the committed perf trajectory.
//!
//! A load run produces one [`LoadReport`] (config + a [`SweepPoint`]
//! per offered rate + the detected knee); `zest-loadgen` collects one
//! report per scenario (healthy, chaos) into the top-level
//! `BENCH_load.json` document. The JSON is **committed to the repo** —
//! the schema below is therefore versioned ([`SCHEMA`]) and linted by
//! `tools/check_bench.py` in CI, so a field rename is a reviewed
//! change, not silent drift.

use crate::util::json::Json;

/// Schema tag of the emitted document (bump on field changes).
pub const SCHEMA: &str = "zest-load-v1";

/// Achieved/offered ratio below which a rate point counts as past the
/// saturation knee.
pub const KNEE_RATIO: f64 = 0.95;

/// One offered-rate measurement.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    /// Arrivals/sec the schedule fired (sent / elapsed).
    pub offered_hz: f64,
    /// Successful answers/sec over the same window.
    pub achieved_hz: f64,
    /// Requests dispatched on schedule.
    pub sent: u64,
    /// Successful answers.
    pub ok: u64,
    /// Requests shed on deadline (client fail-fast, submit reject, or
    /// batcher drain shed — all surface as `DeadlineExceeded`).
    pub shed: u64,
    /// Backpressure rejects (`Overloaded`: ingress queue full).
    pub rejected: u64,
    /// Every other failure (transport, protocol, internal). Zero in a
    /// healthy run below the knee — the acceptance bar.
    pub failed: u64,
    /// End-to-end latency quantiles of successful answers, measured
    /// from the **scheduled** arrival (ms).
    pub p50_ms: f64,
    /// 99th percentile (ms).
    pub p99_ms: f64,
    /// 99.9th percentile (ms).
    pub p999_ms: f64,
    /// Front-door hits / (hits + misses) over this point's window.
    pub cache_hit_rate: f64,
    /// Replica failovers ticked during this point.
    pub failovers: u64,
    /// Hedged reads fired during this point.
    pub hedges: u64,
}

impl SweepPoint {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("offered_hz", Json::num(self.offered_hz)),
            ("achieved_hz", Json::num(self.achieved_hz)),
            ("sent", Json::num(self.sent as f64)),
            ("ok", Json::num(self.ok as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("failed", Json::num(self.failed as f64)),
            ("p50_ms", Json::num(self.p50_ms)),
            ("p99_ms", Json::num(self.p99_ms)),
            ("p999_ms", Json::num(self.p999_ms)),
            ("cache_hit_rate", Json::num(self.cache_hit_rate)),
            ("failovers", Json::num(self.failovers as f64)),
            ("hedges", Json::num(self.hedges as f64)),
        ])
    }
}

/// The first offered rate whose achieved rate falls below
/// [`KNEE_RATIO`] × offered — the saturation knee. `None` when every
/// point keeps up (the sweep never reached saturation).
pub fn find_knee(points: &[SweepPoint]) -> Option<f64> {
    points
        .iter()
        .find(|p| p.achieved_hz < KNEE_RATIO * p.offered_hz)
        .map(|p| p.offered_hz)
}

/// One scenario's full sweep + the config that produced it.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// Scenario label (`healthy`, `chaos`).
    pub scenario: String,
    /// Simulated user keys.
    pub users: usize,
    /// Zipf exponent over users.
    pub zipf_s: f64,
    /// Session (sender) threads.
    pub sessions: usize,
    /// Per-point run window, ms.
    pub duration_ms: u64,
    /// Arrival process (`fixed` | `poisson`).
    pub arrival: String,
    /// Workload seed (schedule + mix replay).
    pub seed: u64,
    /// Shards × replicas of the target cluster (0 when unknown, e.g.
    /// an external `--server` target).
    pub shards: usize,
    /// Replicas per shard (0 when unknown).
    pub replicas: usize,
    /// One measurement per offered rate, in sweep order.
    pub points: Vec<SweepPoint>,
    /// Detected saturation knee ([`find_knee`]).
    pub knee_hz: Option<f64>,
}

impl LoadReport {
    /// Serialize one scenario.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scenario", Json::str(&self.scenario)),
            ("users", Json::num(self.users as f64)),
            ("zipf_s", Json::num(self.zipf_s)),
            ("sessions", Json::num(self.sessions as f64)),
            ("duration_ms", Json::num(self.duration_ms as f64)),
            ("arrival", Json::str(&self.arrival)),
            ("seed", Json::num(self.seed as f64)),
            ("shards", Json::num(self.shards as f64)),
            ("replicas", Json::num(self.replicas as f64)),
            (
                "points",
                Json::Arr(self.points.iter().map(SweepPoint::to_json).collect()),
            ),
            (
                "knee_hz",
                match self.knee_hz {
                    Some(hz) => Json::num(hz),
                    None => Json::Null,
                },
            ),
        ])
    }
}

/// Assemble the committed `BENCH_load.json` document from scenario
/// reports.
pub fn document(runs: &[LoadReport]) -> Json {
    Json::obj(vec![
        ("schema", Json::str(SCHEMA)),
        ("runs", Json::Arr(runs.iter().map(LoadReport::to_json).collect())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(offered: f64, achieved: f64) -> SweepPoint {
        SweepPoint {
            offered_hz: offered,
            achieved_hz: achieved,
            sent: offered as u64,
            ok: achieved as u64,
            shed: 0,
            rejected: 0,
            failed: 0,
            p50_ms: 1.0,
            p99_ms: 2.0,
            p999_ms: 3.0,
            cache_hit_rate: 0.5,
            failovers: 0,
            hedges: 0,
        }
    }

    #[test]
    fn knee_is_first_lagging_point() {
        let points = vec![point(100.0, 99.0), point(200.0, 197.0), point(400.0, 310.0)];
        assert_eq!(find_knee(&points), Some(400.0));
        assert_eq!(find_knee(&points[..2]), None);
    }

    #[test]
    fn document_round_trips_through_json() {
        let report = LoadReport {
            scenario: "healthy".to_string(),
            users: 1000,
            zipf_s: 1.1,
            sessions: 32,
            duration_ms: 2000,
            arrival: "poisson".to_string(),
            seed: 7,
            shards: 2,
            replicas: 2,
            points: vec![point(100.0, 100.0)],
            knee_hz: None,
        };
        let text = document(std::slice::from_ref(&report)).to_string();
        let parsed = Json::parse(&text).expect("emitted document must parse");
        let Json::Obj(top) = &parsed else { panic!("not an object") };
        assert_eq!(top.get("schema"), Some(&Json::Str(SCHEMA.to_string())));
        let Some(Json::Arr(runs)) = top.get("runs") else {
            panic!("runs not an array");
        };
        assert_eq!(runs.len(), 1);
    }
}
