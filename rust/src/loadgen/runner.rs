//! The open-loop runner: fire the schedule, never look back.
//!
//! One scheduler (the calling thread) walks a [`Schedule`], sleeps
//! until each absolute arrival deadline, samples the [`WorkloadMix`]
//! and pushes the materialized request onto an **unbounded** dispatch
//! channel — so a slow or stalled server can never exert backpressure
//! on the *arrival* process. A pool of session threads drains the
//! channel and issues blocking wire calls; each records latency from
//! the request's **scheduled** arrival into a lock-free
//! [`Histogram`], so time spent queued behind saturated sessions is
//! charged to the request (the anti-coordinated-omission invariant —
//! see the `loadgen` module docs).

use super::mix::WorkloadMix;
use super::schedule::{Arrival, Schedule};
use crate::net::client::{ClientError, PartitionClient};
use crate::net::wire::ErrorCode;
use crate::obs::{Histogram, HistogramSnapshot, MetricsBlob};
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// One fixed-rate run's knobs.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Offered arrival rate, requests/sec.
    pub rate_hz: f64,
    /// Run window: arrivals scheduled in `[0, duration)`.
    pub duration: Duration,
    /// Session (sender) threads draining the dispatch channel. Sizes
    /// the achievable concurrency, **not** the offered rate.
    pub sessions: usize,
    /// Inter-arrival process.
    pub arrival: Arrival,
    /// Schedule + mix seed (a run is replayable from this).
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            rate_hz: 500.0,
            duration: Duration::from_secs(2),
            sessions: 32,
            arrival: Arrival::Poisson,
            seed: 1,
        }
    }
}

#[derive(Default)]
struct Counters {
    ok: AtomicU64,
    shed: AtomicU64,
    rejected: AtomicU64,
    failed: AtomicU64,
}

/// What one run measured.
#[derive(Clone, Debug)]
pub struct RunStats {
    /// Arrivals actually dispatched (≈ rate × duration; short only if
    /// every session thread died).
    pub sent: u64,
    /// Successful answers.
    pub ok: u64,
    /// `DeadlineExceeded` outcomes (shed anywhere along the path).
    pub shed: u64,
    /// `Overloaded` rejects (ingress backpressure).
    pub rejected: u64,
    /// Any other failure.
    pub failed: u64,
    /// Wall time from first scheduled arrival to last settled answer.
    pub elapsed: Duration,
    /// Scheduled-arrival → answer latency of successful requests.
    pub latency: HistogramSnapshot,
}

impl RunStats {
    /// Offered rate over the settled window.
    pub fn offered_hz(&self) -> f64 {
        self.sent as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }

    /// Successful-answer rate over the settled window.
    pub fn achieved_hz(&self) -> f64 {
        self.ok as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }
}

struct Job {
    scheduled: Instant,
    spec: crate::coordinator::EstimateSpec,
}

/// Drive one open-loop run of `cfg` against `client` with the given
/// workload mix. Blocks until every dispatched request has settled
/// (the schedule itself never blocks on any of them).
pub fn run_open_loop(
    client: &Arc<PartitionClient>,
    mix: &Arc<WorkloadMix>,
    cfg: &RunConfig,
) -> RunStats {
    let hist = Arc::new(Histogram::new());
    let counters = Arc::new(Counters::default());
    let (tx, rx) = mpsc::channel::<Job>();
    let rx = Arc::new(Mutex::new(rx));

    let sessions: Vec<_> = (0..cfg.sessions.max(1))
        .map(|i| {
            let rx = Arc::clone(&rx);
            let client = Arc::clone(client);
            let hist = Arc::clone(&hist);
            let counters = Arc::clone(&counters);
            std::thread::Builder::new()
                .name(format!("loadgen-session-{i}"))
                .spawn(move || loop {
                    // Hold the receiver lock only for the dequeue; the
                    // blocking wire call runs lock-free so sessions
                    // drain concurrently.
                    let job = match rx.lock().unwrap().recv() {
                        Ok(job) => job,
                        Err(_) => return,
                    };
                    let outcome = client.estimate(job.spec);
                    match outcome {
                        Ok(_) => {
                            // Only successes shape the latency
                            // quantiles; sheds and rejects are counted,
                            // not timed.
                            hist.record_duration(job.scheduled.elapsed());
                            counters.ok.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(ClientError::Remote { code, .. }) => {
                            let c = match code {
                                ErrorCode::DeadlineExceeded => &counters.shed,
                                ErrorCode::Overloaded => &counters.rejected,
                                _ => &counters.failed,
                            };
                            c.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(_) => {
                            counters.failed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                })
                .expect("spawn session thread")
        })
        .collect();

    let start = Instant::now();
    let mut rng = Rng::seeded(cfg.seed ^ 0x3A11_0CA7);
    let mut sent = 0u64;
    for offset in Schedule::new(cfg.rate_hz, cfg.arrival, cfg.seed) {
        if offset >= cfg.duration {
            break;
        }
        let due = start + offset;
        let now = Instant::now();
        if due > now {
            std::thread::sleep(due - now);
        }
        // Sample + materialize at (approximately) the scheduled
        // instant so class deadlines anchor at arrival, then dispatch
        // without ever checking how far behind the sessions are.
        let req = mix.sample(&mut rng);
        if tx.send(Job { scheduled: due, spec: mix.spec(req) }).is_err() {
            break; // every session thread died — nothing can settle
        }
        sent += 1;
    }
    drop(tx);
    for t in sessions {
        let _ = t.join();
    }
    let elapsed = start.elapsed();

    RunStats {
        sent,
        ok: counters.ok.load(Ordering::Relaxed),
        shed: counters.shed.load(Ordering::Relaxed),
        rejected: counters.rejected.load(Ordering::Relaxed),
        failed: counters.failed.load(Ordering::Relaxed),
        elapsed,
        latency: hist.snapshot(),
    }
}

/// Cluster-side counter deltas over one run window, scraped via
/// `GetMetrics` before/after. Zeros when the target does not expose a
/// counter (or the scrape itself fails — never let telemetry kill a
/// load run).
#[derive(Clone, Copy, Debug, Default)]
pub struct MetricsDelta {
    /// Front-door result-cache hits.
    pub cache_hits: u64,
    /// Front-door result-cache misses.
    pub cache_misses: u64,
    /// Replica failovers.
    pub failovers: u64,
    /// Hedged reads fired.
    pub hedges: u64,
}

impl MetricsDelta {
    /// Hits / (hits + misses); 0 when nothing was cacheable.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }
}

fn scrape(client: &PartitionClient) -> MetricsBlob {
    client.get_metrics().unwrap_or_else(|e| {
        log::warn!("loadgen metrics scrape failed: {e}");
        MetricsBlob::default()
    })
}

fn delta(before: &MetricsBlob, after: &MetricsBlob) -> MetricsDelta {
    let d = |name: &str| after.counter(name).saturating_sub(before.counter(name));
    MetricsDelta {
        cache_hits: d("cache_hits"),
        cache_misses: d("cache_misses"),
        failovers: d("shard_failovers"),
        hedges: d("shard_hedges"),
    }
}

/// Walk a rate ladder: one [`run_open_loop`] per offered rate (same
/// duration/sessions/seed), each bracketed by a `GetMetrics` scrape so
/// cache/failover/hedge counters attribute per point. Points are
/// returned in ladder order; feed them through
/// [`super::report::find_knee`] to locate saturation.
pub fn sweep(
    client: &Arc<PartitionClient>,
    mix: &Arc<WorkloadMix>,
    rates: &[f64],
    base: &RunConfig,
) -> Vec<(RunStats, MetricsDelta)> {
    rates
        .iter()
        .map(|&rate_hz| {
            let cfg = RunConfig { rate_hz, ..base.clone() };
            let before = scrape(client);
            let stats = run_open_loop(client, mix, &cfg);
            let after = scrape(client);
            log::info!(
                "loadgen: offered {:.0}/s achieved {:.0}/s ok={} shed={} rejected={} failed={}",
                stats.offered_hz(),
                stats.achieved_hz(),
                stats.ok,
                stats.shed,
                stats.rejected,
                stats.failed
            );
            (stats, delta(&before, &after))
        })
        .collect()
}

/// Fold one measured point into a report row.
pub fn to_point(stats: &RunStats, metrics: &MetricsDelta) -> super::report::SweepPoint {
    super::report::SweepPoint {
        offered_hz: stats.offered_hz(),
        achieved_hz: stats.achieved_hz(),
        sent: stats.sent,
        ok: stats.ok,
        shed: stats.shed,
        rejected: stats.rejected,
        failed: stats.failed,
        p50_ms: stats.latency.p50().as_secs_f64() * 1e3,
        p99_ms: stats.latency.p99().as_secs_f64() * 1e3,
        p999_ms: stats.latency.p999().as_secs_f64() * 1e3,
        cache_hit_rate: metrics.cache_hit_rate(),
        failovers: metrics.failovers,
        hedges: metrics.hedges,
    }
}
