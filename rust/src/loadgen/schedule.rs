//! Open-loop arrival schedule: when each request *must* be fired.
//!
//! The schedule is a pure function of `(rate, arrival process, seed)` —
//! an iterator of absolute offsets from the run's start instant. The
//! runner sleeps until each offset and dispatches; it never looks at
//! responses, which is the whole point (see the module docs on
//! coordinated omission). Determinism under a seed makes a run
//! replayable: the same seed yields byte-identical arrival times.

use crate::util::rng::Rng;
use std::time::Duration;

/// The inter-arrival process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arrival {
    /// Evenly spaced arrivals: gap = 1/rate exactly. The harshest
    /// schedule for a batcher (no natural burstiness to amortize) and
    /// the easiest to reason about.
    Fixed,
    /// Poisson arrivals: exponential gaps with mean 1/rate, the
    /// classical open-system model. Bursts and lulls at the same
    /// offered rate — closer to real user traffic.
    Poisson,
}

impl Arrival {
    /// Parse a CLI spelling (`fixed` | `poisson`).
    pub fn parse(s: &str) -> Result<Arrival, String> {
        match s {
            "fixed" => Ok(Arrival::Fixed),
            "poisson" => Ok(Arrival::Poisson),
            other => Err(format!("unknown arrival process '{other}' (want fixed|poisson)")),
        }
    }
}

impl std::fmt::Display for Arrival {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Arrival::Fixed => write!(f, "fixed"),
            Arrival::Poisson => write!(f, "poisson"),
        }
    }
}

/// Infinite iterator of absolute arrival offsets (from the run start)
/// at `rate_hz` under an [`Arrival`] process. The first arrival is at
/// offset 0; offsets are strictly non-decreasing. Gap arithmetic runs
/// in f64 nanoseconds so fractional rates (e.g. 2500.5 Hz) accumulate
/// without drift.
pub struct Schedule {
    arrival: Arrival,
    /// Mean gap, ns.
    gap_ns: f64,
    rng: Rng,
    /// Offset of the next arrival, ns.
    next_ns: f64,
}

impl Schedule {
    /// Arrivals at `rate_hz` (> 0) under `arrival`, deterministic in
    /// `seed` (only `Poisson` consumes randomness, but `Fixed` derives
    /// the same way so swapping processes never perturbs the workload
    /// RNG).
    pub fn new(rate_hz: f64, arrival: Arrival, seed: u64) -> Schedule {
        assert!(
            rate_hz.is_finite() && rate_hz > 0.0,
            "arrival rate must be positive, got {rate_hz}"
        );
        Schedule {
            arrival,
            gap_ns: 1e9 / rate_hz,
            rng: Rng::seeded(seed ^ 0x09E4_100D),
            next_ns: 0.0,
        }
    }
}

impl Iterator for Schedule {
    type Item = Duration;

    fn next(&mut self) -> Option<Duration> {
        let at = self.next_ns;
        let gap = match self.arrival {
            Arrival::Fixed => self.gap_ns,
            // Inverse-CDF exponential draw; 1-u keeps the argument in
            // (0, 1] so ln never sees 0.
            Arrival::Poisson => -(1.0 - self.rng.f64()).ln() * self.gap_ns,
        };
        self.next_ns = at + gap;
        Some(Duration::from_nanos(at as u64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_gaps_are_exact() {
        let times: Vec<Duration> = Schedule::new(1000.0, Arrival::Fixed, 7).take(5).collect();
        let want: Vec<Duration> = (0..5).map(|i| Duration::from_micros(i * 1000)).collect();
        assert_eq!(times, want);
    }

    #[test]
    fn seeded_schedules_replay() {
        for arrival in [Arrival::Fixed, Arrival::Poisson] {
            let a: Vec<Duration> = Schedule::new(5000.0, arrival, 42).take(1000).collect();
            let b: Vec<Duration> = Schedule::new(5000.0, arrival, 42).take(1000).collect();
            assert_eq!(a, b, "{arrival}: same seed must replay exactly");
            let c: Vec<Duration> = Schedule::new(5000.0, arrival, 43).take(1000).collect();
            if arrival == Arrival::Poisson {
                assert_ne!(a, c, "different seeds must differ");
            }
        }
    }

    #[test]
    fn poisson_mean_gap_matches_rate() {
        let rate = 2000.0;
        let times: Vec<Duration> = Schedule::new(rate, Arrival::Poisson, 3).take(20_001).collect();
        let span = times.last().unwrap().as_secs_f64();
        let mean_gap = span / 20_000.0;
        let want = 1.0 / rate;
        assert!(
            (mean_gap - want).abs() < want * 0.05,
            "mean gap {mean_gap} vs want {want}"
        );
        // Offsets never go backwards.
        assert!(times.windows(2).all(|w| w[0] <= w[1]));
    }
}
