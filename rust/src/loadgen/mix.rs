//! The workload mix: *who* asks *what*.
//!
//! Users are simulated keys drawn from a [`Zipf`] law — a handful of
//! hot users dominate, a long tail appears once — which is both the
//! empirical shape of query traffic and the regime the front-door
//! cache is built for: every draw of a hot user repeats that user's
//! deterministic query, so cache hit rate under load is an emergent
//! property of the mix, not a scripted scenario. Each request also
//! draws a weighted *class* (estimator kind, budgets, precision,
//! deadline), mirroring production traffic where cheap top-k lookups
//! vastly outnumber exact partition sums.

use crate::coordinator::{EstimateSpec, Precision};
use crate::estimators::EstimatorKind;
use crate::util::rng::{Rng, Zipf};
use std::time::Duration;

/// One request class in the mix: everything of an [`EstimateSpec`]
/// except the query, plus a sampling weight.
#[derive(Clone, Debug)]
pub struct MixClass {
    /// Display name (report rows, logs).
    pub name: &'static str,
    /// Estimator kind.
    pub kind: EstimatorKind,
    /// Head budget (kinds that read it; see service validation).
    pub k: usize,
    /// Tail budget (kinds that read it).
    pub l: usize,
    /// Remote execution precision.
    pub precision: Precision,
    /// Latency budget, anchored at the request's scheduled arrival;
    /// `None` never sheds.
    pub deadline: Option<Duration>,
    /// Relative sampling weight (> 0; normalized over the class table).
    pub weight: f64,
}

/// The default production-shaped mix: mostly cheap sampler lookups
/// under tight deadlines, a thin stream of exact sums under loose ones.
pub fn default_classes() -> Vec<MixClass> {
    vec![
        MixClass {
            name: "nmimps-tight",
            kind: EstimatorKind::Nmimps,
            k: 16,
            l: 0,
            precision: Precision::BitExact,
            deadline: Some(Duration::from_millis(100)),
            weight: 0.40,
        },
        MixClass {
            name: "mimps-tight",
            kind: EstimatorKind::Mimps,
            k: 16,
            l: 32,
            precision: Precision::BitExact,
            deadline: Some(Duration::from_millis(100)),
            weight: 0.25,
        },
        MixClass {
            name: "mince-mid",
            kind: EstimatorKind::Mince,
            k: 16,
            l: 32,
            precision: Precision::BitExact,
            deadline: Some(Duration::from_millis(150)),
            weight: 0.15,
        },
        MixClass {
            name: "fmbe-mid",
            kind: EstimatorKind::Fmbe,
            k: 0,
            l: 0,
            precision: Precision::BitExact,
            deadline: Some(Duration::from_millis(150)),
            weight: 0.10,
        },
        MixClass {
            name: "exact-loose",
            kind: EstimatorKind::Exact,
            k: 0,
            l: 0,
            precision: Precision::Pipelined,
            deadline: Some(Duration::from_millis(500)),
            weight: 0.10,
        },
    ]
}

/// One sampled arrival: user key + class index into the mix table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LoadRequest {
    /// Zipf-ranked user key (0 = hottest).
    pub user: usize,
    /// Index into [`WorkloadMix::classes`].
    pub class: usize,
}

/// A Zipf-over-users workload with per-user deterministic queries and a
/// weighted class table. Query vectors for every user are materialized
/// up front (`users × dim × 4` bytes) so sampling on the dispatch path
/// is an index + clone, never RNG-bound.
pub struct WorkloadMix {
    zipf: Zipf,
    /// `queries[user]` — the user's fixed unit query vector.
    queries: Vec<Vec<f32>>,
    classes: Vec<MixClass>,
    /// Cumulative normalized class weights, for inverse-CDF class draws.
    cum: Vec<f64>,
}

impl WorkloadMix {
    /// A mix over `users` simulated keys with Zipf exponent `zipf_s`,
    /// `dim`-dimensional queries, and the given class table.
    /// Deterministic in `seed`: user u's query is the same vector in
    /// every run with the same seed.
    pub fn new(
        users: usize,
        zipf_s: f64,
        dim: usize,
        classes: Vec<MixClass>,
        seed: u64,
    ) -> WorkloadMix {
        assert!(users > 0, "need at least one user");
        assert!(!classes.is_empty(), "need at least one mix class");
        assert!(
            classes.iter().all(|c| c.weight > 0.0),
            "class weights must be positive"
        );
        let mut qrng = Rng::seeded(seed ^ 0x0A11_05E5);
        let queries = (0..users).map(|_| qrng.unit_vec(dim)).collect();
        let total: f64 = classes.iter().map(|c| c.weight).sum();
        let mut acc = 0.0;
        let cum = classes
            .iter()
            .map(|c| {
                acc += c.weight / total;
                acc
            })
            .collect();
        WorkloadMix {
            zipf: Zipf::new(users, zipf_s),
            queries,
            classes,
            cum,
        }
    }

    /// Number of simulated users.
    pub fn users(&self) -> usize {
        self.queries.len()
    }

    /// The class table, in `LoadRequest::class` order.
    pub fn classes(&self) -> &[MixClass] {
        &self.classes
    }

    /// The Zipf law user keys are drawn from (frequency tests).
    pub fn zipf(&self) -> &Zipf {
        &self.zipf
    }

    /// User u's fixed query vector.
    pub fn query(&self, user: usize) -> &[f32] {
        &self.queries[user]
    }

    /// Draw one arrival: Zipf user + weighted class.
    pub fn sample(&self, rng: &mut Rng) -> LoadRequest {
        let user = self.zipf.sample(rng);
        let u = rng.f64();
        let class = self
            .cum
            .iter()
            .position(|&c| u < c)
            .unwrap_or(self.classes.len() - 1);
        LoadRequest { user, class }
    }

    /// Materialize the [`EstimateSpec`] for a sampled arrival. Call at
    /// the request's **scheduled** time: the class deadline anchors
    /// here, so budget burned queueing behind a saturated dispatch
    /// counts against the request exactly as it would for a real user.
    pub fn spec(&self, req: LoadRequest) -> EstimateSpec {
        let c = &self.classes[req.class];
        let mut spec = EstimateSpec::new(self.queries[req.user].clone())
            .kind(c.kind)
            .k(c.k)
            .l(c.l)
            .precision(c.precision);
        if let Some(budget) = c.deadline {
            spec = spec.deadline_in(budget);
        }
        spec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn user_queries_are_deterministic() {
        let a = WorkloadMix::new(64, 1.1, 8, default_classes(), 9);
        let b = WorkloadMix::new(64, 1.1, 8, default_classes(), 9);
        for u in 0..64 {
            assert_eq!(a.query(u), b.query(u));
        }
    }

    #[test]
    fn class_draws_follow_weights() {
        let mix = WorkloadMix::new(16, 1.0, 4, default_classes(), 11);
        let mut rng = Rng::seeded(5);
        let mut counts = vec![0usize; mix.classes().len()];
        let draws = 200_000;
        for _ in 0..draws {
            counts[mix.sample(&mut rng).class] += 1;
        }
        let total: f64 = mix.classes().iter().map(|c| c.weight).sum();
        for (i, c) in mix.classes().iter().enumerate() {
            let want = c.weight / total;
            let got = counts[i] as f64 / draws as f64;
            assert!(
                (got - want).abs() < 0.01,
                "class {}: frequency {got} vs weight {want}",
                c.name
            );
        }
    }
}
