//! Open-loop load generation: the million-user workload harness.
//!
//! Microbenches measure *operations*; this subsystem measures
//! *traffic*. The generator is **open-loop**: request arrival times are
//! absolute deadlines derived from a monotonic clock and a target rate
//! ([`Schedule`]), never gated on responses. A closed-loop generator
//! (issue → wait → issue) silently stops offering load the moment the
//! server stalls, which deletes exactly the tail samples a saturated
//! system produces — the *coordinated omission* artifact. Here a
//! stalled server keeps receiving arrivals on schedule (they queue in
//! an unbounded dispatch channel) and every latency is measured from
//! the request's **scheduled** arrival, so queueing delay the user
//! would have experienced is in the histogram.
//!
//! The workload is a Zipf-distributed query mix over thousands of
//! simulated user keys ([`WorkloadMix`]): each user owns a
//! deterministic query vector, so the hot keys Zipf re-draws are
//! repeat queries the front-door cache can serve, and the mix spreads
//! requests over estimator kinds, budgets, precisions and deadline
//! classes like real traffic would.
//!
//! [`run_open_loop`] drives one fixed-rate run and records latency into
//! the `obs/` lock-free [`crate::obs::Histogram`] (recording never
//! blocks the workload); [`sweep`] walks a rate ladder and brackets the
//! saturation knee; [`report`] serializes the result as the committed
//! `BENCH_load.json`. [`ClusterHarness`] self-spawns a full in-process
//! cluster (shard workers × replicas, optionally behind
//! [`crate::testing::fault::FaultProxy`] links, the batching service,
//! and a real wire front door) for chaos-under-load runs where the
//! writer thread publishes add/remove epochs mid-run.

pub mod harness;
pub mod mix;
pub mod report;
pub mod runner;
pub mod schedule;

pub use harness::{ClusterHarness, HarnessConfig};
pub use mix::{default_classes, LoadRequest, MixClass, WorkloadMix};
pub use report::{document, find_knee, LoadReport, SweepPoint, KNEE_RATIO, SCHEMA};
pub use runner::{run_open_loop, sweep, to_point, MetricsDelta, RunConfig, RunStats};
pub use schedule::{Arrival, Schedule};
