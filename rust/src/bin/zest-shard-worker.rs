//! `zest-shard-worker` — serve one shard of the category set over the
//! wire (UDS or TCP), as one process of a [`zest::net::remote::RemoteCluster`].
//!
//! ```bash
//! # shard 0 of 2 over a 100k-row synthetic set, on a unix socket:
//! zest-shard-worker --listen unix:///tmp/shard0.sock \
//!     --synth 100000,128,0 --range 0,50000
//! # from a saved embedding file:
//! zest-shard-worker --listen tcp://127.0.0.1:7101 --data vecs.bin --range 50000,100000
//! ```
//!
//! `--range lo,hi` serves rows `[lo, hi)` of the loaded/generated set —
//! how one dataset is cut across worker processes. Keep every worker's
//! row count a multiple of 4 (the last excepted) for bit-pinned `Exact`
//! answers (see `zest::net::remote::aligned_split_lens`). Prints
//! `READY <addr>` on stdout once listening.
//!
//! **Replicas**: a replica set is simply several workers started with
//! the *same* `--range` (and data source), listed with `|` in the
//! coordinator's `--cluster`/`--workers` grammar
//! (`s0a|s0b,s1a|s1b`). Identical rows + the deterministic kernels
//! make replica answers bit-identical at a given epoch, which is what
//! lets `RemoteCluster` fail reads over transparently.

use anyhow::{bail, Result};
use std::io::Write as _;
use std::sync::Arc;
use zest::coordinator::ServiceMetrics;
use zest::data::embeddings::EmbeddingStore;
use zest::net::server::{Server, ServerConfig};
use zest::net::shard::ShardWorker;
use zest::net::Addr;
use zest::util::cli::Args;

fn main() {
    zest::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv).map_err(anyhow::Error::msg)?;
    args.check_known(&[
        "listen",
        "data",
        "synth",
        "range",
        // Accepted for launcher-script uniformity with `zest-server`;
        // shard workers hold no front-door cache (caching happens at
        // the coordinator, which keys on the publish epoch).
        "cache-entries",
        "cache-bytes",
        "max-conns",
        "read-timeout-ms",
        "reactor-threads",
        "handler-threads",
    ])
    .map_err(anyhow::Error::msg)?;
    let listen: String = args.require("listen").map_err(anyhow::Error::msg)?;
    let addr = Addr::parse(&listen)?;

    let Some(full) = zest::data::rows_from_cli(&args)? else {
        bail!("one of --data <file> or --synth n,d,seed is required");
    };
    let rows = slice_range(&args, full)?;
    if rows.is_empty() {
        bail!("shard worker has no rows to serve");
    }
    log::info!(
        "shard worker: {} rows × {} dims",
        rows.len(),
        rows.dim()
    );

    let defaults = ServerConfig::default();
    let cfg = ServerConfig {
        max_connections: args.get_or("max-conns", 64),
        read_timeout: match args.get_or("read-timeout-ms", 30_000u64) {
            0 => None,
            ms => Some(std::time::Duration::from_millis(ms)),
        },
        reactor_threads: args.get_or("reactor-threads", defaults.reactor_threads),
        handler_threads: args.get_or("handler-threads", defaults.handler_threads),
    };
    // One sink shared by the worker handler and the wire server, so a
    // `GetMetrics` scrape reports this worker's connection/frame
    // counters and handler-pool histograms alongside everything else.
    let metrics = Arc::new(ServiceMetrics::new());
    let server = Server::serve(
        &addr,
        Arc::new(ShardWorker::new(rows).with_metrics(metrics.clone())),
        cfg,
        metrics,
    )?;
    println!("READY {}", server.local_addr());
    std::io::stdout().flush().ok();
    // Serve until killed.
    loop {
        std::thread::park();
    }
}

fn slice_range(args: &Args, full: EmbeddingStore) -> Result<EmbeddingStore> {
    if !args.has("range") {
        return Ok(full);
    }
    let r: Vec<usize> = args.get_list("range", &[]);
    if r.len() != 2 || r[0] >= r[1] || r[1] > full.len() {
        bail!(
            "--range wants lo,hi with 0 <= lo < hi <= {} rows",
            full.len()
        );
    }
    let (lo, hi) = (r[0], r[1]);
    let d = full.dim();
    Ok(EmbeddingStore::from_data(hi - lo, d, full.rows(lo, hi).to_vec())?)
}
