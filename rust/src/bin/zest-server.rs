//! `zest-server` — the partition server: expose estimation over the
//! wire (UDS or TCP), backed by a **local** epoch-snapshotted sharded
//! store or by **remote shard workers** — the latter either directly
//! (`--workers`) or through the full batching service (`--cluster`).
//!
//! ```bash
//! # local serving (the in-process PartitionService behind a socket):
//! zest-server --listen tcp://127.0.0.1:7070 --synth 100000,128,0 --shards 4
//! # direct pass-through to two shard-worker processes (no batcher):
//! zest-server --listen unix:///tmp/zest.sock \
//!     --workers unix:///tmp/shard0.sock,unix:///tmp/shard1.sock
//! # the dynamic batcher + backpressure + ServiceMetrics in front of
//! # the same worker cluster (PartitionService over a ClusterBackend):
//! zest-server --listen unix:///tmp/zest.sock \
//!     --cluster unix:///tmp/shard0.sock,unix:///tmp/shard1.sock
//! # replicated shards: `|` groups replicas of one shard; reads
//! # load-balance across them and fail over transparently:
//! zest-server --listen unix:///tmp/zest.sock \
//!     --cluster unix:///tmp/s0a.sock|unix:///tmp/s0b.sock,unix:///tmp/s1a.sock|unix:///tmp/s1b.sock
//! # with telemetry: trace 1% of requests, expose Prometheus text:
//! zest-server --listen unix:///tmp/zest.sock --synth 100000,128,0 \
//!     --trace-sample-rate 0.01 --metrics-listen tcp://127.0.0.1:9464
//! ```
//!
//! `--metrics-listen ADDR` serves `GET /metrics` (Prometheus text;
//! merged with the shard workers' own counters in the `--cluster` and
//! `--workers` modes). `--trace-sample-rate R` traces every ⌈1/R⌉-th
//! request through the service stages (see `docs/OBSERVABILITY.md`).
//! Prints `READY <addr>` on stdout once listening. Clients speak
//! [`zest::net::client::PartitionClient`].

use anyhow::{bail, Result};
use std::io::Write as _;
use std::sync::Arc;
use zest::coordinator::{ClusterBackend, PartitionService, Router, ServiceConfig, ServiceMetrics};
use zest::net::client::ClientConfig;
use zest::net::remote::{ClusterHandler, RemoteCluster};
use zest::net::server::{Handler, Server, ServerConfig, ServiceHandler};
use zest::net::Addr;
use zest::obs::{MetricsBlob, MetricsHttpServer};
use zest::store::{ShardedStore, SnapshotHandle};
use zest::util::cli::Args;

/// What `--metrics-listen` exposes.
type MetricsSource = Arc<dyn Fn() -> MetricsBlob + Send + Sync>;

fn main() {
    zest::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv).map_err(anyhow::Error::msg)?;
    args.check_known(&[
        "listen",
        "workers",
        "cluster",
        "data",
        "synth",
        "shards",
        "service-workers",
        "queue-capacity",
        "cache-entries",
        "cache-bytes",
        "max-conns",
        "read-timeout-ms",
        "reactor-threads",
        "handler-threads",
        "seed",
        "trace-sample-rate",
        "metrics-listen",
        "hedge-delay-ms",
    ])
    .map_err(anyhow::Error::msg)?;
    let listen: String = args.require("listen").map_err(anyhow::Error::msg)?;
    let addr = Addr::parse(&listen)?;
    let seed: u64 = args.get_or("seed", 0);
    // Front-door result cache bounds (entries and bytes; whichever is
    // tighter wins — see coordinator::CacheConfig). 0 disables caching.
    let cache_defaults = ServiceConfig::default();
    let cache_entries: usize = args.get_or("cache-entries", cache_defaults.cache_entries);
    let cache_bytes: usize = args.get_or("cache-bytes", cache_defaults.cache_bytes);
    // Fraction of requests carrying a per-stage trace (0 disables; 1
    // traces everything). Sampled traces land in the service's ring and
    // feed the per-stage histograms `--metrics-listen` exposes.
    let trace_sample_rate: f64 = args.get_or("trace-sample-rate", 0.0);
    // Hedge delay for idempotent replica reads (TopK): a read still
    // unanswered after this long is duplicated to the next healthy
    // replica and the first answer wins. 0 disables (the default);
    // only meaningful with ≥ 2 replicas per shard.
    let hedge_delay_ms: u64 = args.get_or("hedge-delay-ms", 0);

    // What a `GET /metrics` scrape reports: the serving stack's own
    // sink, merged with the worker fan-out where one exists.
    let metrics_source: MetricsSource;
    let mut metrics: Option<Arc<ServiceMetrics>> = None;
    let handler: Arc<dyn Handler> = if args.has("cluster") {
        // Cross-process shards behind the full service: the dynamic
        // batcher, backpressure policy and ServiceMetrics in front of
        // the remote cluster (PartitionService over a ClusterBackend).
        // `,` separates shards, `|` separates replicas of one shard
        // (e.g. `w0a|w0b,w1a|w1b` — see net::parse_worker_groups).
        let groups = zest::net::parse_worker_groups(args.get("cluster").unwrap())?;
        let backend = ClusterBackend::connect_groups(&groups, ClientConfig::default())
            .map_err(|e| anyhow::anyhow!("connect cluster workers: {e}"))?;
        let cluster = backend.cluster().clone();
        log::info!(
            "serving {} categories × {} dims from {} shards × {:?} replicas (epoch {}) \
             through the batching service",
            cluster.len(),
            cluster.dim(),
            cluster.num_shards(),
            cluster.replica_status().iter().map(Vec::len).collect::<Vec<_>>(),
            cluster.epoch()
        );
        let svc = Arc::new(PartitionService::start_with_backend(
            backend,
            ServiceConfig {
                workers: args.get_or(
                    "service-workers",
                    zest::util::threadpool::default_threads().min(8),
                ),
                queue_capacity: args.get_or("queue-capacity", 1024),
                seed,
                cache_entries,
                cache_bytes,
                trace_sample_rate,
                ..Default::default()
            },
        ));
        // Failovers tick the same per-shard table the batcher's scatter
        // errors land in (`shard_stats[..].failovers`).
        cluster.set_metrics(svc.metrics_handle());
        if hedge_delay_ms > 0 {
            cluster.set_hedge_delay(std::time::Duration::from_millis(hedge_delay_ms));
        }
        metrics = Some(svc.metrics_handle());
        let scrape = svc.clone();
        metrics_source = Arc::new(move || {
            let mut blob = scrape.metrics_handle().blob();
            if let Some(workers) = scrape.backend().metrics() {
                blob.merge(&workers);
            }
            blob
        });
        Arc::new(ServiceHandler::new(svc))
    } else if args.has("workers") {
        // Cross-process shards: scatter across worker processes
        // (direct pass-through handler, no queue/batcher). Same
        // replica-group grammar as `--cluster`.
        let groups = zest::net::parse_worker_groups(args.get("workers").unwrap())?;
        let cluster = Arc::new(
            RemoteCluster::connect_groups(&groups, ClientConfig::default())
                .map_err(|e| anyhow::anyhow!("connect workers: {e}"))?,
        );
        log::info!(
            "serving {} categories × {} dims from {} shards × {:?} replicas (epoch {})",
            cluster.len(),
            cluster.dim(),
            cluster.num_shards(),
            cluster.replica_status().iter().map(Vec::len).collect::<Vec<_>>(),
            cluster.epoch()
        );
        // No service in front: scrapes merge the wire server's own
        // sink with the worker fan-out.
        let sink = Arc::new(ServiceMetrics::new());
        cluster.set_metrics(sink.clone());
        if hedge_delay_ms > 0 {
            cluster.set_hedge_delay(std::time::Duration::from_millis(hedge_delay_ms));
        }
        metrics = Some(sink.clone());
        let scrape_cluster = cluster.clone();
        metrics_source = Arc::new(move || {
            let mut blob = sink.blob();
            blob.merge(&scrape_cluster.cluster_metrics());
            blob
        });
        Arc::new(ClusterHandler::new(cluster, seed))
    } else {
        // Local serving: the in-process service behind a socket.
        let Some(store) = zest::data::rows_from_cli(&args)? else {
            bail!("one of --cluster, --workers, --data or --synth is required");
        };
        let shards: usize = args.get_or("shards", 1);
        log::info!(
            "serving {} categories × {} dims from {shards} local shard(s)",
            store.len(),
            store.dim()
        );
        let handle = Arc::new(SnapshotHandle::brute(ShardedStore::split(&store, shards)));
        let svc = Arc::new(PartitionService::start_sharded(
            handle,
            Router::new(Default::default()),
            ServiceConfig {
                workers: args.get_or(
                    "service-workers",
                    zest::util::threadpool::default_threads().min(8),
                ),
                queue_capacity: args.get_or("queue-capacity", 1024),
                seed,
                cache_entries,
                cache_bytes,
                trace_sample_rate,
                ..Default::default()
            },
            None,
        ));
        // Wire-level counters land in the service's own metrics sink.
        metrics = Some(svc.metrics_handle());
        let scrape = svc.clone();
        metrics_source = Arc::new(move || scrape.metrics_handle().blob());
        Arc::new(ServiceHandler::new(svc))
    };

    let defaults = ServerConfig::default();
    let cfg = ServerConfig {
        max_connections: args.get_or("max-conns", 256),
        read_timeout: match args.get_or("read-timeout-ms", 30_000u64) {
            0 => None,
            ms => Some(std::time::Duration::from_millis(ms)),
        },
        reactor_threads: args.get_or("reactor-threads", defaults.reactor_threads),
        handler_threads: args.get_or("handler-threads", defaults.handler_threads),
    };
    let server = Server::serve(
        &addr,
        handler,
        cfg,
        metrics.unwrap_or_else(|| Arc::new(ServiceMetrics::new())),
    )?;
    // Optional Prometheus-text endpoint; held for the process lifetime.
    let _metrics_http = match args.get("metrics-listen") {
        Some(listen) => {
            let maddr = Addr::parse(listen)?;
            let http = MetricsHttpServer::serve(&maddr, metrics_source)
                .map_err(|e| anyhow::anyhow!("bind metrics endpoint {maddr}: {e}"))?;
            log::info!("metrics on {} (GET /metrics)", http.addr());
            Some(http)
        }
        None => None,
    };
    println!("READY {}", server.local_addr());
    std::io::stdout().flush().ok();
    loop {
        std::thread::park();
    }
}
