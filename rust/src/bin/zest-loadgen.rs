//! `zest-loadgen` — open-loop load generator for the partition server.
//!
//! Fires requests at a fixed offered rate (absolute-deadline schedule
//! off a monotonic clock — never gated on responses), drawing a Zipf
//! query mix over thousands of simulated users with mixed estimator
//! kinds, budgets, precisions and deadlines, and sweeps a rate ladder
//! to bracket the saturation knee. Emits the `BENCH_load.json` schema
//! (`zest-load-v1`) on stdout or to `--out`.
//!
//! ```bash
//! # against a live server (CI perf-smoke shape):
//! zest-loadgen --server tcp://127.0.0.1:7070 \
//!     --rates 200,400,800 --duration-ms 2000 --users 5000 --sessions 64
//! # self-spawned cluster, healthy:
//! zest-loadgen --synth 8192,32 --shards 2 --replicas 2 \
//!     --rates 200,400,800,1600 --publish-period-ms 500
//! # self-spawned cluster, chaos under load (replica kill mid-point +
//! # epoch publishes; replica 0 of every shard rides a fault proxy):
//! zest-loadgen --synth 8192,32 --shards 2 --replicas 2 --chaos \
//!     --rates 200,400 --hedge-delay-ms 5 --scenario chaos
//! ```
//!
//! Two target modes:
//!
//! * `--server ADDR` — drive an external `zest-server`. Publishes and
//!   chaos are **disabled**: epoch publishes must go through the
//!   serving coordinator (a second coordinator publishing to the same
//!   workers trips the split-brain guards), and an external server's
//!   links aren't ours to cut.
//! * self-spawn (default) — build the full cluster in-process
//!   (`loadgen::ClusterHarness`): synth store → shard workers ×
//!   replicas (replica 0 proxied under `--chaos`) → batching service →
//!   real TCP front door. A writer thread publishes add/remove epochs
//!   every `--publish-period-ms`; under `--chaos`, replica 0 of every
//!   shard is killed for the middle third of each sweep point.

use std::io::Write as _;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;
use zest::loadgen::{
    default_classes, document, find_knee, run_open_loop, to_point, Arrival, ClusterHarness,
    HarnessConfig, LoadReport, MetricsDelta, RunConfig, WorkloadMix,
};
use zest::net::client::{ClientConfig, PartitionClient};
use zest::net::Addr;
use zest::obs::MetricsBlob;
use zest::testing::fault::FaultMode;
use zest::util::cli::{Args, HelpBuilder};

fn main() {
    zest::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.iter().any(|a| a == "--help" || a == "-h") {
        print!("{}", help());
        return;
    }
    if let Err(e) = run(argv) {
        eprintln!("zest-loadgen: {e}");
        std::process::exit(1);
    }
}

fn help() -> String {
    HelpBuilder::new("zest-loadgen", "open-loop load generator (BENCH_load.json emitter)")
        .flag("server", "", "external target address (disables publishes/chaos)")
        .flag("synth", "8192,32", "self-spawn store: N,D")
        .flag("shards", "2", "self-spawn shard workers")
        .flag("replicas", "2", "self-spawn replicas per shard")
        .flag("chaos", "false", "kill replica 0 of every shard mid-point (self-spawn)")
        .flag("publish-period-ms", "500", "writer-thread epoch publish cadence (0 off)")
        .flag("hedge-delay-ms", "0", "TopK hedge delay on the spawned cluster (0 off)")
        .flag("rates", "200,400,800", "offered-rate ladder, req/s")
        .flag("duration-ms", "2000", "window per rate point")
        .flag("users", "5000", "simulated Zipf user keys")
        .flag("zipf-s", "1.1", "Zipf exponent over users")
        .flag("sessions", "64", "sender threads (concurrency, not rate)")
        .flag("arrival", "poisson", "arrival process: fixed|poisson")
        .flag("seed", "1", "schedule + mix seed (replayable)")
        .flag("scenario", "healthy", "report label")
        .flag("out", "", "write BENCH_load.json here (default stdout)")
        .render()
}

fn scrape(client: &PartitionClient) -> MetricsBlob {
    client.get_metrics().unwrap_or_default()
}

fn delta(before: &MetricsBlob, after: &MetricsBlob) -> MetricsDelta {
    let d = |name: &str| after.counter(name).saturating_sub(before.counter(name));
    MetricsDelta {
        cache_hits: d("cache_hits"),
        cache_misses: d("cache_misses"),
        failovers: d("shard_failovers"),
        hedges: d("shard_hedges"),
    }
}

fn run(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Args::parse(argv).map_err(anyhow::Error::msg)?;
    args.check_known(&[
        "server",
        "synth",
        "shards",
        "replicas",
        "chaos",
        "publish-period-ms",
        "hedge-delay-ms",
        "rates",
        "duration-ms",
        "users",
        "zipf-s",
        "sessions",
        "arrival",
        "seed",
        "scenario",
        "out",
    ])
    .map_err(anyhow::Error::msg)?;

    let rates: Vec<f64> = args.get_list("rates", &[200.0, 400.0, 800.0]);
    anyhow::ensure!(!rates.is_empty(), "--rates must name at least one rate");
    let duration = Duration::from_millis(args.get_or("duration-ms", 2000u64));
    let users: usize = args.get_or("users", 5000);
    let zipf_s: f64 = args.get_or("zipf-s", 1.1);
    let sessions: usize = args.get_or("sessions", 64);
    let arrival = Arrival::parse(args.get("arrival").unwrap_or("poisson"))
        .map_err(anyhow::Error::msg)?;
    let seed: u64 = args.get_or("seed", 1);
    let chaos = args.get_bool("chaos");
    let publish_period = Duration::from_millis(args.get_or("publish-period-ms", 500u64));
    let scenario = args
        .get("scenario")
        .unwrap_or(if chaos { "chaos" } else { "healthy" })
        .to_string();

    // Target: external server, or a self-spawned cluster.
    let mut shards = 0usize;
    let mut replicas = 0usize;
    let harness = if args.has("server") {
        anyhow::ensure!(
            !chaos,
            "--chaos needs the self-spawned cluster (an external server's \
             replicas and links aren't ours to kill)"
        );
        None
    } else {
        let synth: Vec<usize> = args.get_list("synth", &[8192usize, 32]);
        anyhow::ensure!(synth.len() == 2, "--synth wants N,D");
        shards = args.get_or("shards", 2);
        replicas = args.get_or("replicas", 2);
        let hedge_ms: u64 = args.get_or("hedge-delay-ms", 0);
        let h = ClusterHarness::spawn(&HarnessConfig {
            n: synth[0],
            dim: synth[1],
            shards,
            replicas,
            proxied: chaos,
            seed,
            max_connections: (sessions + 16).max(512),
            hedge_delay: (hedge_ms > 0).then(|| Duration::from_millis(hedge_ms)),
            ..HarnessConfig::default()
        })?;
        Some(h)
    };
    let addr = match args.get("server") {
        Some(a) => Addr::parse(a)?,
        None => harness.as_ref().unwrap().addr.clone(),
    };

    let client = Arc::new(
        PartitionClient::connect(addr.clone(), ClientConfig::for_sessions(sessions))
            .map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?,
    );
    let (len, dim, epoch) = client
        .manifest()
        .map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
    log::info!("target {addr}: {len} categories × {dim} dims at epoch {epoch}");
    let mix = Arc::new(WorkloadMix::new(users, zipf_s, dim, default_classes(), seed));
    let base = RunConfig {
        rate_hz: rates[0],
        duration,
        sessions,
        arrival,
        seed,
    };

    // Writer thread: epoch publishes through the serving coordinator,
    // for the whole sweep. Self-spawn only.
    let stop = Arc::new(AtomicBool::new(true));
    let writer = harness.as_ref().filter(|_| !publish_period.is_zero()).map(|h| {
        stop.store(false, Ordering::Relaxed);
        let stop = Arc::clone(&stop);
        let svc = Arc::clone(&h.svc);
        let dim = h.dim();
        std::thread::spawn(move || {
            let mut wave = 0u64;
            let mut pending = 0usize;
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(publish_period);
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                // Alternate add/remove so the serving set stays
                // size-stable; publishes go through the coordinator's
                // own handles (frontdoor invalidation included).
                let outcome = if pending == 0 {
                    let fresh = zest::data::synth::generate(&zest::data::synth::SynthConfig {
                        n: 64,
                        d: dim,
                        seed: wave ^ 0x9B11_5EED,
                        ..zest::data::synth::SynthConfig::tiny()
                    });
                    pending = 64;
                    svc.add_categories(fresh).map(|e| ("add", e))
                } else {
                    let (len, _) = svc.serving_info();
                    let ids: Vec<usize> = (len - pending..len).collect();
                    pending = 0;
                    svc.remove_categories(&ids).map(|e| ("remove", e))
                };
                match outcome {
                    Ok((op, epoch)) => log::info!("writer: {op} wave {wave} → epoch {epoch}"),
                    Err(e) => log::warn!("writer: publish wave {wave} failed: {e}"),
                }
                wave += 1;
            }
        })
    });

    let mut points = Vec::new();
    for &rate in &rates {
        let cfg = RunConfig { rate_hz: rate, ..base.clone() };
        let before = scrape(&client);
        // Chaos choreography: replica 0 of every shard dies for the
        // middle third of the point, then heals. Scoped so the kill
        // thread borrows the harness proxies and joins with the point.
        let stats = std::thread::scope(|scope| {
            if let Some(h) = harness.as_ref().filter(|_| chaos) {
                let third = duration / 3;
                scope.spawn(move || {
                    std::thread::sleep(third);
                    for p in &h.proxies {
                        p.set_mode(FaultMode::Refuse);
                        p.cut_all();
                    }
                    std::thread::sleep(third);
                    for p in &h.proxies {
                        p.restore();
                    }
                });
            }
            run_open_loop(&client, &mix, &cfg)
        });
        let after = scrape(&client);
        let point = to_point(&stats, &delta(&before, &after));
        log::info!(
            "rate {rate:.0}/s: achieved {:.0}/s p99 {:.2}ms shed {} failed {}",
            point.achieved_hz,
            point.p99_ms,
            point.shed,
            point.failed
        );
        points.push(point);
    }

    stop.store(true, Ordering::Relaxed);
    if let Some(w) = writer {
        let _ = w.join();
    }

    let knee = find_knee(&points);
    let report = LoadReport {
        scenario,
        users,
        zipf_s,
        sessions,
        duration_ms: duration.as_millis() as u64,
        arrival: arrival.to_string(),
        seed,
        shards,
        replicas,
        points,
        knee_hz: knee,
    };
    match knee {
        Some(hz) => log::info!("saturation knee at {hz:.0}/s offered"),
        None => log::info!("no knee within the sweep (system kept up)"),
    }
    let text = document(std::slice::from_ref(&report)).to_string();
    match args.get("out") {
        Some(path) if !path.is_empty() => {
            std::fs::write(path, text.as_bytes())?;
            log::info!("wrote {path}");
        }
        _ => {
            let mut out = std::io::stdout().lock();
            out.write_all(text.as_bytes())?;
            out.write_all(b"\n")?;
        }
    }

    drop(client);
    if let Some(h) = harness {
        h.shutdown();
    }
    Ok(())
}
