//! Artifact loading + execution: parse `meta.json`, compile every
//! `*.hlo.txt` on the PJRT CPU client, validate argument shapes, and
//! marshal [`HostTensor`]s ⇄ `xla::Literal`s.

use super::HostTensor;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Declared argument of one graph (from meta.json).
#[derive(Clone, Debug, PartialEq)]
pub struct ArgSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// Parsed `artifacts/meta.json`.
#[derive(Clone, Debug)]
pub struct ArtifactsMeta {
    /// graph name → (hlo file name, arg specs)
    pub graphs: BTreeMap<String, (String, Vec<ArgSpec>)>,
    /// The exporter's config (chunk, d, vocab, …).
    pub config: BTreeMap<String, f64>,
    pub dir: PathBuf,
}

impl ArtifactsMeta {
    pub fn load(dir: &Path) -> Result<ArtifactsMeta> {
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("read {meta_path:?} — run `make artifacts` first"))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("parse meta.json: {e}"))?;
        let mut graphs = BTreeMap::new();
        let graphs_obj = v
            .get("graphs")
            .and_then(|g| g.as_obj())
            .ok_or_else(|| anyhow!("meta.json missing graphs object"))?;
        for (name, info) in graphs_obj {
            let file = info
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| anyhow!("graph {name} missing file"))?
                .to_string();
            let mut specs = Vec::new();
            for arg in info
                .get("args")
                .and_then(|a| a.as_arr())
                .ok_or_else(|| anyhow!("graph {name} missing args"))?
            {
                let shape = arg
                    .get("shape")
                    .and_then(|s| s.as_arr())
                    .ok_or_else(|| anyhow!("arg missing shape"))?
                    .iter()
                    .map(|x| x.as_usize().unwrap_or(0))
                    .collect();
                let dtype = arg
                    .get("dtype")
                    .and_then(|d| d.as_str())
                    .unwrap_or("float32")
                    .to_string();
                specs.push(ArgSpec { shape, dtype });
            }
            graphs.insert(name.clone(), (file, specs));
        }
        let mut config = BTreeMap::new();
        if let Some(cfg) = v.get("config").and_then(|c| c.as_obj()) {
            for (k, val) in cfg {
                if let Some(x) = val.as_f64() {
                    config.insert(k.clone(), x);
                }
            }
        }
        Ok(ArtifactsMeta {
            graphs,
            config,
            dir: dir.to_path_buf(),
        })
    }

    pub fn config_usize(&self, key: &str) -> Option<usize> {
        self.config.get(key).map(|x| *x as usize)
    }
}

/// One compiled graph.
#[cfg(feature = "pjrt")]
struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    args: Vec<ArgSpec>,
}

/// The single-threaded PJRT runtime (see module docs for threading).
#[cfg(feature = "pjrt")]
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    compiled: BTreeMap<String, Compiled>,
    meta: ArtifactsMeta,
}

/// Stub runtime for builds without the `pjrt` feature: artifact metadata
/// still parses (so shape/config probing and error messages behave the
/// same), but loading always fails with a clear pointer at the feature
/// flag. The serving and bench paths fall back to the native SIMD
/// kernels, which is the default offline configuration.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    meta: ArtifactsMeta,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    pub fn load(dir: &Path) -> Result<Runtime> {
        let _ = ArtifactsMeta::load(dir)?;
        bail!(
            "zest was built without the `pjrt` feature; rebuild with \
             `--features pjrt` (and the `xla` dependency) to execute AOT artifacts"
        )
    }

    pub fn load_subset(dir: &Path, _names: &[&str]) -> Result<Runtime> {
        Self::load(dir)
    }

    pub fn meta(&self) -> &ArtifactsMeta {
        &self.meta
    }

    pub fn graph_names(&self) -> Vec<&str> {
        self.meta.graphs.keys().map(|s| s.as_str()).collect()
    }

    pub fn run(&self, _name: &str, _inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        bail!("pjrt feature disabled: no executable graphs are loaded")
    }
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create a CPU client and compile every artifact listed in meta.json.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let meta = ArtifactsMeta::load(dir)?;
        Self::load_with_meta(meta)
    }

    /// Compile only a subset of graphs (faster startup for tools that need
    /// one executable).
    pub fn load_subset(dir: &Path, names: &[&str]) -> Result<Runtime> {
        let mut meta = ArtifactsMeta::load(dir)?;
        meta.graphs.retain(|k, _| names.contains(&k.as_str()));
        if meta.graphs.len() != names.len() {
            bail!(
                "missing graphs: wanted {names:?}, found {:?}",
                meta.graphs.keys().collect::<Vec<_>>()
            );
        }
        Self::load_with_meta(meta)
    }

    fn load_with_meta(meta: ArtifactsMeta) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let mut compiled = BTreeMap::new();
        for (name, (file, args)) in &meta.graphs {
            let path = meta.dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            compiled.insert(
                name.clone(),
                Compiled {
                    exe,
                    args: args.clone(),
                },
            );
            log::debug!("compiled artifact {name} from {path:?}");
        }
        Ok(Runtime {
            client,
            compiled,
            meta,
        })
    }

    pub fn meta(&self) -> &ArtifactsMeta {
        &self.meta
    }

    pub fn graph_names(&self) -> Vec<&str> {
        self.compiled.keys().map(|s| s.as_str()).collect()
    }

    fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
        let dims: Vec<i64> = t.shape().iter().map(|&x| x as i64).collect();
        let lit = match t {
            HostTensor::F32(data, shape) => {
                if shape.is_empty() {
                    xla::Literal::scalar(data[0])
                } else {
                    xla::Literal::vec1(data)
                        .reshape(&dims)
                        .map_err(|e| anyhow!("reshape f32 {shape:?}: {e:?}"))?
                }
            }
            HostTensor::I32(data, shape) => {
                if shape.is_empty() {
                    xla::Literal::scalar(data[0])
                } else {
                    xla::Literal::vec1(data)
                        .reshape(&dims)
                        .map_err(|e| anyhow!("reshape i32 {shape:?}: {e:?}"))?
                }
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow!("output shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&x| x as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32(
                lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
                dims,
            )),
            xla::ElementType::S32 => Ok(HostTensor::I32(
                lit.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?,
                dims,
            )),
            other => bail!("unsupported output dtype {other:?}"),
        }
    }

    /// Validate inputs against meta and execute one graph.
    pub fn run(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let c = self
            .compiled
            .get(name)
            .ok_or_else(|| anyhow!("unknown graph {name:?} (have {:?})", self.graph_names()))?;
        if inputs.len() != c.args.len() {
            bail!(
                "graph {name}: expected {} inputs, got {}",
                c.args.len(),
                inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(&c.args).enumerate() {
            if t.shape() != spec.shape.as_slice() {
                bail!(
                    "graph {name} arg {i}: shape {:?} != declared {:?}",
                    t.shape(),
                    spec.shape
                );
            }
            if t.dtype_name() != spec.dtype {
                bail!(
                    "graph {name} arg {i}: dtype {} != declared {}",
                    t.dtype_name(),
                    spec.dtype
                );
            }
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(Self::to_literal)
            .collect::<Result<_>>()?;
        let result = c
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch output of {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: the output is always a tuple.
        let parts = out_lit
            .to_tuple()
            .map_err(|e| anyhow!("untuple output of {name}: {e:?}"))?;
        parts.iter().map(Self::from_literal).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("meta.json").exists().then_some(dir)
    }

    #[test]
    fn meta_parses_when_artifacts_exist() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        let meta = ArtifactsMeta::load(&dir).unwrap();
        assert!(meta.graphs.contains_key("partition_chunk"));
        let (_, args) = &meta.graphs["partition_chunk"];
        assert_eq!(args.len(), 2);
        assert_eq!(args[0].shape.len(), 2);
        assert!(meta.config_usize("chunk").unwrap() > 0);
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let err = ArtifactsMeta::load(Path::new("/nonexistent_zest")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
