//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `meta.json` produced by `python/compile/aot.py`) and executes them on
//! the XLA CPU client from the Rust hot path.
//!
//! Threading model: the `xla` crate's `PjRtClient` is `Rc`-based (neither
//! `Send` nor `Sync`), so [`Runtime`] is confined to one thread — exactly
//! one executor loop per accelerator, the same shape a real serving stack
//! uses. Cross-thread access goes through [`pool::RuntimeHandle`], which
//! ships [`HostTensor`]s over channels to the runtime thread.

pub mod executor;
pub mod pool;

pub use executor::{ArgSpec, ArtifactsMeta, Runtime};
pub use pool::{spawn_runtime_thread, RuntimeHandle};

/// A host-side tensor that can cross threads (unlike `xla::Literal`).
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::F32(vec![v], vec![])
    }

    pub fn f32(data: Vec<f32>, shape: &[usize]) -> HostTensor {
        assert_eq!(data.len(), shape.iter().product::<usize>().max(1));
        HostTensor::F32(data, shape.to_vec())
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> HostTensor {
        assert_eq!(data.len(), shape.iter().product::<usize>().max(1));
        HostTensor::I32(data, shape.to_vec())
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    pub fn dtype_name(&self) -> &'static str {
        match self {
            HostTensor::F32(..) => "float32",
            HostTensor::I32(..) => "int32",
        }
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            HostTensor::F32(d, _) => Some(d),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            HostTensor::I32(d, _) => Some(d),
            _ => None,
        }
    }

    /// First element as f64 (for scalar outputs like losses/partials).
    pub fn first_f64(&self) -> Option<f64> {
        match self {
            HostTensor::F32(d, _) => d.first().map(|x| *x as f64),
            HostTensor::I32(d, _) => d.first().map(|x| *x as f64),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_accessors() {
        let t = HostTensor::f32(vec![1.0, 2.0], &[2]);
        assert_eq!(t.shape(), &[2]);
        assert_eq!(t.as_f32(), Some(&[1.0f32, 2.0][..]));
        assert!(t.as_i32().is_none());
        assert_eq!(t.first_f64(), Some(1.0));
        assert_eq!(t.dtype_name(), "float32");
        let s = HostTensor::scalar_f32(7.0);
        assert_eq!(s.shape(), &[] as &[usize]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![1.0; 3], &[2, 2]);
    }
}
