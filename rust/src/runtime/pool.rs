//! Cross-thread access to the single-threaded PJRT runtime: a dedicated
//! runtime thread owns [`Runtime`]; [`RuntimeHandle`]s (cheaply cloneable,
//! `Send`) submit named-graph executions over a channel and block on a
//! per-request reply channel. This is the executor-loop shape of a real
//! single-accelerator server: many request threads, one device queue.

use super::{executor::Runtime, HostTensor};
use anyhow::{anyhow, Result};
use std::path::PathBuf;
use std::sync::mpsc;

enum Msg {
    Run {
        graph: String,
        inputs: Vec<HostTensor>,
        reply: mpsc::Sender<Result<Vec<HostTensor>>>,
    },
    Shutdown,
}

/// Cloneable, `Send` handle to the runtime thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: mpsc::Sender<Msg>,
}

impl RuntimeHandle {
    /// Execute `graph` with `inputs`, blocking until the device replies.
    pub fn run(&self, graph: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Msg::Run {
                graph: graph.to_string(),
                inputs,
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("runtime thread is gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("runtime thread dropped the reply"))?
    }

    /// Ask the runtime thread to exit once queued work drains.
    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }
}

/// Spawn the runtime thread. Artifacts load + compile happen on that
/// thread; the join handle and a ready-signal error (if loading failed)
/// are surfaced to the caller.
pub fn spawn_runtime_thread(
    artifacts_dir: PathBuf,
    subset: Option<Vec<String>>,
) -> Result<(RuntimeHandle, std::thread::JoinHandle<()>)> {
    let (tx, rx) = mpsc::channel::<Msg>();
    let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
    let join = std::thread::Builder::new()
        .name("zest-pjrt".to_string())
        .spawn(move || {
            let rt = match &subset {
                Some(names) => {
                    let name_refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
                    Runtime::load_subset(&artifacts_dir, &name_refs)
                }
                None => Runtime::load(&artifacts_dir),
            };
            let rt = match rt {
                Ok(rt) => {
                    let _ = ready_tx.send(Ok(()));
                    rt
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Run {
                        graph,
                        inputs,
                        reply,
                    } => {
                        let res = rt.run(&graph, &inputs);
                        let _ = reply.send(res);
                    }
                    Msg::Shutdown => break,
                }
            }
        })
        .expect("spawn runtime thread");
    ready_rx
        .recv()
        .map_err(|_| anyhow!("runtime thread died during load"))??;
    Ok((RuntimeHandle { tx }, join))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("meta.json").exists().then_some(dir)
    }

    #[test]
    fn load_failure_is_reported() {
        let err = spawn_runtime_thread(PathBuf::from("/nonexistent_zest"), None);
        assert!(err.is_err());
    }

    #[test]
    fn handle_runs_partition_chunk_from_other_threads() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        let (h, join) =
            spawn_runtime_thread(dir, Some(vec!["partition_chunk".to_string()])).unwrap();
        let meta_chunk = 8192usize; // default export config
        let d = 300usize;
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    let v = vec![0f32; meta_chunk * d];
                    let q = vec![0f32; d];
                    let out = h
                        .run(
                            "partition_chunk",
                            vec![
                                HostTensor::f32(v, &[meta_chunk, d]),
                                HostTensor::f32(q, &[d]),
                            ],
                        )
                        .unwrap();
                    // exp(0)·chunk = chunk
                    let z = out[0].first_f64().unwrap();
                    assert!((z - meta_chunk as f64).abs() < 1e-3, "thread {t}: {z}");
                })
            })
            .collect();
        for th in handles {
            th.join().unwrap();
        }
        h.shutdown();
        join.join().unwrap();
    }
}
