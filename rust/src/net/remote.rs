//! Cross-process shards: compose S [`super::shard::ShardWorker`]
//! processes into one logical category set, so N can exceed one
//! process' memory.
//!
//! [`RemoteShardIndex`] is a [`MipsIndex`] over one worker's rows —
//! `top_k_batch` goes over the wire, local hits come back, and the
//! existing in-process [`ShardedIndex`] scatter/merge (same `hit_cmp`
//! ordering) composes the workers exactly like local sub-indexes.
//! [`RemoteCluster`] owns the worker handles, the derived scatter index,
//! and the cluster-wide operations: chained exp-sums for `Exact`, remote
//! tail scoring for the samplers, and two-phase epoch publishes.
//!
//! ## Bit-exactness contract (`Exact`)
//!
//! The chained exp-sum reproduces the in-process f64 accumulation
//! exactly: worker s receives the running accumulator(s) after workers
//! `0..s` and extends them over its own rows in strict row order. The
//! per-row f32 scores also match the in-process kernels **when every
//! worker's row count is a multiple of 4 (the last worker excepted)**:
//! the blocked gemv/gemm kernels score rows in 4-row quads, so 4-aligned
//! worker boundaries keep every row in the same quad-vs-remainder class
//! as the single-process global tiling. [`aligned_split_lens`] produces
//! such layouts; `RemoteCluster` logs a warning when connected workers
//! break the alignment (answers are then still correct to the last ulp
//! of a handful of f32 scores, just not bit-pinned).
//! `rust/tests/net_e2e.rs` pins bit-identity over UDS for S ∈ {1,2,4}.
//!
//! ## Estimators over remote shards
//!
//! Every estimator family is served:
//!
//! * `Exact` — chained exp-sum (sequential by design, see above).
//! * `Nmimps` — scatter top-k, exp-sum the hits.
//! * `Mimps` / `Uniform` — scatter top-k + the same global tail draw
//!   as in-process, scored remotely via `ScoreIds`.
//! * `Mince` — head from the scatter top-k, noise from the same global
//!   tail draw as the in-process estimator, scored remotely via
//!   `ScoreIds`, then the identical Halley solve cluster-side — the
//!   paper's NCE estimator without shipping a single row.
//! * `Fmbe` — each worker fits the seed-deterministic feature maps over
//!   its local rows (`FitFmbe`), the cluster sums the per-shard λ̃
//!   vectors (λ̃ is additive over row partitions) and rebuilds the
//!   estimator via [`crate::estimators::fmbe::Fmbe::from_lambdas`].
//!   The fit is epoch-tagged in an
//!   [`EpochCache`](crate::coordinator::EpochCache) exactly like the
//!   in-process `Router` refit: a publish invalidates it and the next
//!   FMBE request refits from the new epoch. Cluster answers match the
//!   monolithic fit up to the f64 summation order of per-shard partials
//!   (bit-identical at S = 1).
//!
//! ## Multiplexed worker pipelines
//!
//! Each [`RemoteShard`] owns **one persistent multiplexed connection**
//! driven by a two-thread pipeline: a *writer* drains an mpsc
//! submission queue onto the socket in submission order, and a *reader*
//! routes response frames back to per-request completion slots keyed by
//! the `request_id` every frame carries (wire v3+; v5 adds a header
//! flag that asks the worker to annex server-side
//! [`wire::WireTimes`] onto its response — see `record_shard_spans`
//! for how those become per-shard trace spans). Submitting is
//! non-blocking
//! and many requests ride the connection concurrently, so cluster-side
//! operations submit to every worker and then join — the wall-clock
//! cost of a cluster-wide operation is the **slowest worker, not the
//! sum**, and concurrent batches (scatters, two-phase publishes,
//! `ScoreIds` tail scoring from overlapping requests) genuinely overlap
//! on one socket per worker instead of queueing behind each other.
//! Fanned out this way: the two-phase `prepare_*`/`commit`/`abort`
//! publish phases, `ScoreIds` tail scoring, `FitFmbe` fits, and
//! manifest refreshes. The top-k scatter fans out through the
//! [`ShardedIndex`] scoped pool (given one scatter thread per worker —
//! the calls are I/O-bound, so the budget is worker count, not core
//! count). `Exact` is two-mode
//! ([`Precision`](crate::coordinator::Precision)): the **bit-exact
//! chain** stays deliberately sequential — its ordering *is* the
//! contract — while `Precision::Pipelined` fans an `ExpSumPart` out to
//! every worker concurrently and reduces the per-worker partials in
//! worker order (max-over-workers latency, last-ulp-different answers;
//! see [`RemoteCluster::exp_sum_parts`]). Per-worker **submission
//! order** is preserved on the wire (the publish protocol relies on
//! prepare-before-commit per worker), while responses may complete out
//! of order. Fan-out failures are wrapped in [`ClientError::Shard`] at
//! the cluster join sites, so metrics and operators can name the
//! failing worker without parsing messages.
//!
//! ## Two-phase epoch publish
//!
//! A cluster mutation prepares on **every** worker concurrently
//! (workers without local changes stage a pure epoch bump), and only if
//! all S stage successfully commits everywhere; any prepare failure
//! aborts the staged workers and leaves every epoch untouched. Worker
//! epochs stay in lockstep, and [`RemoteCluster::refresh`] re-validates
//! manifests after each publish. `ARCHITECTURE.md` documents the full
//! protocol, including the failure / [`RemoteCluster::resolve_token`]
//! recovery states.
//!
//! ## Replica sets + failover
//!
//! Each logical shard is a [`ReplicaSet`] of R interchangeable workers
//! serving identical rows. Because every answer is deterministic per
//! (seed, epoch), replicas at the same epoch return the **same bytes**,
//! so reads load-balance round-robin across healthy replicas and a
//! failed sub-request — connect error, timeout, id-0 error frame,
//! mid-stream EOF (any [`ClientError::is_transient`] failure) — retries
//! transparently on an alternate replica instead of surfacing an error.
//! Only idempotent reads ride the failover path; the publish phases
//! address each replica directly (a `Commit` is never blindly re-sent).
//!
//! A publish commits to **all replicas of every shard** in lockstep. A
//! replica that misses one or more publishes (dead socket, restart) is
//! marked unhealthy and catches up through the coordinator-held
//! **publish log**: [`RemoteCluster::refresh`] replays the missed
//! `(prepare, commit)` pairs — any number of epochs deep, bounded by
//! the log capacity — and re-marks the replica healthy once it answers
//! at the lockstep epoch. Split-brain states are refused, never
//! "healed": a replica *ahead* of every epoch this coordinator ever
//! published, or replicas disagreeing on the row count at one epoch,
//! fail `refresh()` with a typed error.

use super::client::{remote_err, ClientConfig, ClientError, Result};
use super::server::Handler;
use super::wire::{self, Encoded, ErrorCode, Request as WireRequest, Response as WireResponse};
use super::{Addr, Stream};
use crate::coordinator::{EpochCache, Precision, ServiceMetrics};
use crate::data::embeddings::EmbeddingStore;
use crate::estimators::fmbe::{Fmbe, FmbeConfig};
use crate::estimators::mince::{self, Solver};
use crate::estimators::{tail, EstimatorKind};
use crate::mips::sharded::ShardedIndex;
use crate::mips::{Hit, MipsIndex};
use crate::obs::{MetricsBlob, Trace};
use crate::util::rng::Rng;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------
// Per-worker multiplexed request pipeline.

/// Why a call failed before producing a response. `retryable` is `true`
/// only when the request frame **provably never reached the socket**
/// (the connection was already dead at submit, or died while the job
/// sat unsent in the submission queue), so one re-submission on a fresh
/// connection cannot double-execute anything — not even a `Commit`. A
/// request that was (even partially) written is ambiguous and is never
/// silently re-sent; higher layers resolve it (see
/// `RemoteCluster::publish`).
struct CallFailure {
    error: ClientError,
    retryable: bool,
}

/// A routed response plus the server-side timing annex it carried (only
/// on responses to [`wire::FLAG_TRACED`] requests).
type CallResult = std::result::Result<(WireResponse, Option<wire::WireTimes>), CallFailure>;

/// One in-flight request's completion slot in the [`MuxTable`].
struct PendingEntry {
    tx: mpsc::Sender<CallResult>,
    /// Flipped by the writer thread right before the frame hits the
    /// socket; decides [`CallFailure::retryable`] when the connection
    /// dies with the call outstanding.
    sent: bool,
}

/// The completion table of one multiplexed connection. `dead` and the
/// entries flip together under one lock, so a submission can never slip
/// an entry in after the reader drained the table (which would leave
/// its [`Pending`] waiting forever).
struct MuxTable {
    dead: bool,
    pending: HashMap<u64, PendingEntry>,
}

struct MuxShared {
    table: Mutex<MuxTable>,
}

impl MuxShared {
    /// Mark the connection dead and fail every outstanding call.
    /// `describe` renders one error per call; calls whose frames never
    /// reached the socket come back `retryable`.
    fn fail_all(&self, describe: impl Fn() -> ClientError) {
        let mut table = self.table.lock().unwrap();
        table.dead = true;
        for (_, entry) in table.pending.drain() {
            let _ = entry.tx.send(Err(CallFailure {
                error: describe(),
                retryable: !entry.sent,
            }));
        }
    }
}

/// Writer half of a [`MuxConn`]: drains the submission queue onto the
/// socket **in submission order** (per-worker ordering is what the
/// publish protocol's prepare-before-commit relies on). Exits when the
/// queue closes (connection dropped) or a write fails.
fn mux_writer(
    mut stream: Stream,
    rx: mpsc::Receiver<(u64, u8, Arc<Encoded>)>,
    shared: Arc<MuxShared>,
) {
    while let Ok((id, flags, req)) = rx.recv() {
        {
            let mut table = shared.table.lock().unwrap();
            if table.dead {
                // The reader already failed every pending (this one came
                // back retryable — its frame was never written). Nothing
                // left to write to.
                continue;
            }
            match table.pending.get_mut(&id) {
                Some(entry) => entry.sent = true,
                // Already answered or failed; nothing waits on the frame.
                None => continue,
            }
        }
        if let Err(e) = wire::write_frame_flagged(&mut stream, id, flags, req.payload()) {
            // Broken socket: this call is ambiguous (bytes may be on the
            // wire), the queued rest was never written. Fail this one
            // here, then wake the reader so it drains the rest.
            let mut table = shared.table.lock().unwrap();
            table.dead = true;
            if let Some(entry) = table.pending.remove(&id) {
                let _ = entry.tx.send(Err(CallFailure {
                    error: ClientError::Wire(e),
                    retryable: false,
                }));
            }
            drop(table);
            let _ = stream.shutdown_read();
            return;
        }
    }
}

/// Reader half of a [`MuxConn`]: routes every response frame to the
/// completion slot its `request_id` names. Responses may arrive in any
/// order — that is the point of the multiplexed pipeline. Exits on EOF,
/// a transport/codec failure, or a connection-level (id 0) error frame,
/// failing all outstanding calls.
fn mux_reader(mut stream: Stream, shared: Arc<MuxShared>) {
    loop {
        match wire::read_response_timed(&mut stream) {
            Ok(Some((0, WireResponse::Error { code, message }, _))) => {
                // Connection-level error frame (e.g. `ConnLimit`): the
                // server wrote it before reading any request and is
                // closing, so it answers every outstanding call.
                shared.fail_all(|| remote_err(code, message.clone()));
                return;
            }
            Ok(Some((id, resp, times))) => {
                let entry = shared.table.lock().unwrap().pending.remove(&id);
                match entry {
                    Some(entry) => {
                        let _ = entry.tx.send(Ok((resp, times)));
                    }
                    // A response no call waits for (request-id mismatch
                    // from a confused server): log and keep serving the
                    // calls that do match instead of dying.
                    None => log::warn!(
                        "mux reader: response tagged {id} matches no in-flight request (ignored)"
                    ),
                }
            }
            Ok(None) => {
                shared.fail_all(|| ClientError::ConnectionClosed);
                return;
            }
            Err(wire::WireError::Io(ref e))
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) && shared.table.lock().unwrap().pending.is_empty() =>
            {
                // Idle read timeout with nothing in flight: keep the
                // connection warm. (A timeout *with* calls outstanding
                // falls through below — the per-call read timeout bounds
                // how long a quiet socket may sit on unanswered calls.)
                continue;
            }
            Err(e) => {
                // Typed as `ConnectionLost`, not `Protocol`: the
                // transport died mid-stream, which is exactly the class
                // of failure the replica failover treats as transient
                // (`ClientError::is_transient`).
                let reason = format!("connection to worker lost: {e}");
                shared.fail_all(|| ClientError::ConnectionLost(reason.clone()));
                return;
            }
        }
    }
}

/// One multiplexed connection to a worker: the writer/reader thread
/// pair around a single socket plus the shared completion table.
struct MuxConn {
    tx: Option<mpsc::Sender<(u64, u8, Arc<Encoded>)>>,
    /// Kept for `Drop`: shutting the read half down unblocks the reader.
    stream: Stream,
    shared: Arc<MuxShared>,
    writer: Option<std::thread::JoinHandle<()>>,
    reader: Option<std::thread::JoinHandle<()>>,
}

impl MuxConn {
    fn open(addr: &Addr, cfg: &ClientConfig, name: &str) -> Result<MuxConn> {
        let stream = Stream::connect(addr).map_err(wire::WireError::Io)?;
        let _ = stream.set_read_timeout(cfg.read_timeout);
        let writer_stream = stream.try_clone().map_err(wire::WireError::Io)?;
        let reader_stream = stream.try_clone().map_err(wire::WireError::Io)?;
        let shared = Arc::new(MuxShared {
            table: Mutex::new(MuxTable {
                dead: false,
                pending: HashMap::new(),
            }),
        });
        let (tx, rx) = mpsc::channel();
        let writer = std::thread::Builder::new()
            .name(format!("{name}-wr"))
            .spawn({
                let shared = Arc::clone(&shared);
                move || mux_writer(writer_stream, rx, shared)
            })
            .expect("spawn shard mux writer");
        let reader = std::thread::Builder::new()
            .name(format!("{name}-rd"))
            .spawn({
                let shared = Arc::clone(&shared);
                move || mux_reader(reader_stream, shared)
            })
            .expect("spawn shard mux reader");
        Ok(MuxConn {
            tx: Some(tx),
            stream,
            shared,
            writer: Some(writer),
            reader: Some(reader),
        })
    }

    fn dead(&self) -> bool {
        self.shared.table.lock().unwrap().dead
    }
}

impl Drop for MuxConn {
    fn drop(&mut self) {
        // Close the submission queue (writer drains what's queued and
        // exits), then shut the read half down so a reader blocked in
        // `read` wakes with a clean EOF and fails any leftovers.
        drop(self.tx.take());
        if let Some(writer) = self.writer.take() {
            let _ = writer.join();
        }
        let _ = self.stream.shutdown_read();
        if let Some(reader) = self.reader.take() {
            let _ = reader.join();
        }
    }
}

/// A worker's multiplexed submission pipeline: the lazily (re)opened
/// [`MuxConn`] plus the request-id source. Cheaply cloneable (shared
/// inner) so a joinable [`Pending`] can re-submit a provably-unsent
/// call on a fresh connection.
#[derive(Clone)]
struct MuxSlot {
    inner: Arc<MuxSlotInner>,
}

struct MuxSlotInner {
    addr: Addr,
    cfg: ClientConfig,
    name: String,
    /// Wire v3 request ids (start at 1; 0 is reserved for
    /// connection-level server frames).
    next_id: AtomicU64,
    conn: Mutex<Option<MuxConn>>,
}

impl MuxSlot {
    fn new(addr: Addr, cfg: ClientConfig) -> MuxSlot {
        let name = format!("zest-mux-{addr}");
        MuxSlot {
            inner: Arc::new(MuxSlotInner {
                addr,
                cfg,
                name,
                next_id: AtomicU64::new(1),
                conn: Mutex::new(None),
            }),
        }
    }

    /// Register a completion slot and enqueue the request frame —
    /// non-blocking (socket I/O happens on the pipeline threads), so a
    /// caller can put many requests in flight before joining any. A
    /// dead or never-opened connection is (re)opened here: the lazy
    /// reconnect that heals a worker restart on the next submission.
    fn submit(&self, req: Arc<Encoded>) -> Pending {
        self.submit_flagged(req, 0)
    }

    /// [`MuxSlot::submit`] with explicit header flags —
    /// [`wire::FLAG_TRACED`] asks the server to append its timing annex
    /// to the response.
    fn submit_flagged(&self, req: Arc<Encoded>, flags: u8) -> Pending {
        let (tx, rx) = mpsc::channel();
        let pending = Pending {
            slot: self.clone(),
            req: Arc::clone(&req),
            flags,
            rx,
            retried: false,
        };
        let mut conn = self.inner.conn.lock().unwrap();
        let reopen = match conn.as_ref() {
            Some(c) => c.dead(),
            None => true,
        };
        if reopen {
            match MuxConn::open(&self.inner.addr, &self.inner.cfg, &self.inner.name) {
                Ok(c) => *conn = Some(c),
                Err(error) => {
                    // Connect failures are hard errors: there is no
                    // fresher connection a retry could land on.
                    let _ = tx.send(Err(CallFailure {
                        error,
                        retryable: false,
                    }));
                    return pending;
                }
            }
        }
        let c = conn.as_ref().expect("connection just opened");
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        {
            let mut table = c.shared.table.lock().unwrap();
            if table.dead {
                // Died between the liveness check and now; the frame was
                // never written, so the caller may retry.
                let _ = tx.send(Err(CallFailure {
                    error: ClientError::ConnectionClosed,
                    retryable: true,
                }));
                return pending;
            }
            table.pending.insert(
                id,
                PendingEntry {
                    tx: tx.clone(),
                    sent: false,
                },
            );
        }
        let queue = c.tx.as_ref().expect("live connection keeps its queue");
        if queue.send((id, flags, req)).is_err() {
            // The writer exited before accepting the job: never written.
            let entry = c.shared.table.lock().unwrap().pending.remove(&id);
            if entry.is_some() {
                let _ = tx.send(Err(CallFailure {
                    error: ClientError::ConnectionClosed,
                    retryable: true,
                }));
            }
            // else: `fail_all` already answered it (retryable — unsent).
        }
        pending
    }
}

/// A not-yet-joined multiplexed call: joins when the reader routes the
/// response carrying this call's request id back (or the connection
/// dies). A call that provably never reached the socket is re-submitted
/// once on a fresh connection — the mux analogue of the pooled client's
/// stale-connection retry, minus any possibility of double-sending.
struct Pending {
    slot: MuxSlot,
    req: Arc<Encoded>,
    flags: u8,
    rx: mpsc::Receiver<CallResult>,
    /// Whether the one allowed provably-unsent re-submission happened.
    retried: bool,
}

impl Pending {
    /// Block until the worker answered this call (or it failed).
    fn join(self) -> Result<WireResponse> {
        self.join_timed().map(|(resp, _)| resp)
    }

    /// [`Pending::join`], keeping the server-side timing annex (present
    /// only when the call was submitted with [`wire::FLAG_TRACED`] and
    /// the server honored it).
    fn join_timed(mut self) -> Result<(WireResponse, Option<wire::WireTimes>)> {
        loop {
            match self.rx.recv() {
                // `settle` re-submits a provably-unsent frame at most
                // once (the `retried` cap), so this loop runs at most
                // twice.
                Ok(result) => {
                    if let Some(settled) = self.settle(result) {
                        return settled;
                    }
                }
                Err(_) => return Err(dropped_call()),
            }
        }
    }

    /// Wait up to `timeout` for this call's completion without
    /// consuming the handle: `Some(result)` once settled, `None` while
    /// still in flight (including across the one transparent
    /// re-submission of a provably-unsent frame). The hedged replica
    /// read alternates this over two in-flight copies.
    fn poll_timed(
        &mut self,
        timeout: Duration,
    ) -> Option<Result<(WireResponse, Option<wire::WireTimes>)>> {
        match self.rx.recv_timeout(timeout) {
            Ok(result) => self.settle(result),
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => Some(Err(dropped_call())),
        }
    }

    /// Fold one completion message: an answer settles the call, a
    /// provably-unsent failure is re-submitted once on a fresh
    /// connection (swapping in the fresh receiver, `None` — still in
    /// flight), anything else is the call's error.
    fn settle(
        &mut self,
        result: CallResult,
    ) -> Option<Result<(WireResponse, Option<wire::WireTimes>)>> {
        match result {
            Ok(out) => Some(Ok(out)),
            Err(f) if f.retryable && !self.retried => {
                self.retried = true;
                let fresh = self.slot.submit_flagged(Arc::clone(&self.req), self.flags);
                self.rx = fresh.rx;
                None
            }
            Err(f) => Some(Err(f.error)),
        }
    }
}

/// Defensive: every completion path sends before dropping the sender,
/// so a bare disconnect is a pipeline bug — surfaced as an error
/// instead of a panic.
fn dropped_call() -> ClientError {
    ClientError::Protocol("multiplexed connection dropped a call without answering".to_string())
}

/// Wrap a fan-out failure with the worker index it came from. Applied
/// only at the cluster join sites — the blocking [`RemoteShard`]
/// helpers stay unwrapped so callers (publish healing, token
/// resolution) can match on [`ClientError::Remote`] codes directly.
fn attribute(e: ClientError, shard: usize) -> ClientError {
    ClientError::Shard {
        shard,
        source: Box::new(e),
    }
}

/// Record one scatter RPC on a sampled request's trace, on the shard's
/// track (`1 + shard`): an `rpc` span covering the client-side wall
/// (submit → joined response) and, when the worker's timing annex came
/// back, a nested `worker` span for the server-side handler execution
/// offset by the worker's own queueing lag.
fn record_shard_spans(
    trace: &Trace,
    shard: usize,
    start: Instant,
    times: Option<wire::WireTimes>,
) {
    let track = 1 + shard as u64;
    trace.span_at(
        "rpc",
        start,
        start.elapsed(),
        track,
        vec![("shard".to_string(), shard.to_string())],
    );
    if let Some(t) = times {
        trace.span_at(
            "worker",
            start + Duration::from_nanos(t.handle_lag_ns),
            Duration::from_nanos(t.exec_ns),
            track,
            vec![
                ("shard".to_string(), shard.to_string()),
                ("handle_lag_ns".to_string(), t.handle_lag_ns.to_string()),
            ],
        );
    }
}

/// One query's in-flight cross-worker `ScoreIds` scatter: the submit
/// half of `RemoteCluster::score_global_ids`, joined later so batched
/// callers can overlap scatters across queries.
struct ScoreScatter {
    /// Per non-empty worker bucket: worker index, expected score count,
    /// the in-flight call (replica-failover aware), and the positions
    /// (in the original `ids` order) its scores land in.
    in_flight: Vec<(usize, usize, SetPending, Vec<usize>)>,
    /// Total ids scattered (output length).
    len: usize,
}

impl ScoreScatter {
    /// Join every worker bucket and gather scores in `ids` order.
    fn join(self) -> Result<Vec<f32>> {
        let mut out = vec![0f32; self.len];
        for (shard, want, pending, positions) in self.in_flight {
            let scores = pending
                .join()
                .and_then(|resp| to_scores(resp, want))
                .map_err(|e| attribute(e, shard))?;
            for (score, pos) in scores.into_iter().zip(positions) {
                out[pos] = score;
            }
        }
        Ok(out)
    }
}

/// Client handle to one shard worker process.
///
/// All traffic rides the worker's multiplexed pipeline ([`MuxSlot`]):
/// one persistent connection carrying many overlapped request ids. The
/// blocking RPC helpers serialize straight from borrowed payloads
/// ([`Encoded`]) — no owned `Request` clone on the hot path — and are
/// just submit + join, so they interleave freely with the cluster's
/// fan-out traffic on the same socket.
pub struct RemoteShard {
    slot: MuxSlot,
}

impl RemoteShard {
    /// Connect and fetch the worker's manifest: `(len, dim, epoch)`.
    /// The connection itself opens lazily on this first call and is
    /// re-opened transparently on the first submission after it dies
    /// (worker restart, idle disconnect).
    pub fn connect(addr: Addr, cfg: ClientConfig) -> Result<(RemoteShard, (usize, usize, u64))> {
        let shard = RemoteShard {
            slot: MuxSlot::new(addr, cfg),
        };
        let manifest = shard.manifest()?;
        Ok((shard, manifest))
    }

    /// The worker's serving address.
    pub fn addr(&self) -> &Addr {
        &self.slot.inner.addr
    }

    /// Issue a pre-encoded request on this worker's multiplexed
    /// pipeline and return a joinable handle — the fan-out primitive
    /// every parallel cluster operation is built from. Submissions do
    /// not block on the socket, and any number may be in flight on the
    /// one connection at once (responses route back by request id).
    fn submit(&self, req: Encoded) -> Pending {
        self.slot.submit(Arc::new(req))
    }

    /// [`RemoteShard::submit`] with [`wire::FLAG_TRACED`] set: the
    /// worker's response carries its timing annex (handle lag + exec
    /// wall), joined via [`Pending::join_timed`].
    fn submit_traced(&self, req: Encoded) -> Pending {
        self.slot.submit_flagged(Arc::new(req), wire::FLAG_TRACED)
    }

    /// Submit + join in one blocking call.
    fn call(&self, req: Encoded) -> Result<WireResponse> {
        self.submit(req).join()
    }

    /// The worker's current `(len, dim, epoch)` manifest.
    pub fn manifest(&self) -> Result<(usize, usize, u64)> {
        to_manifest(self.call(Encoded::manifest())?)
    }

    /// Local top-k for every query (local ids).
    pub fn top_k_batch(&self, queries: &[Vec<f32>], k: usize) -> Result<Vec<Vec<Hit>>> {
        match self.call(Encoded::top_k(k as u64, queries))? {
            WireResponse::Hits(hits) => Ok(hits),
            other => Err(unexpected("top_k", other)),
        }
    }

    /// Continue a single-query chained exp-sum over this worker's rows.
    pub fn exp_sum_chain(&self, acc: f64, query: &[f32]) -> Result<f64> {
        match self.call(Encoded::exp_sum_chain(acc, query))? {
            WireResponse::ExpSums(acc) if acc.len() == 1 => Ok(acc[0]),
            other => Err(unexpected("exp_sum_chain", other)),
        }
    }

    /// Continue a batched chained exp-sum (one accumulator per query).
    pub fn exp_sum_chain_batch(&self, acc_in: Vec<f64>, queries: &[Vec<f32>]) -> Result<Vec<f64>> {
        let want = acc_in.len();
        match self.call(Encoded::exp_sum_chain_batch(&acc_in, queries))? {
            WireResponse::ExpSums(acc) if acc.len() == want => Ok(acc),
            other => Err(unexpected("exp_sum_chain_batch", other)),
        }
    }

    /// Inner products of the given **local** rows with the query.
    pub fn score_ids(&self, ids: &[u64], query: &[f32]) -> Result<Vec<f32>> {
        let want = ids.len();
        to_scores(self.call(Encoded::score_ids(ids, query))?, want)
    }

    /// Stage an epoch appending `rows` under `token` (publish phase 1).
    pub fn prepare_add(&self, token: u64, rows: &EmbeddingStore) -> Result<u64> {
        to_prepared(self.call(Encoded::prepare_add(token, rows.dim() as u64, rows.data()))?)
    }

    /// Stage an epoch dropping the given local ids under `token`
    /// (publish phase 1; empty `ids` is a pure epoch bump).
    pub fn prepare_remove(&self, token: u64, ids: &[u64]) -> Result<u64> {
        to_prepared(self.call(Encoded::prepare_remove(token, ids))?)
    }

    /// Publish the epoch staged under `token` (publish phase 2). Never
    /// re-sent once its frame may have reached the wire — the pipeline
    /// only retries calls that provably were never written (see
    /// [`CallFailure`]), so an ambiguous commit failure surfaces as an
    /// error for `RemoteCluster::publish` to resolve.
    pub fn commit(&self, token: u64) -> Result<u64> {
        to_committed(self.call(Encoded::commit(token))?)
    }

    /// Drop the preparation staged under `token` (idempotent).
    pub fn abort(&self, token: u64) -> Result<()> {
        match self.call(Encoded::abort(token))? {
            WireResponse::Aborted => Ok(()),
            other => Err(unexpected("abort", other)),
        }
    }

    /// Fit FMBE over this worker's local rows: the per-feature λ̃
    /// vector plus the epoch it was fitted on.
    pub fn fit_fmbe(&self, seed: u64, p_features: usize) -> Result<(u64, Vec<f64>)> {
        to_lambdas(self.call(Encoded::fit_fmbe(seed, p_features as u64))?, p_features)
    }
}

fn to_manifest(resp: WireResponse) -> Result<(usize, usize, u64)> {
    match resp {
        WireResponse::Manifest { len, dim, epoch } => Ok((len as usize, dim as usize, epoch)),
        other => Err(unexpected("manifest", other)),
    }
}

fn to_prepared(resp: WireResponse) -> Result<u64> {
    match resp {
        WireResponse::Prepared { epoch } => Ok(epoch),
        other => Err(unexpected("prepare", other)),
    }
}

fn to_committed(resp: WireResponse) -> Result<u64> {
    match resp {
        WireResponse::Committed { epoch } => Ok(epoch),
        other => Err(unexpected("commit", other)),
    }
}

fn to_scores(resp: WireResponse, want: usize) -> Result<Vec<f32>> {
    match resp {
        WireResponse::Scores(s) if s.len() == want => Ok(s),
        other => Err(unexpected("score_ids", other)),
    }
}

fn to_lambdas(resp: WireResponse, p_features: usize) -> Result<(u64, Vec<f64>)> {
    match resp {
        WireResponse::Lambdas { epoch, lambdas } if lambdas.len() == p_features => {
            Ok((epoch, lambdas))
        }
        other => Err(unexpected("fit_fmbe", other)),
    }
}

fn unexpected(what: &str, resp: WireResponse) -> ClientError {
    match resp {
        WireResponse::Error { code, message } => remote_err(code, message),
        other => ClientError::Protocol(format!("{what} answered with {other:?}")),
    }
}

// ---------------------------------------------------------------------
// Replica sets: R interchangeable workers per logical shard.

/// One logical shard served by R interchangeable replica workers
/// holding identical rows. Reads load-balance round-robin across the
/// replicas currently marked healthy and fail over transparently on any
/// [`ClientError::is_transient`] failure — only idempotent reads route
/// through here (the publish phases address each replica directly).
/// Health flags are advisory routing hints, not a membership protocol:
/// a transient failure marks the replica unhealthy immediately, and
/// [`RemoteCluster::refresh`] re-marks every replica that answers at
/// the lockstep epoch (the reconnect half of failover).
pub struct ReplicaSet {
    /// Replica handles, in the order the cluster was configured with.
    replicas: Vec<Arc<RemoteShard>>,
    /// Shard position within the cluster (metrics attribution).
    shard: usize,
    /// Round-robin read cursor.
    cursor: AtomicUsize,
    /// Per-replica advisory health (indexes `replicas`).
    health: Vec<AtomicBool>,
    /// Reads transparently re-routed to an alternate replica.
    failovers: AtomicU64,
    /// Hedge delay in nanoseconds for hedge-safe reads (0 disables):
    /// a read still unanswered after this long is duplicated to the
    /// next healthy replica and the first answer wins.
    hedge_delay_ns: AtomicU64,
    /// Reads that fired a hedge duplicate (whichever copy won).
    hedges: AtomicU64,
    /// Optional service sink failovers and hedges are mirrored into
    /// (`ServiceMetrics::on_shard_failover` / `on_shard_hedge`).
    sink: RwLock<Option<Arc<ServiceMetrics>>>,
}

impl ReplicaSet {
    fn new(shard: usize, replicas: Vec<Arc<RemoteShard>>) -> ReplicaSet {
        let health = replicas.iter().map(|_| AtomicBool::new(true)).collect();
        ReplicaSet {
            replicas,
            shard,
            cursor: AtomicUsize::new(0),
            health,
            failovers: AtomicU64::new(0),
            hedge_delay_ns: AtomicU64::new(0),
            hedges: AtomicU64::new(0),
            sink: RwLock::new(None),
        }
    }

    /// Number of replicas configured for this shard.
    pub fn num_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Per-replica advisory health flags, in replica order.
    pub fn health(&self) -> Vec<bool> {
        self.health
            .iter()
            .map(|h| h.load(Ordering::Relaxed))
            .collect()
    }

    /// Total reads that failed over to an alternate replica.
    pub fn failovers(&self) -> u64 {
        self.failovers.load(Ordering::Relaxed)
    }

    /// Total reads that fired a hedge duplicate to a second replica.
    pub fn hedges(&self) -> u64 {
        self.hedges.load(Ordering::Relaxed)
    }

    /// Set the hedge delay for hedge-safe reads (0 disables hedging).
    pub fn set_hedge_delay(&self, delay: Duration) {
        self.hedge_delay_ns
            .store(delay.as_nanos().min(u64::MAX as u128) as u64, Ordering::Relaxed);
    }

    /// The replica addresses joined `a|b|c` — the shard's display name
    /// in logs and error messages.
    pub fn name(&self) -> String {
        self.replicas
            .iter()
            .map(|r| r.addr().to_string())
            .collect::<Vec<_>>()
            .join("|")
    }

    fn mark(&self, replica: usize, healthy: bool) {
        self.health[replica].store(healthy, Ordering::Relaxed);
    }

    /// Next replica for a fresh read: round-robin over the healthy
    /// ones. With every replica marked unhealthy the flags are ignored
    /// (plain round-robin) — routing everything into a guaranteed
    /// failure would wedge the set, and the marks are only advisory.
    fn pick(&self) -> usize {
        let n = self.replicas.len();
        let start = self.cursor.fetch_add(1, Ordering::Relaxed) % n;
        for i in 0..n {
            let idx = (start + i) % n;
            if self.health[idx].load(Ordering::Relaxed) {
                return idx;
            }
        }
        start
    }

    /// The next failover target: an untried healthy replica first, then
    /// any untried one (a stale unhealthy mark beats failing the read).
    fn next_untried(&self, tried: &[bool]) -> Option<usize> {
        let healthy = (0..self.replicas.len())
            .find(|&i| !tried[i] && self.health[i].load(Ordering::Relaxed));
        healthy.or_else(|| (0..self.replicas.len()).find(|&i| !tried[i]))
    }

    fn on_failover(&self) {
        self.failovers.fetch_add(1, Ordering::Relaxed);
        if let Some(sink) = self.sink.read().unwrap().as_ref() {
            sink.on_shard_failover(self.shard);
        }
    }

    fn on_hedge(&self) {
        self.hedges.fetch_add(1, Ordering::Relaxed);
        if let Some(sink) = self.sink.read().unwrap().as_ref() {
            sink.on_shard_hedge(self.shard);
        }
    }

    /// Issue an **idempotent read** on one replica with transparent
    /// failover at join time — the replica-aware analogue of
    /// [`RemoteShard::submit`]. Never used for publish traffic.
    fn submit(self: &Arc<Self>, req: Encoded) -> SetPending {
        self.submit_flagged(Arc::new(req), 0)
    }

    /// [`ReplicaSet::submit`] with [`wire::FLAG_TRACED`] set.
    fn submit_traced(self: &Arc<Self>, req: Encoded) -> SetPending {
        self.submit_flagged(Arc::new(req), wire::FLAG_TRACED)
    }

    fn submit_flagged(self: &Arc<Self>, req: Arc<Encoded>, flags: u8) -> SetPending {
        let replica = self.pick();
        let mut tried = vec![false; self.replicas.len()];
        tried[replica] = true;
        let pending = self.replicas[replica]
            .slot
            .submit_flagged(Arc::clone(&req), flags);
        SetPending {
            set: Arc::clone(self),
            req,
            flags,
            tried,
            replica,
            pending,
        }
    }

    /// Submit + join in one blocking call (with failover).
    fn call(self: &Arc<Self>, req: Encoded) -> Result<WireResponse> {
        self.submit(req).join()
    }

    /// Local top-k for every query across the replica set (local ids).
    pub fn top_k_batch(self: &Arc<Self>, queries: &[Vec<f32>], k: usize) -> Result<Vec<Vec<Hit>>> {
        match self.call(Encoded::top_k(k as u64, queries))? {
            WireResponse::Hits(hits) => Ok(hits),
            other => Err(unexpected("top_k", other)),
        }
    }

    /// Continue a single-query chained exp-sum over this shard's rows.
    fn exp_sum_chain(self: &Arc<Self>, acc: f64, query: &[f32]) -> Result<f64> {
        match self.call(Encoded::exp_sum_chain(acc, query))? {
            WireResponse::ExpSums(acc) if acc.len() == 1 => Ok(acc[0]),
            other => Err(unexpected("exp_sum_chain", other)),
        }
    }

    /// Continue a batched chained exp-sum (one accumulator per query).
    fn exp_sum_chain_batch(self: &Arc<Self>, acc_in: Vec<f64>, queries: &[Vec<f32>]) -> Result<Vec<f64>> {
        let want = acc_in.len();
        match self.call(Encoded::exp_sum_chain_batch(&acc_in, queries))? {
            WireResponse::ExpSums(acc) if acc.len() == want => Ok(acc),
            other => Err(unexpected("exp_sum_chain_batch", other)),
        }
    }
}

/// A not-yet-joined replica-set read: joins the in-flight call and, on
/// any transient failure ([`ClientError::is_transient`]), marks the
/// failed replica unhealthy, ticks the failover counter and re-submits
/// on an alternate replica — each replica tried at most once. Safe
/// **only because every request routed through a [`ReplicaSet`] is an
/// idempotent read**: replicas at the same epoch answer with identical
/// bytes, so a re-submission after an ambiguous mid-stream failure
/// cannot change the result (unlike a `Commit`, which never routes
/// through here).
struct SetPending {
    set: Arc<ReplicaSet>,
    req: Arc<Encoded>,
    flags: u8,
    tried: Vec<bool>,
    /// Replica the in-flight `pending` was submitted on.
    replica: usize,
    pending: Pending,
}

impl SetPending {
    fn join(self) -> Result<WireResponse> {
        self.join_timed().map(|(resp, _)| resp)
    }

    fn join_timed(self) -> Result<(WireResponse, Option<wire::WireTimes>)> {
        let SetPending {
            set,
            req,
            flags,
            mut tried,
            mut replica,
            mut pending,
        } = self;
        let hedge_ns = set.hedge_delay_ns.load(Ordering::Relaxed);
        if hedge_ns > 0 && req.hedge_safe() && set.replicas.len() > 1 {
            return Self::join_hedged(set, req, flags, tried, replica, pending, hedge_ns);
        }
        loop {
            let failed = match pending.join_timed() {
                Ok(out) => return Ok(out),
                Err(e) if e.is_transient() => e,
                Err(e) => return Err(e),
            };
            set.mark(replica, false);
            let Some(next) = set.next_untried(&tried) else {
                // Replica set exhausted: surface the last failure.
                return Err(failed);
            };
            set.on_failover();
            log::warn!(
                "shard {}: read failed transiently ({failed}); failing over to replica {}",
                set.name(),
                set.replicas[next].addr()
            );
            tried[next] = true;
            replica = next;
            pending = set.replicas[next].slot.submit_flagged(Arc::clone(&req), flags);
        }
    }

    /// Hedged join for hedge-safe reads: wait `hedge_ns` on the primary
    /// lane, then duplicate the read onto the next untried replica and
    /// take whichever lane answers first (alternating short poll
    /// slices). A lane that fails transiently is marked unhealthy and
    /// dropped without a failover tick — the surviving hedge lane *is*
    /// the alternate — and only when every lane has died does this fall
    /// back to the classic failover resubmit. The abandoned lane's
    /// response is dropped harmlessly by the mux reader (its completion
    /// channel is closed). Safe only because both copies may execute:
    /// [`Encoded::hedge_safe`] gates this to stateless pure reads.
    fn join_hedged(
        set: Arc<ReplicaSet>,
        req: Arc<Encoded>,
        flags: u8,
        mut tried: Vec<bool>,
        primary: usize,
        primary_pending: Pending,
        hedge_ns: u64,
    ) -> Result<(WireResponse, Option<wire::WireTimes>)> {
        /// Alternating-poll slice width: long enough that two lanes cost
        /// ~no extra wakeups at serving latencies, short enough that the
        /// winner is noticed promptly.
        const SLICE: Duration = Duration::from_micros(200);

        let mut lanes: Vec<(usize, Pending)> = vec![(primary, primary_pending)];
        let mut hedged = false;
        let mut wait = Duration::from_nanos(hedge_ns);
        let mut last_err: Option<ClientError> = None;
        loop {
            let mut i = 0;
            while i < lanes.len() {
                let (rep, pending) = &mut lanes[i];
                match pending.poll_timed(wait) {
                    Some(Ok(out)) => return Ok(out),
                    Some(Err(e)) if e.is_transient() => {
                        set.mark(*rep, false);
                        log::warn!(
                            "shard {}: hedged read lane {} failed transiently ({e})",
                            set.name(),
                            set.replicas[*rep].addr()
                        );
                        last_err = Some(e);
                        lanes.remove(i);
                    }
                    Some(Err(e)) => return Err(e),
                    None => i += 1,
                }
            }
            if lanes.is_empty() {
                // Every lane died: classic failover resubmit (the hedge
                // no longer covers the loss).
                let Some(next) = set.next_untried(&tried) else {
                    return Err(last_err.unwrap_or(ClientError::ConnectionClosed));
                };
                set.on_failover();
                tried[next] = true;
                lanes.push((next, set.replicas[next].slot.submit_flagged(Arc::clone(&req), flags)));
                wait = Duration::from_nanos(hedge_ns);
                hedged = false;
                continue;
            }
            if !hedged {
                // Hedge delay elapsed with the primary still unanswered:
                // fire the duplicate and start alternating.
                hedged = true;
                wait = SLICE;
                if let Some(next) = set.next_untried(&tried) {
                    set.on_hedge();
                    tried[next] = true;
                    lanes.push((
                        next,
                        set.replicas[next].slot.submit_flagged(Arc::clone(&req), flags),
                    ));
                }
            }
        }
    }
}

/// [`MipsIndex`] over one remote replica set. `len` is pinned at
/// construction (cluster epoch) so the in-process scatter sees a stable
/// layout; the cluster rebuilds these handles on every published epoch.
/// `top_k_batch` load-balances across the set's healthy replicas and
/// fails over transparently like every other cluster read.
///
/// Wire failures inside the `MipsIndex` methods panic with context —
/// the trait has no error channel — and are caught at the serving
/// boundary (`net::Server` answers `Internal` instead of crashing).
pub struct RemoteShardIndex {
    shard: Arc<ReplicaSet>,
    len: usize,
}

impl RemoteShardIndex {
    /// Wrap one shard's replica set as a `len`-row [`MipsIndex`].
    pub fn new(shard: Arc<ReplicaSet>, len: usize) -> RemoteShardIndex {
        RemoteShardIndex { shard, len }
    }
}

impl MipsIndex for RemoteShardIndex {
    fn top_k(&self, q: &[f32], k: usize) -> Vec<Hit> {
        self.top_k_batch(std::slice::from_ref(&q.to_vec()), k)
            .pop()
            .unwrap_or_default()
    }

    fn top_k_batch(&self, qs: &[Vec<f32>], k: usize) -> Vec<Vec<Hit>> {
        if qs.is_empty() {
            return vec![];
        }
        self.shard.top_k_batch(qs, k).unwrap_or_else(|e| {
            panic!("remote shard {}: top_k failed: {e}", self.shard.name())
        })
    }

    fn len(&self) -> usize {
        self.len
    }

    fn probe_cost(&self, _k: usize) -> usize {
        // Exact brute retrieval on the worker: every local row scored.
        self.len
    }

    fn name(&self) -> &'static str {
        "remote"
    }
}

/// Near-even row split with every boundary 4-aligned: all shards but the
/// last hold a multiple of 4 rows (the bit-exactness contract above),
/// sizes within 4 of each other. Shard count is clamped so no shard is
/// empty.
pub fn aligned_split_lens(n: usize, s: usize) -> Vec<usize> {
    if n == 0 {
        return vec![];
    }
    let s = s.clamp(1, (n / 4).max(1));
    let base = (n / s) & !3;
    if base == 0 {
        return vec![n];
    }
    let mut lens = vec![base; s];
    lens[s - 1] = n - base * (s - 1);
    lens
}

/// Cut `store` into [`aligned_split_lens`] row blocks (what each shard
/// worker should serve).
pub fn aligned_split(store: &EmbeddingStore, s: usize) -> Vec<EmbeddingStore> {
    let d = store.dim();
    let mut offset = 0usize;
    aligned_split_lens(store.len(), s)
        .into_iter()
        .map(|len| {
            let block =
                EmbeddingStore::from_data(len, d, store.rows(offset, offset + len).to_vec())
                    .expect("aligned split tiles the range");
            offset += len;
            block
        })
        .collect()
}

struct ClusterState {
    lens: Vec<usize>,
    epoch: u64,
    index: Arc<ShardedIndex>,
}

/// A query block's answers plus the pinned cluster view they were
/// computed against (see [`RemoteCluster::estimate_batch`]).
pub struct ClusterAnswer {
    /// Ẑ per query, in request order.
    pub zs: Vec<f64>,
    /// Epoch of the pinned view that produced `zs`.
    pub epoch: u64,
    /// Categories the pinned view served.
    pub len: usize,
    /// Per-worker row counts of the pinned view, in worker order
    /// (feeds per-shard service metrics when the cluster serves behind
    /// a `PartitionService`).
    pub shard_lens: Vec<usize>,
}

/// One publish this coordinator drove, recorded for replica catch-up:
/// the staging token, the epoch the commit targeted, and every shard's
/// phase-1 payload (shared, not cloned — replicas of one shard replay
/// the same bytes). [`RemoteCluster::refresh`] replays missed
/// `(prepare, commit)` pairs from these entries to heal a replica
/// lagging any number of epochs still covered by the log.
struct PublishLogEntry {
    token: u64,
    /// The epoch committing this entry publishes.
    epoch: u64,
    /// Per-shard phase-1 request (`PrepareAdd` / `PrepareRemove`).
    prepares: Vec<Arc<Encoded>>,
}

/// Publishes the catch-up log retains. A replica lagging deeper than
/// this cannot be healed in place (restart it with current data or
/// re-drive the missed mutations); the bound keeps prepare payloads —
/// which may carry whole row blocks — from accumulating forever.
const PUBLISH_LOG_CAP: usize = 32;

/// S replica sets composed into one logical store.
///
/// Concurrency model: one `RemoteCluster` is the single coordinator of
/// its workers (the cross-process analogue of one `SnapshotHandle`).
/// Mutations serialize on an internal publish lock and estimates pin
/// one `ClusterState` (layout + scatter index) per request, so
/// cluster-side reads never mix two layouts. What a remote seam cannot
/// give is in-process snapshot pinning on the **workers**: a worker
/// answers every wire call from its currently published epoch, so an
/// estimate racing a publish may read rows of the new epoch through the
/// old layout (versioned worker reads are a ROADMAP follow-on). Drive
/// mutations and traffic from one coordinator process; a second
/// coordinator's publish is fenced only by the worker-side staging
/// token (`Busy`).
pub struct RemoteCluster {
    shards: Vec<Arc<ReplicaSet>>,
    dim: usize,
    state: RwLock<Arc<ClusterState>>,
    /// Serializes cluster-side mutations (global-id interpretation +
    /// two-phase publish are read-modify-write on the layout).
    publish_lock: Mutex<()>,
    /// Every publish that reached its commit phase, newest last,
    /// bounded by [`PUBLISH_LOG_CAP`]: the replay source for replica
    /// catch-up ([`RemoteCluster::refresh`]), generalizing the old
    /// lag-1 "unresolved commit" slot to any lag depth the log covers.
    publish_log: Mutex<VecDeque<PublishLogEntry>>,
    token: AtomicU64,
    /// Configuration of the cluster-wide FMBE fit (seed + feature
    /// count; the wire op pins the geometric parameter to the default).
    fmbe_cfg: FmbeConfig,
    /// Epoch-tagged cluster FMBE — the remote analogue of the
    /// `Router`'s in-process refit cache.
    fmbe: EpochCache<Fmbe>,
}

impl RemoteCluster {
    /// Connect to every worker (in global shard order, one replica per
    /// shard), validate that dimensionalities match and epochs are in
    /// lockstep, and build the scatter index. Replicated shards go
    /// through [`RemoteCluster::connect_groups`].
    pub fn connect(addrs: &[Addr], cfg: ClientConfig) -> Result<RemoteCluster> {
        let groups: Vec<Vec<Addr>> = addrs.iter().map(|a| vec![a.clone()]).collect();
        Self::connect_groups(&groups, cfg)
    }

    /// Connect to every replica of every shard (`groups[s]` is shard
    /// `s`'s replica addresses), validate that dimensionalities match,
    /// that every worker — replicas included — is at the lockstep
    /// epoch, and that replicas of one shard agree on their row count,
    /// then build the scatter index. Connect-time validation is strict
    /// (every replica must answer); failover tolerance starts once the
    /// cluster is up.
    pub fn connect_groups(groups: &[Vec<Addr>], cfg: ClientConfig) -> Result<RemoteCluster> {
        if groups.is_empty() || groups.iter().any(|g| g.is_empty()) {
            return Err(ClientError::Protocol(
                "empty worker list (every shard needs at least one replica)".to_string(),
            ));
        }
        let mut shards = Vec::with_capacity(groups.len());
        let mut lens = Vec::with_capacity(groups.len());
        let mut dim = None;
        let mut epoch = None;
        for (s, group) in groups.iter().enumerate() {
            let mut replicas = Vec::with_capacity(group.len());
            let mut shard_len = None;
            for addr in group {
                let (shard, (len, d, e)) = RemoteShard::connect(addr.clone(), cfg.clone())?;
                match dim {
                    None => dim = Some(d),
                    Some(want) if want != d => {
                        return Err(ClientError::Protocol(format!(
                            "worker {addr} serves dim {d}, cluster dim is {want}"
                        )));
                    }
                    _ => {}
                }
                match epoch {
                    None => epoch = Some(e),
                    Some(want) if want != e => {
                        return Err(ClientError::Protocol(format!(
                            "worker {addr} at epoch {e}, cluster epoch is {want} \
                             (out-of-lockstep workers)"
                        )));
                    }
                    _ => {}
                }
                match shard_len {
                    None => shard_len = Some(len),
                    Some(want) if want != len => {
                        return Err(ClientError::Protocol(format!(
                            "replica {addr} of shard {s} serves {len} rows, its peers \
                             serve {want} (replicas must hold identical data)"
                        )));
                    }
                    _ => {}
                }
                replicas.push(Arc::new(shard));
            }
            shards.push(Arc::new(ReplicaSet::new(s, replicas)));
            lens.push(shard_len.unwrap());
        }
        if lens[..lens.len() - 1].iter().any(|&l| l % 4 != 0) {
            log::warn!(
                "worker row counts {lens:?} are not 4-aligned; Exact answers stay correct \
                 but are not bit-pinned to the in-process kernels (see aligned_split_lens)"
            );
        }
        let index = Arc::new(Self::build_index(&shards, &lens));
        Ok(RemoteCluster {
            shards,
            dim: dim.unwrap(),
            state: RwLock::new(Arc::new(ClusterState {
                lens,
                epoch: epoch.unwrap(),
                index,
            })),
            publish_lock: Mutex::new(()),
            publish_log: Mutex::new(VecDeque::new()),
            // Seed tokens with process-unique entropy so a replacement
            // coordinator cannot collide with a crashed predecessor's
            // orphaned staged preparation (worker staging is keyed by
            // token; see `ShardWorker`).
            token: AtomicU64::new(
                std::time::SystemTime::now()
                    .duration_since(std::time::UNIX_EPOCH)
                    .map(|d| d.as_nanos() as u64)
                    .unwrap_or(0)
                    ^ ((std::process::id() as u64) << 32),
            ),
            fmbe_cfg: FmbeConfig::default(),
            fmbe: EpochCache::new(),
        })
    }

    /// Mirror per-shard failover ticks into a service metrics sink
    /// (`ServiceMetrics::on_shard_failover` → `shard_stats[..].failovers`).
    /// `zest-server` wires the serving stack's own sink in here so
    /// failovers show up next to the per-shard error counters.
    pub fn set_metrics(&self, sink: Arc<ServiceMetrics>) {
        for set in &self.shards {
            *set.sink.write().unwrap() = Some(sink.clone());
        }
    }

    /// Per-shard, per-replica advisory health flags (`true` = routed to
    /// by reads), in shard/replica order — the `replica_health` gauge.
    pub fn replica_status(&self) -> Vec<Vec<bool>> {
        self.shards.iter().map(|set| set.health()).collect()
    }

    /// Total reads re-routed to an alternate replica, across all shards.
    pub fn failovers(&self) -> u64 {
        self.shards.iter().map(|set| set.failovers()).sum()
    }

    /// Total hedge duplicates fired, across all shards.
    pub fn hedges(&self) -> u64 {
        self.shards.iter().map(|set| set.hedges()).sum()
    }

    /// Set the hedge delay for hedge-safe reads (`TopK`) on every
    /// shard's replica set. 0 disables hedging (the default). Only
    /// meaningful with ≥ 2 replicas per shard.
    pub fn set_hedge_delay(&self, delay: Duration) {
        for set in &self.shards {
            set.set_hedge_delay(delay);
        }
    }

    /// Configure the cluster-wide FMBE fit (feature count + seed). The
    /// wire `FitFmbe` op carries only `(seed, p_features)` and pins the
    /// geometric parameter to the library default, so a non-default
    /// `p_geom` is rejected at fit time. Clears any cached fit.
    pub fn with_fmbe_config(mut self, cfg: FmbeConfig) -> RemoteCluster {
        self.fmbe_cfg = cfg;
        self.fmbe = EpochCache::new();
        self
    }

    /// The cluster-wide FMBE fit configuration.
    pub fn fmbe_config(&self) -> &FmbeConfig {
        &self.fmbe_cfg
    }

    /// Pin the current cluster view (layout + scatter index) for one
    /// unit of work — the cross-process analogue of `SnapshotHandle::load`.
    fn state(&self) -> Arc<ClusterState> {
        self.state.read().unwrap().clone()
    }

    fn build_index(shards: &[Arc<ReplicaSet>], lens: &[usize]) -> ShardedIndex {
        let mut offset = 0usize;
        let parts: Vec<(usize, Arc<dyn MipsIndex>)> = shards
            .iter()
            .zip(lens)
            .map(|(shard, &len)| {
                let part = (
                    offset,
                    Arc::new(RemoteShardIndex::new(shard.clone(), len)) as Arc<dyn MipsIndex>,
                );
                offset += len;
                part
            })
            .collect();
        // One scatter thread per worker: the sub-index calls block on
        // wire round-trips, so the budget is worker count, not cores —
        // every worker's RPC must be in flight concurrently.
        ShardedIndex::from_parts(parts).with_scatter_threads(shards.len())
    }

    /// Number of worker processes composed by this cluster.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Dimensionality every worker serves.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Total categories across workers at the current epoch.
    pub fn len(&self) -> usize {
        self.state().lens.iter().sum()
    }

    /// Whether the cluster currently serves zero categories.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The lockstep epoch of the current cluster view.
    pub fn epoch(&self) -> u64 {
        self.state().epoch
    }

    /// The scatter-gather [`ShardedIndex`] over the current epoch's
    /// remote shards (pin the `Arc` for a unit of work, like a snapshot).
    pub fn index(&self) -> Arc<ShardedIndex> {
        self.state().index.clone()
    }

    /// Single-query chained exact partition: Σ exp(vᵢ·q) accumulated
    /// across workers in strict global row order (the gemv kernel chain
    /// — mirrors `Exact::estimate`).
    pub fn exp_sum(&self, q: &[f32]) -> Result<f64> {
        let mut acc = 0f64;
        for (s, shard) in self.shards.iter().enumerate() {
            acc = shard.exp_sum_chain(acc, q).map_err(|e| attribute(e, s))?;
        }
        Ok(acc)
    }

    /// Batched chained exact partition (the gemm kernel chain — mirrors
    /// `Exact::estimate_batch`).
    pub fn exp_sum_batch(&self, qs: &[Vec<f32>]) -> Result<Vec<f64>> {
        self.exp_sum_batch_traced(qs, None)
    }

    /// [`RemoteCluster::exp_sum_batch`] recording each sequential
    /// worker round-trip on `trace` (when sampled): per-shard `rpc`
    /// client wall + the worker's annex-reported `worker` exec span.
    fn exp_sum_batch_traced(&self, qs: &[Vec<f32>], trace: Option<&Trace>) -> Result<Vec<f64>> {
        let mut acc = vec![0f64; qs.len()];
        if qs.is_empty() {
            return Ok(acc);
        }
        for (s, shard) in self.shards.iter().enumerate() {
            let want = acc.len();
            acc = match trace {
                None => shard.exp_sum_chain_batch(acc, qs),
                Some(t) => {
                    let req = Encoded::exp_sum_chain_batch(&acc, qs);
                    let start = Instant::now();
                    shard.submit_traced(req).join_timed().and_then(
                        |(resp, times)| {
                            record_shard_spans(t, s, start, times);
                            match resp {
                                WireResponse::ExpSums(acc) if acc.len() == want => Ok(acc),
                                other => Err(unexpected("exp_sum_chain_batch", other)),
                            }
                        },
                    )
                }
            }
            .map_err(|e| attribute(e, s))?;
        }
        Ok(acc)
    }

    /// Batched **pipelined** exact partition ([`Precision::Pipelined`]):
    /// one `ExpSumPart` is submitted to every worker's I/O slot
    /// concurrently, and the per-worker partial sums are reduced in
    /// worker order. Latency is the slowest worker instead of the sum
    /// of all S round-trips the bit-exact chain pays; the price is the
    /// f64 summation *grouping* — each worker accumulates its own rows
    /// from zero and the partials are then added, so answers are
    /// last-ulp different from [`RemoteCluster::exp_sum_batch`]
    /// (identical bits at S = 1, where the reduce adds a single partial
    /// to zero). `tests/net_e2e.rs` pins the relative-error bound for
    /// S ∈ {1, 2, 4}.
    pub fn exp_sum_parts(&self, qs: &[Vec<f32>]) -> Result<Vec<f64>> {
        self.exp_sum_parts_traced(qs, None)
    }

    /// [`RemoteCluster::exp_sum_parts`] recording each concurrent
    /// worker fan-out leg on `trace` (when sampled): the per-shard
    /// `rpc` spans overlap, which is exactly what distinguishes this
    /// mode from the sequential chain in a trace dump.
    fn exp_sum_parts_traced(&self, qs: &[Vec<f32>], trace: Option<&Trace>) -> Result<Vec<f64>> {
        let mut zs = vec![0f64; qs.len()];
        if qs.is_empty() {
            return Ok(zs);
        }
        let start = Instant::now();
        let in_flight: Vec<_> = self
            .shards
            .iter()
            .map(|shard| {
                let req = Encoded::exp_sum_part(qs);
                match trace {
                    None => shard.submit(req),
                    Some(_) => shard.submit_traced(req),
                }
            })
            .collect();
        for (s, pending) in in_flight.into_iter().enumerate() {
            let (resp, times) = pending.join_timed().map_err(|e| attribute(e, s))?;
            if let Some(t) = trace {
                record_shard_spans(t, s, start, times);
            }
            match resp {
                WireResponse::ExpSums(partials) if partials.len() == qs.len() => {
                    for (z, p) in zs.iter_mut().zip(partials) {
                        *z += p;
                    }
                }
                other => return Err(attribute(unexpected("exp_sum_part", other), s)),
            }
        }
        Ok(zs)
    }

    /// Submit the `ScoreIds` scatter for one query: bucket each global
    /// id to its owning worker under the caller's pinned layout and
    /// issue every bucket on its worker's I/O slot. The returned
    /// [`ScoreScatter`] joins into scores in `ids` order. Splitting
    /// submit from join lets batched callers put **every query's**
    /// scatter in flight before joining any (cross-query overlap on top
    /// of the per-query cross-worker overlap).
    fn submit_score_ids(&self, lens: &[usize], ids: &[usize], q: &[f32]) -> Result<ScoreScatter> {
        let mut buckets: Vec<(Vec<u64>, Vec<usize>)> =
            (0..self.shards.len()).map(|_| (vec![], vec![])).collect();
        for (pos, &g) in ids.iter().enumerate() {
            let mut offset = 0usize;
            let mut owner = None;
            for (s, &len) in lens.iter().enumerate() {
                if g < offset + len {
                    owner = Some((s, g - offset));
                    break;
                }
                offset += len;
            }
            let Some((s, local)) = owner else {
                return Err(ClientError::Protocol(format!(
                    "tail id {g} out of range (cluster len {})",
                    lens.iter().sum::<usize>()
                )));
            };
            buckets[s].0.push(local as u64);
            buckets[s].1.push(pos);
        }
        let in_flight: Vec<_> = buckets
            .into_iter()
            .enumerate()
            .filter(|(_, (locals, _))| !locals.is_empty())
            .map(|(s, (locals, positions))| {
                let pending = self.shards[s].submit(Encoded::score_ids(&locals, q));
                (s, locals.len(), pending, positions)
            })
            .collect();
        Ok(ScoreScatter {
            in_flight,
            len: ids.len(),
        })
    }

    /// Score global ids against `q` and wait: submit + join in one call
    /// (single-query use; batched paths interleave the halves).
    fn score_global_ids(&self, lens: &[usize], ids: &[usize], q: &[f32]) -> Result<Vec<f32>> {
        self.submit_score_ids(lens, ids, q)?.join()
    }

    /// Estimate a same-(kind, k, l, precision) query block across the
    /// remote shards, mirroring the in-process estimator math for
    /// **every** [`EstimatorKind`]: `Exact` exactly under
    /// [`Precision::BitExact`] (the sequential chain) or
    /// last-ulp-different under [`Precision::Pipelined`] (the
    /// `ExpSumPart` fan-out, max-over-workers latency); `Nmimps`,
    /// `Mimps`, `Uniform` and `Mince` with the same global tail draw as
    /// in-process, scored remotely; `Fmbe` from the epoch-tagged
    /// cluster fit (per-shard λ̃ sums). Non-`Exact` kinds ignore the
    /// precision mode — their remote execution already fans out.
    /// The returned [`ClusterAnswer`] carries the epoch and category
    /// count of the **pinned** cluster view that produced the answers,
    /// so callers report a consistent `Response.epoch` even when a
    /// publish lands mid-request.
    pub fn estimate_batch(
        &self,
        kind: EstimatorKind,
        k: usize,
        l: usize,
        precision: Precision,
        qs: &[Vec<f32>],
        rng: &mut Rng,
        trace: Option<&Trace>,
    ) -> Result<ClusterAnswer> {
        // One pinned cluster view for the whole block, so the head
        // retrieval, tail sizing, tail scoring and the reported
        // epoch/len all use one layout.
        let state = self.state();
        let zs = match kind {
            EstimatorKind::Exact => match precision {
                Precision::BitExact => self.exp_sum_batch_traced(qs, trace)?,
                Precision::Pipelined => self.exp_sum_parts_traced(qs, trace)?,
            },
            EstimatorKind::Nmimps => {
                let heads = state.index.top_k_batch(qs, k);
                heads.iter().map(|head| tail::head_sum(head)).collect()
            }
            EstimatorKind::Mimps => self.sampled_batch(&state, qs, k, l, rng)?,
            EstimatorKind::Uniform => self.sampled_batch(&state, qs, 0, l, rng)?,
            EstimatorKind::Mince => self.mince_batch(&state, qs, k, l, rng)?,
            EstimatorKind::Fmbe => self.fmbe_for(&state)?.estimate_queries(qs),
        };
        Ok(ClusterAnswer {
            zs,
            epoch: state.epoch,
            len: state.lens.iter().sum(),
            shard_lens: state.lens.clone(),
        })
    }

    /// MIMPS (k > 0) / Uniform (k = 0) over remote shards: retrieve the
    /// head through the pinned scatter index, draw the same global tail
    /// sample as the in-process estimators, and score the drawn ids on
    /// their owning workers (same pinned layout throughout).
    ///
    /// Two phases so a batch costs one scoring wave, not Q sequential
    /// ones: the draws run sequentially (RNG-sequence parity with the
    /// in-process estimators) while every query's `ScoreIds` scatter is
    /// submitted as soon as it is drawn; the joins run after all
    /// scatters are in flight.
    fn sampled_batch(
        &self,
        state: &ClusterState,
        qs: &[Vec<f32>],
        k: usize,
        l: usize,
        rng: &mut Rng,
    ) -> Result<Vec<f64>> {
        let n: usize = state.lens.iter().sum();
        let heads: Vec<Vec<Hit>> = if k > 0 {
            state.index.top_k_batch(qs, k)
        } else {
            vec![vec![]; qs.len()]
        };
        let mut scratch = tail::TailScratch::new();
        // Phase 1: draw + submit. `(head_z, k_eff, drawn, scatter)`.
        let mut staged = Vec::with_capacity(qs.len());
        for (q, head) in qs.iter().zip(&heads) {
            let head_z = tail::head_sum(head);
            let k_eff = head.len();
            if k_eff >= n || l == 0 {
                staged.push((head_z, k_eff, 0usize, None));
                continue;
            }
            tail::sample_tail_ids(n, head, l, rng, &mut scratch);
            let drawn = scratch.indices.len();
            if drawn == 0 {
                staged.push((head_z, k_eff, 0, None));
                continue;
            }
            let scatter = self.submit_score_ids(&state.lens, &scratch.indices, q)?;
            staged.push((head_z, k_eff, drawn, Some(scatter)));
        }
        // Phase 2: join in query order.
        let mut out = Vec::with_capacity(qs.len());
        for (head_z, k_eff, drawn, scatter) in staged {
            let Some(scatter) = scatter else {
                out.push(head_z);
                continue;
            };
            let exp_sum: f64 = scatter
                .join()?
                .iter()
                .map(|&s| (s as f64).exp())
                .sum();
            out.push(head_z + (n - k_eff) as f64 * (exp_sum / drawn as f64));
        }
        Ok(out)
    }

    /// MINCE over remote shards, mirroring `Mince::estimate` term for
    /// term: the head `S_k` from the pinned scatter index plays the
    /// "data" samples, the **same global noise draw** as the in-process
    /// estimator (via [`tail::sample_tail_ids`]) plays the noise —
    /// scored on its owning workers through the parallel `ScoreIds`
    /// fan-out — and the identical safeguarded Halley solve runs
    /// cluster-side. Under a fixed seed the draw sequence matches the
    /// in-process estimator exactly; answers agree to float tolerance
    /// (head/noise scores come from differently-chunked scoring passes).
    ///
    /// Like the pre-existing `sampled_batch` path, the `ScoreIds`
    /// round-trips carry no epoch: a publish racing this call can shift
    /// a worker's local-id mapping under the pinned layout (see the
    /// worker-side-pinning caveat on [`RemoteCluster`]; versioned
    /// worker reads are the ROADMAP follow-on). Drive mutations and
    /// traffic from one coordinator.
    fn mince_batch(
        &self,
        state: &ClusterState,
        qs: &[Vec<f32>],
        k: usize,
        l: usize,
        rng: &mut Rng,
    ) -> Result<Vec<f64>> {
        let n: usize = state.lens.iter().sum();
        let heads: Vec<Vec<Hit>> = state.index.top_k_batch(qs, k);
        let mut scratch = tail::TailScratch::new();
        // Phase 1: sequential draws (RNG-sequence parity) with every
        // query's noise scatter submitted immediately, so a batch pays
        // one scoring wave instead of Q sequential round-trips.
        // `(head_z, scale, a, scatter)` per query.
        let mut staged = Vec::with_capacity(qs.len());
        for (q, head) in qs.iter().zip(&heads) {
            if head.is_empty() {
                return Err(remote_err(
                    ErrorCode::BadRequest,
                    "MINCE needs a non-empty head (k ≥ 1 and a non-empty store)".to_string(),
                ));
            }
            let head_z = tail::head_sum(head);
            let k_eff = head.len();
            tail::sample_tail_ids(n, head, l, rng, &mut scratch);
            if scratch.indices.is_empty() {
                // Degenerate: no complement to sample — head sum, like
                // the in-process estimator.
                staged.push((head_z, 0.0, vec![], None));
                continue;
            }
            let l_eff = scratch.indices.len();
            // a_i, b_j with the k(N−k)/l scaling from paper eq. (7).
            let scale = k_eff as f64 * (n - k_eff) as f64 / l_eff as f64;
            let a: Vec<f64> = head
                .iter()
                .map(|h| (h.score as f64).exp() * scale)
                .collect();
            let scatter = self.submit_score_ids(&state.lens, &scratch.indices, q)?;
            staged.push((head_z, scale, a, Some(scatter)));
        }
        // Phase 2: join + solve in query order.
        let mut out = Vec::with_capacity(qs.len());
        for (head_z, scale, a, scatter) in staged {
            let Some(scatter) = scatter else {
                out.push(head_z);
                continue;
            };
            let b: Vec<f64> = scatter
                .join()?
                .into_iter()
                .map(|s| (s as f64).exp() * scale)
                .collect();
            let z0 = head_z.max(1e-12);
            out.push(mince::solve(&a, &b, z0, Solver::Halley).z);
        }
        Ok(out)
    }

    /// The cluster-wide FMBE for the pinned view's epoch, fitting on
    /// demand: every worker runs `FitFmbe` **concurrently** (same seed
    /// and feature count → identical feature draws), the per-shard λ̃
    /// vectors are summed in worker order, and the estimator is rebuilt
    /// cluster-side via [`Fmbe::from_lambdas`]. Cached per epoch (the
    /// remote analogue of the `Router` refit); a publish invalidates it
    /// and the next FMBE request refits. A fit that races a publish
    /// (some worker already serving a different epoch) fails with a
    /// retryable `Busy` error instead of mixing category sets — the
    /// caller retries against the new epoch.
    fn fmbe_for(&self, state: &ClusterState) -> Result<Arc<Fmbe>> {
        self.fmbe
            .get_or_try_fit(state.epoch, || self.fit_fmbe_cluster(state))
    }

    fn fit_fmbe_cluster(&self, state: &ClusterState) -> Result<Fmbe> {
        let cfg = self.fmbe_cfg.clone();
        if (cfg.p_geom - FmbeConfig::default().p_geom).abs() > 1e-12 {
            return Err(ClientError::Protocol(format!(
                "FitFmbe carries only (seed, p_features); p_geom must stay at the \
                 default {} (got {})",
                FmbeConfig::default().p_geom,
                cfg.p_geom
            )));
        }
        let p = cfg.p_features;
        let in_flight: Vec<_> = self
            .shards
            .iter()
            .map(|shard| shard.submit(Encoded::fit_fmbe(cfg.seed, p as u64)))
            .collect();
        let mut lambdas = vec![0f64; p];
        for (s, (shard, pending)) in self.shards.iter().zip(in_flight).enumerate() {
            let (epoch, worker) = match pending.join().map_err(|e| attribute(e, s))? {
                WireResponse::Lambdas { epoch, lambdas } if lambdas.len() == p => {
                    (epoch, lambdas)
                }
                other => return Err(attribute(unexpected("fit_fmbe", other), s)),
            };
            if epoch != state.epoch {
                // Typed + retryable: `Busy` reaches wire clients as-is
                // (unlike a Protocol error, which the handler would
                // surface as `Internal`), so callers can tell this
                // transient race from a server bug and just retry.
                return Err(remote_err(
                    ErrorCode::Busy,
                    format!(
                        "shard {} fitted FMBE at epoch {epoch}, pinned view is epoch {} \
                         (publish raced the fit — retry)",
                        shard.name(),
                        state.epoch
                    ),
                ));
            }
            for (acc, w) in lambdas.iter_mut().zip(&worker) {
                *acc += w;
            }
        }
        Ok(Fmbe::from_lambdas(self.dim, cfg, lambdas))
    }

    /// Two-phase cluster-wide append: the rows join the **last** worker
    /// (preserving global id contiguity); every other worker stages a
    /// pure epoch bump so epochs stay in lockstep. All-or-nothing: any
    /// prepare failure aborts every staged worker. Returns the new
    /// cluster epoch.
    pub fn add_categories(&self, rows: &EmbeddingStore) -> Result<u64> {
        let _p = self.publish_lock.lock().unwrap();
        let last = self.shards.len() - 1;
        self.publish(|s, token| {
            if s == last {
                Encoded::prepare_add(token, rows.dim() as u64, rows.data())
            } else {
                Encoded::prepare_remove(token, &[])
            }
        })
    }

    /// Two-phase cluster-wide removal of the given **global** ids
    /// (current epoch's positions; remaining ids compact downward, like
    /// the in-process `SnapshotHandle`). Emptying a worker outright is
    /// rejected at prepare time and aborts the publish.
    pub fn remove_categories(&self, global_ids: &[usize]) -> Result<u64> {
        // The publish lock covers the global-id interpretation too: ids
        // are positions in the layout we read here, and a concurrent
        // publish would silently shift them.
        let _p = self.publish_lock.lock().unwrap();
        let lens = self.state().lens.clone();
        let n: usize = lens.iter().sum();
        let mut sorted: Vec<usize> = global_ids.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        if let Some(&bad) = sorted.last() {
            if bad >= n {
                return Err(ClientError::Protocol(format!(
                    "remove_categories: id {bad} out of range (len {n})"
                )));
            }
        }
        // Bucket global ids into per-worker local ids.
        let mut per_worker: Vec<Vec<u64>> = vec![vec![]; self.shards.len()];
        let mut it = sorted.into_iter().peekable();
        let mut offset = 0usize;
        for (s, &len) in lens.iter().enumerate() {
            while let Some(&g) = it.peek() {
                if g >= offset + len {
                    break;
                }
                per_worker[s].push((g - offset) as u64);
                it.next();
            }
            offset += len;
        }
        self.publish(|s, token| Encoded::prepare_remove(token, &per_worker[s]))
    }

    /// The two-phase skeleton: prepare on **every replica of every
    /// shard concurrently** (shard `s`'s phase-1 request is built once
    /// by `encode_prepare` and the same encoded bytes are issued on
    /// each of its replicas' I/O slots), join, abort everywhere on any
    /// *shard-level* failure; then commit on every successfully
    /// prepared replica concurrently; then refresh the cluster view
    /// from the workers' manifests. Fan-out makes publish latency the
    /// slowest worker's prepare + commit instead of the sum over
    /// workers (`tests/net_e2e.rs` pins the overlap with a slow-worker
    /// handler).
    ///
    /// Replica semantics: a shard publishes if **at least one** of its
    /// replicas prepares and commits — a dead replica does not block
    /// the cluster (it is marked unhealthy and healed later from the
    /// publish log, see [`RemoteCluster::refresh`]); a shard with *no*
    /// live replica fails the publish all-or-nothing, exactly like a
    /// dead worker did pre-replication. A replica that prepares a
    /// *different* epoch than its peers is treated as failed (it has
    /// diverged; refresh's split-brain guard will keep it out of the
    /// read set).
    ///
    /// A failed commit RPC is **ambiguous** (the worker may or may not
    /// have published before the response was lost), so it is resolved
    /// rather than blindly retried: the worker's manifest is consulted —
    /// if it already serves the prepared epoch the commit landed and the
    /// lost response is forgotten; otherwise one explicit commit retry
    /// runs (covering mid-write transport failures, which the
    /// multiplexed pipeline deliberately never resends). A replica that
    /// still fails is marked unhealthy for the log-replay heal; only a
    /// shard whose *every* replica failed its commit surfaces an error
    /// (never masked by the follow-up refresh).
    fn publish<F>(&self, encode_prepare: F) -> Result<u64>
    where
        F: Fn(usize, u64) -> Encoded,
    {
        let token = self.token.fetch_add(1, Ordering::SeqCst) + 1;
        // Build each shard's phase-1 payload once; replicas of a shard
        // replay the identical bytes (and the publish log retains the
        // same `Arc`s for catch-up replay — no clone either way).
        let payloads: Vec<Arc<Encoded>> = (0..self.shards.len())
            .map(|s| Arc::new(encode_prepare(s, token)))
            .collect();
        // Phase 1: fan the prepares out to every replica, then join in
        // shard/replica order. `prepared[s][r]` records whether that
        // replica staged the publish.
        let prepares: Vec<Vec<Pending>> = self
            .shards
            .iter()
            .zip(&payloads)
            .map(|(set, payload)| {
                set.replicas
                    .iter()
                    .map(|replica| replica.slot.submit(Arc::clone(payload)))
                    .collect()
            })
            .collect();
        let mut next_epoch = None;
        let mut failure: Option<ClientError> = None;
        let mut prepared: Vec<Vec<bool>> = Vec::with_capacity(self.shards.len());
        for (s, (set, pendings)) in self.shards.iter().zip(prepares).enumerate() {
            let mut shard_ok = vec![false; set.replicas.len()];
            let mut shard_failure: Option<ClientError> = None;
            for (r, pending) in pendings.into_iter().enumerate() {
                match pending.join().and_then(to_prepared) {
                    Ok(epoch) => {
                        let expect = *next_epoch.get_or_insert(epoch);
                        if epoch == expect {
                            shard_ok[r] = true;
                        } else {
                            // Diverged replica: staged a different next
                            // epoch than its peers. Treat as failed and
                            // keep it out of the commit fan-out.
                            set.mark(r, false);
                            shard_failure.get_or_insert(ClientError::Protocol(format!(
                                "replica {} of shard {s} staged epoch {epoch}, peers staged \
                                 {expect} (diverged replica)",
                                set.replicas[r].addr()
                            )));
                        }
                    }
                    Err(e) => {
                        // Keep joining: the remaining prepares are
                        // already in flight and may have staged
                        // server-side. A transiently failed replica is
                        // routed around, not fatal for the shard.
                        set.mark(r, false);
                        shard_failure.get_or_insert(e);
                    }
                }
            }
            if !shard_ok.iter().any(|&ok| ok) {
                let e = shard_failure.expect("failed shard recorded an error");
                failure.get_or_insert(attribute(e, s));
            } else if let Some(e) = shard_failure {
                log::warn!(
                    "prepare of token {token} failed on a replica of shard {} ({e}); \
                     publishing through its peers, refresh() will heal it",
                    set.name()
                );
            }
            prepared.push(shard_ok);
        }
        if let Some(e) = failure {
            // A whole shard failed to stage: abort every replica of
            // every shard — every prepare was issued, and even a failed
            // one's staging is ambiguous (abort is token-checked and
            // idempotent, so this clears a possible orphan instead of
            // wedging all future publishes on Busy). Aborts fan out too.
            let aborts: Vec<Pending> = self
                .shards
                .iter()
                .flat_map(|set| {
                    set.replicas
                        .iter()
                        .map(|replica| replica.submit(Encoded::abort(token)))
                        .collect::<Vec<_>>()
                })
                .collect();
            for pending in aborts {
                let _ = pending.join();
            }
            return Err(e);
        }
        let next_epoch = next_epoch.expect("at least one replica prepared");
        // Record the publish in the catch-up log *before* the commit
        // phase: once any replica commits, a lagging peer must be able
        // to replay this entry (`refresh()`), and a log entry for a
        // publish that ends up fully failed is harmless (its token
        // commits as StalePrepare everywhere).
        {
            let mut log = self.publish_log.lock().unwrap();
            if log.back().is_some_and(|e| e.epoch == next_epoch) {
                // A retried publish targeting the same epoch supersedes
                // the failed attempt's entry.
                log.pop_back();
            }
            log.push_back(PublishLogEntry {
                token,
                epoch: next_epoch,
                prepares: payloads,
            });
            while log.len() > PUBLISH_LOG_CAP {
                log.pop_front();
            }
        }
        // Phase 2: fan the commits out to every prepared replica, then
        // join and resolve stragglers.
        let commits: Vec<Vec<Option<Pending>>> = self
            .shards
            .iter()
            .zip(&prepared)
            .map(|(set, shard_ok)| {
                set.replicas
                    .iter()
                    .zip(shard_ok)
                    .map(|(replica, &ok)| ok.then(|| replica.submit(Encoded::commit(token))))
                    .collect()
            })
            .collect();
        let mut commit_failure = None;
        for (s, (set, pendings)) in self.shards.iter().zip(commits).enumerate() {
            let mut committed = false;
            let mut shard_failure: Option<ClientError> = None;
            for (r, pending) in pendings.into_iter().enumerate() {
                let Some(pending) = pending else { continue };
                let replica = &set.replicas[r];
                match pending.join().and_then(to_committed) {
                    Ok(_) => committed = true,
                    Err(first) => {
                        // Ambiguous failure: check whether the commit
                        // landed before retrying explicitly.
                        let landed =
                            matches!(replica.manifest(), Ok((_, _, e)) if e == next_epoch);
                        if landed || replica.commit(token).is_ok() {
                            committed = true;
                        } else {
                            // The replica may still hold the staged
                            // preparation — the publish-log replay in
                            // refresh() heals it once reachable again.
                            set.mark(r, false);
                            log::warn!(
                                "commit of token {token} failed on replica {} of shard {s}: \
                                 {first}; refresh() will heal it once it is reachable",
                                replica.addr()
                            );
                            shard_failure.get_or_insert(first);
                        }
                    }
                }
            }
            // Keep committing the remaining shards even on failure: a
            // partial publish is worse than a completed one with one
            // reported failure.
            if !committed {
                let e = shard_failure.expect("uncommitted shard recorded an error");
                commit_failure.get_or_insert(attribute(e, s));
            }
        }
        // Refresh best-effort, but never let it mask a commit failure.
        let refreshed = self.refresh();
        if let Some(e) = commit_failure {
            return Err(e);
        }
        refreshed?;
        Ok(self.epoch())
    }

    /// Best-effort recovery for a publish whose commit phase partially
    /// failed (the failure log names the token): re-send `Commit`
    /// (`commit = true`) or `Abort` to **every replica of every
    /// worker** — both are idempotent worker-side — then refresh. This
    /// heals a replica that was unreachable during the commit phase and
    /// still holds the staged preparation (which otherwise answers
    /// `Busy` to every future publish until its process restarts).
    /// `refresh()` subsumes the commit direction via the publish log;
    /// this remains the explicit abort path and the operator-facing
    /// escape hatch.
    pub fn resolve_token(&self, token: u64, commit: bool) -> Result<()> {
        let _p = self.publish_lock.lock().unwrap();
        let mut first_failure = None;
        for set in &self.shards {
            for replica in &set.replicas {
                let res = if commit {
                    replica.commit(token).map(|_| ())
                } else {
                    replica.abort(token)
                };
                match res {
                    Ok(()) => {}
                    // Nothing staged under this token: already resolved.
                    Err(ClientError::Remote {
                        code: ErrorCode::StalePrepare,
                        ..
                    }) => {}
                    // Keep resolving the rest — an unreachable replica
                    // should not leave its peers wedged on Busy.
                    Err(e) => {
                        first_failure.get_or_insert(e);
                    }
                }
            }
        }
        if let Some(e) = first_failure {
            return Err(e);
        }
        self.refresh()
    }

    /// Re-probe every replica of every shard (concurrently), heal
    /// lagging replicas from the publish log, re-validate lockstep over
    /// the replicas that answer, re-mark replica health, and rebuild
    /// the scatter index for the (possibly shifted) layout.
    ///
    /// **Auto-heal**: a reachable replica lagging behind the lockstep
    /// target — it was dead or partitioned during one *or more*
    /// publishes — is caught up by replaying its missed `(prepare,
    /// commit)` pairs from the coordinator's publish log, in epoch
    /// order (see [`RemoteCluster::heal_from_log`]). This generalizes
    /// the earlier commit-retry heal from "lagging exactly one missed
    /// commit" to any lag the log still covers, and it is the reconnect
    /// half of failover: kill a replica, let its peers serve, restart
    /// it, and the next `refresh()` restores lockstep without operator
    /// intervention.
    ///
    /// **Split-brain guards** (never healed, always surfaced): a
    /// replica *ahead* of every epoch this coordinator published or
    /// observed means another coordinator has published through it;
    /// replicas at the lockstep epoch disagreeing on their row count
    /// means a replica serves different data under the same epoch
    /// number. Both refuse the view rather than silently serving mixed
    /// answers.
    pub fn refresh(&self) -> Result<()> {
        let mut probes = self.probe_replicas()?;
        // Split-brain guard #1: nobody may be ahead of this
        // coordinator's history.
        let expected = {
            let log = self.publish_log.lock().unwrap();
            self.state().epoch.max(log.back().map_or(0, |entry| entry.epoch))
        };
        for (s, (set, shard_probes)) in self.shards.iter().zip(&probes).enumerate() {
            for (r, probe) in shard_probes.iter().enumerate() {
                if let Some((_, e)) = probe {
                    if *e > expected {
                        return Err(ClientError::Protocol(format!(
                            "replica {} of shard {s} serves epoch {e}, ahead of every epoch \
                             this coordinator published or observed ({expected}) — refusing \
                             the split-brain view (did another coordinator publish?)",
                            set.replicas[r].addr()
                        )));
                    }
                }
            }
        }
        // The lockstep target is the furthest epoch any replica serves.
        let target = probes
            .iter()
            .flatten()
            .flatten()
            .map(|&(_, e)| e)
            .max()
            .expect("every shard probed at least one replica");
        if self.heal_from_log(&probes, target) {
            probes = self.probe_replicas()?;
        }
        // Per shard: the read set is the replicas at the target epoch.
        // Every shard needs at least one, and their row counts must
        // agree (identical data is what makes failover bit-exact).
        let mut lens = Vec::with_capacity(self.shards.len());
        for (s, (set, shard_probes)) in self.shards.iter().zip(&probes).enumerate() {
            let mut shard_len: Option<usize> = None;
            for (r, probe) in shard_probes.iter().enumerate() {
                let at_target = matches!(probe, Some((_, e)) if *e == target);
                set.mark(r, at_target);
                if !at_target {
                    continue;
                }
                let len = probe.expect("at_target implies Some").0;
                match shard_len {
                    None => shard_len = Some(len),
                    // Split-brain guard #2: same epoch, different data.
                    Some(want) if want != len => {
                        return Err(ClientError::Protocol(format!(
                            "replicas of shard {s} disagree at epoch {target}: {} serves \
                             {len} rows, a peer serves {want} — refusing the split-brain \
                             view (diverged replica)",
                            set.replicas[r].addr()
                        )));
                    }
                    _ => {}
                }
            }
            let Some(len) = shard_len else {
                let detail: Vec<String> = set
                    .replicas
                    .iter()
                    .zip(shard_probes)
                    .map(|(replica, probe)| match probe {
                        Some((_, e)) => format!("{} at epoch {e}", replica.addr()),
                        None => format!("{} unreachable", replica.addr()),
                    })
                    .collect();
                return Err(ClientError::Protocol(format!(
                    "workers out of lockstep: shard {s} has no replica at epoch {target} \
                     ({})",
                    detail.join(", ")
                )));
            };
            lens.push(len);
        }
        let index = Arc::new(Self::build_index(&self.shards, &lens));
        *self.state.write().unwrap() = Arc::new(ClusterState {
            lens,
            epoch: target,
            index,
        });
        Ok(())
    }

    /// Probe every replica of every shard concurrently with `Manifest`:
    /// `probes[s][r]` is `Some((len, epoch))` for a replica that
    /// answered (dimensionality validated against the cluster's),
    /// `None` for one that did not — which is marked unhealthy, not
    /// fatal. A shard with **no** reachable replica at all is an error:
    /// the cluster cannot serve without it.
    fn probe_replicas(&self) -> Result<Vec<Vec<Option<(usize, u64)>>>> {
        let in_flight: Vec<Vec<Pending>> = self
            .shards
            .iter()
            .map(|set| {
                set.replicas
                    .iter()
                    .map(|replica| replica.submit(Encoded::manifest()))
                    .collect()
            })
            .collect();
        let mut probes = Vec::with_capacity(self.shards.len());
        for (s, (set, pendings)) in self.shards.iter().zip(in_flight).enumerate() {
            let mut shard_probes = Vec::with_capacity(set.replicas.len());
            let mut last_err = None;
            for (r, pending) in pendings.into_iter().enumerate() {
                match pending.join().and_then(to_manifest) {
                    Ok((len, d, e)) => {
                        if d != self.dim {
                            return Err(ClientError::Protocol(format!(
                                "replica {} of shard {s} switched to dim {d}",
                                set.replicas[r].addr()
                            )));
                        }
                        shard_probes.push(Some((len, e)));
                    }
                    Err(e) => {
                        set.mark(r, false);
                        log::warn!(
                            "replica {} of shard {s} unreachable during refresh: {e}",
                            set.replicas[r].addr()
                        );
                        shard_probes.push(None);
                        last_err = Some(e);
                    }
                }
            }
            if shard_probes.iter().all(|p| p.is_none()) {
                let e = last_err.expect("unreachable shard recorded an error");
                return Err(attribute(e, s));
            }
            probes.push(shard_probes);
        }
        Ok(probes)
    }

    /// Replay missed publishes onto every reachable replica lagging
    /// behind `target`, in epoch order, from the publish log: first try
    /// the bare recorded `Commit` (a replica that staged but missed
    /// only the commit completes instantly); on `StalePrepare` — the
    /// staging is gone, i.e. the replica restarted — replay the
    /// recorded prepare payload and then commit. `Busy` during a
    /// replayed prepare means an orphaned staging under a different
    /// token blocks the slot: every logged token is aborted best-effort
    /// and the prepare retried once. Returns whether any replica
    /// accepted a replay (so the caller re-probes). A replica lagging
    /// deeper than the log reaches is logged with the resolution
    /// (restart it with current data) and skipped.
    fn heal_from_log(&self, probes: &[Vec<Option<(usize, u64)>>], target: u64) -> bool {
        let log = self.publish_log.lock().unwrap();
        let tokens: Vec<u64> = log.iter().map(|entry| entry.token).collect();
        let mut healed = false;
        for (s, (set, shard_probes)) in self.shards.iter().zip(probes).enumerate() {
            for (r, probe) in shard_probes.iter().enumerate() {
                let Some((_, at)) = *probe else { continue };
                if at >= target {
                    continue;
                }
                let entries: Vec<&PublishLogEntry> = log
                    .iter()
                    .filter(|entry| entry.epoch > at && entry.epoch <= target)
                    .collect();
                let contiguous = entries.first().is_some_and(|f| f.epoch == at + 1)
                    && entries.last().is_some_and(|l| l.epoch == target)
                    && entries.len() as u64 == target - at;
                if !contiguous {
                    log::warn!(
                        "replica {} of shard {s} lags at epoch {at}, beyond the publish \
                         log's reach (target {target}, log covers {} publishes); restart \
                         it with current data or re-drive the missed mutations",
                        set.replicas[r].addr(),
                        tokens.len()
                    );
                    continue;
                }
                if self.replay_entries(set, s, r, &entries, &tokens) {
                    healed = true;
                }
            }
        }
        healed
    }

    /// Replay each missed `(prepare, commit)` pair on one replica, in
    /// epoch order. Returns whether the replica accepted the complete
    /// replay (partial progress still helps — the next `refresh()`
    /// resumes from wherever the replica now stands).
    fn replay_entries(
        &self,
        set: &ReplicaSet,
        s: usize,
        r: usize,
        entries: &[&PublishLogEntry],
        tokens: &[u64],
    ) -> bool {
        let replica = &set.replicas[r];
        for entry in entries {
            let staged_commit = match replica.commit(entry.token) {
                Ok(_) => true,
                Err(ClientError::Remote {
                    code: ErrorCode::StalePrepare,
                    ..
                }) => false,
                Err(e) => {
                    log::warn!(
                        "heal of replica {} of shard {s} failed committing token {}: {e}",
                        replica.addr(),
                        entry.token
                    );
                    return false;
                }
            };
            if staged_commit {
                continue;
            }
            // The staging is gone (replica restarted): replay the
            // recorded prepare, then commit it.
            if !Self::replay_prepare(replica, entry, s, tokens) {
                return false;
            }
            if let Err(e) = replica.commit(entry.token) {
                log::warn!(
                    "heal of replica {} of shard {s} failed committing replayed token {}: {e}",
                    replica.addr(),
                    entry.token
                );
                return false;
            }
        }
        log::info!(
            "auto-healed replica {} of shard {s}: replayed {} missed publish(es) up to \
             epoch {}",
            replica.addr(),
            entries.len(),
            entries.last().map_or(0, |entry| entry.epoch)
        );
        true
    }

    /// Replay one recorded prepare payload on a replica, expecting it
    /// to stage exactly the entry's epoch. On `Busy` (an orphaned
    /// staging under another token holds the slot) every logged token
    /// is aborted best-effort and the prepare retried once.
    fn replay_prepare(
        replica: &RemoteShard,
        entry: &PublishLogEntry,
        s: usize,
        tokens: &[u64],
    ) -> bool {
        for attempt in 0..2 {
            let staged = replica
                .slot
                .submit(Arc::clone(&entry.prepares[s]))
                .join()
                .and_then(to_prepared);
            match staged {
                Ok(epoch) if epoch == entry.epoch => return true,
                Ok(epoch) => {
                    // The replica would stage a different epoch than
                    // this entry published: its state diverged from the
                    // log's idea of it. Undo and let the lockstep check
                    // report it.
                    log::warn!(
                        "replaying token {} on replica {} staged epoch {epoch}, wanted {}; \
                         aborting the replay",
                        entry.token,
                        replica.addr(),
                        entry.epoch
                    );
                    let _ = replica.abort(entry.token);
                    return false;
                }
                Err(ClientError::Remote {
                    code: ErrorCode::Busy,
                    ..
                }) if attempt == 0 => {
                    for &token in tokens {
                        let _ = replica.abort(token);
                    }
                }
                Err(e) => {
                    log::warn!(
                        "heal of replica {} failed replaying prepare of token {}: {e}",
                        replica.addr(),
                        entry.token
                    );
                    return false;
                }
            }
        }
        false
    }

    /// Merged telemetry from every **replica of every** worker:
    /// `GetMetrics` fanned out concurrently, snapshots folded with
    /// [`MetricsBlob::merge`] (sums counters, pools histogram buckets).
    /// Best-effort — a replica that fails to answer is logged and
    /// skipped rather than failing the scrape, so one sick worker
    /// cannot blind the monitoring for the rest of the cluster. The
    /// coordinator folds in its own replica-layer gauges:
    /// `replicas_total` / `replicas_healthy` (the `replica_health`
    /// roll-up) and `shard_failovers` (reads transparently re-routed).
    pub fn cluster_metrics(&self) -> MetricsBlob {
        let in_flight: Vec<(usize, &Arc<RemoteShard>, Pending)> = self
            .shards
            .iter()
            .enumerate()
            .flat_map(|(s, set)| {
                set.replicas
                    .iter()
                    .map(move |replica| (s, replica, replica.submit(Encoded::get_metrics())))
            })
            .collect();
        let mut merged = MetricsBlob::default();
        for (s, replica, pending) in in_flight {
            match pending.join() {
                Ok(WireResponse::Metrics(blob)) => merged.merge(&blob),
                Ok(other) => log::warn!(
                    "metrics scrape of replica {} of shard {s} answered unexpectedly: {:?}",
                    replica.addr(),
                    std::mem::discriminant(&other)
                ),
                Err(e) => log::warn!(
                    "metrics scrape of replica {} of shard {s} failed: {e}",
                    replica.addr()
                ),
            }
        }
        let total: u64 = self.shards.iter().map(|set| set.num_replicas() as u64).sum();
        let healthy: u64 = self
            .shards
            .iter()
            .map(|set| set.health().iter().filter(|&&h| h).count() as u64)
            .sum();
        merged.merge(&MetricsBlob {
            counters: vec![
                ("replicas_total".to_string(), total),
                ("replicas_healthy".to_string(), healthy),
                ("shard_failovers".to_string(), self.failovers()),
                ("shard_hedges".to_string(), self.hedges()),
            ],
            hists: vec![],
        });
        merged
    }
}

/// Per-request scoring budget over remote shards (mirror of
/// `Router::scorings`; `p_features` is the cluster's FMBE feature
/// count). Shared with `coordinator::ClusterBackend` so the cost table
/// lives once for all cluster-serving paths.
pub(crate) fn scorings_for(
    kind: EstimatorKind,
    k: usize,
    l: usize,
    n: usize,
    p_features: usize,
) -> usize {
    match kind {
        EstimatorKind::Exact => n,
        EstimatorKind::Uniform => l,
        EstimatorKind::Nmimps => k.min(n),
        EstimatorKind::Mimps | EstimatorKind::Mince => (k + l).min(n),
        EstimatorKind::Fmbe => p_features.min(n),
    }
}

/// [`Handler`] that serves `Estimate` / `EstimateBatch` from a
/// [`RemoteCluster`] — the partition server's backend when the category
/// set lives in shard worker processes instead of local memory.
pub struct ClusterHandler {
    cluster: Arc<RemoteCluster>,
    rng: Mutex<Rng>,
}

impl ClusterHandler {
    /// Serve estimation from `cluster`; `seed` drives the per-request
    /// sampling RNG forks.
    pub fn new(cluster: Arc<RemoteCluster>, seed: u64) -> ClusterHandler {
        ClusterHandler {
            cluster,
            rng: Mutex::new(Rng::seeded(seed ^ 0x5EED_0CEA)),
        }
    }

    fn estimate_block(
        &self,
        kind: EstimatorKind,
        k: usize,
        l: usize,
        precision: Precision,
        deadline_ns: u64,
        queries: &[Vec<f32>],
    ) -> WireResponse {
        let dim = self.cluster.dim();
        if let Some(q) = queries.iter().find(|q| q.len() != dim) {
            return WireResponse::Error {
                code: ErrorCode::DimMismatch,
                message: format!(
                    "query dimensionality {} != store dimensionality {dim}",
                    q.len()
                ),
            };
        }
        // This handler has no ingress queue, so there is no drain point
        // at which a queued deadline could be shed: execution starts
        // immediately and the budget is ignored (`deadline_ns` is
        // honored by the batcher when the cluster serves behind a
        // `PartitionService` — `zest-server --cluster`).
        let _ = deadline_ns;
        let started = Instant::now();
        // Fork a per-request RNG (held lock is momentary) so concurrent
        // requests never serialize on the scatter's wire round-trips;
        // non-sampling kinds skip the lock entirely.
        let mut rng = if matches!(
            kind,
            EstimatorKind::Mimps | EstimatorKind::Uniform | EstimatorKind::Mince
        ) {
            self.rng.lock().unwrap().fork()
        } else {
            Rng::seeded(0) // never drawn from
        };
        let answer = self
            .cluster
            .estimate_batch(kind, k, l, precision, queries, &mut rng, None);
        let exec_ns = started.elapsed().as_nanos() as u64;
        match answer {
            Ok(answer) => {
                // Epoch and scoring budget come from the same pinned
                // view that produced the answers.
                let scorings = scorings_for(
                    kind,
                    k,
                    l,
                    answer.len,
                    self.cluster.fmbe_config().p_features,
                ) as u64;
                let epoch = answer.epoch;
                WireResponse::Estimates(
                    answer
                        .zs
                        .into_iter()
                        .map(|z| wire::Estimate {
                            z,
                            kind,
                            epoch,
                            scorings,
                            queue_wait_ns: 0,
                            exec_ns,
                            // Shard workers hold no front-door cache;
                            // caching happens at the coordinator.
                            served_from_cache: false,
                        })
                        .collect(),
                )
            }
            Err(e) => {
                // Strip any shard attribution before dispatching on the
                // code so typed errors (`Busy`, `DimMismatch`, …) keep
                // their retry semantics over the wire; the attributed
                // rendering survives in the `Internal` message.
                let attributed = format!("remote scatter failed: {e}");
                match e.into_unattributed() {
                    ClientError::Remote { code, message } => WireResponse::Error { code, message },
                    _ => WireResponse::Error {
                        code: ErrorCode::Internal,
                        message: attributed,
                    },
                }
            }
        }
    }
}

impl Handler for ClusterHandler {
    fn handle(&self, req: WireRequest) -> WireResponse {
        match req {
            WireRequest::Ping => WireResponse::Pong,
            WireRequest::Manifest => WireResponse::Manifest {
                len: self.cluster.len() as u64,
                dim: self.cluster.dim() as u64,
                epoch: self.cluster.epoch(),
            },
            WireRequest::Estimate {
                kind,
                k,
                l,
                precision,
                deadline_ns,
                query,
            } => self.estimate_block(
                kind,
                k as usize,
                l as usize,
                precision,
                deadline_ns,
                std::slice::from_ref(&query),
            ),
            WireRequest::EstimateBatch {
                kind,
                k,
                l,
                precision,
                deadline_ns,
                queries,
            } => {
                self.estimate_block(kind, k as usize, l as usize, precision, deadline_ns, &queries)
            }
            // Scrape fans out to every worker and merges; the server
            // loop wrapping this handler folds its own net counters in
            // at the exposition layer.
            WireRequest::GetMetrics => WireResponse::Metrics(self.cluster.cluster_metrics()),
            _ => WireResponse::Error {
                code: ErrorCode::Unsupported,
                message: "shard-worker operation sent to a partition server".to_string(),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligned_split_lens_are_quad_aligned_and_cover() {
        for (n, s) in [(503usize, 4usize), (512, 4), (100, 3), (7, 2), (4, 9), (1, 3)] {
            let lens = aligned_split_lens(n, s);
            assert_eq!(lens.iter().sum::<usize>(), n, "n={n} s={s}: {lens:?}");
            assert!(lens.iter().all(|&l| l > 0), "n={n} s={s}: {lens:?}");
            for &l in &lens[..lens.len() - 1] {
                assert_eq!(l % 4, 0, "n={n} s={s}: {lens:?}");
            }
        }
        assert_eq!(aligned_split_lens(0, 3), Vec::<usize>::new());
    }

    #[test]
    fn aligned_split_tiles_the_store() {
        let s = crate::data::synth::generate(&crate::data::synth::SynthConfig {
            n: 103,
            d: 8,
            ..crate::data::synth::SynthConfig::tiny()
        });
        let blocks = aligned_split(&s, 3);
        let total: usize = blocks.iter().map(|b| b.len()).sum();
        assert_eq!(total, 103);
        let mut offset = 0usize;
        for b in &blocks {
            for r in 0..b.len() {
                assert_eq!(b.row(r), s.row(offset + r));
            }
            offset += b.len();
        }
    }

    #[test]
    fn scorings_mirror_router() {
        assert_eq!(scorings_for(EstimatorKind::Exact, 5, 5, 1000, 100), 1000);
        assert_eq!(scorings_for(EstimatorKind::Mimps, 50, 60, 1000, 100), 110);
        assert_eq!(scorings_for(EstimatorKind::Mince, 50, 60, 1000, 100), 110);
        assert_eq!(scorings_for(EstimatorKind::Nmimps, 2000, 0, 1000, 100), 1000);
        assert_eq!(scorings_for(EstimatorKind::Fmbe, 0, 0, 1000, 100), 100);
    }
}
