//! Readiness-driven network server: a fixed pool of reactor threads
//! multiplexing framed request/response traffic ([`super::wire`]) over
//! TCP or UDS.
//!
//! One thread accepts (bounded by [`ServerConfig::max_connections`] —
//! excess connections are answered with a `ConnLimit` error frame and
//! closed) and hands each accepted socket, switched to nonblocking, to
//! one of [`ServerConfig::reactor_threads`] reactor threads. Each
//! reactor owns a [`super::reactor::Poller`] and drives its share of
//! connections through per-connection read/write buffers and a
//! frame-assembly state machine: inbound bytes accumulate until a full
//! frame (header + payload) is present, decoded requests are
//! dispatched to a shared pool of [`ServerConfig::handler_threads`]
//! handler threads, and completed responses are routed back to the
//! owning reactor (wakeup pipe) which writes them out **in completion
//! order** — one connection can carry many overlapped RPCs, each
//! response echoing the `request_id` of the frame it answers.
//!
//! A malformed or truncated frame is answered with a `BadRequest` error
//! frame and the connection is closed — the server never panics on wire
//! input, and a panicking handler is caught and answered with an
//! `Internal` error. Read timeouts bound how long an *idle* connection
//! (no in-flight requests) can hold a slot. [`Server::shutdown`] stops
//! accepting, wakes every reactor through its wakeup pipe, flushes
//! in-flight responses, and joins every thread.
//!
//! Per-connection activity (accepts, rejections, frames, wire errors)
//! feeds the shared [`ServiceMetrics`] so network serving shows up next
//! to batching/queueing in one `MetricsSnapshot`.

use super::reactor::{Event, Poller, Waker};
use super::wire::{self, ErrorCode, Request, Response};
use super::{Addr, Listener, Stream};
use crate::coordinator::{EstimateSpec, PartitionService, Precision, ServiceMetrics, SubmitError};
use crate::estimators::EstimatorKind;
use std::collections::VecDeque;
use std::io::{Read, Write};
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Serves decoded requests. Implementations: [`ServiceHandler`]
/// (partition server), [`super::shard::ShardWorker`] (shard worker),
/// [`super::remote::ClusterHandler`] (partition server over remote
/// shards).
pub trait Handler: Send + Sync + 'static {
    /// Answer one decoded request. Called concurrently from the
    /// server's handler pool — including for overlapped requests from
    /// the *same* connection; a panic is caught by the server and
    /// answered with an `Internal` error frame.
    fn handle(&self, req: Request) -> Response;
}

/// Server knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Concurrent connections served; further connections get `ConnLimit`.
    pub max_connections: usize,
    /// Per-connection read timeout; an idle connection (no in-flight
    /// requests, nothing buffered) past it is closed, freeing its slot.
    /// `None` keeps idle connections forever.
    pub read_timeout: Option<Duration>,
    /// Reactor (event-loop) threads multiplexing the connections. A
    /// handful suffices for hundreds of connections; clamped to ≥ 1.
    pub reactor_threads: usize,
    /// Handler threads executing decoded requests (these may block in
    /// the service/store, so they are separate from the reactors).
    /// Also the cap on overlapped in-flight requests making progress at
    /// once. Clamped to ≥ 1.
    pub handler_threads: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_connections: 256,
            read_timeout: Some(Duration::from_secs(30)),
            reactor_threads: 2,
            handler_threads: 16,
        }
    }
}

/// Wakeup-pipe token inside each reactor (connection slots count up
/// from 0).
const WAKER_TOKEN: u64 = u64::MAX;

/// A finished handler invocation on its way back to the reactor that
/// owns the connection.
struct Completion {
    slot: usize,
    gen: u64,
    request_id: u64,
    payload: Vec<u8>,
    /// Server-side timings to annex onto the response frame; `Some`
    /// exactly when the request frame carried [`wire::FLAG_TRACED`].
    times: Option<wire::WireTimes>,
}

/// One decoded request on its way to the handler pool.
struct HandlerJob {
    reactor: usize,
    slot: usize,
    gen: u64,
    request_id: u64,
    req: Request,
    /// The request frame asked for a [`wire::WireTimes`] annex.
    traced: bool,
    /// When the frame was peeled off the read buffer — the handler
    /// thread measures its pickup lag (handler-pool queueing) from it.
    parsed_at: Instant,
}

/// What other threads push into a reactor between polls.
#[derive(Default)]
struct Inbox {
    conns: Vec<Stream>,
    completions: Vec<Completion>,
}

/// The cross-thread half of one reactor: its wakeup pipe plus the
/// mailbox the accept thread and handler pool feed.
struct ReactorShared {
    waker: Waker,
    inbox: Mutex<Inbox>,
}

impl ReactorShared {
    fn push_conn(&self, s: Stream) {
        self.inbox.lock().unwrap().conns.push(s);
        self.waker.wake();
    }

    fn push_completion(&self, c: Completion) {
        self.inbox.lock().unwrap().completions.push(c);
        self.waker.wake();
    }
}

/// One connection owned by a reactor.
struct Conn {
    stream: Stream,
    /// Accumulated unparsed inbound bytes (the frame-assembly buffer).
    buf: Vec<u8>,
    /// Outbound frames not yet fully written, oldest first.
    out: VecDeque<Vec<u8>>,
    /// Write offset into `out.front()`.
    out_pos: usize,
    /// Requests dispatched to the handler pool, not yet answered.
    in_flight: usize,
    /// Peer sent EOF (or the read half failed): no more requests, but
    /// in-flight responses still drain.
    read_closed: bool,
    /// Close as soon as the outbound buffer drains (error-frame path).
    closing: bool,
    /// Interests currently registered with the poller.
    interest: (bool, bool),
    last_activity: Instant,
}

impl Conn {
    fn wants(&self) -> (bool, bool) {
        (!self.read_closed && !self.closing, !self.out.is_empty())
    }

    /// Done: nothing buffered in either direction and nothing pending.
    fn drained(&self) -> bool {
        self.in_flight == 0 && self.out.is_empty()
    }

    fn queue_frame(&mut self, request_id: u64, payload: &[u8]) {
        self.queue_frame_timed(request_id, payload, None);
    }

    /// [`Conn::queue_frame`] with an optional [`wire::WireTimes`] annex:
    /// the annex bytes ride inside the payload length and the header
    /// carries [`wire::FLAG_TRACED`] so the client peels them back off
    /// (mirrors [`wire::write_response_timed`], assembled into the
    /// nonblocking outbound buffer instead of a blocking writer).
    fn queue_frame_timed(
        &mut self,
        request_id: u64,
        payload: &[u8],
        times: Option<wire::WireTimes>,
    ) {
        let annex = times.map(|t| t.encode());
        let annex_len = annex.as_ref().map_or(0, |a| a.len());
        let flags = if annex.is_some() { wire::FLAG_TRACED } else { 0 };
        let mut frame = Vec::with_capacity(wire::HEADER_LEN + payload.len() + annex_len);
        frame.extend_from_slice(&wire::encode_header_flagged(
            request_id,
            payload.len() + annex_len,
            flags,
        ));
        frame.extend_from_slice(payload);
        if let Some(a) = annex {
            frame.extend_from_slice(&a);
        }
        self.out.push_back(frame);
    }
}

/// A running server; dropping it without [`Server::shutdown`] detaches
/// the threads (the pool keeps serving until the process exits).
pub struct Server {
    addr: Addr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    reactors: Vec<Arc<ReactorShared>>,
    reactor_threads: Vec<std::thread::JoinHandle<()>>,
    handler_tx: Option<mpsc::Sender<HandlerJob>>,
    handler_threads: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` and start serving `handler` on a reactor pool.
    pub fn serve(
        addr: &Addr,
        handler: Arc<dyn Handler>,
        cfg: ServerConfig,
        metrics: Arc<ServiceMetrics>,
    ) -> anyhow::Result<Server> {
        let listener = Listener::bind(addr).map_err(|e| anyhow::anyhow!("bind {addr}: {e}"))?;
        let bound = listener.bound_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let active = Arc::new(AtomicUsize::new(0));

        // Reactor pool: poller + waker per thread, created up front so
        // the accept thread can address them immediately.
        let n_reactors = cfg.reactor_threads.max(1);
        let mut reactors = Vec::with_capacity(n_reactors);
        let mut pollers = Vec::with_capacity(n_reactors);
        for _ in 0..n_reactors {
            let poller = Poller::new().map_err(|e| anyhow::anyhow!("poller: {e}"))?;
            let waker =
                Waker::new(&poller, WAKER_TOKEN).map_err(|e| anyhow::anyhow!("waker: {e}"))?;
            reactors.push(Arc::new(ReactorShared {
                waker,
                inbox: Mutex::new(Inbox::default()),
            }));
            pollers.push(poller);
        }

        // Handler pool: a shared receiver; jobs carry their way home.
        let (handler_tx, handler_rx) = mpsc::channel::<HandlerJob>();
        let handler_rx = Arc::new(Mutex::new(handler_rx));
        let mut handler_threads = Vec::new();
        for i in 0..cfg.handler_threads.max(1) {
            let rx = handler_rx.clone();
            let handler = handler.clone();
            let reactors: Vec<Arc<ReactorShared>> = reactors.clone();
            let metrics = metrics.clone();
            handler_threads.push(
                std::thread::Builder::new()
                    .name(format!("zest-net-handler-{i}"))
                    .spawn(move || loop {
                        let job = match rx.lock().unwrap().recv() {
                            Ok(j) => j,
                            Err(_) => break,
                        };
                        let picked_up = Instant::now();
                        let resp = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            handler.handle(job.req)
                        }))
                        .unwrap_or_else(|_| Response::Error {
                            code: ErrorCode::Internal,
                            message: "handler panicked".to_string(),
                        });
                        // Handle lag = time the decoded frame sat in the
                        // handler-pool queue; exec = time inside the
                        // handler. Both always feed the histograms; they
                        // ride back on the wire only when asked for.
                        let lag = picked_up.saturating_duration_since(job.parsed_at);
                        let exec = picked_up.elapsed();
                        metrics.on_net_handle(lag, exec);
                        let times = job.traced.then(|| wire::WireTimes {
                            handle_lag_ns: lag.as_nanos() as u64,
                            exec_ns: exec.as_nanos() as u64,
                        });
                        reactors[job.reactor].push_completion(Completion {
                            slot: job.slot,
                            gen: job.gen,
                            request_id: job.request_id,
                            payload: resp.encode(),
                            times,
                        });
                    })
                    .expect("spawn handler thread"),
            );
        }

        let mut reactor_threads = Vec::with_capacity(n_reactors);
        for (i, poller) in pollers.into_iter().enumerate() {
            let shared = reactors[i].clone();
            let stop = stop.clone();
            let active = active.clone();
            let metrics = metrics.clone();
            let tx = handler_tx.clone();
            let read_timeout = cfg.read_timeout;
            reactor_threads.push(
                std::thread::Builder::new()
                    .name(format!("zest-net-reactor-{i}"))
                    .spawn(move || {
                        Reactor {
                            id: i,
                            poller,
                            shared,
                            stop,
                            active,
                            metrics,
                            handler_tx: tx,
                            read_timeout,
                            slots: Vec::new(),
                        }
                        .run()
                    })
                    .expect("spawn reactor thread"),
            );
        }

        let accept_thread = {
            let stop = stop.clone();
            let reactors: Vec<Arc<ReactorShared>> = reactors.clone();
            let bound_str = bound.to_string();
            std::thread::Builder::new()
                .name("zest-net-accept".into())
                .spawn(move || {
                    log::info!("serving on {bound_str} ({} reactors)", reactors.len());
                    let mut next = 0usize;
                    loop {
                        let stream = match listener.accept() {
                            Ok(s) => s,
                            Err(e) => {
                                if stop.load(Ordering::SeqCst) {
                                    break;
                                }
                                log::warn!("accept failed: {e}");
                                continue;
                            }
                        };
                        if stop.load(Ordering::SeqCst) {
                            break; // the shutdown wake-up connection
                        }
                        if active.load(Ordering::SeqCst) >= cfg.max_connections {
                            metrics.on_conn_rejected();
                            let mut stream = stream;
                            // Connection-level error: request id 0.
                            let _ = wire::write_response(
                                &mut stream,
                                0,
                                &Response::Error {
                                    code: ErrorCode::ConnLimit,
                                    message: format!(
                                        "connection limit {} reached",
                                        cfg.max_connections
                                    ),
                                },
                            );
                            continue; // drop closes it
                        }
                        if stream.set_nonblocking(true).is_err() {
                            continue; // drop a socket we cannot drive
                        }
                        metrics.on_conn_open();
                        active.fetch_add(1, Ordering::SeqCst);
                        reactors[next % reactors.len()].push_conn(stream);
                        next = next.wrapping_add(1);
                    }
                })
                .expect("spawn accept thread")
        };

        Ok(Server {
            addr: bound,
            stop,
            accept_thread: Some(accept_thread),
            reactors,
            reactor_threads,
            handler_tx: Some(handler_tx),
            handler_threads,
        })
    }

    /// The actually bound address (resolves `:0` TCP ports).
    pub fn local_addr(&self) -> &Addr {
        &self.addr
    }

    /// Stop accepting, wake every reactor through its wakeup pipe, and
    /// join every thread. In-flight requests finish and their responses
    /// are flushed before the reactors close their connections, so
    /// shutdown does not wait out read timeouts — and terminates even
    /// with `read_timeout: None`.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection.
        let _ = Stream::connect(&self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        // Reactors drain in-flight work (handler completions keep
        // waking them), then close their connections and exit.
        for r in &self.reactors {
            r.waker.wake();
        }
        for t in self.reactor_threads.drain(..) {
            let _ = t.join();
        }
        // With the reactors gone every job sender is dropped; the
        // handler pool drains and exits.
        self.handler_tx.take();
        for t in self.handler_threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// The per-thread event loop: owns its poller and its connections.
struct Reactor {
    id: usize,
    poller: Poller,
    shared: Arc<ReactorShared>,
    stop: Arc<AtomicBool>,
    active: Arc<AtomicUsize>,
    metrics: Arc<ServiceMetrics>,
    handler_tx: mpsc::Sender<HandlerJob>,
    read_timeout: Option<Duration>,
    /// Connection slots; the index is the poller token. `gen` guards
    /// against completions for a closed connection landing on a new one
    /// that reused the slot.
    slots: Vec<(u64, Option<Conn>)>,
}

impl Reactor {
    fn run(mut self) {
        let mut events: Vec<Event> = Vec::new();
        let mut scratch = vec![0u8; 64 << 10];
        // Poll granularity: fine enough to sweep read timeouts, coarse
        // enough to stay idle-cheap.
        let tick = match self.read_timeout {
            Some(t) => (t / 4).clamp(Duration::from_millis(10), Duration::from_millis(500)),
            None => Duration::from_millis(500),
        };
        loop {
            self.drain_inbox();
            if self.stop.load(Ordering::SeqCst) && self.quiesced() {
                break;
            }
            if self.poller.wait(&mut events, Some(tick)).is_err() {
                // A failing poller means the loop can no longer make
                // progress; bail out rather than spin.
                break;
            }
            let mut saw_wake = false;
            for i in 0..events.len() {
                let ev = events[i];
                if ev.token == WAKER_TOKEN {
                    saw_wake = true;
                    continue;
                }
                let slot = ev.token as usize;
                if ev.readable {
                    self.handle_readable(slot, &mut scratch);
                }
                if ev.writable {
                    self.handle_writable(slot);
                }
                self.update_interest(slot);
            }
            if saw_wake {
                self.shared.waker.drain();
                self.drain_inbox();
            }
            self.sweep_idle();
        }
        // Stop: every remaining connection is drained; close them all.
        for slot in 0..self.slots.len() {
            self.close(slot);
        }
    }

    /// True once shutdown can proceed: nothing queued for this reactor
    /// and every connection has flushed its in-flight work.
    fn quiesced(&self) -> bool {
        let inbox = self.shared.inbox.lock().unwrap();
        inbox.conns.is_empty()
            && inbox.completions.is_empty()
            && self.slots.iter().all(|(_, c)| match c {
                Some(conn) => conn.drained(),
                None => true,
            })
    }

    fn drain_inbox(&mut self) {
        let Inbox { conns, completions } = {
            let mut inbox = self.shared.inbox.lock().unwrap();
            std::mem::take(&mut *inbox)
        };
        for stream in conns {
            self.add_conn(stream);
        }
        for c in completions {
            self.deliver(c);
        }
    }

    fn add_conn(&mut self, stream: Stream) {
        let slot = match self.slots.iter().position(|(_, c)| c.is_none()) {
            Some(i) => i,
            None => {
                self.slots.push((0, None));
                self.slots.len() - 1
            }
        };
        if self
            .poller
            .register(stream.as_raw_fd(), slot as u64, true, false)
            .is_err()
        {
            // Cannot drive this socket: count it closed again.
            self.active.fetch_sub(1, Ordering::SeqCst);
            self.metrics.on_conn_close();
            return;
        }
        self.slots[slot].0 += 1;
        self.slots[slot].1 = Some(Conn {
            stream,
            buf: Vec::new(),
            out: VecDeque::new(),
            out_pos: 0,
            in_flight: 0,
            read_closed: false,
            closing: false,
            interest: (true, false),
            last_activity: Instant::now(),
        });
    }

    /// Route one handler completion to its connection (dropped if the
    /// connection died while the handler ran), queue the response frame
    /// and flush opportunistically.
    fn deliver(&mut self, c: Completion) {
        let Some((gen, Some(conn))) = self.slots.get_mut(c.slot).map(|(g, c)| (*g, c.as_mut()))
        else {
            return;
        };
        if gen != c.gen {
            return;
        }
        conn.in_flight -= 1;
        conn.queue_frame_timed(c.request_id, &c.payload, c.times);
        self.metrics.on_frame_out();
        self.handle_writable(c.slot);
        self.update_interest(c.slot);
    }

    fn handle_readable(&mut self, slot: usize, scratch: &mut [u8]) {
        let Some((_, Some(conn))) = self.slots.get_mut(slot) else {
            return;
        };
        if conn.read_closed || conn.closing {
            return;
        }
        loop {
            match conn.stream.read(scratch) {
                Ok(0) => {
                    conn.read_closed = true;
                    break;
                }
                Ok(n) => {
                    conn.buf.extend_from_slice(&scratch[..n]);
                    conn.last_activity = Instant::now();
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    // Transport failure: nothing sensible left to send.
                    self.metrics.on_wire_error();
                    self.close(slot);
                    return;
                }
            }
        }
        self.parse_frames(slot);
        // Peer EOF with a partial frame still buffered: truncated input
        // is malformed — answer a connection-level (id 0) error before
        // closing, like any other unframeable byte stream.
        let truncated = match self.slots.get_mut(slot) {
            Some((_, Some(conn)))
                if conn.read_closed && !conn.closing && !conn.buf.is_empty() =>
            {
                let resp = bad_request(&wire::WireError::Malformed(
                    "connection closed mid-frame".to_string(),
                ));
                conn.queue_frame(0, &resp.encode());
                conn.closing = true;
                true
            }
            _ => false,
        };
        if truncated {
            self.metrics.on_wire_error();
            self.handle_writable(slot);
            return;
        }
        self.try_close_if_done(slot);
    }

    /// The frame-assembly state machine: peel complete frames off the
    /// inbound buffer, dispatch decoded requests, answer malformed
    /// input with a `BadRequest` frame and close.
    fn parse_frames(&mut self, slot: usize) {
        loop {
            let Some((gen, Some(conn))) = self.slots.get_mut(slot).map(|(g, c)| (*g, c.as_mut()))
            else {
                return;
            };
            if conn.closing || conn.buf.len() < wire::HEADER_LEN {
                return;
            }
            let mut header = [0u8; wire::HEADER_LEN];
            header.copy_from_slice(&conn.buf[..wire::HEADER_LEN]);
            let (request_id, flags, len) = match wire::decode_header(&header) {
                Ok(h) => h,
                Err(e) => {
                    // Unframeable input: the id cannot be trusted, so
                    // the error frame is connection-level (id 0).
                    self.metrics.on_wire_error();
                    conn.queue_frame(0, &bad_request(&e).encode());
                    conn.closing = true;
                    return;
                }
            };
            if conn.buf.len() < wire::HEADER_LEN + len {
                return; // wait for the rest of the payload
            }
            let payload: Vec<u8> = conn
                .buf
                .drain(..wire::HEADER_LEN + len)
                .skip(wire::HEADER_LEN)
                .collect();
            match Request::decode(&payload) {
                Ok(req) => {
                    self.metrics.on_frame_in();
                    conn.in_flight += 1;
                    let job = HandlerJob {
                        reactor: self.id,
                        slot,
                        gen,
                        request_id,
                        req,
                        traced: flags & wire::FLAG_TRACED != 0,
                        parsed_at: Instant::now(),
                    };
                    if self.handler_tx.send(job).is_err() {
                        // Shutdown raced us: answer directly.
                        let (_, Some(conn)) = &mut self.slots[slot] else {
                            return;
                        };
                        conn.in_flight -= 1;
                        conn.queue_frame(
                            request_id,
                            &Response::Error {
                                code: ErrorCode::Closed,
                                message: "server shutting down".to_string(),
                            }
                            .encode(),
                        );
                        conn.closing = true;
                        return;
                    }
                }
                Err(e) => {
                    self.metrics.on_wire_error();
                    conn.queue_frame(request_id, &bad_request(&e).encode());
                    conn.closing = true;
                    return;
                }
            }
        }
    }

    fn handle_writable(&mut self, slot: usize) {
        let Some((_, Some(conn))) = self.slots.get_mut(slot) else {
            return;
        };
        while let Some(front) = conn.out.front() {
            match conn.stream.write(&front[conn.out_pos..]) {
                Ok(0) => {
                    self.metrics.on_wire_error();
                    self.close(slot);
                    return;
                }
                Ok(n) => {
                    conn.out_pos += n;
                    conn.last_activity = Instant::now();
                    if conn.out_pos == front.len() {
                        conn.out.pop_front();
                        conn.out_pos = 0;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.metrics.on_wire_error();
                    self.close(slot);
                    return;
                }
            }
        }
        self.try_close_if_done(slot);
    }

    /// Close once a connection has nothing left to do: the error-frame
    /// path (`closing`) and the peer-EOF path both wait for in-flight
    /// responses to flush first.
    fn try_close_if_done(&mut self, slot: usize) {
        let Some((_, Some(conn))) = self.slots.get(slot).map(|(g, c)| (g, c.as_ref())) else {
            return;
        };
        if (conn.closing || conn.read_closed) && conn.drained() {
            self.close(slot);
        }
    }

    /// Re-sync poller interest with what the connection currently needs
    /// (read while open, write while the outbound buffer is nonempty).
    fn update_interest(&mut self, slot: usize) {
        let Some((_, Some(conn))) = self.slots.get_mut(slot) else {
            return;
        };
        let want = conn.wants();
        if want != conn.interest {
            let fd = conn.stream.as_raw_fd();
            if self.poller.reregister(fd, slot as u64, want.0, want.1).is_ok() {
                conn.interest = want;
            }
        }
    }

    /// Idle connections (no buffered or in-flight work) past the read
    /// timeout are closed, freeing their slots.
    fn sweep_idle(&mut self) {
        let Some(timeout) = self.read_timeout else {
            return;
        };
        let now = Instant::now();
        for slot in 0..self.slots.len() {
            let stale = match &self.slots[slot].1 {
                Some(c) => c.drained() && now.duration_since(c.last_activity) > timeout,
                None => false,
            };
            if stale {
                self.close(slot);
            }
        }
    }

    fn close(&mut self, slot: usize) {
        let Some((_, conn_opt)) = self.slots.get_mut(slot) else {
            return;
        };
        if let Some(conn) = conn_opt.take() {
            let _ = self.poller.deregister(conn.stream.as_raw_fd());
            drop(conn);
            self.active.fetch_sub(1, Ordering::SeqCst);
            self.metrics.on_conn_close();
        }
    }
}

fn bad_request(e: &wire::WireError) -> Response {
    Response::Error {
        code: ErrorCode::BadRequest,
        message: e.to_string(),
    }
}

/// [`Handler`] that fronts an in-process [`PartitionService`]: the
/// partition server. `Estimate` / `EstimateBatch` go through the
/// service's bounded queue, batcher and workers exactly like in-process
/// submissions; `Manifest` reports the served store.
pub struct ServiceHandler {
    svc: Arc<PartitionService>,
}

impl ServiceHandler {
    /// Front the given service (shares its metrics sink with the server
    /// via [`PartitionService::metrics_handle`]).
    pub fn new(svc: Arc<PartitionService>) -> ServiceHandler {
        ServiceHandler { svc }
    }

    fn submit_error(e: SubmitError) -> Response {
        let code = match e {
            SubmitError::Overloaded => ErrorCode::Overloaded,
            SubmitError::Closed => ErrorCode::Closed,
            SubmitError::DeadlineExceeded => ErrorCode::DeadlineExceeded,
            SubmitError::DimMismatch { .. } => ErrorCode::DimMismatch,
            SubmitError::KOutOfRange { .. } | SubmitError::LOutOfRange { .. } => {
                ErrorCode::BadRequest
            }
        };
        Response::Error {
            code,
            message: e.to_string(),
        }
    }

    /// The wire deadline budget as an absolute instant (one clock read
    /// per request frame, shared by every query of a batch).
    fn wire_deadline(deadline_ns: u64) -> Option<Instant> {
        (deadline_ns > 0).then(|| Instant::now() + Duration::from_nanos(deadline_ns))
    }

    /// The wire request fields as an in-process [`EstimateSpec`].
    fn to_spec(
        query: Vec<f32>,
        kind: EstimatorKind,
        k: u64,
        l: u64,
        precision: Precision,
        deadline: Option<Instant>,
    ) -> EstimateSpec {
        let mut spec = EstimateSpec::new(query)
            .kind(kind)
            .k(k as usize)
            .l(l as usize)
            .precision(precision);
        if let Some(d) = deadline {
            spec = spec.deadline(d);
        }
        spec
    }

    fn to_wire(r: crate::coordinator::Response) -> wire::Estimate {
        wire::Estimate {
            z: r.z,
            kind: r.kind,
            epoch: r.epoch,
            scorings: r.scorings as u64,
            queue_wait_ns: r.queue_wait.as_nanos() as u64,
            exec_ns: r.exec_time.as_nanos() as u64,
            served_from_cache: r.served_from_cache,
        }
    }
}

impl Handler for ServiceHandler {
    fn handle(&self, req: Request) -> Response {
        match req {
            Request::Ping => Response::Pong,
            Request::Manifest => {
                let (len, epoch) = self.svc.serving_info();
                Response::Manifest {
                    len: len as u64,
                    dim: self.svc.dim() as u64,
                    epoch,
                }
            }
            Request::Estimate {
                kind,
                k,
                l,
                precision,
                deadline_ns,
                query,
            } => {
                let deadline = Self::wire_deadline(deadline_ns);
                match self
                    .svc
                    .estimate(Self::to_spec(query, kind, k, l, precision, deadline))
                {
                    Ok(r) => Response::Estimates(vec![Self::to_wire(r)]),
                    Err(e) => Self::submit_error(e),
                }
            }
            Request::EstimateBatch {
                kind,
                k,
                l,
                precision,
                deadline_ns,
                queries,
            } => {
                // Submit the whole block, then collect in order — the
                // service's batcher coalesces them into shared
                // estimate_batch groups. One absolute deadline for the
                // whole block (single clock read), so every query shares
                // the wire budget exactly.
                let deadline = Self::wire_deadline(deadline_ns);
                let mut receivers = Vec::with_capacity(queries.len());
                for query in queries {
                    match self
                        .svc
                        .submit(Self::to_spec(query, kind, k, l, precision, deadline))
                    {
                        Ok(rx) => receivers.push(rx),
                        Err(e) => return Self::submit_error(e),
                    }
                }
                let mut items = Vec::with_capacity(receivers.len());
                for rx in receivers {
                    match rx.recv() {
                        Ok(r) => items.push(Self::to_wire(r)),
                        // A dropped reply channel is either the batcher's
                        // drain-time deadline shed or a shutdown/backend
                        // failure — the deadline tells which.
                        Err(_) => {
                            let expired = deadline.is_some_and(|d| Instant::now() >= d);
                            return Self::submit_error(if expired {
                                SubmitError::DeadlineExceeded
                            } else {
                                SubmitError::Closed
                            });
                        }
                    }
                }
                Response::Estimates(items)
            }
            Request::GetMetrics => {
                // One scrape answers for the whole serving stack: the
                // coordinator's counters/histograms (which already
                // include the wire-level counters — the server shares
                // the service's metrics sink) merged with whatever the
                // backend contributes (a cluster backend fans the same
                // scrape out to its workers).
                let mut blob = self.svc.metrics_handle().blob();
                if let Some(backend) = self.svc.backend().metrics() {
                    blob.merge(&backend);
                }
                Response::Metrics(blob)
            }
            // Shard-worker operations don't belong on a partition server.
            Request::TopK { .. }
            | Request::ExpSumChain { .. }
            | Request::ExpSumChainBatch { .. }
            | Request::ExpSumPart { .. }
            | Request::ScoreIds { .. }
            | Request::PrepareAdd { .. }
            | Request::PrepareRemove { .. }
            | Request::Commit { .. }
            | Request::Abort { .. }
            | Request::FitFmbe { .. } => Response::Error {
                code: ErrorCode::Unsupported,
                message: "shard-worker operation sent to a partition server".to_string(),
            },
        }
    }
}
