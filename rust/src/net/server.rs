//! Blocking network server: a small accept loop serving framed
//! request/response traffic ([`super::wire`]) over TCP or UDS.
//!
//! One thread accepts; each connection is served by its own thread
//! (bounded by [`ServerConfig::max_connections`] — excess connections
//! are answered with a `ConnLimit` error frame and closed). Connections are
//! request-per-frame, pipelined sequentially; a malformed or truncated
//! frame is answered with a `BadRequest` error frame and the connection
//! is closed — the server never panics on wire input, and a panicking
//! handler is caught and answered with an `Internal` error. Read
//! timeouts bound how long an idle connection can hold a slot.
//! [`Server::shutdown`] stops accepting, wakes the accept loop, and
//! joins every connection thread.
//!
//! Per-connection activity (accepts, rejections, frames, wire errors)
//! feeds the shared [`ServiceMetrics`] so network serving shows up next
//! to batching/queueing in one `MetricsSnapshot`.

use super::wire::{self, ErrorCode, Request, Response};
use super::{Addr, Listener, Stream};
use crate::coordinator::{EstimateSpec, PartitionService, Precision, ServiceMetrics, SubmitError};
use crate::estimators::EstimatorKind;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Serves decoded requests. Implementations: [`ServiceHandler`]
/// (partition server), [`super::shard::ShardWorker`] (shard worker),
/// [`super::remote::ClusterHandler`] (partition server over remote
/// shards).
pub trait Handler: Send + Sync + 'static {
    /// Answer one decoded request. Called concurrently from every
    /// connection thread; a panic is caught by the server and answered
    /// with an `Internal` error frame.
    fn handle(&self, req: Request) -> Response;
}

/// Server knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Concurrent connections served; further connections get `ConnLimit`.
    pub max_connections: usize,
    /// Per-connection read timeout; an idle connection past it is
    /// closed (freeing its slot). `None` blocks forever.
    pub read_timeout: Option<Duration>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_connections: 256,
            read_timeout: Some(Duration::from_secs(30)),
        }
    }
}

/// One tracked connection: its serving thread plus a second handle to
/// the stream so shutdown can wake a blocked read.
type ConnEntry = (std::thread::JoinHandle<()>, Option<Stream>);

/// A running server; dropping it without [`Server::shutdown`] detaches
/// the threads (they exit as clients disconnect or time out).
pub struct Server {
    addr: Addr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<ConnEntry>>>,
}

impl Server {
    /// Bind `addr` and start serving `handler`.
    pub fn serve(
        addr: &Addr,
        handler: Arc<dyn Handler>,
        cfg: ServerConfig,
        metrics: Arc<ServiceMetrics>,
    ) -> anyhow::Result<Server> {
        let listener = Listener::bind(addr)
            .map_err(|e| anyhow::anyhow!("bind {addr}: {e}"))?;
        let bound = listener.bound_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<ConnEntry>>> = Arc::new(Mutex::new(Vec::new()));
        let active = Arc::new(AtomicUsize::new(0));

        let accept_thread = {
            let stop = stop.clone();
            let conns = conns.clone();
            let bound_str = bound.to_string();
            std::thread::Builder::new()
                .name("zest-net-accept".into())
                .spawn(move || {
                    log::info!("serving on {bound_str}");
                    loop {
                        let stream = match listener.accept() {
                            Ok(s) => s,
                            Err(e) => {
                                if stop.load(Ordering::SeqCst) {
                                    break;
                                }
                                log::warn!("accept failed: {e}");
                                continue;
                            }
                        };
                        if stop.load(Ordering::SeqCst) {
                            break; // the shutdown wake-up connection
                        }
                        if active.load(Ordering::SeqCst) >= cfg.max_connections {
                            metrics.on_conn_rejected();
                            let mut stream = stream;
                            let _ = wire::write_response(
                                &mut stream,
                                &Response::Error {
                                    code: ErrorCode::ConnLimit,
                                    message: format!(
                                        "connection limit {} reached",
                                        cfg.max_connections
                                    ),
                                },
                            );
                            continue; // drop closes it
                        }
                        metrics.on_conn_open();
                        active.fetch_add(1, Ordering::SeqCst);
                        // Second handle to the stream so shutdown can
                        // wake this connection's blocked read.
                        let waker = stream.try_clone().ok();
                        let handler = handler.clone();
                        let metrics = metrics.clone();
                        let active = active.clone();
                        let stop = stop.clone();
                        let read_timeout = cfg.read_timeout;
                        let join = std::thread::Builder::new()
                            .name("zest-net-conn".into())
                            .spawn(move || {
                                serve_conn(stream, handler, read_timeout, &metrics, &stop);
                                active.fetch_sub(1, Ordering::SeqCst);
                                metrics.on_conn_close();
                            })
                            .expect("spawn connection thread");
                        let mut guard = conns.lock().unwrap();
                        // Reap finished threads so the vector stays
                        // bounded on long-lived servers.
                        guard.retain(|(h, _)| !h.is_finished());
                        guard.push((join, waker));
                    }
                })
                .expect("spawn accept thread")
        };

        Ok(Server {
            addr: bound,
            stop,
            accept_thread: Some(accept_thread),
            conns,
        })
    }

    /// The actually bound address (resolves `:0` TCP ports).
    pub fn local_addr(&self) -> &Addr {
        &self.addr
    }

    /// Stop accepting, wake the accept loop, and join every thread.
    /// In-flight connections finish the request they are handling;
    /// connections blocked in a read are woken by shutting the read
    /// half of their stream (clean EOF), so shutdown does not wait out
    /// read timeouts — and terminates even with `read_timeout: None`.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection.
        let _ = Stream::connect(&self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        let entries: Vec<ConnEntry> = std::mem::take(&mut *self.conns.lock().unwrap());
        for (join, waker) in entries {
            if let Some(w) = &waker {
                let _ = w.shutdown_read();
            }
            let _ = join.join();
        }
    }
}

/// Serve one connection: read frames until EOF, error, timeout or stop.
fn serve_conn(
    mut stream: Stream,
    handler: Arc<dyn Handler>,
    read_timeout: Option<Duration>,
    metrics: &ServiceMetrics,
    stop: &AtomicBool,
) {
    let _ = stream.set_read_timeout(read_timeout);
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let req = match wire::read_request(&mut stream) {
            Ok(Some(req)) => req,
            Ok(None) => break, // clean disconnect
            Err(wire::WireError::Io(e))
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                break; // idle past the read timeout — free the slot
            }
            Err(e) => {
                // Malformed/truncated frame (or transport failure):
                // answer with an error frame (best effort) and close.
                metrics.on_wire_error();
                let _ = wire::write_response(
                    &mut stream,
                    &Response::Error {
                        code: ErrorCode::BadRequest,
                        message: e.to_string(),
                    },
                );
                break;
            }
        };
        metrics.on_frame_in();
        let resp = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| handler.handle(req)))
            .unwrap_or_else(|_| Response::Error {
                code: ErrorCode::Internal,
                message: "handler panicked".to_string(),
            });
        match wire::write_response(&mut stream, &resp) {
            Ok(()) => metrics.on_frame_out(),
            Err(_) => {
                metrics.on_wire_error();
                break;
            }
        }
    }
}

/// [`Handler`] that fronts an in-process [`PartitionService`]: the
/// partition server. `Estimate` / `EstimateBatch` go through the
/// service's bounded queue, batcher and workers exactly like in-process
/// submissions; `Manifest` reports the served store.
pub struct ServiceHandler {
    svc: Arc<PartitionService>,
}

impl ServiceHandler {
    /// Front the given service (shares its metrics sink with the server
    /// via [`PartitionService::metrics_handle`]).
    pub fn new(svc: Arc<PartitionService>) -> ServiceHandler {
        ServiceHandler { svc }
    }

    fn submit_error(e: SubmitError) -> Response {
        let code = match e {
            SubmitError::Overloaded => ErrorCode::Overloaded,
            SubmitError::Closed => ErrorCode::Closed,
            SubmitError::DeadlineExceeded => ErrorCode::DeadlineExceeded,
            SubmitError::DimMismatch { .. } => ErrorCode::DimMismatch,
        };
        Response::Error {
            code,
            message: e.to_string(),
        }
    }

    /// The wire deadline budget as an absolute instant (one clock read
    /// per request frame, shared by every query of a batch).
    fn wire_deadline(deadline_ns: u64) -> Option<Instant> {
        (deadline_ns > 0).then(|| Instant::now() + Duration::from_nanos(deadline_ns))
    }

    /// The wire request fields as an in-process [`EstimateSpec`].
    fn to_spec(
        query: Vec<f32>,
        kind: EstimatorKind,
        k: u64,
        l: u64,
        precision: Precision,
        deadline: Option<Instant>,
    ) -> EstimateSpec {
        let mut spec = EstimateSpec::new(query)
            .kind(kind)
            .k(k as usize)
            .l(l as usize)
            .precision(precision);
        if let Some(d) = deadline {
            spec = spec.deadline(d);
        }
        spec
    }

    fn to_wire(r: crate::coordinator::Response) -> wire::Estimate {
        wire::Estimate {
            z: r.z,
            kind: r.kind,
            epoch: r.epoch,
            scorings: r.scorings as u64,
            queue_wait_ns: r.queue_wait.as_nanos() as u64,
            exec_ns: r.exec_time.as_nanos() as u64,
        }
    }
}

impl Handler for ServiceHandler {
    fn handle(&self, req: Request) -> Response {
        match req {
            Request::Ping => Response::Pong,
            Request::Manifest => {
                let (len, epoch) = self.svc.serving_info();
                Response::Manifest {
                    len: len as u64,
                    dim: self.svc.dim() as u64,
                    epoch,
                }
            }
            Request::Estimate {
                kind,
                k,
                l,
                precision,
                deadline_ns,
                query,
            } => {
                let deadline = Self::wire_deadline(deadline_ns);
                match self
                    .svc
                    .estimate(Self::to_spec(query, kind, k, l, precision, deadline))
                {
                    Ok(r) => Response::Estimates(vec![Self::to_wire(r)]),
                    Err(e) => Self::submit_error(e),
                }
            }
            Request::EstimateBatch {
                kind,
                k,
                l,
                precision,
                deadline_ns,
                queries,
            } => {
                // Submit the whole block, then collect in order — the
                // service's batcher coalesces them into shared
                // estimate_batch groups. One absolute deadline for the
                // whole block (single clock read), so every query shares
                // the wire budget exactly.
                let deadline = Self::wire_deadline(deadline_ns);
                let mut receivers = Vec::with_capacity(queries.len());
                for query in queries {
                    match self
                        .svc
                        .submit(Self::to_spec(query, kind, k, l, precision, deadline))
                    {
                        Ok(rx) => receivers.push(rx),
                        Err(e) => return Self::submit_error(e),
                    }
                }
                let mut items = Vec::with_capacity(receivers.len());
                for rx in receivers {
                    match rx.recv() {
                        Ok(r) => items.push(Self::to_wire(r)),
                        // A dropped reply channel is either the batcher's
                        // drain-time deadline shed or a shutdown/backend
                        // failure — the deadline tells which.
                        Err(_) => {
                            let expired =
                                deadline.is_some_and(|d| Instant::now() >= d);
                            return Self::submit_error(if expired {
                                SubmitError::DeadlineExceeded
                            } else {
                                SubmitError::Closed
                            });
                        }
                    }
                }
                Response::Estimates(items)
            }
            // Shard-worker operations don't belong on a partition server.
            Request::TopK { .. }
            | Request::ExpSumChain { .. }
            | Request::ExpSumChainBatch { .. }
            | Request::ExpSumPart { .. }
            | Request::ScoreIds { .. }
            | Request::PrepareAdd { .. }
            | Request::PrepareRemove { .. }
            | Request::Commit { .. }
            | Request::Abort { .. }
            | Request::FitFmbe { .. } => Response::Error {
                code: ErrorCode::Unsupported,
                message: "shard-worker operation sent to a partition server".to_string(),
            },
        }
    }
}
