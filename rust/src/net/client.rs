//! Connection-pooling client for the partition server.
//!
//! [`PartitionClient::estimate`] / [`PartitionClient::estimate_batch`]
//! mirror the in-process [`crate::coordinator::PartitionService`] API —
//! the same [`EstimateSpec`] request builder in (precision mode and
//! deadline included; the deadline ships as a relative budget so clocks
//! never need to agree), the same
//! [`crate::coordinator::Response`] out — so a
//! caller can swap between in-process and over-the-wire serving without
//! touching its own code. Idle connections are pooled (up to
//! [`ClientConfig::max_idle`]); a call that finds the pool empty opens a
//! fresh connection, and a call that trips over a stale pooled
//! connection (server restarted, idle timeout) retries once on a fresh
//! one before giving up.
//!
//! The shared [`Pool`] also backs the remote-shard handles
//! ([`super::remote::RemoteShard`]), whose hot paths serialize borrowed
//! payloads through [`Pool::call_encoded`] +
//! [`wire::Encoded`](super::wire::Encoded) instead of cloning into
//! owned [`WireRequest`] values.

use super::wire::{self, ErrorCode, Request as WireRequest, Response as WireResponse};
use super::{Addr, Stream};
use crate::coordinator::{EstimateSpec, Response};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Client knobs.
#[derive(Clone, Debug)]
pub struct ClientConfig {
    /// Idle connections kept for reuse.
    pub max_idle: usize,
    /// Per-call read timeout (covers the server's whole queue + exec
    /// time for the call). `None` blocks forever.
    pub read_timeout: Option<Duration>,
}

impl Default for ClientConfig {
    fn default() -> Self {
        ClientConfig {
            max_idle: 4,
            read_timeout: Some(Duration::from_secs(60)),
        }
    }
}

impl ClientConfig {
    /// Pool sizing for `sessions` concurrent user sessions sharing one
    /// client (the load-generator shape: thousands of simulated users
    /// multiplexed over a bounded session-thread pool). Keeps one idle
    /// connection per session so a full-rate burst never churns
    /// connects, capped so a misconfigured run can't exhaust fds.
    pub fn for_sessions(sessions: usize) -> Self {
        ClientConfig {
            max_idle: sessions.clamp(4, 1024),
            ..ClientConfig::default()
        }
    }
}

/// Client-side failure.
#[derive(Debug)]
pub enum ClientError {
    /// Transport or codec failure.
    Wire(wire::WireError),
    /// The server answered with an error frame.
    Remote { code: ErrorCode, message: String },
    /// The server answered with an unexpected response variant.
    Protocol(String),
    /// The server hung up between request and response.
    ConnectionClosed,
    /// The transport to the server died mid-stream (read error, timeout
    /// with calls outstanding, codec failure on a response). Distinct
    /// from [`ClientError::Protocol`] so failover layers can treat it
    /// as transient: the *connection* failed, not the request.
    ConnectionLost(String),
    /// A cluster fan-out failure attributed to one worker shard — the
    /// wrapper [`super::remote::RemoteCluster`] puts around per-worker
    /// errors so metrics (and operators) can name the failing shard.
    Shard {
        /// Worker index within the cluster's shard order.
        shard: usize,
        /// The underlying failure.
        source: Box<ClientError>,
    },
}

impl ClientError {
    /// The worker index this failure is attributed to, if any (set by
    /// the cluster fan-out paths in [`super::remote`]).
    pub fn shard(&self) -> Option<usize> {
        match self {
            ClientError::Shard { shard, .. } => Some(*shard),
            _ => None,
        }
    }

    /// The error with any shard attribution stripped (for callers that
    /// dispatch on the underlying `Remote` code).
    pub fn into_unattributed(self) -> ClientError {
        match self {
            ClientError::Shard { source, .. } => source.into_unattributed(),
            other => other,
        }
    }

    /// Whether this failure says "the *connection or worker* failed",
    /// not "the *request* is wrong" — the retryable-vs-fatal split the
    /// replica failover in [`super::remote`] dispatches on. Transient:
    /// transport/codec failures ([`ClientError::Wire`]), a hung-up or
    /// mid-stream-dead connection ([`ClientError::ConnectionClosed`],
    /// [`ClientError::ConnectionLost`]), and a `ConnLimit` rejection
    /// (the server turned the connection away before reading anything).
    /// Everything else — every other [`ClientError::Remote`] code
    /// (`Busy`, `StalePrepare`, `BadRequest`, `DimMismatch`,
    /// `Unsupported`, `DeadlineExceeded`, `Internal`) and
    /// [`ClientError::Protocol`] — describes the request or the
    /// server's answer and would fail identically on any replica, so a
    /// blind retry is never safe. Failover re-submission itself is only
    /// safe for idempotent reads; the publish path never routes through
    /// it (`Commit` in particular is never blindly re-sent — see
    /// [`resend_safe`] and the mux pipeline's provably-unsent rule).
    pub fn is_transient(&self) -> bool {
        match self {
            ClientError::Wire(_)
            | ClientError::ConnectionClosed
            | ClientError::ConnectionLost(_) => true,
            ClientError::Remote { code, .. } => *code == ErrorCode::ConnLimit,
            ClientError::Protocol(_) => false,
            ClientError::Shard { source, .. } => source.is_transient(),
        }
    }
}

/// Whether a failed roundtrip of `req` may be re-sent blindly on a
/// fresh connection. `Commit` is the one wire request that is **never**
/// resend-safe: the worker may have published the staged epoch before
/// the response was lost, and a second `Commit` racing a later publish
/// under the same token could double-execute. Everything else is either
/// a pure read or idempotent worker-side (`Prepare*` restages under the
/// same token, `Abort` is a token-checked no-op when nothing matches).
pub fn resend_safe(req: &WireRequest) -> bool {
    !matches!(req, WireRequest::Commit { .. })
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Wire(e) => write!(f, "wire: {e}"),
            ClientError::Remote { code, message } => write!(f, "remote {code:?}: {message}"),
            ClientError::Protocol(m) => write!(f, "protocol: {m}"),
            ClientError::ConnectionClosed => write!(f, "connection closed mid-call"),
            ClientError::ConnectionLost(m) => write!(f, "connection lost: {m}"),
            ClientError::Shard { shard, source } => write!(f, "worker {shard}: {source}"),
        }
    }
}

impl std::error::Error for ClientError {}

impl From<wire::WireError> for ClientError {
    fn from(e: wire::WireError) -> Self {
        ClientError::Wire(e)
    }
}

/// Client-level result alias.
pub type Result<T> = std::result::Result<T, ClientError>;

/// A pool of idle connections to one address, with a call-level
/// request/response roundtrip. Shared by [`PartitionClient`] and the
/// remote-shard handles ([`super::remote::RemoteShard`]).
pub struct Pool {
    addr: Addr,
    cfg: ClientConfig,
    idle: Mutex<Vec<Stream>>,
    /// Wire v3 request-id source (ids start at 1; 0 is reserved for
    /// connection-level server frames).
    next_id: AtomicU64,
}

impl Pool {
    /// A pool with no connections yet (they open lazily per call).
    pub fn new(addr: Addr, cfg: ClientConfig) -> Pool {
        Pool {
            addr,
            cfg,
            idle: Mutex::new(Vec::new()),
            next_id: AtomicU64::new(1),
        }
    }

    /// The address every pooled connection targets.
    pub fn addr(&self) -> &Addr {
        &self.addr
    }

    /// One request/response roundtrip from an owned [`WireRequest`] —
    /// encodes and delegates to [`Pool::call_encoded`]. Non-idempotent
    /// requests (`Commit` — the worker may have published before the
    /// response was lost) are **never** re-sent: a failed roundtrip
    /// surfaces as an error instead of a silent double-send.
    pub fn call(&self, req: &WireRequest) -> Result<WireResponse> {
        self.call_encoded(&req.encode(), resend_safe(req))
    }

    /// One request/response roundtrip from pre-encoded payload bytes
    /// (the borrowed-encode fast path; build `payload` with
    /// [`wire::Encoded`](super::wire::Encoded)). Pooled connections get
    /// one retry on a fresh connection when `resend_safe` (covers the
    /// server having dropped an idle connection); fresh-connection
    /// failures are returned as-is. An error frame from the server
    /// keeps the connection pooled (the stream stays frame-aligned) —
    /// except `ConnLimit`, which the server writes right before closing;
    /// transport failures drop the stream.
    pub fn call_encoded(&self, payload: &[u8], resend_safe: bool) -> Result<WireResponse> {
        if let Some(stream) = self.idle.lock().unwrap().pop() {
            match self.roundtrip(stream, payload) {
                Ok((stream, resp)) => {
                    self.pool_unless_closing(stream, &resp);
                    return Ok(resp);
                }
                Err(_) if resend_safe => { /* fall through to a fresh connection */ }
                Err(e) => return Err(e),
            }
        }
        let stream = Stream::connect(&self.addr).map_err(wire::WireError::Io)?;
        let _ = stream.set_read_timeout(self.cfg.read_timeout);
        let (stream, resp) = self.roundtrip(stream, payload)?;
        self.pool_unless_closing(stream, &resp);
        Ok(resp)
    }

    /// Keep the stream for reuse unless the server is about to close it
    /// (a `ConnLimit` rejection is written right before the drop —
    /// handler-level errors, `Busy` included, keep the connection open).
    fn pool_unless_closing(&self, stream: Stream, resp: &WireResponse) {
        if matches!(
            resp,
            WireResponse::Error {
                code: wire::ErrorCode::ConnLimit,
                ..
            }
        ) {
            return;
        }
        self.put_back(stream);
    }

    /// One tagged request/response exchange. Pooled connections are
    /// strictly one-call-at-a-time, so the response must echo the
    /// request id just sent — anything else is a protocol error. The
    /// exception is a connection-level error frame (id 0), which the
    /// server emits before it has read any request (`ConnLimit`).
    fn roundtrip(&self, mut stream: Stream, payload: &[u8]) -> Result<(Stream, WireResponse)> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        wire::write_frame(&mut stream, id, payload)?;
        match wire::read_response(&mut stream)? {
            Some((got, resp)) if got == id => Ok((stream, resp)),
            Some((0, resp @ WireResponse::Error { .. })) => Ok((stream, resp)),
            Some((got, _)) => Err(ClientError::Protocol(format!(
                "response tagged {got} on a call tagged {id}"
            ))),
            None => Err(ClientError::ConnectionClosed),
        }
    }

    fn put_back(&self, stream: Stream) {
        let mut idle = self.idle.lock().unwrap();
        if idle.len() < self.cfg.max_idle {
            idle.push(stream);
        }
    }
}

/// Turn an error frame into a typed [`ClientError::Remote`].
pub(crate) fn remote_err(code: ErrorCode, message: String) -> ClientError {
    ClientError::Remote { code, message }
}

/// Network client mirroring the in-process service API.
pub struct PartitionClient {
    pool: Pool,
}

impl PartitionClient {
    /// Connect to a partition server and verify liveness with a ping.
    pub fn connect(addr: Addr, cfg: ClientConfig) -> Result<PartitionClient> {
        let client = PartitionClient {
            pool: Pool::new(addr, cfg),
        };
        match client.pool.call(&WireRequest::Ping)? {
            WireResponse::Pong => Ok(client),
            WireResponse::Error { code, message } => Err(remote_err(code, message)),
            other => Err(ClientError::Protocol(format!(
                "ping answered with {other:?}"
            ))),
        }
    }

    /// The server's merged telemetry snapshot ([`WireRequest::GetMetrics`]):
    /// service counters plus histogram percentiles, with every shard
    /// worker's own snapshot folded in when the server fronts a
    /// cluster. `zest-top` polls this; `--metrics-listen` serves the
    /// same blob as Prometheus text.
    pub fn get_metrics(&self) -> Result<crate::obs::MetricsBlob> {
        match self.pool.call(&WireRequest::GetMetrics)? {
            WireResponse::Metrics(blob) => Ok(blob),
            WireResponse::Error { code, message } => Err(remote_err(code, message)),
            other => Err(ClientError::Protocol(format!(
                "get_metrics answered with {other:?}"
            ))),
        }
    }

    /// `(categories, dim, epoch)` the server currently serves.
    pub fn manifest(&self) -> Result<(usize, usize, u64)> {
        match self.pool.call(&WireRequest::Manifest)? {
            WireResponse::Manifest { len, dim, epoch } => Ok((len as usize, dim as usize, epoch)),
            WireResponse::Error { code, message } => Err(remote_err(code, message)),
            other => Err(ClientError::Protocol(format!(
                "manifest answered with {other:?}"
            ))),
        }
    }

    /// Submit one estimation and wait — the wire mirror of
    /// [`crate::coordinator::PartitionService::estimate`]. The spec's
    /// deadline is shipped as the **remaining** budget at send time; a
    /// spec already expired fails fast with a
    /// [`wire::ErrorCode::DeadlineExceeded`] remote error without a
    /// round-trip.
    pub fn estimate(&self, spec: EstimateSpec) -> Result<Response> {
        let deadline_ns = remaining_budget_ns(spec.deadline)?;
        let wire_req = WireRequest::Estimate {
            kind: spec.kind,
            k: spec.k as u64,
            l: spec.l as u64,
            precision: spec.precision,
            deadline_ns,
            query: spec.query,
        };
        match self.pool.call(&wire_req)? {
            WireResponse::Estimates(items) if items.len() == 1 => {
                Ok(to_response(items.into_iter().next().unwrap()))
            }
            WireResponse::Error { code, message } => Err(remote_err(code, message)),
            other => Err(ClientError::Protocol(format!(
                "estimate answered with {other:?}"
            ))),
        }
    }

    /// Estimate a whole query block sharing `template`'s parameters
    /// (kind, k, l, precision, deadline — `template.query` is unused;
    /// build one with [`EstimateSpec::template`]) in one wire call —
    /// the server coalesces it into shared `estimate_batch` groups, so
    /// the wire overhead is paid once per block instead of per query.
    pub fn estimate_batch(
        &self,
        template: &EstimateSpec,
        queries: Vec<Vec<f32>>,
    ) -> Result<Vec<Response>> {
        let n = queries.len();
        if n == 0 {
            return Ok(vec![]);
        }
        // The wire query block is (count, dim, flat floats): a ragged
        // batch would silently re-slice into different vectors on the
        // server, so reject it here.
        let d = queries[0].len();
        if let Some(bad) = queries.iter().find(|q| q.len() != d) {
            return Err(ClientError::Protocol(format!(
                "ragged batch: query of dimensionality {} next to {d}",
                bad.len()
            )));
        }
        let deadline_ns = remaining_budget_ns(template.deadline)?;
        let wire_req = WireRequest::EstimateBatch {
            kind: template.kind,
            k: template.k as u64,
            l: template.l as u64,
            precision: template.precision,
            deadline_ns,
            queries,
        };
        match self.pool.call(&wire_req)? {
            WireResponse::Estimates(items) if items.len() == n => {
                Ok(items.into_iter().map(to_response).collect())
            }
            WireResponse::Estimates(items) => Err(ClientError::Protocol(format!(
                "batch of {n} answered with {} estimates",
                items.len()
            ))),
            WireResponse::Error { code, message } => Err(remote_err(code, message)),
            other => Err(ClientError::Protocol(format!(
                "estimate_batch answered with {other:?}"
            ))),
        }
    }
}

/// The wire deadline budget for `deadline`: 0 when unset, the remaining
/// nanoseconds otherwise. An already-expired deadline is a typed error
/// — the request would only be shed server-side anyway.
fn remaining_budget_ns(deadline: Option<Instant>) -> Result<u64> {
    let Some(d) = deadline else { return Ok(0) };
    let remaining = d.saturating_duration_since(Instant::now());
    if remaining.is_zero() {
        return Err(remote_err(
            ErrorCode::DeadlineExceeded,
            "deadline expired before the request was sent".to_string(),
        ));
    }
    // A deadline can never ship as "0 = none": the minimum budget is 1ns.
    Ok((remaining.as_nanos() as u64).max(1))
}

fn to_response(e: wire::Estimate) -> Response {
    Response {
        z: e.z,
        kind: e.kind,
        epoch: e.epoch,
        queue_wait: Duration::from_nanos(e.queue_wait_ns),
        exec_time: Duration::from_nanos(e.exec_ns),
        scorings: e.scorings as usize,
        served_from_cache: e.served_from_cache,
    }
}
