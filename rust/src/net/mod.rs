//! Network serving layer: the cross-process seam around the coordinator.
//!
//! Five pieces:
//!
//! * [`wire`] — length-prefixed, versioned, hand-rolled little-endian
//!   framing codec for requests, responses, `Hit` batches, shard
//!   manifests and the two-phase epoch-publish handshake. Since wire
//!   **v3** every frame header carries a `request_id: u64`, so one
//!   connection multiplexes many overlapped RPCs and responses may
//!   return out of request order.
//! * [`reactor`] — the readiness shim: a hand-rolled `mio`-style
//!   [`reactor::Poller`] (epoll on Linux, kqueue on macOS/BSD via raw
//!   syscalls — no tokio, no external crates) plus a pipe-backed
//!   [`reactor::Waker`] for cross-thread wakeups and graceful shutdown.
//! * [`server`] — a readiness-driven [`server::Server`] exposing any
//!   [`server::Handler`] over TCP or Unix domain sockets: a fixed pool
//!   of reactor threads multiplexes all connections through
//!   nonblocking sockets, per-connection read/write buffers and a
//!   frame-assembly state machine, dispatching decoded requests to a
//!   handler pool and writing responses back in completion order (the
//!   request id keeps them attributable). Connection limits,
//!   per-connection read timeouts, graceful shutdown and per-connection
//!   metrics feeding [`crate::coordinator::ServiceMetrics`] are
//!   preserved from the blocking implementation.
//! * [`client`] — [`client::PartitionClient`], a connection-pooling
//!   client whose `estimate` / `estimate_batch` mirror the in-process
//!   [`crate::coordinator::PartitionService`] API.
//! * [`shard`] + [`remote`] — the cross-process shard seam:
//!   [`shard::ShardWorker`] serves one shard's store behind the wire
//!   ops (`TopK`, chained exp-sums, tail scoring, FMBE fits,
//!   prepare/commit), and [`remote::RemoteShardIndex`] /
//!   [`remote::RemoteCluster`] compose S worker processes back into a
//!   [`crate::mips::sharded::ShardedIndex`] scatter with the existing
//!   `hit_cmp` merge — N beyond one process' memory, with **every**
//!   estimator family served remotely. Each worker handle owns a
//!   multiplexed submission pipeline (one connection, many in-flight
//!   request ids), so cluster-wide operations (publishes, tail scoring,
//!   FMBE fits, refreshes) and concurrent batches genuinely overlap and
//!   cost the slowest worker, not the sum. Epoch swaps become a
//!   two-phase publish (prepare on all workers, then commit) through
//!   [`crate::store::SnapshotHandle`]'s `prepare_*`/`commit` split.
//!
//! Addresses are written `tcp://host:port` or `unix:///path/to.sock`
//! ([`Addr::parse`]); both transports speak the same frames. The wire
//! format is specified in `docs/WIRE.md`; the crate-wide serving
//! architecture (in-process vs remote request flow, the publish
//! protocol's failure states) in `ARCHITECTURE.md`.

// Every public item of the serving seam carries its invariants (epoch
// lockstep, Busy semantics, pool reuse) in its docs; keep it that way.
#![warn(missing_docs)]

pub mod client;
pub mod reactor;
pub mod remote;
pub mod server;
pub mod shard;
pub mod wire;

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::{AsRawFd, RawFd};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::time::Duration;

/// A serving endpoint: TCP host:port or a Unix-domain socket path.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Addr {
    /// `host:port`, e.g. `127.0.0.1:7071`.
    Tcp(String),
    /// Filesystem socket path (Unix only).
    #[cfg(unix)]
    Unix(std::path::PathBuf),
}

impl Addr {
    /// Parse `tcp://host:port` or `unix:///path`. A bare string
    /// containing `/` is taken as a socket path, otherwise as
    /// `host:port`.
    pub fn parse(s: &str) -> anyhow::Result<Addr> {
        if let Some(rest) = s.strip_prefix("tcp://") {
            return Ok(Addr::Tcp(rest.to_string()));
        }
        if let Some(rest) = s.strip_prefix("unix://") {
            #[cfg(unix)]
            return Ok(Addr::Unix(rest.into()));
            #[cfg(not(unix))]
            anyhow::bail!("unix sockets unavailable on this platform: {rest}");
        }
        if s.contains('/') {
            #[cfg(unix)]
            return Ok(Addr::Unix(s.into()));
            #[cfg(not(unix))]
            anyhow::bail!("unix sockets unavailable on this platform: {s}");
        }
        if s.contains(':') {
            return Ok(Addr::Tcp(s.to_string()));
        }
        anyhow::bail!("unparseable address {s:?} (want tcp://host:port or unix:///path)")
    }
}

/// Parse a worker-cluster listing into replica groups: `,` separates
/// shards, `|` separates the replicas of one shard. Each element obeys
/// [`Addr::parse`]. `w0,w1` (two single-replica shards) and
/// `w0a|w0b,w1a|w1b` (two shards × two replicas) are both valid — this
/// is the grammar `zest-server --cluster` / `--workers` accepts.
pub fn parse_worker_groups(list: &str) -> anyhow::Result<Vec<Vec<Addr>>> {
    let mut groups = Vec::new();
    for (s, group) in list.split(',').enumerate() {
        let mut replicas = Vec::new();
        for part in group.split('|') {
            let part = part.trim();
            if part.is_empty() {
                anyhow::bail!("empty address in replica group {s} of {list:?}");
            }
            replicas.push(Addr::parse(part)?);
        }
        groups.push(replicas);
    }
    Ok(groups)
}

impl std::fmt::Display for Addr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Addr::Tcp(hp) => write!(f, "tcp://{hp}"),
            #[cfg(unix)]
            Addr::Unix(p) => write!(f, "unix://{}", p.display()),
        }
    }
}

/// One connected byte stream over either transport.
pub enum Stream {
    /// A connected TCP socket.
    Tcp(TcpStream),
    /// A connected Unix-domain socket.
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    /// Connect to `addr` (blocking).
    pub fn connect(addr: &Addr) -> std::io::Result<Stream> {
        match addr {
            Addr::Tcp(hp) => TcpStream::connect(hp.as_str()).map(Stream::Tcp),
            #[cfg(unix)]
            Addr::Unix(p) => UnixStream::connect(p).map(Stream::Unix),
        }
    }

    /// Bound read timeout (None = block forever).
    pub fn set_read_timeout(&self, t: Option<Duration>) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(t),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_read_timeout(t),
        }
    }

    /// A second handle to the same connection (for out-of-band wakeups).
    pub fn try_clone(&self) -> std::io::Result<Stream> {
        match self {
            Stream::Tcp(s) => s.try_clone().map(Stream::Tcp),
            #[cfg(unix)]
            Stream::Unix(s) => s.try_clone().map(Stream::Unix),
        }
    }

    /// Shut down the read half: a thread blocked in `read` wakes with a
    /// clean EOF while in-flight writes still drain (how the
    /// multiplexed remote pipeline unblocks its reader thread during
    /// shutdown).
    pub fn shutdown_read(&self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.shutdown(std::net::Shutdown::Read),
            #[cfg(unix)]
            Stream::Unix(s) => s.shutdown(std::net::Shutdown::Read),
        }
    }

    /// Toggle nonblocking mode (the reactor server drives every
    /// accepted connection nonblocking).
    pub fn set_nonblocking(&self, nonblocking: bool) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_nonblocking(nonblocking),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_nonblocking(nonblocking),
        }
    }
}

impl AsRawFd for Stream {
    fn as_raw_fd(&self) -> RawFd {
        match self {
            Stream::Tcp(s) => s.as_raw_fd(),
            #[cfg(unix)]
            Stream::Unix(s) => s.as_raw_fd(),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// A bound listening socket over either transport. Unix sockets unlink
/// their path on bind (stale socket files from a previous run) and on
/// drop.
pub enum Listener {
    /// A bound TCP listener.
    Tcp(TcpListener),
    /// A bound Unix-domain listener plus the socket path it unlinks on
    /// drop.
    #[cfg(unix)]
    Unix {
        /// The bound listener.
        listener: UnixListener,
        /// Socket path (removed on bind of a stale file, and on drop).
        path: std::path::PathBuf,
    },
}

impl Listener {
    /// Bind `addr` (a stale Unix socket file is unlinked first).
    pub fn bind(addr: &Addr) -> std::io::Result<Listener> {
        match addr {
            Addr::Tcp(hp) => TcpListener::bind(hp.as_str()).map(Listener::Tcp),
            #[cfg(unix)]
            Addr::Unix(p) => {
                let _ = std::fs::remove_file(p);
                Ok(Listener::Unix {
                    listener: UnixListener::bind(p)?,
                    path: p.clone(),
                })
            }
        }
    }

    /// The actually bound address (resolves `:0` TCP ports).
    pub fn bound_addr(&self) -> std::io::Result<Addr> {
        match self {
            Listener::Tcp(l) => Ok(Addr::Tcp(l.local_addr()?.to_string())),
            #[cfg(unix)]
            Listener::Unix { path, .. } => Ok(Addr::Unix(path.clone())),
        }
    }

    /// Block until the next connection arrives.
    pub fn accept(&self) -> std::io::Result<Stream> {
        match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
            #[cfg(unix)]
            Listener::Unix { listener, .. } => listener.accept().map(|(s, _)| Stream::Unix(s)),
        }
    }
}

#[cfg(unix)]
impl Drop for Listener {
    fn drop(&mut self) {
        if let Listener::Unix { path, .. } = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addr_parses_both_schemes() {
        assert_eq!(
            Addr::parse("tcp://127.0.0.1:7071").unwrap(),
            Addr::Tcp("127.0.0.1:7071".to_string())
        );
        assert_eq!(
            Addr::parse("localhost:80").unwrap(),
            Addr::Tcp("localhost:80".to_string())
        );
        #[cfg(unix)]
        {
            assert_eq!(
                Addr::parse("unix:///tmp/z.sock").unwrap(),
                Addr::Unix("/tmp/z.sock".into())
            );
            assert_eq!(
                Addr::parse("/tmp/z.sock").unwrap(),
                Addr::Unix("/tmp/z.sock".into())
            );
            assert_eq!(
                Addr::parse("unix:///tmp/z.sock").unwrap().to_string(),
                "unix:///tmp/z.sock"
            );
        }
        assert!(Addr::parse("nonsense").is_err());
    }

    #[test]
    fn worker_groups_parse_shards_and_replicas() {
        let flat = parse_worker_groups("h0:1,h1:2").unwrap();
        assert_eq!(
            flat,
            vec![
                vec![Addr::Tcp("h0:1".to_string())],
                vec![Addr::Tcp("h1:2".to_string())]
            ]
        );
        let replicated = parse_worker_groups("a:1|b:1, c:2 | d:2").unwrap();
        assert_eq!(
            replicated,
            vec![
                vec![Addr::Tcp("a:1".to_string()), Addr::Tcp("b:1".to_string())],
                vec![Addr::Tcp("c:2".to_string()), Addr::Tcp("d:2".to_string())]
            ]
        );
        assert!(parse_worker_groups("a:1|,b:2").is_err());
        assert!(parse_worker_groups("a:1||b:1").is_err());
    }
}
