//! Shard worker: serves **one shard** of the global category set behind
//! the wire protocol, so a [`super::remote::RemoteCluster`] can compose
//! S worker processes into one logical store.
//!
//! The worker owns an epoch-snapshotted [`SnapshotHandle`] over its
//! local rows (internally a single-shard [`ShardedStore`] at startup;
//! `PrepareAdd` epochs append internal shards). Local ids `[0, len)` are
//! what the wire ops speak — the cluster maps them to global ids by the
//! worker's offset, exactly like [`crate::mips::sharded::ShardedIndex`]
//! globalizes in-process sub-indexes.
//!
//! Epoch swaps are two-phase: `PrepareAdd` / `PrepareRemove` build the
//! next epoch through [`SnapshotHandle::prepare_add`] /
//! [`prepare_remove`](SnapshotHandle::prepare_remove) and stage it under
//! the coordinator's token **without publishing**; `Commit` publishes
//! atomically (failing with `StalePrepare` if a different preparation
//! got committed since); `Abort` drops the staged epoch. One staged
//! preparation at a time — a second `Prepare*` under a different token
//! answers `Busy`, so two coordinators cannot interleave a publish.
//! A staged preparation persists until its `Commit`/`Abort` arrives (or
//! the worker restarts): if a coordinator crashes mid-publish, the
//! worker stays `Busy` to other tokens until an operator aborts with
//! the orphaned token or restarts the worker. Coordinators draw tokens
//! from process-unique entropy so a replacement coordinator cannot
//! accidentally commit an orphan.
//!
//! Beyond retrieval and publishes, the worker serves the estimator
//! ops the cluster composes: chained exp-sums (`Exact`), tail scoring
//! (`ScoreIds`, for the samplers and MINCE's noise draws), and
//! `FitFmbe` — a local FMBE fit over the worker's rows whose λ̃ vector
//! the cluster sums with the other workers' (λ̃ is additive over row
//! partitions; see [`crate::estimators::fmbe::Fmbe::from_lambdas`]).
//!
//! Under the wire-v3 reactor server, one connection carries many
//! overlapped requests and the handler pool executes them
//! **concurrently** — there is no per-connection serialization. Every
//! op here is therefore written against shared state only through the
//! lock-free epoch snapshots ([`SnapshotHandle::load`]) or the `staged`
//! mutex; a retrieval racing a publish simply answers from whichever
//! epoch it loaded, tagged so the caller can detect the race.

use super::server::Handler;
use super::wire::{ErrorCode, Request, Response};
use crate::coordinator::ServiceMetrics;
use crate::data::embeddings::EmbeddingStore;
use crate::estimators::fmbe::{Fmbe, FmbeConfig};
use crate::linalg;
use crate::store::{
    exp_sum_view_batch, exp_sum_view_chain, PendingEpoch, ShardedStore, SnapshotHandle, StoreView,
};
use std::sync::{Arc, Mutex};

/// The worker-side handler.
pub struct ShardWorker {
    handle: SnapshotHandle,
    /// At most one staged (token, prepared epoch) at a time.
    staged: Mutex<Option<(u64, PendingEpoch)>>,
    /// Telemetry answered to `GetMetrics`. Share this sink with the
    /// [`super::server::Server`] wrapping the worker (via
    /// [`ShardWorker::with_metrics`] /
    /// [`ShardWorker::metrics_handle`]) so one scrape reports the
    /// worker's wire counters and handler histograms; a worker driven
    /// without a server answers from its own (then handler-only) sink.
    metrics: Arc<ServiceMetrics>,
}

impl ShardWorker {
    /// Serve `rows` as this worker's shard (exact brute-force local
    /// index).
    pub fn new(rows: EmbeddingStore) -> ShardWorker {
        Self::with_handle(SnapshotHandle::brute(ShardedStore::split(&rows, 1)))
    }

    /// Serve an existing handle (custom per-shard index families).
    pub fn with_handle(handle: SnapshotHandle) -> ShardWorker {
        ShardWorker {
            handle,
            staged: Mutex::new(None),
            metrics: Arc::new(ServiceMetrics::new()),
        }
    }

    /// Answer `GetMetrics` from `metrics` instead of a private sink —
    /// pass the same `Arc` to the server fronting this worker so
    /// scrapes see the full picture.
    pub fn with_metrics(mut self, metrics: Arc<ServiceMetrics>) -> ShardWorker {
        self.metrics = metrics;
        self
    }

    /// The sink `GetMetrics` answers from.
    pub fn metrics_handle(&self) -> Arc<ServiceMetrics> {
        self.metrics.clone()
    }

    /// The underlying snapshot handle (tests, local mutation).
    pub fn snapshot_handle(&self) -> &SnapshotHandle {
        &self.handle
    }

    fn err(code: ErrorCode, message: impl Into<String>) -> Response {
        Response::Error {
            code,
            message: message.into(),
        }
    }

    fn check_dim(&self, got: usize, want: usize) -> Option<Response> {
        if got != want {
            return Some(Self::err(
                ErrorCode::DimMismatch,
                format!("query dimensionality {got} != shard dimensionality {want}"),
            ));
        }
        None
    }

    fn stage(&self, token: u64, pending: PendingEpoch) -> Response {
        let mut staged = self.staged.lock().unwrap();
        if let Some((t, _)) = staged.as_ref() {
            if *t != token {
                return Self::err(
                    ErrorCode::Busy,
                    format!("another preparation (token {t}) is staged"),
                );
            }
        }
        let epoch = pending.epoch();
        *staged = Some((token, pending));
        Response::Prepared { epoch }
    }
}

impl Handler for ShardWorker {
    fn handle(&self, req: Request) -> Response {
        match req {
            Request::Ping => Response::Pong,
            Request::Manifest => {
                let snap = self.handle.load();
                Response::Manifest {
                    len: StoreView::len(snap.store.as_ref()) as u64,
                    dim: StoreView::dim(snap.store.as_ref()) as u64,
                    epoch: snap.epoch,
                }
            }
            Request::TopK { k, queries } => {
                let snap = self.handle.load();
                let d = StoreView::dim(snap.store.as_ref());
                if let Some(resp) = queries
                    .first()
                    .and_then(|q| self.check_dim(q.len(), d))
                {
                    return resp;
                }
                Response::Hits(snap.index.top_k_batch(&queries, k as usize))
            }
            Request::ExpSumChain { acc, query } => {
                let snap = self.handle.load();
                let d = StoreView::dim(snap.store.as_ref());
                if let Some(resp) = self.check_dim(query.len(), d) {
                    return resp;
                }
                // Single-query gemv chain: continues the caller's strict
                // sequential accumulation over this worker's rows.
                Response::ExpSums(vec![exp_sum_view_chain(snap.store.as_ref(), &query, acc)])
            }
            Request::ExpSumChainBatch { acc_in, queries } => {
                if acc_in.len() != queries.len() {
                    return Self::err(
                        ErrorCode::BadRequest,
                        format!(
                            "{} accumulators for {} queries",
                            acc_in.len(),
                            queries.len()
                        ),
                    );
                }
                let snap = self.handle.load();
                let d = StoreView::dim(snap.store.as_ref());
                if let Some(resp) = queries
                    .first()
                    .and_then(|q| self.check_dim(q.len(), d))
                {
                    return resp;
                }
                // Batched gemm chain: exp_sum_view_batch accumulates
                // *into* zs, so seeding with acc_in continues the chain.
                let mut zs = acc_in;
                if !queries.is_empty() {
                    let qs_flat = linalg::flatten_queries(&queries, d);
                    exp_sum_view_batch(snap.store.as_ref(), &qs_flat, queries.len(), &mut zs);
                }
                Response::ExpSums(zs)
            }
            Request::ExpSumPart { queries } => {
                let snap = self.handle.load();
                let d = StoreView::dim(snap.store.as_ref());
                if let Some(resp) = queries
                    .first()
                    .and_then(|q| self.check_dim(q.len(), d))
                {
                    return resp;
                }
                // Partial sums from zero over this worker's rows: the
                // same batched gemm kernel as the chained op seeded with
                // zero accumulators, so the pipelined reduction differs
                // from the chain only in the final f64 grouping.
                let mut zs = vec![0f64; queries.len()];
                if !queries.is_empty() {
                    let qs_flat = linalg::flatten_queries(&queries, d);
                    exp_sum_view_batch(snap.store.as_ref(), &qs_flat, queries.len(), &mut zs);
                }
                Response::ExpSums(zs)
            }
            Request::ScoreIds { ids, query } => {
                let snap = self.handle.load();
                let view = snap.store.as_ref();
                let d = StoreView::dim(view);
                if let Some(resp) = self.check_dim(query.len(), d) {
                    return resp;
                }
                let n = StoreView::len(view);
                let mut scores = Vec::with_capacity(ids.len());
                for id in ids {
                    let id = id as usize;
                    if id >= n {
                        return Self::err(
                            ErrorCode::BadRequest,
                            format!("row {id} out of range (len {n})"),
                        );
                    }
                    scores.push(linalg::dot(StoreView::row(view, id), &query));
                }
                Response::Scores(scores)
            }
            Request::PrepareAdd { token, dim, rows } => {
                let dim = dim as usize;
                if dim == 0 || rows.len() % dim != 0 {
                    return Self::err(
                        ErrorCode::BadRequest,
                        format!("{} row floats not divisible by dim {dim}", rows.len()),
                    );
                }
                let n = rows.len() / dim;
                let store = match EmbeddingStore::from_data(n, dim, rows) {
                    Ok(s) => s,
                    Err(e) => return Self::err(ErrorCode::BadRequest, e.to_string()),
                };
                match self.handle.prepare_add(store) {
                    Ok(pending) => self.stage(token, pending),
                    Err(e) => Self::err(ErrorCode::BadRequest, e.to_string()),
                }
            }
            Request::PrepareRemove { token, ids } => {
                let ids: Vec<usize> = ids.into_iter().map(|i| i as usize).collect();
                match self.handle.prepare_remove(&ids) {
                    Ok(pending) => self.stage(token, pending),
                    Err(e) => Self::err(ErrorCode::BadRequest, e.to_string()),
                }
            }
            Request::Commit { token } => {
                // Hold the stage lock across the publish so a concurrent
                // Prepare cannot slip between take and commit.
                let mut staged = self.staged.lock().unwrap();
                match staged.take() {
                    Some((t, pending)) if t == token => match self.handle.commit(pending) {
                        Ok(epoch) => Response::Committed { epoch },
                        Err(e) => Self::err(ErrorCode::StalePrepare, e.to_string()),
                    },
                    other => {
                        *staged = other; // not ours: put it back untouched
                        Self::err(
                            ErrorCode::StalePrepare,
                            format!("no preparation staged under token {token}"),
                        )
                    }
                }
            }
            Request::Abort { token } => {
                let mut staged = self.staged.lock().unwrap();
                if matches!(staged.as_ref(), Some((t, _)) if *t == token) {
                    *staged = None;
                }
                Response::Aborted
            }
            Request::FitFmbe { seed, p_features } => {
                // Cap P so one frame cannot demand an unbounded fit (the
                // λ̃ response itself is 8·P bytes — 8 MB at the cap).
                const MAX_FIT_FEATURES: u64 = 1 << 20;
                if p_features == 0 || p_features > MAX_FIT_FEATURES {
                    return Self::err(
                        ErrorCode::BadRequest,
                        format!("p_features {p_features} outside (0, {MAX_FIT_FEATURES}]"),
                    );
                }
                // Fit over the currently published snapshot; the epoch in
                // the answer lets the cluster reject a fit that raced a
                // publish. The feature draw depends only on (seed, d) and
                // the geometric parameter is protocol-pinned to the
                // default, so identically configured workers draw the
                // same maps and their λ̃ vectors sum to the global fit.
                let snap = self.handle.load();
                let cfg = FmbeConfig {
                    p_features: p_features as usize,
                    seed,
                    ..Default::default()
                };
                let fitted = Fmbe::fit(snap.store.as_ref(), cfg);
                Response::Lambdas {
                    epoch: snap.epoch,
                    lambdas: fitted.lambdas(),
                }
            }
            Request::GetMetrics => Response::Metrics(self.metrics.blob()),
            // Partition-server operations don't belong on a shard worker.
            Request::Estimate { .. } | Request::EstimateBatch { .. } => Self::err(
                ErrorCode::Unsupported,
                "partition-server operation sent to a shard worker",
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::mips::MipsIndex;

    fn worker(n: usize, d: usize) -> (ShardWorker, EmbeddingStore) {
        let s = generate(&SynthConfig {
            n,
            d,
            ..SynthConfig::tiny()
        });
        (ShardWorker::new(s.clone()), s)
    }

    #[test]
    fn manifest_and_topk_serve_local_rows() {
        let (w, s) = worker(120, 8);
        assert_eq!(
            w.handle(Request::Manifest),
            Response::Manifest {
                len: 120,
                dim: 8,
                epoch: 0
            }
        );
        let q = s.row(7).to_vec();
        let resp = w.handle(Request::TopK {
            k: 5,
            queries: vec![q.clone()],
        });
        let Response::Hits(hits) = resp else {
            panic!("{resp:?}");
        };
        let want = crate::mips::brute::BruteIndex::new(&s).top_k(&q, 5);
        assert_eq!(hits[0], want);
    }

    #[test]
    fn exp_sum_chain_continues_accumulator() {
        let (w, s) = worker(100, 8);
        let q = s.row(3).to_vec();
        let local = crate::store::exp_sum_view(&s, &q);
        let resp = w.handle(Request::ExpSumChain {
            acc: 10.0,
            query: q,
        });
        let Response::ExpSums(acc) = resp else {
            panic!("{resp:?}");
        };
        assert_eq!(acc[0].to_bits(), (10.0 + local).to_bits());
    }

    /// `ExpSumPart` equals the chained batch op seeded with zeros, bit
    /// for bit — the pipelined fan-out's per-worker contract.
    #[test]
    fn exp_sum_part_matches_zero_seeded_chain() {
        let (w, s) = worker(100, 8);
        let queries: Vec<Vec<f32>> = (0..3).map(|i| s.row(i * 30).to_vec()).collect();
        let part = w.handle(Request::ExpSumPart {
            queries: queries.clone(),
        });
        let chain = w.handle(Request::ExpSumChainBatch {
            acc_in: vec![0.0; queries.len()],
            queries,
        });
        let (Response::ExpSums(part), Response::ExpSums(chain)) = (part, chain) else {
            panic!("non-ExpSums answer");
        };
        for (p, c) in part.iter().zip(&chain) {
            assert_eq!(p.to_bits(), c.to_bits());
        }
        // Dimension mismatches are an error frame, not a panic.
        let resp = w.handle(Request::ExpSumPart {
            queries: vec![vec![0.0; 3]],
        });
        assert!(matches!(
            resp,
            Response::Error {
                code: ErrorCode::DimMismatch,
                ..
            }
        ));
    }

    #[test]
    fn score_ids_match_direct_dots() {
        let (w, s) = worker(60, 8);
        let q = s.row(1).to_vec();
        let resp = w.handle(Request::ScoreIds {
            ids: vec![0, 17, 59],
            query: q.clone(),
        });
        let Response::Scores(scores) = resp else {
            panic!("{resp:?}");
        };
        for (i, &id) in [0usize, 17, 59].iter().enumerate() {
            assert_eq!(scores[i], linalg::dot(s.row(id), &q));
        }
        // Out-of-range ids are a BadRequest, not a panic.
        let resp = w.handle(Request::ScoreIds {
            ids: vec![60],
            query: q,
        });
        assert!(matches!(
            resp,
            Response::Error {
                code: ErrorCode::BadRequest,
                ..
            }
        ));
    }

    #[test]
    fn two_phase_publish_stages_then_commits() {
        let (w, _) = worker(40, 8);
        let added = generate(&SynthConfig {
            n: 8,
            d: 8,
            seed: 3,
            ..SynthConfig::tiny()
        });
        let resp = w.handle(Request::PrepareAdd {
            token: 1,
            dim: 8,
            rows: added.data().to_vec(),
        });
        assert_eq!(resp, Response::Prepared { epoch: 1 });
        // Not published yet.
        assert_eq!(w.snapshot_handle().epoch(), 0);
        // A different token cannot stage or commit over it.
        let busy = w.handle(Request::PrepareRemove {
            token: 2,
            ids: vec![],
        });
        assert!(matches!(
            busy,
            Response::Error {
                code: ErrorCode::Busy,
                ..
            }
        ));
        let stale = w.handle(Request::Commit { token: 2 });
        assert!(matches!(
            stale,
            Response::Error {
                code: ErrorCode::StalePrepare,
                ..
            }
        ));
        // The staged preparation survives the mismatched commit.
        assert_eq!(
            w.handle(Request::Commit { token: 1 }),
            Response::Committed { epoch: 1 }
        );
        assert_eq!(w.snapshot_handle().epoch(), 1);
        let Response::Manifest { len, .. } = w.handle(Request::Manifest) else {
            panic!()
        };
        assert_eq!(len, 48);
    }

    #[test]
    fn abort_unstages_and_commit_then_fails() {
        let (w, _) = worker(20, 8);
        w.handle(Request::PrepareRemove {
            token: 5,
            ids: vec![0, 1],
        });
        assert_eq!(w.handle(Request::Abort { token: 5 }), Response::Aborted);
        assert!(matches!(
            w.handle(Request::Commit { token: 5 }),
            Response::Error {
                code: ErrorCode::StalePrepare,
                ..
            }
        ));
        assert_eq!(w.snapshot_handle().epoch(), 0);
    }

    /// `FitFmbe` answers the same λ̃ vector a local fit over the
    /// worker's rows produces, tagged with the published epoch.
    #[test]
    fn fit_fmbe_matches_local_fit() {
        let (w, s) = worker(80, 8);
        let resp = w.handle(Request::FitFmbe {
            seed: 5,
            p_features: 150,
        });
        let Response::Lambdas { epoch, lambdas } = resp else {
            panic!("{resp:?}");
        };
        assert_eq!(epoch, 0);
        let want = Fmbe::fit(
            &s,
            FmbeConfig {
                p_features: 150,
                seed: 5,
                ..Default::default()
            },
        )
        .lambdas();
        assert_eq!(lambdas, want);
        // Degenerate feature counts are a BadRequest, not a panic.
        let resp = w.handle(Request::FitFmbe {
            seed: 5,
            p_features: 0,
        });
        assert!(matches!(
            resp,
            Response::Error {
                code: ErrorCode::BadRequest,
                ..
            }
        ));
    }

    #[test]
    fn dim_mismatch_is_an_error_frame() {
        let (w, _) = worker(20, 8);
        let resp = w.handle(Request::ExpSumChain {
            acc: 0.0,
            query: vec![0.0; 5],
        });
        assert!(matches!(
            resp,
            Response::Error {
                code: ErrorCode::DimMismatch,
                ..
            }
        ));
    }
}
