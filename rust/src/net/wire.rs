//! Framed wire protocol: length-prefixed, versioned, hand-rolled
//! little-endian binary codec (no serde) for every message the serving
//! layer exchanges — estimation requests/responses, `Hit` batches, shard
//! manifests, chained exp-sums, and the two-phase epoch-publish
//! handshake.
//!
//! ## Frame layout (version 5)
//!
//! ```text
//! ┌─────────┬────────────┬─────────────┬────────────────┬──────────┬───────────────┐
//! │ "ZNW1"  │ version u16│ payload len │ request id u64 │ flags u8 │ payload       │
//! │ 4 bytes │ LE         │ u32 LE      │ LE             │          │ tag u8 + body │
//! └─────────┴────────────┴─────────────┴────────────────┴──────────┴───────────────┘
//! ```
//!
//! Version 3 added the `request_id` header field: a response frame
//! echoes the id of the request it answers, so one connection can carry
//! many overlapped RPCs and responses may return **out of request
//! order** (the reactor server and the multiplexed [`super::remote`]
//! pipeline both rely on this). Id `0` is reserved for
//! connection-level frames a server emits before it has read any
//! request (e.g. the `ConnLimit` rejection); clients start their ids at
//! 1.
//!
//! Version 5 widened the header with a `flags` byte. The only defined
//! bit is [`FLAG_TRACED`]: a client sets it on a request frame to ask
//! the server for server-side timings; the server echoes the bit on
//! the response frame and **appends a 16-byte timing annex**
//! ([`WireTimes`]: `handle_lag_ns u64, exec_ns u64`) after the normal
//! response payload (the header's `len` covers payload + annex; the
//! annex is stripped at the frame layer before `Response::decode`).
//! Unknown flag bits are malformed — they would change frame
//! interpretation, so they cannot be skipped forward-compatibly.
//!
//! Every multi-byte integer and float is little-endian. Vectors are a
//! `u32` count followed by raw elements; query blocks are `count u32,
//! dim u32, count*dim f32`. A frame larger than [`MAX_FRAME_LEN`]
//! (guarding allocation-from-the-wire), a bad magic, an unknown version,
//! an unknown tag, a short body, or trailing bytes all decode to
//! [`WireError::Malformed`]-family errors — the server answers with an
//! error frame and closes the connection instead of panicking
//! (`rust/tests/net_e2e.rs` pins this).
//!
//! Golden-byte tests at the bottom freeze the encoding: changing any of
//! them is a wire-format break and requires a `VERSION` bump.
//!
//! The full frame specification — header layout, every op's encoding,
//! the 4-aligned worker-split contract for bit-exact chained exp-sums,
//! and the length-bomb limits — lives in `docs/WIRE.md` at the
//! repository root; it is written so a non-Rust client can be
//! implemented from the document alone. Keep the two in lockstep: any
//! change here must update the document (and vice versa).
//!
//! Hot-path callers that would otherwise clone payloads into an owned
//! [`Request`] just to serialize them can build the wire bytes straight
//! from borrowed data through [`Encoded`] (same bytes, pinned by
//! `borrowed_encode_matches_owned`), then send via
//! `Pool::call_encoded` in [`super::client`].

use crate::coordinator::Precision;
use crate::estimators::EstimatorKind;
use crate::mips::Hit;
use crate::obs::hist::HistogramSnapshot;
use crate::obs::MetricsBlob;
use std::io::{Read, Write};

/// Frame magic: "ZNW1" (Zest NetWork, format 1).
pub const MAGIC: [u8; 4] = *b"ZNW1";
/// Protocol version carried in every frame header. Version 2 extended
/// `Estimate`/`EstimateBatch` with a precision byte and a deadline
/// budget, and added the `ExpSumPart` worker op; version 3 widened the
/// header with a `request_id: u64` so one connection multiplexes many
/// overlapped RPCs; version 4 appended a `served_from_cache` byte to
/// each `Estimates` entry; version 5 widened the header with a `flags`
/// byte ([`FLAG_TRACED`] + response timing annex) and added the
/// `GetMetrics`/`Metrics` telemetry ops (see `docs/WIRE.md` §8 for the
/// history).
pub const VERSION: u16 = 5;
/// Upper bound on one frame's payload (guards against allocating
/// attacker-controlled lengths; also the practical cap on one
/// `PrepareAdd` row shipment — ~64M f32s).
pub const MAX_FRAME_LEN: usize = 256 << 20;

/// Fixed frame-header size: magic (4) + version (2) + payload length
/// (4) + request id (8) + flags (1). Exposed so readiness-driven
/// readers (the reactor's frame-assembly state machine) can buffer
/// exactly one header before deciding how much payload to expect.
pub const HEADER_LEN: usize = 19;

/// Header flag bit: the sender of a request frame asks for server-side
/// timings; the server echoes the bit on the response frame and
/// appends a [`WireTimes`] annex after the response payload.
pub const FLAG_TRACED: u8 = 0b0000_0001;

/// Every header flag bit this version defines; anything outside is
/// malformed.
const FLAGS_MASK: u8 = FLAG_TRACED;

/// Server-side timing annex appended to a [`FLAG_TRACED`] response
/// frame: how long the decoded request waited for a handler thread and
/// how long the handler ran. Fixed [`WireTimes::LEN`] bytes (two LE
/// u64s) so the frame layer can strip it without understanding the
/// payload.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireTimes {
    /// Nanoseconds between frame decode and handler start (the
    /// server-side queueing lag).
    pub handle_lag_ns: u64,
    /// Nanoseconds the handler ran (server-side execution wall time).
    pub exec_ns: u64,
}

impl WireTimes {
    /// Encoded annex size in bytes.
    pub const LEN: usize = 16;

    /// Encode as the 16-byte wire annex.
    pub fn encode(&self) -> [u8; WireTimes::LEN] {
        let mut out = [0u8; WireTimes::LEN];
        out[..8].copy_from_slice(&self.handle_lag_ns.to_le_bytes());
        out[8..].copy_from_slice(&self.exec_ns.to_le_bytes());
        out
    }

    /// Decode the 16-byte wire annex.
    pub fn decode(bytes: &[u8]) -> Result<WireTimes> {
        if bytes.len() != WireTimes::LEN {
            return Err(WireError::Malformed(format!(
                "timing annex of {} bytes (want {})",
                bytes.len(),
                WireTimes::LEN
            )));
        }
        Ok(WireTimes {
            handle_lag_ns: u64::from_le_bytes(bytes[..8].try_into().unwrap()),
            exec_ns: u64::from_le_bytes(bytes[8..].try_into().unwrap()),
        })
    }
}

/// Decode/transport failure.
#[derive(Debug)]
pub enum WireError {
    /// Underlying transport error (socket read/write failed).
    Io(std::io::Error),
    /// The frame header did not start with [`MAGIC`].
    BadMagic([u8; 4]),
    /// The frame header carried an unsupported protocol version.
    BadVersion(u16),
    /// A frame length prefix exceeded [`MAX_FRAME_LEN`].
    FrameTooLarge(usize),
    /// Undecodable payload: short body, trailing bytes, unknown tag,
    /// inner length bomb, or a truncated/stalled frame.
    Malformed(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "i/o: {e}"),
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:02x?}"),
            WireError::BadVersion(v) => {
                write!(f, "unsupported protocol version {v} (want {VERSION})")
            }
            WireError::FrameTooLarge(n) => {
                write!(f, "frame payload of {n} bytes exceeds cap {MAX_FRAME_LEN}")
            }
            WireError::Malformed(m) => write!(f, "malformed frame: {m}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

/// Codec-level result alias.
pub type Result<T> = std::result::Result<T, WireError>;

/// Typed error codes carried by [`Response::Error`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// Ingress queue full under shedding backpressure.
    Overloaded,
    /// Service shut down.
    Closed,
    /// Query dimensionality does not match the served store.
    DimMismatch,
    /// Operation not supported by this endpoint (e.g. a shard-worker op
    /// sent to a partition server, or a remote-incapable estimator).
    Unsupported,
    /// Undecodable or semantically invalid request.
    BadRequest,
    /// Handler failure.
    Internal,
    /// Two-phase commit against a preparation that no longer matches.
    StalePrepare,
    /// Handler-level contention (e.g. a different coordinator's staged
    /// preparation); the connection stays open — retry later.
    Busy,
    /// Connection limit reached; the server closes this connection
    /// right after the error frame.
    ConnLimit,
    /// The request's deadline budget expired before it could execute
    /// (rejected at submit, shed by the batcher at drain time, or
    /// already expired on receipt).
    DeadlineExceeded,
    /// Forward-compatibility catch-all.
    Unknown(u16),
}

impl ErrorCode {
    /// Wire representation of the code.
    pub fn as_u16(self) -> u16 {
        match self {
            ErrorCode::Overloaded => 1,
            ErrorCode::Closed => 2,
            ErrorCode::DimMismatch => 3,
            ErrorCode::Unsupported => 4,
            ErrorCode::BadRequest => 5,
            ErrorCode::Internal => 6,
            ErrorCode::StalePrepare => 7,
            ErrorCode::Busy => 8,
            ErrorCode::ConnLimit => 9,
            ErrorCode::DeadlineExceeded => 10,
            ErrorCode::Unknown(v) => v,
        }
    }

    /// Decode a wire code; unrecognized values land in
    /// [`ErrorCode::Unknown`] instead of failing the frame.
    pub fn from_u16(v: u16) -> ErrorCode {
        match v {
            1 => ErrorCode::Overloaded,
            2 => ErrorCode::Closed,
            3 => ErrorCode::DimMismatch,
            4 => ErrorCode::Unsupported,
            5 => ErrorCode::BadRequest,
            6 => ErrorCode::Internal,
            7 => ErrorCode::StalePrepare,
            8 => ErrorCode::Busy,
            9 => ErrorCode::ConnLimit,
            10 => ErrorCode::DeadlineExceeded,
            other => ErrorCode::Unknown(other),
        }
    }
}

/// One request frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Liveness probe.
    Ping,
    /// What is served here? → [`Response::Manifest`].
    Manifest,
    /// One estimation (partition server).
    Estimate {
        kind: EstimatorKind,
        k: u64,
        l: u64,
        /// Bit-exact vs pipelined multi-worker `Exact` (byte 0/1 on the
        /// wire; unknown bytes are malformed).
        precision: Precision,
        /// Remaining deadline budget in nanoseconds, measured from the
        /// server's receipt of the frame; 0 = no deadline. Relative
        /// rather than absolute so clocks never need to agree.
        deadline_ns: u64,
        query: Vec<f32>,
    },
    /// A query block of one (kind, k, l, precision) configuration.
    EstimateBatch {
        kind: EstimatorKind,
        k: u64,
        l: u64,
        /// Shared precision mode of the block (see [`Request::Estimate`]).
        precision: Precision,
        /// Shared deadline budget of the block in nanoseconds (0 = none).
        deadline_ns: u64,
        queries: Vec<Vec<f32>>,
    },
    /// Shard worker: top-k for every query, local ids.
    TopK { k: u64, queries: Vec<Vec<f32>> },
    /// Shard worker: continue a single-query chained exp-sum — returns
    /// `acc + Σ exp(row · q)` over the worker's rows, accumulated in
    /// strict local row order (the single-query gemv kernel).
    ExpSumChain { acc: f64, query: Vec<f32> },
    /// Shard worker: batched chained exp-sum (the multi-query gemm
    /// kernel); `acc_in[j]` seeds query `j`'s accumulator.
    ExpSumChainBatch {
        acc_in: Vec<f64>,
        queries: Vec<Vec<f32>>,
    },
    /// Shard worker: raw inner products of the given local rows with the
    /// query (remote tail scoring).
    ScoreIds { ids: Vec<u64>, query: Vec<f32> },
    /// Two-phase publish, phase 1: stage an epoch that appends the given
    /// row-major block as new categories.
    PrepareAdd {
        token: u64,
        dim: u64,
        rows: Vec<f32>,
    },
    /// Two-phase publish, phase 1: stage an epoch that drops the given
    /// local ids. Empty `ids` is a pure epoch bump, which is how workers
    /// without local changes stay in lockstep.
    PrepareRemove { token: u64, ids: Vec<u64> },
    /// Two-phase publish, phase 2: atomically publish the staged epoch.
    Commit { token: u64 },
    /// Drop a staged preparation.
    Abort { token: u64 },
    /// Shard worker: **partial** exp-sums over this worker's rows only —
    /// one f64 per query, accumulated from zero in strict local row
    /// order (the same kernel as [`Request::ExpSumChainBatch`] seeded
    /// with zeros). The pipelined-`Exact` fan-out op: the cluster sends
    /// it to all workers concurrently and reduces the partials in
    /// worker order, trading the chained mode's bit-exactness
    /// (last-ulp-different f64 summation grouping) for
    /// max-over-workers latency.
    ExpSumPart {
        /// The query block to partially exp-sum.
        queries: Vec<Vec<f32>>,
    },
    /// Shard worker: fit FMBE random-feature sums over the worker's
    /// local rows and return the per-feature λ̃ vector
    /// ([`Response::Lambdas`]). The feature draw depends only on
    /// `(seed, dimensionality)` and the geometric parameter is pinned at
    /// the protocol level to the library default (p = 2), so every
    /// worker given the same `(seed, p_features)` draws identical
    /// feature maps and the per-shard λ̃ vectors are additive —
    /// the cluster sums them into the global fit without shipping rows.
    FitFmbe {
        /// Feature-draw seed (the coordinator's `FmbeConfig::seed`).
        seed: u64,
        /// Number of random features P (`FmbeConfig::p_features`).
        p_features: u64,
    },
    /// Telemetry scrape → [`Response::Metrics`]. Served by partition
    /// servers (which merge in their workers' blobs) and shard workers
    /// alike; wire version 5.
    GetMetrics,
}

/// One estimation answer (mirrors `coordinator::Response`; durations in
/// nanoseconds).
#[derive(Clone, Debug, PartialEq)]
pub struct Estimate {
    /// The estimated partition value Ẑ(q).
    pub z: f64,
    /// Estimator that produced the answer.
    pub kind: EstimatorKind,
    /// Snapshot epoch the answer was computed against.
    pub epoch: u64,
    /// Category-vector scorings the estimate performed.
    pub scorings: u64,
    /// Time spent queued before execution, in nanoseconds.
    pub queue_wait_ns: u64,
    /// Execution time, in nanoseconds.
    pub exec_ns: u64,
    /// Whether the coordinator's front-door cache answered this request
    /// without executing it (bit-identical replay of an earlier answer;
    /// `scorings`/`exec_ns` then describe the original execution while
    /// `queue_wait_ns` is zero). Wire version 4.
    pub served_from_cache: bool,
}

/// One response frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// Liveness ack for [`Request::Ping`].
    Pong,
    /// Serving manifest: categories, dimensionality, snapshot epoch.
    Manifest { len: u64, dim: u64, epoch: u64 },
    /// Estimation answers, in request order (one element for
    /// [`Request::Estimate`]).
    Estimates(Vec<Estimate>),
    /// Per-query hit lists (local ids on shard workers).
    Hits(Vec<Vec<Hit>>),
    /// Continued accumulator(s) of a chained exp-sum.
    ExpSums(Vec<f64>),
    /// Raw inner products for [`Request::ScoreIds`], in id order.
    Scores(Vec<f32>),
    /// Phase-1 ack: the epoch the staged snapshot will publish as.
    Prepared { epoch: u64 },
    /// Phase-2 ack: the epoch now published.
    Committed { epoch: u64 },
    /// Ack for [`Request::Abort`] (idempotent: also answered when
    /// nothing was staged under the token).
    Aborted,
    /// Per-feature λ̃ sums over the worker's local rows for
    /// [`Request::FitFmbe`], plus the epoch of the snapshot they were
    /// fitted on (so the cluster can reject a fit that raced a publish).
    Lambdas { epoch: u64, lambdas: Vec<f64> },
    /// Telemetry snapshot for [`Request::GetMetrics`]: named counters
    /// plus named histogram snapshots (sparse `(bucket, count)`
    /// encoding). Blobs merge exactly across nodes
    /// ([`crate::obs::MetricsBlob::merge`]); wire version 5.
    Metrics(MetricsBlob),
    /// Typed failure; see [`ErrorCode`] for retry/close semantics.
    Error { code: ErrorCode, message: String },
}

// ---------------------------------------------------------------------
// Primitive little-endian encode/decode.

/// Append-only little-endian encoder.
struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn with_tag(tag: u8) -> Enc {
        Enc { buf: vec![tag] }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.f32(x);
        }
    }

    fn f64s(&mut self, v: &[f64]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.f64(x);
        }
    }

    fn u64s(&mut self, v: &[u64]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.u64(x);
        }
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Query block: `count u32, dim u32, count×dim f32`. All queries
    /// must share one dimensionality (the protocol's invariant). Hard
    /// assert — a ragged block would encode a frame that silently
    /// re-slices into *different* queries on the peer, which is worse
    /// than a panic at the call site.
    fn queries(&mut self, qs: &[Vec<f32>]) {
        self.u32(qs.len() as u32);
        let d = qs.first().map_or(0, |q| q.len());
        self.u32(d as u32);
        for q in qs {
            assert_eq!(q.len(), d, "ragged query block");
            for &x in q {
                self.f32(x);
            }
        }
    }
}

/// Checked little-endian decoder over one payload.
struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            return Err(WireError::Malformed(format!(
                "short body: want {n} more bytes, have {}",
                self.buf.len() - self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn f64(&mut self) -> Result<f64> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes(b.try_into().unwrap()))
    }

    /// A length prefix that the remaining body can actually hold
    /// `elem_size`-byte elements for (rejects allocation bombs).
    fn len_prefix(&mut self, elem_size: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        if n.saturating_mul(elem_size) > self.buf.len() - self.pos {
            return Err(WireError::Malformed(format!(
                "length prefix {n} overruns body"
            )));
        }
        Ok(n)
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.len_prefix(4)?;
        (0..n).map(|_| self.f32()).collect()
    }

    fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.len_prefix(8)?;
        (0..n).map(|_| self.f64()).collect()
    }

    fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.len_prefix(8)?;
        (0..n).map(|_| self.u64()).collect()
    }

    fn str(&mut self) -> Result<String> {
        let n = self.len_prefix(1)?;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec())
            .map_err(|_| WireError::Malformed("non-utf8 string".to_string()))
    }

    fn queries(&mut self) -> Result<Vec<Vec<f32>>> {
        let n = self.u32()? as usize;
        let d = self.u32()? as usize;
        // d == 0 with n > 0 would zero out the byte-cost check below and
        // let a tiny frame claim ~4G queries (an allocation bomb).
        if n > 0 && d == 0 {
            return Err(WireError::Malformed(format!(
                "query block claims {n} zero-dimensional queries"
            )));
        }
        if n.saturating_mul(d).saturating_mul(4) > self.buf.len() - self.pos {
            return Err(WireError::Malformed(format!(
                "query block {n}×{d} overruns body"
            )));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let mut q = Vec::with_capacity(d);
            for _ in 0..d {
                q.push(self.f32()?);
            }
            out.push(q);
        }
        Ok(out)
    }

    fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(WireError::Malformed(format!(
                "{} trailing bytes after message",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn kind_to_u8(kind: EstimatorKind) -> u8 {
    match kind {
        EstimatorKind::Exact => 0,
        EstimatorKind::Uniform => 1,
        EstimatorKind::Nmimps => 2,
        EstimatorKind::Mimps => 3,
        EstimatorKind::Mince => 4,
        EstimatorKind::Fmbe => 5,
    }
}

fn precision_to_u8(p: Precision) -> u8 {
    match p {
        Precision::BitExact => 0,
        Precision::Pipelined => 1,
    }
}

fn precision_from_u8(v: u8) -> Result<Precision> {
    Ok(match v {
        0 => Precision::BitExact,
        1 => Precision::Pipelined,
        other => {
            return Err(WireError::Malformed(format!(
                "unknown precision mode {other}"
            )))
        }
    })
}

fn kind_from_u8(v: u8) -> Result<EstimatorKind> {
    Ok(match v {
        0 => EstimatorKind::Exact,
        1 => EstimatorKind::Uniform,
        2 => EstimatorKind::Nmimps,
        3 => EstimatorKind::Mimps,
        4 => EstimatorKind::Mince,
        5 => EstimatorKind::Fmbe,
        other => {
            return Err(WireError::Malformed(format!(
                "unknown estimator kind {other}"
            )))
        }
    })
}

// ---------------------------------------------------------------------
// Message encode/decode.

const REQ_PING: u8 = 1;
const REQ_MANIFEST: u8 = 2;
const REQ_ESTIMATE: u8 = 3;
const REQ_ESTIMATE_BATCH: u8 = 4;
const REQ_TOP_K: u8 = 5;
const REQ_EXP_SUM_CHAIN: u8 = 6;
const REQ_EXP_SUM_CHAIN_BATCH: u8 = 7;
const REQ_SCORE_IDS: u8 = 8;
const REQ_PREPARE_ADD: u8 = 9;
const REQ_PREPARE_REMOVE: u8 = 10;
const REQ_COMMIT: u8 = 11;
const REQ_ABORT: u8 = 12;
const REQ_FIT_FMBE: u8 = 13;
const REQ_EXP_SUM_PART: u8 = 14;
const REQ_GET_METRICS: u8 = 15;

const RESP_PONG: u8 = 1;
const RESP_MANIFEST: u8 = 2;
const RESP_ESTIMATES: u8 = 3;
const RESP_HITS: u8 = 4;
const RESP_EXP_SUMS: u8 = 5;
const RESP_SCORES: u8 = 6;
const RESP_PREPARED: u8 = 7;
const RESP_COMMITTED: u8 = 8;
const RESP_ABORTED: u8 = 9;
const RESP_ERROR: u8 = 10;
const RESP_LAMBDAS: u8 = 11;
const RESP_METRICS: u8 = 12;

impl Request {
    /// Serialize to the frame payload (tag byte + body).
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Request::Ping => Enc::with_tag(REQ_PING).buf,
            Request::Manifest => Enc::with_tag(REQ_MANIFEST).buf,
            Request::Estimate {
                kind,
                k,
                l,
                precision,
                deadline_ns,
                query,
            } => {
                let mut e = Enc::with_tag(REQ_ESTIMATE);
                e.u8(kind_to_u8(*kind));
                e.u64(*k);
                e.u64(*l);
                e.u8(precision_to_u8(*precision));
                e.u64(*deadline_ns);
                e.f32s(query);
                e.buf
            }
            Request::EstimateBatch {
                kind,
                k,
                l,
                precision,
                deadline_ns,
                queries,
            } => {
                let mut e = Enc::with_tag(REQ_ESTIMATE_BATCH);
                e.u8(kind_to_u8(*kind));
                e.u64(*k);
                e.u64(*l);
                e.u8(precision_to_u8(*precision));
                e.u64(*deadline_ns);
                e.queries(queries);
                e.buf
            }
            Request::TopK { k, queries } => {
                let mut e = Enc::with_tag(REQ_TOP_K);
                e.u64(*k);
                e.queries(queries);
                e.buf
            }
            Request::ExpSumChain { acc, query } => {
                let mut e = Enc::with_tag(REQ_EXP_SUM_CHAIN);
                e.f64(*acc);
                e.f32s(query);
                e.buf
            }
            Request::ExpSumChainBatch { acc_in, queries } => {
                let mut e = Enc::with_tag(REQ_EXP_SUM_CHAIN_BATCH);
                e.f64s(acc_in);
                e.queries(queries);
                e.buf
            }
            Request::ScoreIds { ids, query } => {
                let mut e = Enc::with_tag(REQ_SCORE_IDS);
                e.u64s(ids);
                e.f32s(query);
                e.buf
            }
            Request::PrepareAdd { token, dim, rows } => {
                let mut e = Enc::with_tag(REQ_PREPARE_ADD);
                e.u64(*token);
                e.u64(*dim);
                e.f32s(rows);
                e.buf
            }
            Request::PrepareRemove { token, ids } => {
                let mut e = Enc::with_tag(REQ_PREPARE_REMOVE);
                e.u64(*token);
                e.u64s(ids);
                e.buf
            }
            Request::Commit { token } => {
                let mut e = Enc::with_tag(REQ_COMMIT);
                e.u64(*token);
                e.buf
            }
            Request::Abort { token } => {
                let mut e = Enc::with_tag(REQ_ABORT);
                e.u64(*token);
                e.buf
            }
            Request::FitFmbe { seed, p_features } => {
                let mut e = Enc::with_tag(REQ_FIT_FMBE);
                e.u64(*seed);
                e.u64(*p_features);
                e.buf
            }
            Request::ExpSumPart { queries } => {
                let mut e = Enc::with_tag(REQ_EXP_SUM_PART);
                e.queries(queries);
                e.buf
            }
            Request::GetMetrics => Enc::with_tag(REQ_GET_METRICS).buf,
        }
    }

    /// Decode one frame payload; rejects unknown tags, short bodies,
    /// inner length bombs and trailing bytes.
    pub fn decode(payload: &[u8]) -> Result<Request> {
        let mut d = Dec::new(payload);
        let tag = d.u8()?;
        let req = match tag {
            REQ_PING => Request::Ping,
            REQ_MANIFEST => Request::Manifest,
            REQ_ESTIMATE => Request::Estimate {
                kind: kind_from_u8(d.u8()?)?,
                k: d.u64()?,
                l: d.u64()?,
                precision: precision_from_u8(d.u8()?)?,
                deadline_ns: d.u64()?,
                query: d.f32s()?,
            },
            REQ_ESTIMATE_BATCH => Request::EstimateBatch {
                kind: kind_from_u8(d.u8()?)?,
                k: d.u64()?,
                l: d.u64()?,
                precision: precision_from_u8(d.u8()?)?,
                deadline_ns: d.u64()?,
                queries: d.queries()?,
            },
            REQ_TOP_K => Request::TopK {
                k: d.u64()?,
                queries: d.queries()?,
            },
            REQ_EXP_SUM_CHAIN => Request::ExpSumChain {
                acc: d.f64()?,
                query: d.f32s()?,
            },
            REQ_EXP_SUM_CHAIN_BATCH => Request::ExpSumChainBatch {
                acc_in: d.f64s()?,
                queries: d.queries()?,
            },
            REQ_SCORE_IDS => Request::ScoreIds {
                ids: d.u64s()?,
                query: d.f32s()?,
            },
            REQ_PREPARE_ADD => Request::PrepareAdd {
                token: d.u64()?,
                dim: d.u64()?,
                rows: d.f32s()?,
            },
            REQ_PREPARE_REMOVE => Request::PrepareRemove {
                token: d.u64()?,
                ids: d.u64s()?,
            },
            REQ_COMMIT => Request::Commit { token: d.u64()? },
            REQ_ABORT => Request::Abort { token: d.u64()? },
            REQ_FIT_FMBE => Request::FitFmbe {
                seed: d.u64()?,
                p_features: d.u64()?,
            },
            REQ_EXP_SUM_PART => Request::ExpSumPart {
                queries: d.queries()?,
            },
            REQ_GET_METRICS => Request::GetMetrics,
            other => {
                return Err(WireError::Malformed(format!("unknown request tag {other}")));
            }
        };
        d.finish()?;
        Ok(req)
    }
}

impl Response {
    /// Serialize to the frame payload (tag byte + body).
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Response::Pong => Enc::with_tag(RESP_PONG).buf,
            Response::Manifest { len, dim, epoch } => {
                let mut e = Enc::with_tag(RESP_MANIFEST);
                e.u64(*len);
                e.u64(*dim);
                e.u64(*epoch);
                e.buf
            }
            Response::Estimates(items) => {
                let mut e = Enc::with_tag(RESP_ESTIMATES);
                e.u32(items.len() as u32);
                for it in items {
                    e.f64(it.z);
                    e.u8(kind_to_u8(it.kind));
                    e.u64(it.epoch);
                    e.u64(it.scorings);
                    e.u64(it.queue_wait_ns);
                    e.u64(it.exec_ns);
                    e.u8(u8::from(it.served_from_cache));
                }
                e.buf
            }
            Response::Hits(per_query) => {
                let mut e = Enc::with_tag(RESP_HITS);
                e.u32(per_query.len() as u32);
                for hits in per_query {
                    e.u32(hits.len() as u32);
                    for h in hits {
                        e.u64(h.idx as u64);
                        e.f32(h.score);
                    }
                }
                e.buf
            }
            Response::ExpSums(acc) => {
                let mut e = Enc::with_tag(RESP_EXP_SUMS);
                e.f64s(acc);
                e.buf
            }
            Response::Scores(scores) => {
                let mut e = Enc::with_tag(RESP_SCORES);
                e.f32s(scores);
                e.buf
            }
            Response::Prepared { epoch } => {
                let mut e = Enc::with_tag(RESP_PREPARED);
                e.u64(*epoch);
                e.buf
            }
            Response::Committed { epoch } => {
                let mut e = Enc::with_tag(RESP_COMMITTED);
                e.u64(*epoch);
                e.buf
            }
            Response::Aborted => Enc::with_tag(RESP_ABORTED).buf,
            Response::Lambdas { epoch, lambdas } => {
                let mut e = Enc::with_tag(RESP_LAMBDAS);
                e.u64(*epoch);
                e.f64s(lambdas);
                e.buf
            }
            Response::Metrics(blob) => {
                let mut e = Enc::with_tag(RESP_METRICS);
                e.u32(blob.counters.len() as u32);
                for (name, v) in &blob.counters {
                    e.str(name);
                    e.u64(*v);
                }
                e.u32(blob.hists.len() as u32);
                for (name, h) in &blob.hists {
                    e.str(name);
                    e.u64(h.count);
                    e.u64(h.sum);
                    e.u64(h.max);
                    e.u32(h.buckets.len() as u32);
                    for &(idx, cnt) in &h.buckets {
                        e.u32(idx);
                        e.u64(cnt);
                    }
                }
                e.buf
            }
            Response::Error { code, message } => {
                let mut e = Enc::with_tag(RESP_ERROR);
                e.u16(code.as_u16());
                e.str(message);
                e.buf
            }
        }
    }

    /// Decode one frame payload; rejects unknown tags, short bodies,
    /// inner length bombs and trailing bytes.
    pub fn decode(payload: &[u8]) -> Result<Response> {
        let mut d = Dec::new(payload);
        let tag = d.u8()?;
        let resp = match tag {
            RESP_PONG => Response::Pong,
            RESP_MANIFEST => Response::Manifest {
                len: d.u64()?,
                dim: d.u64()?,
                epoch: d.u64()?,
            },
            RESP_ESTIMATES => {
                let n = d.len_prefix(42)?; // 8 + 1 + 8·4 + 1 bytes per estimate
                let mut items = Vec::with_capacity(n);
                for _ in 0..n {
                    items.push(Estimate {
                        z: d.f64()?,
                        kind: kind_from_u8(d.u8()?)?,
                        epoch: d.u64()?,
                        scorings: d.u64()?,
                        queue_wait_ns: d.u64()?,
                        exec_ns: d.u64()?,
                        served_from_cache: match d.u8()? {
                            0 => false,
                            1 => true,
                            other => {
                                return Err(WireError::Malformed(format!(
                                    "bad served_from_cache byte {other}"
                                )))
                            }
                        },
                    });
                }
                Response::Estimates(items)
            }
            RESP_HITS => {
                let n = d.len_prefix(4)?;
                let mut per_query = Vec::with_capacity(n);
                for _ in 0..n {
                    let m = d.len_prefix(12)?;
                    let mut hits = Vec::with_capacity(m);
                    for _ in 0..m {
                        hits.push(Hit {
                            idx: d.u64()? as usize,
                            score: d.f32()?,
                        });
                    }
                    per_query.push(hits);
                }
                Response::Hits(per_query)
            }
            RESP_EXP_SUMS => Response::ExpSums(d.f64s()?),
            RESP_SCORES => Response::Scores(d.f32s()?),
            RESP_PREPARED => Response::Prepared { epoch: d.u64()? },
            RESP_COMMITTED => Response::Committed { epoch: d.u64()? },
            RESP_ABORTED => Response::Aborted,
            RESP_LAMBDAS => Response::Lambdas {
                epoch: d.u64()?,
                lambdas: d.f64s()?,
            },
            RESP_METRICS => {
                // Minimum bytes per element guard the length prefixes:
                // a counter is ≥ 12 bytes (empty name + value), a
                // histogram header ≥ 32, a sparse bucket exactly 12.
                let nc = d.len_prefix(12)?;
                let mut counters = Vec::with_capacity(nc);
                for _ in 0..nc {
                    counters.push((d.str()?, d.u64()?));
                }
                let nh = d.len_prefix(32)?;
                let mut hists = Vec::with_capacity(nh);
                for _ in 0..nh {
                    let name = d.str()?;
                    let (count, sum, max) = (d.u64()?, d.u64()?, d.u64()?);
                    let nb = d.len_prefix(12)?;
                    let mut buckets = Vec::with_capacity(nb);
                    for _ in 0..nb {
                        buckets.push((d.u32()?, d.u64()?));
                    }
                    hists.push((
                        name,
                        HistogramSnapshot {
                            count,
                            sum,
                            max,
                            buckets,
                        },
                    ));
                }
                Response::Metrics(MetricsBlob { counters, hists })
            }
            RESP_ERROR => Response::Error {
                code: ErrorCode::from_u16(d.u16()?),
                message: d.str()?,
            },
            other => {
                return Err(WireError::Malformed(format!(
                    "unknown response tag {other}"
                )));
            }
        };
        d.finish()?;
        Ok(resp)
    }
}

// ---------------------------------------------------------------------
// Borrowed-encode fast path.

/// A request payload encoded straight from **borrowed** data.
///
/// The owned [`Request`] variants force hot-path callers to clone their
/// payloads (query blocks, row shipments, id lists) into the request
/// value before [`Request::encode`] copies them a second time into the
/// frame buffer — ~3× the row bytes at peak for a large `PrepareAdd`.
/// `Encoded`'s constructors write the identical wire bytes (pinned by
/// the `borrowed_encode_matches_owned` test) in **one** copy, borrowing
/// every slice.
///
/// Also carried: whether the request is safe to silently re-send on a
/// stale pooled connection ([`Encoded::resend_safe`] — `Commit` is not;
/// see `Pool::call` in [`super::client`]), and whether a replica set
/// may **hedge** it — issue a duplicate to a second replica while the
/// first is still in flight and take whichever answers first
/// ([`Encoded::hedge_safe`]). Hedging is stricter than re-sending:
/// both copies may execute to completion, so only stateless reads
/// whose duplicate execution is free of side effects opt in (`TopK`
/// today — see `ReplicaSet` in [`super::remote`]).
pub struct Encoded {
    payload: Vec<u8>,
    resend_safe: bool,
    hedge_safe: bool,
}

impl Encoded {
    fn new(payload: Vec<u8>) -> Encoded {
        Encoded {
            payload,
            resend_safe: true,
            hedge_safe: false,
        }
    }

    /// The frame payload bytes (tag + body).
    pub fn payload(&self) -> &[u8] {
        &self.payload
    }

    /// Whether a pooled-connection failure may transparently retry this
    /// request on a fresh connection (`false` only for `Commit`, whose
    /// effect may have landed before the response was lost).
    pub fn resend_safe(&self) -> bool {
        self.resend_safe
    }

    /// Whether a replica set may race a duplicate of this request on a
    /// second replica and take the first answer (tail-latency hedging).
    /// `true` only for stateless reads that opted in at encode time.
    pub fn hedge_safe(&self) -> bool {
        self.hedge_safe
    }

    /// Pre-encoded [`Request::Manifest`] (scalar-only request: this
    /// just reuses the owned encoder — the borrowed fast path exists
    /// for slice payloads).
    pub fn manifest() -> Encoded {
        Encoded::new(Request::Manifest.encode())
    }

    /// Borrowed encode of [`Request::TopK`]. Marked hedge-safe: a
    /// top-k retrieval is a pure read over the replica's rows, so two
    /// replicas at the same epoch executing the duplicate both produce
    /// the identical answer and nothing double-executes.
    pub fn top_k(k: u64, queries: &[Vec<f32>]) -> Encoded {
        let mut e = Enc::with_tag(REQ_TOP_K);
        e.u64(k);
        e.queries(queries);
        Encoded {
            payload: e.buf,
            resend_safe: true,
            hedge_safe: true,
        }
    }

    /// Borrowed encode of [`Request::ExpSumChain`].
    pub fn exp_sum_chain(acc: f64, query: &[f32]) -> Encoded {
        let mut e = Enc::with_tag(REQ_EXP_SUM_CHAIN);
        e.f64(acc);
        e.f32s(query);
        Encoded::new(e.buf)
    }

    /// Borrowed encode of [`Request::ExpSumChainBatch`].
    pub fn exp_sum_chain_batch(acc_in: &[f64], queries: &[Vec<f32>]) -> Encoded {
        let mut e = Enc::with_tag(REQ_EXP_SUM_CHAIN_BATCH);
        e.f64s(acc_in);
        e.queries(queries);
        Encoded::new(e.buf)
    }

    /// Borrowed encode of [`Request::ExpSumPart`].
    pub fn exp_sum_part(queries: &[Vec<f32>]) -> Encoded {
        let mut e = Enc::with_tag(REQ_EXP_SUM_PART);
        e.queries(queries);
        Encoded::new(e.buf)
    }

    /// Borrowed encode of [`Request::ScoreIds`].
    pub fn score_ids(ids: &[u64], query: &[f32]) -> Encoded {
        let mut e = Enc::with_tag(REQ_SCORE_IDS);
        e.u64s(ids);
        e.f32s(query);
        Encoded::new(e.buf)
    }

    /// Borrowed encode of [`Request::PrepareAdd`] (`rows` row-major,
    /// `rows.len()` divisible by `dim`).
    pub fn prepare_add(token: u64, dim: u64, rows: &[f32]) -> Encoded {
        let mut e = Enc::with_tag(REQ_PREPARE_ADD);
        e.u64(token);
        e.u64(dim);
        e.f32s(rows);
        Encoded::new(e.buf)
    }

    /// Borrowed encode of [`Request::PrepareRemove`].
    pub fn prepare_remove(token: u64, ids: &[u64]) -> Encoded {
        let mut e = Enc::with_tag(REQ_PREPARE_REMOVE);
        e.u64(token);
        e.u64s(ids);
        Encoded::new(e.buf)
    }

    /// Pre-encoded [`Request::Commit`] (scalar-only: reuses the owned
    /// encoder). Marked **not** resend-safe: the worker may have
    /// published before a lost response, so a silent re-send could
    /// double-commit an epoch.
    pub fn commit(token: u64) -> Encoded {
        Encoded {
            payload: Request::Commit { token }.encode(),
            resend_safe: false,
        }
    }

    /// Pre-encoded [`Request::Abort`] (scalar-only: reuses the owned
    /// encoder).
    pub fn abort(token: u64) -> Encoded {
        Encoded::new(Request::Abort { token }.encode())
    }

    /// Pre-encoded [`Request::FitFmbe`] (scalar-only: reuses the owned
    /// encoder).
    pub fn fit_fmbe(seed: u64, p_features: u64) -> Encoded {
        Encoded::new(Request::FitFmbe { seed, p_features }.encode())
    }

    /// Pre-encoded [`Request::GetMetrics`] (scalar-only: reuses the
    /// owned encoder).
    pub fn get_metrics() -> Encoded {
        Encoded::new(Request::GetMetrics.encode())
    }
}

// ---------------------------------------------------------------------
// Frame I/O.

/// Build the fixed 19-byte v5 header (flags clear) for a frame of
/// `payload_len` bytes answering/carrying `request_id`. The caller has
/// already checked `payload_len <= MAX_FRAME_LEN`.
pub fn encode_header(request_id: u64, payload_len: usize) -> [u8; HEADER_LEN] {
    encode_header_flagged(request_id, payload_len, 0)
}

/// [`encode_header`] with explicit header `flags` (see [`FLAG_TRACED`]).
pub fn encode_header_flagged(
    request_id: u64,
    payload_len: usize,
    flags: u8,
) -> [u8; HEADER_LEN] {
    let mut header = [0u8; HEADER_LEN];
    header[..4].copy_from_slice(&MAGIC);
    header[4..6].copy_from_slice(&VERSION.to_le_bytes());
    header[6..10].copy_from_slice(&(payload_len as u32).to_le_bytes());
    header[10..18].copy_from_slice(&request_id.to_le_bytes());
    header[18] = flags;
    header
}

/// Validate a buffered header and extract
/// `(request_id, flags, payload_len)`. This is the pure half of
/// [`read_frame`], shared with the reactor's incremental
/// frame-assembly state machine which accumulates header bytes across
/// readiness events instead of blocking for them.
pub fn decode_header(header: &[u8; HEADER_LEN]) -> Result<(u64, u8, usize)> {
    if header[..4] != MAGIC {
        return Err(WireError::BadMagic([
            header[0], header[1], header[2], header[3],
        ]));
    }
    let version = u16::from_le_bytes([header[4], header[5]]);
    if version != VERSION {
        return Err(WireError::BadVersion(version));
    }
    let len = u32::from_le_bytes([header[6], header[7], header[8], header[9]]) as usize;
    if len > MAX_FRAME_LEN {
        return Err(WireError::FrameTooLarge(len));
    }
    let request_id = u64::from_le_bytes([
        header[10], header[11], header[12], header[13], header[14], header[15], header[16],
        header[17],
    ]);
    let flags = header[18];
    if flags & !FLAGS_MASK != 0 {
        return Err(WireError::Malformed(format!(
            "unknown header flag bits {flags:#04x}"
        )));
    }
    Ok((request_id, flags, len))
}

/// Write one frame (header + payload, flags clear) carrying
/// `request_id`, and flush.
pub fn write_frame(w: &mut dyn Write, request_id: u64, payload: &[u8]) -> Result<()> {
    write_frame_flagged(w, request_id, 0, payload)
}

/// [`write_frame`] with explicit header `flags`. For a traced response
/// the caller has already appended the [`WireTimes`] annex to
/// `payload`.
pub fn write_frame_flagged(
    w: &mut dyn Write,
    request_id: u64,
    flags: u8,
    payload: &[u8],
) -> Result<()> {
    if payload.len() > MAX_FRAME_LEN {
        return Err(WireError::FrameTooLarge(payload.len()));
    }
    let header = encode_header_flagged(request_id, payload.len(), flags);
    w.write_all(&header)?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame's `(request_id, flags, payload)`. `Ok(None)` on a
/// clean EOF **before** any header byte (the peer hung up between
/// frames); a connection dying mid-frame is a truncation error. On a
/// [`FLAG_TRACED`] frame the payload still **includes** the trailing
/// timing annex — [`read_response`]/[`read_response_timed`] strip it.
pub fn read_frame(r: &mut dyn Read) -> Result<Option<(u64, u8, Vec<u8>)>> {
    let mut header = [0u8; HEADER_LEN];
    let mut got = 0usize;
    while got < HEADER_LEN {
        match r.read(&mut header[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                return Err(WireError::Malformed(format!(
                    "connection closed {got} bytes into a frame header"
                )));
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) if is_timeout(&e) && got > 0 => {
                // A timeout *mid-frame* is a truncation (the peer
                // stalled with a frame half-sent), not an idle
                // connection: callers answer with an error frame.
                return Err(WireError::Malformed(format!(
                    "timed out {got} bytes into a frame header"
                )));
            }
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    let (request_id, flags, len) = decode_header(&header)?;
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof || is_timeout(&e) {
            WireError::Malformed(
                "connection closed or stalled mid-payload (truncated frame)".to_string(),
            )
        } else {
            WireError::Io(e)
        }
    })?;
    Ok(Some((request_id, flags, payload)))
}

/// Split a traced response payload into `(message bytes, annex)`.
/// Identity for untraced frames.
fn split_times(flags: u8, payload: &[u8]) -> Result<(&[u8], Option<WireTimes>)> {
    if flags & FLAG_TRACED == 0 {
        return Ok((payload, None));
    }
    if payload.len() < WireTimes::LEN {
        return Err(WireError::Malformed(format!(
            "traced frame of {} bytes cannot hold a timing annex",
            payload.len()
        )));
    }
    let (msg, annex) = payload.split_at(payload.len() - WireTimes::LEN);
    Ok((msg, Some(WireTimes::decode(annex)?)))
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// Encode + frame one request under `request_id`.
pub fn write_request(w: &mut dyn Write, request_id: u64, req: &Request) -> Result<()> {
    write_frame(w, request_id, &req.encode())
}

/// Read + decode one request with its id (`Ok(None)` on clean EOF).
/// The request's [`FLAG_TRACED`] bit, if any, is dropped — servers
/// that honor it read frames through the reactor's header state
/// machine instead.
pub fn read_request(r: &mut dyn Read) -> Result<Option<(u64, Request)>> {
    match read_frame(r)? {
        Some((id, _flags, payload)) => Ok(Some((id, Request::decode(&payload)?))),
        None => Ok(None),
    }
}

/// Encode + frame one response echoing `request_id`.
pub fn write_response(w: &mut dyn Write, request_id: u64, resp: &Response) -> Result<()> {
    write_frame(w, request_id, &resp.encode())
}

/// Encode + frame one traced response: [`FLAG_TRACED`] set and the
/// [`WireTimes`] annex appended after the response payload.
pub fn write_response_timed(
    w: &mut dyn Write,
    request_id: u64,
    resp: &Response,
    times: WireTimes,
) -> Result<()> {
    let mut payload = resp.encode();
    payload.extend_from_slice(&times.encode());
    write_frame_flagged(w, request_id, FLAG_TRACED, &payload)
}

/// Read + decode one response with the request id it answers
/// (`Ok(None)` on clean EOF). A traced frame's timing annex is
/// stripped and discarded — use [`read_response_timed`] to keep it.
pub fn read_response(r: &mut dyn Read) -> Result<Option<(u64, Response)>> {
    match read_response_timed(r)? {
        Some((id, resp, _times)) => Ok(Some((id, resp))),
        None => Ok(None),
    }
}

/// Read + decode one response plus the [`WireTimes`] annex when the
/// frame carried [`FLAG_TRACED`] (`Ok(None)` on clean EOF).
pub fn read_response_timed(
    r: &mut dyn Read,
) -> Result<Option<(u64, Response, Option<WireTimes>)>> {
    match read_frame(r)? {
        Some((id, flags, payload)) => {
            let (msg, times) = split_times(flags, &payload)?;
            Ok(Some((id, Response::decode(msg)?, times)))
        }
        None => Ok(None),
    }
}

/// Decode a response frame body delivered by a frame-at-a-time reader
/// (the remote mux loop): strips the annex when `flags` carries
/// [`FLAG_TRACED`].
pub fn decode_response_payload(
    flags: u8,
    payload: &[u8],
) -> Result<(Response, Option<WireTimes>)> {
    let (msg, times) = split_times(flags, payload)?;
    Ok((Response::decode(msg)?, times))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame_bytes(payload: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        write_frame(&mut out, 0, payload).unwrap();
        out
    }

    /// Golden bytes: the full Ping frame, byte for byte (version 5:
    /// request id 7 in the header, flags byte clear). Changing this is
    /// a wire-format break.
    #[test]
    fn golden_ping_frame() {
        let mut bytes = Vec::new();
        write_frame(&mut bytes, 7, &Request::Ping.encode()).unwrap();
        #[rustfmt::skip]
        let want: Vec<u8> = vec![
            b'Z', b'N', b'W', b'1',                         // magic
            0x05, 0x00,                                     // version 5
            0x01, 0x00, 0x00, 0x00,                         // payload len 1
            0x07, 0, 0, 0, 0, 0, 0, 0,                      // request id 7
            0x00,                                           // flags (none)
            0x01,                                           // Ping tag
        ];
        assert_eq!(bytes, want);
        let mut r = &bytes[..];
        assert_eq!(
            read_request(&mut r).unwrap(),
            Some((7u64, Request::Ping))
        );
    }

    /// Golden bytes: an Estimate request payload with known fields
    /// (version 2 added the precision byte + deadline budget).
    #[test]
    fn golden_estimate_payload() {
        let req = Request::Estimate {
            kind: EstimatorKind::Mimps,
            k: 2,
            l: 3,
            precision: Precision::Pipelined,
            deadline_ns: 5_000,
            query: vec![1.0, -2.0],
        };
        #[rustfmt::skip]
        let want: Vec<u8> = vec![
            0x03,                                           // tag
            0x03,                                           // kind = Mimps
            0x02, 0, 0, 0, 0, 0, 0, 0,                      // k = 2
            0x03, 0, 0, 0, 0, 0, 0, 0,                      // l = 3
            0x01,                                           // precision = Pipelined
            0x88, 0x13, 0, 0, 0, 0, 0, 0,                   // deadline_ns = 5000
            0x02, 0, 0, 0,                                  // query len = 2
            0x00, 0x00, 0x80, 0x3f,                         // 1.0f32
            0x00, 0x00, 0x00, 0xc0,                         // -2.0f32
        ];
        assert_eq!(req.encode(), want);
        assert_eq!(Request::decode(&want).unwrap(), req);
        // An unknown precision byte is malformed, not defaulted.
        let mut bad = want.clone();
        bad[18] = 7;
        assert!(matches!(
            Request::decode(&bad),
            Err(WireError::Malformed(_))
        ));
    }

    /// Golden bytes: an ExpSumPart request payload with known fields.
    #[test]
    fn golden_exp_sum_part_payload() {
        let req = Request::ExpSumPart {
            queries: vec![vec![1.0, -2.0], vec![0.5, 0.25]],
        };
        #[rustfmt::skip]
        let want: Vec<u8> = vec![
            0x0e,                                           // tag
            0x02, 0, 0, 0,                                  // 2 queries
            0x02, 0, 0, 0,                                  // dim = 2
            0x00, 0x00, 0x80, 0x3f,                         // 1.0f32
            0x00, 0x00, 0x00, 0xc0,                         // -2.0f32
            0x00, 0x00, 0x00, 0x3f,                         // 0.5f32
            0x00, 0x00, 0x80, 0x3e,                         // 0.25f32
        ];
        assert_eq!(req.encode(), want);
        assert_eq!(Request::decode(&want).unwrap(), req);
    }

    /// Golden bytes: a Hits response payload with one query, two hits.
    #[test]
    fn golden_hits_payload() {
        let resp = Response::Hits(vec![vec![
            Hit { idx: 7, score: 0.5 },
            Hit { idx: 1, score: -1.5 },
        ]]);
        #[rustfmt::skip]
        let want: Vec<u8> = vec![
            0x04,                                           // tag
            0x01, 0, 0, 0,                                  // 1 query
            0x02, 0, 0, 0,                                  // 2 hits
            0x07, 0, 0, 0, 0, 0, 0, 0,                      // idx 7
            0x00, 0x00, 0x00, 0x3f,                         // 0.5f32
            0x01, 0, 0, 0, 0, 0, 0, 0,                      // idx 1
            0x00, 0x00, 0xc0, 0xbf,                         // -1.5f32
        ];
        assert_eq!(resp.encode(), want);
        assert_eq!(Response::decode(&want).unwrap(), resp);
    }

    #[test]
    fn golden_error_payload() {
        let resp = Response::Error {
            code: ErrorCode::DimMismatch,
            message: "bad".to_string(),
        };
        let want: Vec<u8> = vec![0x0a, 0x03, 0x00, 0x03, 0, 0, 0, b'b', b'a', b'd'];
        assert_eq!(resp.encode(), want);
        assert_eq!(Response::decode(&want).unwrap(), resp);
    }

    /// Golden bytes: a FitFmbe request payload with known fields.
    #[test]
    fn golden_fit_fmbe_payload() {
        let req = Request::FitFmbe {
            seed: 9,
            p_features: 400,
        };
        #[rustfmt::skip]
        let want: Vec<u8> = vec![
            0x0d,                                           // tag
            0x09, 0, 0, 0, 0, 0, 0, 0,                      // seed = 9
            0x90, 0x01, 0, 0, 0, 0, 0, 0,                   // p_features = 400
        ];
        assert_eq!(req.encode(), want);
        assert_eq!(Request::decode(&want).unwrap(), req);
    }

    /// Golden bytes: an Estimates response payload with one entry
    /// (version 4 appended the `served_from_cache` byte — 42 bytes per
    /// estimate).
    #[test]
    fn golden_estimates_payload() {
        let resp = Response::Estimates(vec![Estimate {
            z: 1.0,
            kind: EstimatorKind::Mince,
            epoch: 3,
            scorings: 600,
            queue_wait_ns: 5_000,
            exec_ns: 400,
            served_from_cache: true,
        }]);
        #[rustfmt::skip]
        let want: Vec<u8> = vec![
            0x03,                                           // tag
            0x01, 0, 0, 0,                                  // 1 estimate
            0, 0, 0, 0, 0, 0, 0xf0, 0x3f,                   // z = 1.0f64
            0x04,                                           // kind = Mince
            0x03, 0, 0, 0, 0, 0, 0, 0,                      // epoch = 3
            0x58, 0x02, 0, 0, 0, 0, 0, 0,                   // scorings = 600
            0x88, 0x13, 0, 0, 0, 0, 0, 0,                   // queue_wait_ns = 5000
            0x90, 0x01, 0, 0, 0, 0, 0, 0,                   // exec_ns = 400
            0x01,                                           // served_from_cache
        ];
        assert_eq!(resp.encode(), want);
        assert_eq!(Response::decode(&want).unwrap(), resp);
        // Anything but 0/1 in the cache byte is malformed, not defaulted.
        let mut bad = want.clone();
        *bad.last_mut().unwrap() = 7;
        assert!(matches!(
            Response::decode(&bad),
            Err(WireError::Malformed(_))
        ));
    }

    /// Golden bytes: a Metrics response payload with one counter and
    /// one sparse-bucketed histogram (wire version 5).
    #[test]
    fn golden_metrics_payload() {
        let resp = Response::Metrics(MetricsBlob {
            counters: vec![("completed".to_string(), 7)],
            hists: vec![(
                "queue_ns".to_string(),
                HistogramSnapshot {
                    count: 2,
                    sum: 3000,
                    max: 2000,
                    buckets: vec![(10, 1), (96, 1)],
                },
            )],
        });
        #[rustfmt::skip]
        let want: Vec<u8> = vec![
            0x0c,                                           // tag
            0x01, 0, 0, 0,                                  // 1 counter
            0x09, 0, 0, 0,                                  // name len 9
            b'c', b'o', b'm', b'p', b'l', b'e', b't', b'e', b'd',
            0x07, 0, 0, 0, 0, 0, 0, 0,                      // value = 7
            0x01, 0, 0, 0,                                  // 1 histogram
            0x08, 0, 0, 0,                                  // name len 8
            b'q', b'u', b'e', b'u', b'e', b'_', b'n', b's',
            0x02, 0, 0, 0, 0, 0, 0, 0,                      // count = 2
            0xb8, 0x0b, 0, 0, 0, 0, 0, 0,                   // sum = 3000
            0xd0, 0x07, 0, 0, 0, 0, 0, 0,                   // max = 2000
            0x02, 0, 0, 0,                                  // 2 buckets
            0x0a, 0, 0, 0,                                  // bucket idx 10
            0x01, 0, 0, 0, 0, 0, 0, 0,                      // count 1
            0x60, 0, 0, 0,                                  // bucket idx 96
            0x01, 0, 0, 0, 0, 0, 0, 0,                      // count 1
        ];
        assert_eq!(resp.encode(), want);
        assert_eq!(Response::decode(&want).unwrap(), resp);
        // A bucket-count bomb must be rejected before allocating.
        let mut bomb = vec![0x0c];
        bomb.extend_from_slice(&0u32.to_le_bytes()); // no counters
        bomb.extend_from_slice(&u32::MAX.to_le_bytes()); // 4G histograms
        assert!(matches!(
            Response::decode(&bomb),
            Err(WireError::Malformed(_))
        ));
    }

    /// Golden bytes: a Lambdas response payload with known fields.
    #[test]
    fn golden_lambdas_payload() {
        let resp = Response::Lambdas {
            epoch: 2,
            lambdas: vec![1.0, -0.5],
        };
        #[rustfmt::skip]
        let want: Vec<u8> = vec![
            0x0b,                                           // tag
            0x02, 0, 0, 0, 0, 0, 0, 0,                      // epoch = 2
            0x02, 0, 0, 0,                                  // 2 lambdas
            0, 0, 0, 0, 0, 0, 0xf0, 0x3f,                   // 1.0f64
            0, 0, 0, 0, 0, 0, 0xe0, 0xbf,                   // -0.5f64
        ];
        assert_eq!(resp.encode(), want);
        assert_eq!(Response::decode(&want).unwrap(), resp);
    }

    /// The borrowed-encode fast path must produce byte-identical
    /// payloads to the owned [`Request::encode`] — it is the same wire
    /// format, minus the intermediate clone.
    #[test]
    fn borrowed_encode_matches_owned() {
        let queries = vec![vec![1.0f32, -2.0], vec![0.5, 3.25]];
        let ids = vec![0u64, 17, 40];
        let q = vec![0.25f32, -1.5];
        let rows = vec![1.0f32, 2.0, 3.0, 4.0];
        let accs = vec![1.5f64, -2.5];
        let cases: Vec<(Encoded, Request)> = vec![
            (Encoded::manifest(), Request::Manifest),
            (
                Encoded::top_k(7, &queries),
                Request::TopK {
                    k: 7,
                    queries: queries.clone(),
                },
            ),
            (
                Encoded::exp_sum_chain(12.5, &q),
                Request::ExpSumChain {
                    acc: 12.5,
                    query: q.clone(),
                },
            ),
            (
                Encoded::exp_sum_chain_batch(&accs, &queries),
                Request::ExpSumChainBatch {
                    acc_in: accs.clone(),
                    queries: queries.clone(),
                },
            ),
            (
                Encoded::exp_sum_part(&queries),
                Request::ExpSumPart {
                    queries: queries.clone(),
                },
            ),
            (
                Encoded::score_ids(&ids, &q),
                Request::ScoreIds {
                    ids: ids.clone(),
                    query: q.clone(),
                },
            ),
            (
                Encoded::prepare_add(3, 2, &rows),
                Request::PrepareAdd {
                    token: 3,
                    dim: 2,
                    rows: rows.clone(),
                },
            ),
            (
                Encoded::prepare_remove(4, &ids),
                Request::PrepareRemove {
                    token: 4,
                    ids: ids.clone(),
                },
            ),
            (Encoded::commit(5), Request::Commit { token: 5 }),
            (Encoded::abort(6), Request::Abort { token: 6 }),
            (
                Encoded::fit_fmbe(9, 400),
                Request::FitFmbe {
                    seed: 9,
                    p_features: 400,
                },
            ),
            (Encoded::get_metrics(), Request::GetMetrics),
        ];
        for (enc, req) in cases {
            assert_eq!(enc.payload(), req.encode().as_slice(), "{req:?}");
        }
        assert!(!Encoded::commit(1).resend_safe(), "Commit must not resend");
        assert!(Encoded::prepare_add(1, 2, &rows).resend_safe());
    }

    #[test]
    fn request_roundtrips() {
        let reqs = vec![
            Request::Ping,
            Request::Manifest,
            Request::Estimate {
                kind: EstimatorKind::Exact,
                k: 0,
                l: 0,
                precision: Precision::BitExact,
                deadline_ns: 0,
                query: vec![0.25, 1e30, -0.0],
            },
            Request::EstimateBatch {
                kind: EstimatorKind::Fmbe,
                k: 10,
                l: 20,
                precision: Precision::Pipelined,
                deadline_ns: u64::MAX,
                queries: vec![vec![1.0, 2.0], vec![3.0, 4.0]],
            },
            Request::ExpSumPart {
                queries: vec![vec![0.5; 3]; 2],
            },
            Request::TopK {
                k: 5,
                queries: vec![vec![0.5; 7]; 3],
            },
            Request::ExpSumChain {
                acc: 123.456,
                query: vec![-1.0, 2.5],
            },
            Request::ExpSumChainBatch {
                acc_in: vec![1.0, 2.0],
                queries: vec![vec![0.0; 4]; 2],
            },
            Request::ScoreIds {
                ids: vec![0, 9, u64::from(u32::MAX)],
                query: vec![1.5; 3],
            },
            Request::PrepareAdd {
                token: 42,
                dim: 2,
                rows: vec![1.0, 2.0, 3.0, 4.0],
            },
            Request::PrepareRemove {
                token: 7,
                ids: vec![],
            },
            Request::Commit { token: 9 },
            Request::Abort { token: 11 },
            Request::FitFmbe {
                seed: u64::MAX,
                p_features: 10_000,
            },
            Request::GetMetrics,
        ];
        for req in reqs {
            let got = Request::decode(&req.encode()).unwrap();
            assert_eq!(got, req);
        }
    }

    #[test]
    fn response_roundtrips() {
        let resps = vec![
            Response::Pong,
            Response::Manifest {
                len: 1_000_000,
                dim: 300,
                epoch: 17,
            },
            Response::Estimates(vec![Estimate {
                z: 1234.5,
                kind: EstimatorKind::Mimps,
                epoch: 3,
                scorings: 200,
                queue_wait_ns: 5_000,
                exec_ns: 77_000,
                served_from_cache: false,
            }]),
            Response::Hits(vec![vec![], vec![Hit { idx: 0, score: 1.0 }]]),
            Response::ExpSums(vec![1.0, f64::MAX, 1e-300]),
            Response::Scores(vec![-1.0, 0.0, 3.5]),
            Response::Prepared { epoch: 2 },
            Response::Committed { epoch: 2 },
            Response::Aborted,
            Response::Lambdas {
                epoch: 5,
                lambdas: vec![0.0, -1e300, 42.5],
            },
            Response::Metrics(MetricsBlob {
                counters: vec![("completed".to_string(), u64::MAX), ("shed".to_string(), 0)],
                hists: vec![
                    (
                        "e2e_ns".to_string(),
                        HistogramSnapshot {
                            count: 3,
                            sum: 12_000,
                            max: 9_000,
                            buckets: vec![(0, 1), (400, 2)],
                        },
                    ),
                    ("empty".to_string(), HistogramSnapshot::default()),
                ],
            }),
            Response::Error {
                code: ErrorCode::Unknown(999),
                message: "later version says hi".to_string(),
            },
        ];
        for resp in resps {
            let got = Response::decode(&resp.encode()).unwrap();
            assert_eq!(got, resp);
        }
    }

    #[test]
    fn frame_roundtrip_through_a_byte_stream() {
        let mut buf = Vec::new();
        write_request(&mut buf, 1, &Request::Commit { token: 5 }).unwrap();
        write_request(&mut buf, u64::MAX, &Request::Ping).unwrap();
        let mut r = &buf[..];
        assert_eq!(
            read_request(&mut r).unwrap(),
            Some((1, Request::Commit { token: 5 }))
        );
        assert_eq!(
            read_request(&mut r).unwrap(),
            Some((u64::MAX, Request::Ping))
        );
        assert_eq!(read_request(&mut r).unwrap(), None, "clean EOF");
    }

    #[test]
    fn header_helpers_match_frame_io() {
        let payload = Request::Ping.encode();
        let header = encode_header(42, payload.len());
        assert_eq!(decode_header(&header).unwrap(), (42, 0, payload.len()));
        let mut framed = header.to_vec();
        framed.extend_from_slice(&payload);
        let mut by_writer = Vec::new();
        write_frame(&mut by_writer, 42, &payload).unwrap();
        assert_eq!(framed, by_writer);
        // The flagged variant only differs in the flags byte.
        let flagged = encode_header_flagged(42, payload.len(), FLAG_TRACED);
        assert_eq!(&flagged[..18], &header[..18]);
        assert_eq!(flagged[18], FLAG_TRACED);
        assert_eq!(
            decode_header(&flagged).unwrap(),
            (42, FLAG_TRACED, payload.len())
        );
    }

    #[test]
    fn unknown_flag_bits_rejected() {
        let mut header = encode_header(1, 0);
        header[18] = 0b0000_0010;
        assert!(matches!(decode_header(&header), Err(WireError::Malformed(_))));
        header[18] = 0xff;
        assert!(matches!(decode_header(&header), Err(WireError::Malformed(_))));
    }

    /// A traced response carries FLAG_TRACED and a 16-byte annex after
    /// the payload; the annex is stripped before decode and surfaced
    /// through the timed reader only.
    #[test]
    fn traced_response_roundtrips_with_annex() {
        let times = WireTimes {
            handle_lag_ns: 1_500,
            exec_ns: 42_000,
        };
        let resp = Response::Pong;
        let mut bytes = Vec::new();
        write_response_timed(&mut bytes, 9, &resp, times).unwrap();
        // Header: flags byte set, len covers payload + annex.
        assert_eq!(bytes[18], FLAG_TRACED);
        let len = u32::from_le_bytes(bytes[6..10].try_into().unwrap()) as usize;
        assert_eq!(len, resp.encode().len() + WireTimes::LEN);
        // Timed reader surfaces the annex...
        let mut r = &bytes[..];
        assert_eq!(
            read_response_timed(&mut r).unwrap(),
            Some((9, Response::Pong, Some(times)))
        );
        // ...the plain reader strips and discards it.
        let mut r = &bytes[..];
        assert_eq!(read_response(&mut r).unwrap(), Some((9, Response::Pong)));
        // A traced frame too short to hold the annex is malformed.
        let short = encode_header_flagged(3, 1, FLAG_TRACED);
        let mut framed = short.to_vec();
        framed.push(RESP_PONG);
        let mut r = &framed[..];
        assert!(matches!(
            read_response(&mut r),
            Err(WireError::Malformed(_))
        ));
        // An untraced frame never grows an annex.
        let mut plain = Vec::new();
        write_response(&mut plain, 2, &Response::Pong).unwrap();
        let mut r = &plain[..];
        assert_eq!(
            read_response_timed(&mut r).unwrap(),
            Some((2, Response::Pong, None))
        );
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = frame_bytes(&Request::Ping.encode());
        bytes[0] = b'X';
        let mut r = &bytes[..];
        assert!(matches!(read_frame(&mut r), Err(WireError::BadMagic(_))));
    }

    #[test]
    fn bad_version_rejected() {
        let mut bytes = frame_bytes(&Request::Ping.encode());
        bytes[4] = 9;
        let mut r = &bytes[..];
        assert!(matches!(read_frame(&mut r), Err(WireError::BadVersion(9))));
    }

    #[test]
    fn truncated_frame_rejected_not_eof() {
        let bytes = frame_bytes(&Request::Manifest.encode());
        // Cut mid-magic, mid-request-id and mid-payload: all are
        // malformed, not EOF.
        for cut in [3usize, 12, bytes.len() - 1] {
            let mut r = &bytes[..cut];
            assert!(
                matches!(read_frame(&mut r), Err(WireError::Malformed(_))),
                "cut at {cut}"
            );
        }
    }

    #[test]
    fn oversized_length_prefix_rejected_without_allocating() {
        let mut bytes = frame_bytes(&Request::Ping.encode());
        // Claim a payload just past the cap.
        let bad = (MAX_FRAME_LEN as u32) + 1;
        bytes[6..10].copy_from_slice(&bad.to_le_bytes());
        let mut r = &bytes[..];
        assert!(matches!(
            read_frame(&mut r),
            Err(WireError::FrameTooLarge(_))
        ));
    }

    #[test]
    fn inner_length_bomb_rejected() {
        // A ScoreIds whose id count claims more elements than the body
        // holds must fail before allocating.
        let mut payload = vec![8u8]; // REQ_SCORE_IDS
        payload.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            Request::decode(&payload),
            Err(WireError::Malformed(_))
        ));
        // A query block claiming 4G zero-dimensional queries (d = 0
        // zeroes the byte-cost bound) must also fail before allocating.
        let mut payload = vec![5u8]; // REQ_TOP_K
        payload.extend_from_slice(&7u64.to_le_bytes()); // k
        payload.extend_from_slice(&u32::MAX.to_le_bytes()); // count
        payload.extend_from_slice(&0u32.to_le_bytes()); // dim = 0
        assert!(matches!(
            Request::decode(&payload),
            Err(WireError::Malformed(_))
        ));
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut payload = Request::Ping.encode();
        payload.push(0);
        assert!(matches!(
            Request::decode(&payload),
            Err(WireError::Malformed(_))
        ));
    }

    #[test]
    fn unknown_tags_rejected() {
        assert!(matches!(
            Request::decode(&[200]),
            Err(WireError::Malformed(_))
        ));
        assert!(matches!(
            Response::decode(&[200]),
            Err(WireError::Malformed(_))
        ));
    }

    #[test]
    fn error_codes_roundtrip() {
        for code in [
            ErrorCode::Overloaded,
            ErrorCode::Closed,
            ErrorCode::DimMismatch,
            ErrorCode::Unsupported,
            ErrorCode::BadRequest,
            ErrorCode::Internal,
            ErrorCode::StalePrepare,
            ErrorCode::Busy,
            ErrorCode::ConnLimit,
            ErrorCode::DeadlineExceeded,
            ErrorCode::Unknown(4242),
        ] {
            assert_eq!(ErrorCode::from_u16(code.as_u16()), code);
        }
    }
}
