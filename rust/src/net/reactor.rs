//! A minimal readiness poller: the hand-rolled `mio`-style shim under
//! the reactor server and nothing more.
//!
//! [`Poller`] wraps one OS readiness queue — `epoll` on Linux, `kqueue`
//! on the BSD family (macOS included) — through raw `extern "C"`
//! declarations against the libc that `std` already links, because the
//! crate is vendored/offline and carries no `libc`/`mio`/`tokio`
//! dependency. File descriptors are registered with a caller-chosen
//! `u64` token and a readable/writable interest pair; [`Poller::wait`]
//! blocks until something is ready (or a timeout passes) and translates
//! OS events back into [`Event`]s. Error/hang-up conditions are folded
//! into readability so a single read path observes them as `Ok(0)` /
//! `Err` — callers never need to know the platform's event flags.
//!
//! [`Waker`] is the cross-thread wakeup primitive: a nonblocking pipe
//! whose read end is registered with the poller. Any thread holding the
//! waker can interrupt a blocked [`Poller::wait`] by writing one byte;
//! the reactor drains the pipe and consults whatever shared queue the
//! wakeup advertised (new connections, handler completions, shutdown).
//! This is the "graceful shutdown via a wakeup pipe" seam: dropping the
//! server sets a stop flag and wakes every reactor thread exactly once.
//!
//! The poller is level-triggered on both platforms: an event repeats on
//! every `wait` until the condition is consumed, so a reactor that
//! processes only part of a read buffer is re-notified instead of
//! hanging. All syscall wrappers retry on `EINTR`.

use std::io;
use std::os::fd::RawFd;
use std::time::Duration;

/// One readiness notification out of [`Poller::wait`].
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// The token the file descriptor was registered under.
    pub token: u64,
    /// Reading would make progress (data, EOF, error or hang-up).
    pub readable: bool,
    /// Writing would make progress.
    pub writable: bool,
}

#[cfg(target_os = "linux")]
mod sys {
    use std::os::raw::c_int;

    // glibc packs epoll_event on x86_64 only; mirror that or the
    // kernel writes events at offsets the compiler does not expect.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout_ms: c_int,
        ) -> c_int;
    }

    pub const EPOLL_CLOEXEC: c_int = 0x8_0000;
    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;
    pub const EPOLL_CTL_MOD: c_int = 3;
    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;
}

#[cfg(any(
    target_os = "macos",
    target_os = "ios",
    target_os = "freebsd",
    target_os = "netbsd",
    target_os = "openbsd",
    target_os = "dragonfly"
))]
mod sys {
    use std::os::raw::{c_int, c_void};

    #[repr(C)]
    pub struct Kevent {
        pub ident: usize,
        pub filter: i16,
        pub flags: u16,
        pub fflags: u32,
        pub data: isize,
        pub udata: *mut c_void,
    }

    #[repr(C)]
    pub struct Timespec {
        pub tv_sec: isize,
        pub tv_nsec: isize,
    }

    extern "C" {
        pub fn kqueue() -> c_int;
        pub fn kevent(
            kq: c_int,
            changelist: *const Kevent,
            nchanges: c_int,
            eventlist: *mut Kevent,
            nevents: c_int,
            timeout: *const Timespec,
        ) -> c_int;
    }

    pub const EVFILT_READ: i16 = -1;
    pub const EVFILT_WRITE: i16 = -2;
    pub const EV_ADD: u16 = 0x0001;
    pub const EV_DELETE: u16 = 0x0002;
    pub const EV_ERROR: u16 = 0x4000;
}

#[cfg(not(any(
    target_os = "linux",
    target_os = "macos",
    target_os = "ios",
    target_os = "freebsd",
    target_os = "netbsd",
    target_os = "openbsd",
    target_os = "dragonfly"
)))]
compile_error!("net::reactor needs epoll (Linux) or kqueue (BSD/macOS)");

mod fdio {
    //! Raw pipe/fd helpers shared by both poller backends.
    use std::io;
    use std::os::raw::{c_int, c_void};

    extern "C" {
        fn pipe(fds: *mut c_int) -> c_int;
        fn fcntl(fd: c_int, cmd: c_int, ...) -> c_int;
        pub fn close(fd: c_int) -> c_int;
        fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
        fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    }

    const F_SETFD: c_int = 2;
    const F_GETFL: c_int = 3;
    const F_SETFL: c_int = 4;
    const FD_CLOEXEC: c_int = 1;
    #[cfg(target_os = "linux")]
    const O_NONBLOCK: c_int = 0x800;
    #[cfg(not(target_os = "linux"))]
    const O_NONBLOCK: c_int = 0x4;

    /// A nonblocking close-on-exec pipe as `(read_fd, write_fd)`.
    pub fn nonblocking_pipe() -> io::Result<(c_int, c_int)> {
        let mut fds = [0 as c_int; 2];
        if unsafe { pipe(fds.as_mut_ptr()) } < 0 {
            return Err(io::Error::last_os_error());
        }
        for fd in fds {
            unsafe {
                let flags = fcntl(fd, F_GETFL);
                if flags < 0 || fcntl(fd, F_SETFL, flags | O_NONBLOCK) < 0 {
                    let e = io::Error::last_os_error();
                    close(fds[0]);
                    close(fds[1]);
                    return Err(e);
                }
                fcntl(fd, F_SETFD, FD_CLOEXEC);
            }
        }
        Ok((fds[0], fds[1]))
    }

    /// Best-effort single-byte write (wakeups coalesce when the pipe is
    /// already full, so `EAGAIN` is success).
    pub fn write_byte(fd: c_int) {
        let byte = 1u8;
        unsafe {
            let _ = write(fd, (&byte as *const u8).cast::<c_void>(), 1);
        }
    }

    /// Read and discard everything currently buffered.
    pub fn drain(fd: c_int) {
        let mut buf = [0u8; 64];
        loop {
            let n = unsafe { read(fd, buf.as_mut_ptr().cast::<c_void>(), buf.len()) };
            if n <= 0 {
                return;
            }
        }
    }
}

/// An OS readiness queue (`epoll` / `kqueue`) owning its queue fd.
pub struct Poller {
    fd: RawFd,
}

// The poller fd is just a kernel handle; registration and waiting are
// thread-safe at the syscall level.
unsafe impl Send for Poller {}
unsafe impl Sync for Poller {}

impl Drop for Poller {
    fn drop(&mut self) {
        unsafe {
            fdio::close(self.fd);
        }
    }
}

#[cfg(target_os = "linux")]
impl Poller {
    /// Create an empty readiness queue.
    pub fn new() -> io::Result<Poller> {
        let fd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Poller { fd })
    }

    fn ctl(
        &self,
        op: std::os::raw::c_int,
        fd: RawFd,
        token: u64,
        r: bool,
        w: bool,
    ) -> io::Result<()> {
        let mut ev = sys::EpollEvent {
            events: if r { sys::EPOLLIN | sys::EPOLLRDHUP } else { 0 }
                | if w { sys::EPOLLOUT } else { 0 },
            data: token,
        };
        if unsafe { sys::epoll_ctl(self.fd, op, fd, &mut ev) } < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    /// Start watching `fd` under `token` with the given interests.
    pub fn register(
        &self,
        fd: RawFd,
        token: u64,
        readable: bool,
        writable: bool,
    ) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, token, readable, writable)
    }

    /// Change the interest set of an already registered `fd`.
    pub fn reregister(
        &self,
        fd: RawFd,
        token: u64,
        readable: bool,
        writable: bool,
    ) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_MOD, fd, token, readable, writable)
    }

    /// Stop watching `fd` (closing the fd also deregisters it).
    pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_DEL, fd, 0, false, false)
    }

    /// Block until readiness or `timeout` (None = forever), appending
    /// into `out` (cleared first). `EINTR` returns empty-handed.
    pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
        out.clear();
        let tmo = match timeout {
            // Round up so a 100µs timeout polls, not busy-spins.
            Some(d) => d.as_millis().clamp(1, i32::MAX as u128) as i32,
            None => -1,
        };
        let mut buf: Vec<sys::EpollEvent> = Vec::with_capacity(256);
        let n = unsafe { sys::epoll_wait(self.fd, buf.as_mut_ptr(), 256, tmo) };
        if n < 0 {
            let e = io::Error::last_os_error();
            if e.kind() == io::ErrorKind::Interrupted {
                return Ok(());
            }
            return Err(e);
        }
        unsafe { buf.set_len(n as usize) };
        for ev in &buf {
            let bits = ev.events;
            let hup = bits & (sys::EPOLLERR | sys::EPOLLHUP | sys::EPOLLRDHUP) != 0;
            out.push(Event {
                token: ev.data,
                readable: bits & sys::EPOLLIN != 0 || hup,
                writable: bits & sys::EPOLLOUT != 0 || hup,
            });
        }
        Ok(())
    }
}

#[cfg(not(target_os = "linux"))]
impl Poller {
    /// Create an empty readiness queue.
    pub fn new() -> io::Result<Poller> {
        let fd = unsafe { sys::kqueue() };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Poller { fd })
    }

    fn apply(&self, fd: RawFd, filter: i16, flags: u16, token: u64) -> io::Result<()> {
        let change = sys::Kevent {
            ident: fd as usize,
            filter,
            flags,
            fflags: 0,
            data: 0,
            udata: token as *mut std::os::raw::c_void,
        };
        if unsafe { sys::kevent(self.fd, &change, 1, std::ptr::null_mut(), 0, std::ptr::null()) }
            < 0
        {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    fn set(&self, fd: RawFd, token: u64, readable: bool, writable: bool) -> io::Result<()> {
        // EV_ADD on an existing filter updates it, so register and
        // reregister share this; dropping an interest is a delete whose
        // ENOENT is fine.
        for (filter, on) in [(sys::EVFILT_READ, readable), (sys::EVFILT_WRITE, writable)] {
            if on {
                self.apply(fd, filter, sys::EV_ADD, token)?;
            } else {
                let _ = self.apply(fd, filter, sys::EV_DELETE, token);
            }
        }
        Ok(())
    }

    /// Start watching `fd` under `token` with the given interests.
    pub fn register(
        &self,
        fd: RawFd,
        token: u64,
        readable: bool,
        writable: bool,
    ) -> io::Result<()> {
        self.set(fd, token, readable, writable)
    }

    /// Change the interest set of an already registered `fd`.
    pub fn reregister(
        &self,
        fd: RawFd,
        token: u64,
        readable: bool,
        writable: bool,
    ) -> io::Result<()> {
        self.set(fd, token, readable, writable)
    }

    /// Stop watching `fd` (closing the fd also deregisters it).
    pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
        self.set(fd, 0, false, false)
    }

    /// Block until readiness or `timeout` (None = forever), appending
    /// into `out` (cleared first). `EINTR` returns empty-handed.
    pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
        out.clear();
        let ts;
        let ts_ptr = match timeout {
            Some(d) => {
                ts = sys::Timespec {
                    tv_sec: d.as_secs() as isize,
                    tv_nsec: d.subsec_nanos() as isize,
                };
                &ts as *const sys::Timespec
            }
            None => std::ptr::null(),
        };
        let mut buf: Vec<sys::Kevent> = Vec::with_capacity(256);
        let n = unsafe { sys::kevent(self.fd, std::ptr::null(), 0, buf.as_mut_ptr(), 256, ts_ptr) };
        if n < 0 {
            let e = io::Error::last_os_error();
            if e.kind() == io::ErrorKind::Interrupted {
                return Ok(());
            }
            return Err(e);
        }
        unsafe { buf.set_len(n as usize) };
        for ev in &buf {
            if ev.flags & sys::EV_ERROR != 0 {
                // A deferred registration error: surface it as
                // readability so the consumer's read path reports it.
                out.push(Event {
                    token: ev.udata as u64,
                    readable: true,
                    writable: true,
                });
                continue;
            }
            out.push(Event {
                token: ev.udata as u64,
                readable: ev.filter == sys::EVFILT_READ,
                writable: ev.filter == sys::EVFILT_WRITE,
            });
        }
        Ok(())
    }
}

/// Cross-thread wakeup for a [`Poller`]: a nonblocking pipe whose read
/// end is registered under a caller-chosen token. `wake` from any
/// thread makes a blocked [`Poller::wait`] return with that token;
/// `drain` (called by the reactor on seeing it) resets the pipe.
pub struct Waker {
    read_fd: RawFd,
    write_fd: RawFd,
}

impl Waker {
    /// Build a waker and register its read end with `poller`.
    pub fn new(poller: &Poller, token: u64) -> io::Result<Waker> {
        let (read_fd, write_fd) = fdio::nonblocking_pipe()?;
        if let Err(e) = poller.register(read_fd, token, true, false) {
            unsafe {
                fdio::close(read_fd);
                fdio::close(write_fd);
            }
            return Err(e);
        }
        Ok(Waker { read_fd, write_fd })
    }

    /// Interrupt the poller (coalesces when one is already pending).
    pub fn wake(&self) {
        fdio::write_byte(self.write_fd);
    }

    /// Consume pending wakeups so the next `wait` blocks again.
    pub fn drain(&self) {
        fdio::drain(self.read_fd);
    }
}

impl Drop for Waker {
    fn drop(&mut self) {
        unsafe {
            fdio::close(self.read_fd);
            fdio::close(self.write_fd);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::os::fd::AsRawFd;
    use std::time::Instant;

    #[test]
    fn waker_interrupts_and_coalesces() {
        let poller = Poller::new().unwrap();
        let waker = Waker::new(&poller, 42).unwrap();
        let mut events = Vec::new();

        // Nothing pending: the wait times out empty.
        poller.wait(&mut events, Some(Duration::from_millis(5))).unwrap();
        assert!(events.is_empty());

        waker.wake();
        waker.wake(); // coalesced, not queued twice
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 42);
        assert!(events[0].readable);

        // Drained: quiet again (level-triggered otherwise re-fires).
        waker.drain();
        poller.wait(&mut events, Some(Duration::from_millis(5))).unwrap();
        assert!(events.is_empty());
    }

    #[test]
    fn waker_crosses_threads() {
        let poller = Poller::new().unwrap();
        let waker = std::sync::Arc::new(Waker::new(&poller, 7).unwrap());
        let w = waker.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            w.wake();
        });
        let start = Instant::now();
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_secs(10))).unwrap();
        assert_eq!(events.len(), 1);
        assert!(start.elapsed() < Duration::from_secs(5));
        t.join().unwrap();
    }

    #[test]
    fn socket_readiness_and_interest_changes() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = std::net::TcpStream::connect(addr).unwrap();
        let (mut served, _) = listener.accept().unwrap();
        served.set_nonblocking(true).unwrap();

        let poller = Poller::new().unwrap();
        poller
            .register(served.as_raw_fd(), 1, true, false)
            .unwrap();

        let mut events = Vec::new();
        // Quiet until the client writes.
        poller.wait(&mut events, Some(Duration::from_millis(5))).unwrap();
        assert!(events.iter().all(|e| e.token != 1 || !e.readable));

        client.write_all(b"hi").unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut saw_readable = false;
        while !saw_readable && Instant::now() < deadline {
            poller.wait(&mut events, Some(Duration::from_millis(100))).unwrap();
            saw_readable = events.iter().any(|e| e.token == 1 && e.readable);
        }
        assert!(saw_readable, "client bytes never surfaced as readiness");
        let mut buf = [0u8; 8];
        assert_eq!(served.read(&mut buf).unwrap(), 2);

        // Ask for writability too: an idle socket is instantly writable.
        poller
            .reregister(served.as_raw_fd(), 1, true, true)
            .unwrap();
        let mut saw_writable = false;
        let deadline = Instant::now() + Duration::from_secs(10);
        while !saw_writable && Instant::now() < deadline {
            poller.wait(&mut events, Some(Duration::from_millis(100))).unwrap();
            saw_writable = events.iter().any(|e| e.token == 1 && e.writable);
        }
        assert!(saw_writable);

        poller.deregister(served.as_raw_fd()).unwrap();
        poller.wait(&mut events, Some(Duration::from_millis(5))).unwrap();
        assert!(events.is_empty(), "deregistered fd still reported");
    }

    #[test]
    fn eof_surfaces_as_readable() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::net::TcpStream::connect(addr).unwrap();
        let (mut served, _) = listener.accept().unwrap();
        served.set_nonblocking(true).unwrap();

        let poller = Poller::new().unwrap();
        poller.register(served.as_raw_fd(), 9, true, false).unwrap();
        drop(client);

        let mut events = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut saw = false;
        while !saw && Instant::now() < deadline {
            poller.wait(&mut events, Some(Duration::from_millis(100))).unwrap();
            saw = events.iter().any(|e| e.token == 9 && e.readable);
        }
        assert!(saw, "peer hang-up never surfaced");
        let mut buf = [0u8; 8];
        assert_eq!(served.read(&mut buf).unwrap(), 0, "EOF");
    }
}
