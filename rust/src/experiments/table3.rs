//! Table 3: sensitivity to retrieval errors. The oracle drops the true
//! rank-1 / rank-2 / both vectors from every retrieved set; the paper
//! finds MIMPS degrades sharply when rank-1 is missing (0.8 → 39.3)
//! but mildly for rank-2 (6.1), while MINCE barely notices — evidence
//! that indexing schemes must prioritize top-1 recall.
//!
//! Settings per the paper's caption: MIMPS k = l = 1000; MINCE k = 1,
//! l = 1000.

use super::common::{build_workload, per_seed_errors, standard_queries, Setting};
use crate::bench::harness::Table;
use crate::config::Config;
use crate::data::embeddings::EmbeddingStore;
use crate::estimators::EstimatorKind;
use crate::metrics::Cell;
use crate::oracle::RetrievalError;
use crate::util::json::Json;

pub fn error_modes() -> Vec<RetrievalError> {
    vec![
        RetrievalError::none(),
        RetrievalError::drop_first(),
        RetrievalError::drop_second(),
        RetrievalError::drop_first_two(),
    ]
}

#[derive(Clone, Debug)]
pub struct Table3 {
    /// row label → one (μ, σ) per error mode.
    pub rows: Vec<(String, Vec<Cell>)>,
    pub mode_labels: Vec<String>,
}

pub fn run(store: &EmbeddingStore, cfg: &Config) -> Table3 {
    let k = cfg.k.min(store.len() / 2);
    let l = cfg.l.min(store.len() - k);
    let settings = [
        (
            "MIMPS".to_string(),
            Setting {
                kind: EstimatorKind::Mimps,
                k,
                l,
            },
        ),
        (
            "MINCE".to_string(),
            Setting {
                kind: EstimatorKind::Mince,
                k: 1,
                l,
            },
        ),
    ];
    let queries = standard_queries(store, cfg.queries, 0.0, cfg.seed);
    // Cache two extra head ranks so drops can backfill.
    let evals = build_workload(store, &queries, (k + 2).min(store.len()), cfg.threads);
    let modes = error_modes();
    let mut rows = Vec::new();
    for (label, setting) in &settings {
        let mut cells = Vec::new();
        for err in &modes {
            let per_seed = per_seed_errors(
                store,
                &queries,
                &evals,
                setting,
                err,
                cfg.seeds,
                cfg.seed,
                cfg.threads,
            );
            cells.push(Cell::from_seed_means(&per_seed));
        }
        log::info!("table3: {label} done");
        rows.push((label.clone(), cells));
    }
    Table3 {
        rows,
        mode_labels: modes.iter().map(|m| m.label()).collect(),
    }
}

pub fn render(t: &Table3) -> String {
    let mut headers = vec!["".to_string()];
    for m in &t.mode_labels {
        headers.push(format!("ret err={m} mu"));
        headers.push("s".to_string());
    }
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut tab = Table::new(&hdr_refs);
    for (label, cells) in &t.rows {
        let mut row = vec![label.clone()];
        for c in cells {
            row.push(format!("{:.1}", c.mu));
            row.push(format!("{:.1}", c.sigma));
        }
        tab.row(row);
    }
    tab.render()
}

pub fn to_json(t: &Table3) -> Json {
    Json::obj(vec![
        (
            "modes",
            Json::Arr(t.mode_labels.iter().map(|m| Json::str(m)).collect()),
        ),
        (
            "rows",
            Json::Arr(
                t.rows
                    .iter()
                    .map(|(label, cells)| {
                        Json::obj(vec![
                            ("label", Json::str(label)),
                            (
                                "cells",
                                Json::Arr(
                                    cells
                                        .iter()
                                        .map(|c| {
                                            Json::obj(vec![
                                                ("mu", Json::num(c.mu)),
                                                ("sigma", Json::num(c.sigma)),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};

    #[test]
    fn rank1_hurts_mimps_more_than_rank2() {
        let store = generate(&SynthConfig::tiny());
        let cfg = Config {
            n: store.len(),
            d: store.dim(),
            queries: 40,
            seeds: 2,
            k: 500,
            l: 500,
            threads: 4,
            ..Config::smoke()
        };
        let t = run(&store, &cfg);
        let mimps = &t.rows[0].1;
        let (none, drop1, drop2, drop12) = (mimps[0].mu, mimps[1].mu, mimps[2].mu, mimps[3].mu);
        assert!(
            drop1 > 3.0 * none.max(0.1),
            "dropping rank-1 must hurt: {none} -> {drop1}"
        );
        assert!(
            drop1 > drop2,
            "rank-1 loss ({drop1}) must exceed rank-2 loss ({drop2})"
        );
        assert!(
            drop12 >= drop1 * 0.9,
            "dropping both ({drop12}) at least as bad as rank-1 ({drop1})"
        );
        // MINCE is insensitive to head drops (k=1, it barely uses the head)
        let mince = &t.rows[1].1;
        let spread = (mince[1].mu - mince[0].mu).abs() / mince[0].mu.max(1.0);
        assert!(
            spread < 1.0,
            "MINCE should be comparatively insensitive, spread {spread}"
        );
    }
}
