//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. **Solver** — Halley vs Newton on the MINCE objective (the paper
//!    claims "considerable speedup" from third derivatives).
//! 2. **Index family** — k-means tree vs SimHash LSH vs brute: recall@k
//!    and probe cost at matched budgets.
//! 3. **Probe budget** — MIMPS error as a function of the tree's probe
//!    budget: the bridge from Table 3's oracle drops to real indexes.

use crate::config::Config;
use crate::data::embeddings::EmbeddingStore;
use crate::estimators::mince::{solve, Solver};
use crate::estimators::{mimps::Mimps, EstimateContext, Estimator};
use crate::metrics::abs_rel_err_pct;
use crate::mips::kmeans_tree::{KMeansTreeConfig, KMeansTreeIndex};
use crate::mips::lsh::{LshConfig, SimHashIndex};
use crate::mips::recall::measure;
use crate::mips::brute::BruteIndex;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::threadpool;

/// Solver ablation result.
#[derive(Clone, Debug)]
pub struct SolverAblation {
    pub instances: usize,
    pub newton_iters: usize,
    pub halley_iters: usize,
    pub newton_wall: std::time::Duration,
    pub halley_wall: std::time::Duration,
    pub max_disagreement: f64,
}

/// Run the solver ablation over random MINCE instances shaped like the
/// real estimator's (head sizes k, noise sizes l).
pub fn solver_ablation(instances: usize, k: usize, l: usize, seed: u64) -> SolverAblation {
    let mut rng = Rng::seeded(seed ^ 0xAB1A);
    let cases: Vec<(Vec<f64>, Vec<f64>)> = (0..instances)
        .map(|_| {
            let a: Vec<f64> = (0..k.max(1))
                .map(|_| (rng.normal() * 2.0 + 3.0).exp() * 100.0)
                .collect();
            let b: Vec<f64> = (0..l.max(1)).map(|_| rng.normal().exp()).collect();
            (a, b)
        })
        .collect();
    let run = |solver: Solver| -> (usize, std::time::Duration, Vec<f64>) {
        let t0 = std::time::Instant::now();
        let mut iters = 0usize;
        let mut roots = Vec::with_capacity(cases.len());
        for (a, b) in &cases {
            let r = solve(a, b, a.iter().sum::<f64>(), solver);
            iters += r.iterations;
            roots.push(r.z);
        }
        (iters, t0.elapsed(), roots)
    };
    let (newton_iters, newton_wall, zn) = run(Solver::Newton);
    let (halley_iters, halley_wall, zh) = run(Solver::Halley);
    let max_disagreement = zn
        .iter()
        .zip(&zh)
        .map(|(a, b)| ((a - b) / a.max(1e-300)).abs())
        .fold(0f64, f64::max);
    SolverAblation {
        instances,
        newton_iters,
        halley_iters,
        newton_wall,
        halley_wall,
        max_disagreement,
    }
}

/// Index-family ablation: recall and probe cost at a matched budget.
#[derive(Clone, Debug)]
pub struct IndexAblation {
    pub name: String,
    pub recall_at_10: f64,
    pub top1_recall: f64,
    pub mean_probes: f64,
    pub build_wall: std::time::Duration,
}

pub fn index_ablation(store: &EmbeddingStore, queries: usize, seed: u64) -> Vec<IndexAblation> {
    let brute = BruteIndex::new(store);
    let mut out = Vec::new();

    let t0 = std::time::Instant::now();
    let tree = KMeansTreeIndex::build(
        store,
        KMeansTreeConfig {
            max_probes: store.len() / 20,
            ..Default::default()
        },
    );
    let tree_build = t0.elapsed();
    let mut rng = Rng::seeded(seed);
    let r = measure(&tree, &brute, 10, queries, &mut rng);
    out.push(IndexAblation {
        name: "kmeans-tree".into(),
        recall_at_10: r.recall,
        top1_recall: r.top1_recall,
        mean_probes: r.mean_probes,
        build_wall: tree_build,
    });

    let t0 = std::time::Instant::now();
    let lsh = SimHashIndex::build(store, LshConfig::default());
    let lsh_build = t0.elapsed();
    let mut rng = Rng::seeded(seed);
    let r = measure(&lsh, &brute, 10, queries, &mut rng);
    out.push(IndexAblation {
        name: "simhash-lsh".into(),
        recall_at_10: r.recall,
        top1_recall: r.top1_recall,
        mean_probes: r.mean_probes,
        build_wall: lsh_build,
    });

    let t0 = std::time::Instant::now();
    let pca = crate::mips::pca_tree::PcaTreeIndex::build(
        store,
        crate::mips::pca_tree::PcaTreeConfig {
            max_probes: store.len() / 20,
            ..Default::default()
        },
    );
    let pca_build = t0.elapsed();
    let mut rng = Rng::seeded(seed);
    let r = measure(&pca, &brute, 10, queries, &mut rng);
    out.push(IndexAblation {
        name: "pca-tree".into(),
        recall_at_10: r.recall,
        top1_recall: r.top1_recall,
        mean_probes: r.mean_probes,
        build_wall: pca_build,
    });

    let t0 = std::time::Instant::now();
    let alsh = crate::mips::alsh::AlshIndex::build(store, crate::mips::alsh::AlshConfig::default());
    let alsh_build = t0.elapsed();
    let mut rng = Rng::seeded(seed);
    let r = measure(&alsh, &brute, 10, queries, &mut rng);
    out.push(IndexAblation {
        name: "l2-alsh".into(),
        recall_at_10: r.recall,
        top1_recall: r.top1_recall,
        mean_probes: r.mean_probes,
        build_wall: alsh_build,
    });

    let mut rng = Rng::seeded(seed);
    let r = measure(&brute, &brute, 10, queries, &mut rng);
    out.push(IndexAblation {
        name: "brute".into(),
        recall_at_10: r.recall,
        top1_recall: r.top1_recall,
        mean_probes: r.mean_probes,
        build_wall: std::time::Duration::ZERO,
    });
    out
}

/// Probe-budget ablation: MIMPS error through a real tree index as the
/// probe budget grows.
#[derive(Clone, Debug)]
pub struct BudgetPoint {
    pub probes: usize,
    pub mean_err_pct: f64,
}

pub fn probe_budget_ablation(
    store: &EmbeddingStore,
    cfg: &Config,
    budgets: &[usize],
) -> Vec<BudgetPoint> {
    let queries = super::common::standard_queries(store, cfg.queries, 0.0, cfg.seed);
    let evals = super::common::build_workload(store, &queries, 1, cfg.threads);
    let tree = KMeansTreeIndex::build(store, KMeansTreeConfig::default());
    budgets
        .iter()
        .map(|&budget| {
            let errs = threadpool::par_map(queries.len(), cfg.threads, |qi| {
                let mut rng = Rng::seeded(budget as u64 ^ qi as u64);
                let (head, _) = tree.search_with_budget(&queries[qi], cfg.k, budget);
                let index = super::common::FixedIndex::new(&head, store.len());
                let mut ctx = EstimateContext::new(store, &index, &mut rng);
                let z = Mimps::new(cfg.k.min(head.len()), cfg.l).estimate(&mut ctx, &queries[qi]);
                abs_rel_err_pct(z, evals[qi].z_true)
            });
            BudgetPoint {
                probes: budget,
                mean_err_pct: crate::metrics::mean(&errs),
            }
        })
        .collect()
}

pub fn to_json(
    solver: &SolverAblation,
    index: &[IndexAblation],
    budget: &[BudgetPoint],
) -> Json {
    Json::obj(vec![
        (
            "solver",
            Json::obj(vec![
                ("instances", Json::num(solver.instances as f64)),
                ("newton_iters", Json::num(solver.newton_iters as f64)),
                ("halley_iters", Json::num(solver.halley_iters as f64)),
                (
                    "newton_wall_us",
                    Json::num(solver.newton_wall.as_micros() as f64),
                ),
                (
                    "halley_wall_us",
                    Json::num(solver.halley_wall.as_micros() as f64),
                ),
                ("max_disagreement", Json::num(solver.max_disagreement)),
            ]),
        ),
        (
            "index",
            Json::Arr(
                index
                    .iter()
                    .map(|i| {
                        Json::obj(vec![
                            ("name", Json::str(&i.name)),
                            ("recall_at_10", Json::num(i.recall_at_10)),
                            ("top1_recall", Json::num(i.top1_recall)),
                            ("mean_probes", Json::num(i.mean_probes)),
                            (
                                "build_wall_ms",
                                Json::num(i.build_wall.as_millis() as f64),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "budget",
            Json::Arr(
                budget
                    .iter()
                    .map(|b| {
                        Json::obj(vec![
                            ("probes", Json::num(b.probes as f64)),
                            ("mean_err_pct", Json::num(b.mean_err_pct)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};

    #[test]
    fn halley_converges_in_fewer_iterations() {
        let a = solver_ablation(40, 100, 100, 0);
        assert!(a.halley_iters <= a.newton_iters);
        assert!(a.max_disagreement < 1e-6, "solvers disagree: {}", a.max_disagreement);
    }

    #[test]
    fn error_falls_with_probe_budget() {
        let store = generate(&SynthConfig::tiny());
        let cfg = Config {
            n: store.len(),
            d: store.dim(),
            queries: 25,
            k: 100,
            l: 100,
            threads: 4,
            ..Config::smoke()
        };
        let pts = probe_budget_ablation(&store, &cfg, &[128, 2000]);
        assert!(
            pts[1].mean_err_pct <= pts[0].mean_err_pct + 1.0,
            "more probes should not hurt: {:?}",
            pts
        );
    }

    #[test]
    fn index_ablation_reports_all_families() {
        let store = generate(&SynthConfig {
            n: 1500,
            d: 16,
            ..SynthConfig::tiny()
        });
        let rows = index_ablation(&store, 10, 3);
        assert_eq!(rows.len(), 5);
        let brute = rows.iter().find(|r| r.name == "brute").unwrap();
        assert_eq!(brute.recall_at_10, 1.0);
        let tree = rows.iter().find(|r| r.name == "kmeans-tree").unwrap();
        assert!(tree.mean_probes < store.len() as f64);
    }
}
