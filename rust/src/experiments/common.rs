//! Shared experiment machinery.
//!
//! The oracle experiments need, per query: the true partition `Z` and the
//! exact head `S_K(q)` for the largest K any estimator setting will ask
//! for. Both come out of **one** parallel scan per query (`build_workload`),
//! after which every (estimator, k, l) cell replays the cached head
//! through a [`FixedIndex`] — turning an O(settings × N·d) experiment
//! into O(N·d + settings × (k+l)·d) per query, the same trick the paper's
//! "oracle ability to recover S_k" describes.

use crate::data::embeddings::EmbeddingStore;
use crate::estimators::{EstimateContext, Estimator, EstimatorKind};
use crate::linalg;
use crate::metrics::abs_rel_err_pct;
use crate::mips::{select_top_k, Hit, MipsIndex};
use crate::oracle::RetrievalError;
use crate::util::rng::Rng;
use crate::util::threadpool;

/// Cached per-query oracle scan results.
#[derive(Clone, Debug)]
pub struct QueryEval {
    pub z_true: f64,
    /// Exact top-(max_head) hits, descending.
    pub head: Vec<Hit>,
}

/// One scan: exact Z and top-`max_head` of `q` against the store.
pub fn scan_query(store: &EmbeddingStore, q: &[f32], max_head: usize) -> QueryEval {
    let n = store.len();
    let d = store.dim();
    let mut scores = vec![0f32; n];
    linalg::gemv_blocked(store.data(), n, d, q, &mut scores);
    let z_true = linalg::sum_exp(&scores);
    let head = select_top_k(&scores, max_head.min(n));
    QueryEval { z_true, head }
}

/// Parallel scan of a query set.
pub fn build_workload(
    store: &EmbeddingStore,
    queries: &[Vec<f32>],
    max_head: usize,
    threads: usize,
) -> Vec<QueryEval> {
    threadpool::par_map(queries.len(), threads, |i| {
        scan_query(store, &queries[i], max_head)
    })
}

/// A MIPS "index" that replays a cached head (optionally with injected
/// retrieval errors), so the real estimator implementations run
/// unmodified against oracle retrievals.
pub struct FixedIndex<'a> {
    head: &'a [Hit],
    n: usize,
    err: RetrievalError,
}

impl<'a> FixedIndex<'a> {
    pub fn new(head: &'a [Hit], n: usize) -> Self {
        FixedIndex {
            head,
            n,
            err: RetrievalError::none(),
        }
    }

    pub fn with_error(head: &'a [Hit], n: usize, err: RetrievalError) -> Self {
        FixedIndex { head, n, err }
    }
}

impl MipsIndex for FixedIndex<'_> {
    fn top_k(&self, _q: &[f32], k: usize) -> Vec<Hit> {
        let kept: Vec<Hit> = self
            .head
            .iter()
            .enumerate()
            .filter(|(pos, _)| !self.err.drop_ranks.contains(&(pos + 1)))
            .map(|(_, h)| *h)
            .take(k)
            .collect();
        assert!(
            kept.len() >= k.min(self.n.saturating_sub(self.err.drop_ranks.len()))
                || self.head.len() >= self.n,
            "FixedIndex cached head too small: have {}, need {k} (+{} drops)",
            self.head.len(),
            self.err.drop_ranks.len()
        );
        kept
    }

    fn len(&self) -> usize {
        self.n
    }

    fn probe_cost(&self, k: usize) -> usize {
        k
    }

    fn name(&self) -> &'static str {
        "oracle-cache"
    }
}

/// Estimator settings used across the oracle tables.
#[derive(Clone, Copy, Debug)]
pub struct Setting {
    pub kind: EstimatorKind,
    pub k: usize,
    pub l: usize,
}

impl Setting {
    pub fn label(&self) -> String {
        match self.kind {
            EstimatorKind::Uniform => format!("Uniform (l={})", self.l),
            EstimatorKind::Mimps => format!("MIMPS (k={}, l={})", self.k, self.l),
            EstimatorKind::Mince => format!("MINCE (k={}, l={})", self.k, self.l),
            EstimatorKind::Nmimps => format!("NMIMPS (k={})", self.k),
            EstimatorKind::Exact => "Exact".to_string(),
            EstimatorKind::Fmbe => format!("FMBE (D={})", self.k),
        }
    }

    /// Build the estimator and run it against a cached head.
    pub fn estimate(
        &self,
        store: &EmbeddingStore,
        eval: &QueryEval,
        q: &[f32],
        err: &RetrievalError,
        rng: &mut Rng,
    ) -> f64 {
        let index = FixedIndex::with_error(&eval.head, store.len(), err.clone());
        let mut ctx = EstimateContext::new(store, &index, rng);
        match self.kind {
            EstimatorKind::Uniform => {
                crate::estimators::uniform::Uniform::new(self.l).estimate(&mut ctx, q)
            }
            EstimatorKind::Nmimps => {
                crate::estimators::nmimps::Nmimps::new(self.k).estimate(&mut ctx, q)
            }
            EstimatorKind::Mimps => {
                crate::estimators::mimps::Mimps::new(self.k, self.l).estimate(&mut ctx, q)
            }
            EstimatorKind::Mince => {
                crate::estimators::mince::Mince::new(self.k, self.l).estimate(&mut ctx, q)
            }
            other => panic!("setting {other:?} not supported by oracle replay"),
        }
    }
}

/// Mean % abs relative error of one setting over a workload, per seed.
/// Returns the per-seed means (feed to `metrics::Cell::from_seed_means`).
#[allow(clippy::too_many_arguments)]
pub fn per_seed_errors(
    store: &EmbeddingStore,
    queries: &[Vec<f32>],
    evals: &[QueryEval],
    setting: &Setting,
    err: &RetrievalError,
    seeds: usize,
    base_seed: u64,
    threads: usize,
) -> Vec<f64> {
    (0..seeds)
        .map(|s| {
            let errs = threadpool::par_map(queries.len(), threads, |qi| {
                let mut rng =
                    Rng::seeded(base_seed ^ (s as u64) << 32 ^ (qi as u64).wrapping_mul(0x9E37));
                let z = setting.estimate(store, &evals[qi], &queries[qi], err, &mut rng);
                abs_rel_err_pct(z, evals[qi].z_true)
            });
            crate::metrics::mean(&errs)
        })
        .collect()
}

/// Standard workload construction shared by Tables 1–3: stratified query
/// indices over the vocabulary, queries = data vectors + optional noise.
pub fn standard_queries(
    store: &EmbeddingStore,
    count: usize,
    rel_noise: f32,
    seed: u64,
) -> Vec<Vec<f32>> {
    let mut rng = Rng::seeded(seed ^ 0x9157);
    let idx = crate::data::synth::stratified_query_indices(store.len(), count, &mut rng);
    crate::data::synth::noisy_queries(store, &idx, rel_noise, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::mips::brute::BruteIndex;

    fn store() -> EmbeddingStore {
        generate(&SynthConfig {
            n: 600,
            d: 16,
            ..SynthConfig::tiny()
        })
    }

    #[test]
    fn scan_matches_brute() {
        let s = store();
        let brute = BruteIndex::new(&s);
        let q = s.row(100).to_vec();
        let eval = scan_query(&s, &q, 20);
        assert!((eval.z_true - brute.partition(&q)).abs() < 1e-6 * eval.z_true);
        assert_eq!(
            eval.head.iter().map(|h| h.idx).collect::<Vec<_>>(),
            brute
                .top_k(&q, 20)
                .iter()
                .map(|h| h.idx)
                .collect::<Vec<_>>()
        );
    }

    #[test]
    fn fixed_index_replays_prefix_and_drops() {
        let s = store();
        let q = s.row(3).to_vec();
        let eval = scan_query(&s, &q, 12);
        let idx = FixedIndex::new(&eval.head, s.len());
        assert_eq!(idx.top_k(&q, 5), eval.head[..5].to_vec());
        let idx = FixedIndex::with_error(&eval.head, s.len(), RetrievalError::drop_first());
        let dropped = idx.top_k(&q, 5);
        assert_eq!(dropped[0], eval.head[1]);
        assert_eq!(dropped.len(), 5);
    }

    #[test]
    fn cached_mimps_equals_direct_mimps() {
        // Same seed → identical estimate through cache replay vs brute index.
        let s = store();
        let brute = BruteIndex::new(&s);
        let q = s.row(50).to_vec();
        let eval = scan_query(&s, &q, 40);
        let setting = Setting {
            kind: EstimatorKind::Mimps,
            k: 40,
            l: 30,
        };
        let via_cache = {
            let mut rng = Rng::seeded(9);
            setting.estimate(&s, &eval, &q, &RetrievalError::none(), &mut rng)
        };
        let direct = {
            let mut rng = Rng::seeded(9);
            let mut ctx = EstimateContext::new(&s, &brute, &mut rng);
            crate::estimators::mimps::Mimps::new(40, 30).estimate(&mut ctx, &q)
        };
        assert!(
            (via_cache - direct).abs() < 1e-9 * direct.max(1.0),
            "{via_cache} vs {direct}"
        );
    }

    #[test]
    fn per_seed_errors_reasonable_for_mimps() {
        let s = store();
        let queries = standard_queries(&s, 20, 0.0, 0);
        let evals = build_workload(&s, &queries, 102, 4);
        let errs = per_seed_errors(
            &s,
            &queries,
            &evals,
            &Setting {
                kind: EstimatorKind::Mimps,
                k: 100,
                l: 100,
            },
            &RetrievalError::none(),
            2,
            0,
            4,
        );
        assert_eq!(errs.len(), 2);
        for e in errs {
            assert!(e < 60.0, "MIMPS(100,100) error {e}% too high on tiny set");
        }
    }
}
