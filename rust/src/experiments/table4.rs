//! Table 4: the end-to-end language-modeling experiment. Train a
//! log-bilinear LM with NCE (partition clamped to 1) on the synthetic
//! corpus, then — on held-out test contexts — compare MIMPS partition
//! estimates (via the k-means-tree MIPS index over the Bachrach lift)
//! against the self-normalization heuristic Ẑ = 1 the model was trained
//! with. Columns follow the paper: AbsE (total |Ẑ − Z| over the test
//! set), %Better (share of contexts where MIMPS beats the heuristic),
//! and Speedup over brute force.

use crate::bench::harness::Table;
use crate::data::corpus::{Corpus, CorpusConfig};
use crate::estimators::{mimps::Mimps, EstimateContext, Estimator};
use crate::lm::{train, LblConfig, LblParams, NceConfig};
use crate::metrics::{pct_better, total_abs_err};
use crate::mips::kmeans_tree::{KMeansTreeConfig, KMeansTreeIndex};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::threadpool;
use anyhow::Result;

/// One (k, l) grid cell of Table 4.
#[derive(Clone, Debug)]
pub struct Cell4 {
    pub k: usize,
    pub l: usize,
    pub abse_mips: f64,
    pub abse_nce: f64,
    pub pct_better: f64,
    /// Wall-clock brute-force / MIMPS ratio.
    pub speedup: f64,
    /// Tree probe budget used for this cell (scaled with k, as the
    /// paper's FLANN checks-per-query setting scales).
    pub probes: usize,
}

/// Wrap the tree with a per-cell probe budget: smaller k gets a smaller
/// budget (and a larger speedup), mirroring the paper's Table 4 where
/// Speedup falls from 18.5 (k=10) to 10 (k=100).
struct BudgetedTree<'a> {
    tree: &'a KMeansTreeIndex,
    budget: usize,
}

impl crate::mips::MipsIndex for BudgetedTree<'_> {
    fn top_k(&self, q: &[f32], k: usize) -> Vec<crate::mips::Hit> {
        self.tree.search_with_budget(q, k, self.budget).0
    }
    fn len(&self) -> usize {
        self.tree.len()
    }
    fn probe_cost(&self, _k: usize) -> usize {
        self.budget
    }
    fn name(&self) -> &'static str {
        "kmeans-tree-budgeted"
    }
}

#[derive(Clone, Debug)]
pub struct Table4 {
    pub cells: Vec<Cell4>,
    pub contexts: usize,
    pub train_loss: f64,
    /// Mean true Z over the test contexts (shows how self-normalized the
    /// model is; the paper's AbsE-NCE=352 over 10k contexts ⇒ mean |Z−1|
    /// ≈ 0.035).
    pub mean_z: f64,
}

/// Experiment parameters.
#[derive(Clone, Debug)]
pub struct Table4Config {
    pub corpus: CorpusConfig,
    pub lbl: LblConfig,
    pub nce: NceConfig,
    pub train_steps: usize,
    /// Test contexts to evaluate (paper: ~10k).
    pub contexts: usize,
    pub ks: Vec<usize>,
    pub ls: Vec<usize>,
    pub threads: usize,
    /// Probe budget for the tree search (per paper's FLANN usage: the
    /// budget is what makes the method sublinear).
    pub tree_probes: usize,
}

impl Default for Table4Config {
    fn default() -> Self {
        Table4Config {
            corpus: CorpusConfig::default(),
            lbl: LblConfig::default(),
            nce: NceConfig::default(),
            train_steps: 600,
            contexts: 2000,
            ks: vec![10, 50, 100],
            ls: vec![10, 100],
            threads: crate::util::threadpool::default_threads(),
            tree_probes: 1024,
        }
    }
}

/// Run the full experiment through the PJRT runtime.
pub fn run(
    cfg: &Table4Config,
    rt: &crate::runtime::RuntimeHandle,
    artifacts_dir: &std::path::Path,
) -> Result<Table4> {
    let corpus = crate::data::corpus::generate(&cfg.corpus);
    log::info!(
        "table4: training LBL vocab={} d={} ctx={} for {} steps",
        cfg.lbl.vocab,
        cfg.lbl.d,
        cfg.lbl.ctx,
        cfg.train_steps
    );
    let (params, report) = train(
        &corpus,
        cfg.lbl.clone(),
        cfg.nce.clone(),
        cfg.train_steps,
        rt,
        artifacts_dir,
    )?;
    log::info!(
        "table4: trained, final loss {:.4} ({:?})",
        report.final_loss,
        report.wall
    );
    evaluate(cfg, &corpus, &params, report.final_loss)
}

/// Evaluation half (separated for tests that inject a pre-trained model).
pub fn evaluate(
    cfg: &Table4Config,
    corpus: &Corpus,
    params: &LblParams,
    train_loss: f64,
) -> Result<Table4> {
    let store = params.target_store();
    let tree = KMeansTreeIndex::build(
        &store,
        KMeansTreeConfig {
            max_probes: cfg.tree_probes,
            ..Default::default()
        },
    );

    // Test contexts → lifted queries.
    let windows: Vec<(Vec<u32>, u32)> = Corpus::windows(&corpus.test, cfg.lbl.ctx)
        .take(cfg.contexts)
        .collect();
    let queries: Vec<Vec<f32>> = windows
        .iter()
        .map(|(ctx, _)| LblParams::lift_query(&params.qhat(ctx)))
        .collect();
    log::info!("table4: {} test contexts", queries.len());

    // Ground truth + brute timing.
    let t0 = std::time::Instant::now();
    let truths: Vec<f64> = threadpool::par_map(queries.len(), cfg.threads, |i| {
        crate::experiments::common::scan_query(&store, &queries[i], 1).z_true
    });
    let brute_wall = t0.elapsed();
    let mean_z = crate::metrics::mean(&truths);
    let nce_est: Vec<f64> = vec![1.0; truths.len()];
    let abse_nce = total_abs_err(&nce_est, &truths);

    let mut cells = Vec::new();
    for &k in &cfg.ks {
        for &l in &cfg.ls {
            let est = Mimps::new(k, l);
            // Budget scales with k: retrieving a larger head justifies a
            // deeper search (cfg.tree_probes is the k=100 reference).
            let budget = (cfg.tree_probes * k / 100).clamp(256, store.len());
            let index = BudgetedTree {
                tree: &tree,
                budget,
            };
            let t1 = std::time::Instant::now();
            let mips_est: Vec<f64> = threadpool::par_map(queries.len(), cfg.threads, |i| {
                let mut rng = Rng::seeded((k * 31 + l) as u64 ^ i as u64);
                let mut ctx = EstimateContext::new(&store, &index, &mut rng);
                est.estimate(&mut ctx, &queries[i])
            });
            let mips_wall = t1.elapsed();
            let cell = Cell4 {
                k,
                l,
                abse_mips: total_abs_err(&mips_est, &truths),
                abse_nce,
                pct_better: pct_better(&mips_est, &nce_est, &truths),
                speedup: brute_wall.as_secs_f64() / mips_wall.as_secs_f64().max(1e-12),
                probes: budget,
            };
            log::info!(
                "table4: k={k} l={l} AbsE-MIPS={:.1} %Better={:.1} speedup={:.1}",
                cell.abse_mips,
                cell.pct_better,
                cell.speedup
            );
            cells.push(cell);
        }
    }
    Ok(Table4 {
        cells,
        contexts: queries.len(),
        train_loss,
        mean_z,
    })
}

pub fn render(t: &Table4) -> String {
    let mut tab = Table::new(&["k", "l", "AbsE-MIPS", "AbsE-NCE", "%Better", "Speedup", "probes"]);
    for c in &t.cells {
        tab.row(vec![
            c.k.to_string(),
            c.l.to_string(),
            format!("{:.1}", c.abse_mips),
            format!("{:.1}", c.abse_nce),
            format!("{:.1}", c.pct_better),
            format!("{:.1}", c.speedup),
            c.probes.to_string(),
        ]);
    }
    format!(
        "{}\ncontexts={} train_loss={:.4} mean_true_Z={:.4}\n",
        tab.render(),
        t.contexts,
        t.train_loss,
        t.mean_z
    )
}

pub fn to_json(t: &Table4) -> Json {
    Json::obj(vec![
        ("contexts", Json::num(t.contexts as f64)),
        ("train_loss", Json::num(t.train_loss)),
        ("mean_z", Json::num(t.mean_z)),
        (
            "cells",
            Json::Arr(
                t.cells
                    .iter()
                    .map(|c| {
                        Json::obj(vec![
                            ("k", Json::num(c.k as f64)),
                            ("l", Json::num(c.l as f64)),
                            ("abse_mips", Json::num(c.abse_mips)),
                            ("abse_nce", Json::num(c.abse_nce)),
                            ("pct_better", Json::num(c.pct_better)),
                            ("speedup", Json::num(c.speedup)),
                            ("probes", Json::num(c.probes as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Evaluation-only test with an untrained (random) model: the
    /// mechanics must hold even before training — MIMPS estimates true Z
    /// better as k grows, and the columns are internally consistent.
    #[test]
    fn evaluation_mechanics_on_random_model() {
        let corpus = crate::data::corpus::generate(&CorpusConfig::tiny());
        let lbl = LblConfig {
            vocab: corpus.vocab,
            d: 16,
            ctx: 3,
            seed: 5,
        };
        let params = LblParams::init(lbl.clone());
        let cfg = Table4Config {
            corpus: CorpusConfig::tiny(),
            lbl,
            contexts: 60,
            ks: vec![10, 100],
            ls: vec![10],
            threads: 4,
            tree_probes: 256,
            ..Default::default()
        };
        let t = evaluate(&cfg, &corpus, &params, f64::NAN).unwrap();
        assert_eq!(t.cells.len(), 2);
        let (k10, k100) = (&t.cells[0], &t.cells[1]);
        assert!(
            k100.abse_mips <= k10.abse_mips * 1.2,
            "larger k should not be much worse: {} vs {}",
            k100.abse_mips,
            k10.abse_mips
        );
        for c in &t.cells {
            assert!(c.abse_nce > 0.0);
            assert!((0.0..=100.0).contains(&c.pct_better));
            assert!(c.speedup > 0.0);
        }
        assert!(t.mean_z > 0.0);
    }
}
