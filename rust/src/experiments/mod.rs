//! Experiment drivers: one module per table/figure in the paper's
//! evaluation section (see DESIGN.md per-experiment index). Each driver
//! is callable both from the `zest` CLI and from the corresponding
//! `cargo bench` target, prints the same rows the paper reports, and
//! writes a JSON result file under the configured out dir.

pub mod ablations;
pub mod common;
pub mod figure1;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;
