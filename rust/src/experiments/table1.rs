//! Table 1: mean % absolute relative error (μ) and standard error (σ)
//! for Uniform, MIMPS (k ∈ {1,10,100,1000}) and MINCE (k ∈ {1,10,100,1000})
//! at l ∈ {1000, 100, 10}, plus the FMBE numbers the paper reports in
//! text (μ = 100 at D = 10k, μ = 83.8 at D = 50k).

use super::common::{build_workload, per_seed_errors, standard_queries, Setting};
use crate::bench::harness::Table;
use crate::config::Config;
use crate::data::embeddings::EmbeddingStore;
use crate::estimators::{fmbe, EstimateContext, Estimator, EstimatorKind};
use crate::metrics::{abs_rel_err_pct, Cell};
use crate::oracle::RetrievalError;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::threadpool;

/// The grid the paper sweeps.
pub fn settings() -> Vec<(String, Vec<Setting>)> {
    let ls = [1000usize, 100, 10];
    let mut rows: Vec<(String, Vec<Setting>)> = Vec::new();
    rows.push((
        "Uniform".to_string(),
        ls.iter()
            .map(|&l| Setting {
                kind: EstimatorKind::Uniform,
                k: 0,
                l,
            })
            .collect(),
    ));
    for &k in &[1000usize, 100, 10, 1] {
        rows.push((
            format!("MIMPS (k={k})"),
            ls.iter()
                .map(|&l| Setting {
                    kind: EstimatorKind::Mimps,
                    k,
                    l,
                })
                .collect(),
        ));
    }
    for &k in &[1000usize, 100, 10, 1] {
        rows.push((
            format!("MINCE (k={k})"),
            ls.iter()
                .map(|&l| Setting {
                    kind: EstimatorKind::Mince,
                    k,
                    l,
                })
                .collect(),
        ));
    }
    rows
}

/// One table row: label + one (μ, σ) cell per l.
#[derive(Clone, Debug)]
pub struct Row {
    pub label: String,
    pub cells: Vec<Cell>,
}

/// Full Table 1 result.
#[derive(Clone, Debug)]
pub struct Table1 {
    pub rows: Vec<Row>,
    /// FMBE text numbers: (D, μ, σ).
    pub fmbe: Vec<(usize, f64, f64)>,
}

/// Run Table 1 on a prepared store.
pub fn run(store: &EmbeddingStore, cfg: &Config, fmbe_ds: &[usize]) -> Table1 {
    let queries = standard_queries(store, cfg.queries, 0.0, cfg.seed);
    // Max head any setting needs (k=1000) — cache exactly that.
    let max_head = 1000.min(store.len());
    log::info!(
        "table1: scanning {} queries over N={} d={}",
        queries.len(),
        store.len(),
        store.dim()
    );
    let evals = build_workload(store, &queries, max_head, cfg.threads);
    let mut rows = Vec::new();
    for (label, settings) in settings() {
        let cells: Vec<Cell> = settings
            .iter()
            .map(|s| {
                let per_seed = per_seed_errors(
                    store,
                    &queries,
                    &evals,
                    s,
                    &RetrievalError::none(),
                    cfg.seeds,
                    cfg.seed,
                    cfg.threads,
                );
                Cell::from_seed_means(&per_seed)
            })
            .collect();
        log::info!("table1: {label} done");
        rows.push(Row { label, cells });
    }
    // FMBE at the paper's D values (scaled down by cfg if requested).
    let mut fmbe_rows = Vec::new();
    // FMBE never touches the index; a single empty replay index suffices.
    let no_head: Vec<crate::mips::Hit> = Vec::new();
    for &dfeat in fmbe_ds {
        // The O(D·N·d) fit dominates; on large configs one seed suffices
        // (the paper's own FMBE σ < 0.1 — seed variance is negligible).
        let fmbe_seeds = if dfeat.saturating_mul(store.len()) > 500_000_000 {
            1
        } else {
            cfg.seeds
        };
        let per_seed: Vec<f64> = (0..fmbe_seeds)
            .map(|s| {
                let est = fmbe::Fmbe::fit(
                    store,
                    fmbe::FmbeConfig {
                        p_features: dfeat,
                        seed: cfg.seed + s as u64,
                        threads: cfg.threads,
                        ..Default::default()
                    },
                );
                let errs = threadpool::par_map(queries.len(), cfg.threads, |qi| {
                    let mut rng = Rng::seeded(1 + qi as u64);
                    let dummy = super::common::FixedIndex::new(&no_head, store.len());
                    let mut ctx = EstimateContext::new(store, &dummy, &mut rng);
                    abs_rel_err_pct(est.estimate(&mut ctx, &queries[qi]), evals[qi].z_true)
                });
                crate::metrics::mean(&errs)
            })
            .collect();
        let c = Cell::from_seed_means(&per_seed);
        log::info!("table1: FMBE D={dfeat} done (mu={:.1})", c.mu);
        fmbe_rows.push((dfeat, c.mu, c.sigma));
    }
    Table1 {
        rows,
        fmbe: fmbe_rows,
    }
}

/// Render in the paper's layout.
pub fn render(t: &Table1) -> String {
    let mut tab = Table::new(&[
        "", "l=1000 mu", "sigma", "l=100 mu", "sigma", "l=10 mu", "sigma",
    ]);
    for row in &t.rows {
        let mut cells = vec![row.label.clone()];
        for c in &row.cells {
            cells.push(format!("{:.1}", c.mu));
            cells.push(format!("{:.1}", c.sigma));
        }
        tab.row(cells);
    }
    let mut s = tab.render();
    for (d, mu, sigma) in &t.fmbe {
        s.push_str(&format!("FMBE D={d}: mu={mu:.1} sigma={sigma:.1}\n"));
    }
    s
}

pub fn to_json(t: &Table1) -> Json {
    Json::obj(vec![
        (
            "rows",
            Json::Arr(
                t.rows
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("label", Json::str(&r.label)),
                            (
                                "cells",
                                Json::Arr(
                                    r.cells
                                        .iter()
                                        .map(|c| {
                                            Json::obj(vec![
                                                ("mu", Json::num(c.mu)),
                                                ("sigma", Json::num(c.sigma)),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "fmbe",
            Json::Arr(
                t.fmbe
                    .iter()
                    .map(|(d, mu, sigma)| {
                        Json::obj(vec![
                            ("D", Json::num(*d as f64)),
                            ("mu", Json::num(*mu)),
                            ("sigma", Json::num(*sigma)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};

    /// Scaled-down Table 1 must reproduce the paper's orderings:
    /// MIMPS ≪ Uniform; MIMPS error decreases with k and l; MINCE ≫ MIMPS.
    #[test]
    fn qualitative_orderings_hold() {
        let store = generate(&SynthConfig::tiny());
        let cfg = Config {
            n: store.len(),
            d: store.dim(),
            queries: 40,
            seeds: 2,
            threads: 4,
            ..Config::smoke()
        };
        let t = run(&store, &cfg, &[]);
        let find = |label: &str| -> &Row {
            t.rows
                .iter()
                .find(|r| r.label == label)
                .unwrap_or_else(|| panic!("row {label}"))
        };
        let uniform = find("Uniform");
        let mimps_k1000 = find("MIMPS (k=1000)");
        let mimps_k10 = find("MIMPS (k=10)");
        let mince_k1000 = find("MINCE (k=1000)");
        // l=1000 column (index 0):
        assert!(
            mimps_k1000.cells[0].mu < uniform.cells[0].mu / 5.0,
            "MIMPS {} vs Uniform {}",
            mimps_k1000.cells[0].mu,
            uniform.cells[0].mu
        );
        assert!(
            mimps_k1000.cells[0].mu < mimps_k10.cells[0].mu,
            "error must fall with k"
        );
        // MIMPS error grows as l shrinks (row-wise monotonicity).
        assert!(mimps_k1000.cells[0].mu <= mimps_k1000.cells[2].mu);
        // MINCE is far worse than MIMPS at the same budget.
        assert!(mince_k1000.cells[0].mu > 10.0 * mimps_k1000.cells[0].mu);
        let rendered = render(&t);
        assert!(rendered.contains("MIMPS (k=1000)"));
    }
}
