//! Figure 1: CDF over vocabulary items sorted by their contribution to
//! Z, for probe context words across the frequency range. The paper's
//! observation — common words induce flat distributions (≈80k of 100k
//! neighbors needed for 80% of Z) while rare words are peaked (<1k) — is
//! the motivation for MIPS-based head/tail estimation.

use crate::data::embeddings::EmbeddingStore;
use crate::data::synth::{corpus_frequency, SynthConfig};
use crate::linalg;
use crate::util::json::Json;
use crate::util::threadpool;

/// One probe word's CDF summary.
#[derive(Clone, Debug)]
pub struct ProbeCurve {
    /// Zipf rank of the probe token (0 = most frequent).
    pub rank: usize,
    /// Pseudo corpus frequency (for the legend, like the paper's counts).
    pub corpus_freq: u64,
    /// Neighbors needed to reach 50% / 80% / 90% of Z.
    pub n50: usize,
    pub n80: usize,
    pub n90: usize,
    /// Downsampled CDF series (fraction_of_vocab, fraction_of_Z).
    pub series: Vec<(f64, f64)>,
}

/// Compute the sorted-contribution CDF for one probe token.
pub fn probe_cdf(store: &EmbeddingStore, rank: usize, series_points: usize) -> ProbeCurve {
    let q = store.row(rank).to_vec();
    let n = store.len();
    let mut scores = vec![0f32; n];
    linalg::gemv_blocked(store.data(), n, store.dim(), &q, &mut scores);
    let mut exp: Vec<f64> = scores.iter().map(|&u| (u as f64).exp()).collect();
    exp.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let z: f64 = exp.iter().sum();
    let (mut n50, mut n80, mut n90) = (n, n, n);
    let mut acc = 0f64;
    let mut series = Vec::with_capacity(series_points + 1);
    let stride = (n / series_points.max(1)).max(1);
    for (i, e) in exp.iter().enumerate() {
        acc += e;
        let frac = acc / z;
        if frac >= 0.5 && n50 == n {
            n50 = i + 1;
        }
        if frac >= 0.8 && n80 == n {
            n80 = i + 1;
        }
        if frac >= 0.9 && n90 == n {
            n90 = i + 1;
        }
        if i % stride == 0 || i + 1 == n {
            series.push(((i + 1) as f64 / n as f64, frac));
        }
    }
    ProbeCurve {
        rank,
        corpus_freq: 0,
        n50,
        n80,
        n90,
        series,
    }
}

/// Run the figure: probe tokens at log-spaced ranks.
pub fn run(store: &EmbeddingStore, synth_cfg: &SynthConfig, threads: usize) -> Vec<ProbeCurve> {
    let n = store.len();
    // Log-spaced probe ranks mirroring the paper's word selection:
    // "The"-like head tokens through Chipotle-like tail tokens.
    let mut ranks = vec![0usize, 9, 99];
    let mut r = 999usize;
    while r < n - 1 {
        ranks.push(r);
        r = (r + 1) * 10 - 1;
    }
    ranks.push(n - 1);
    ranks.dedup();
    let mut curves = threadpool::par_map(ranks.len(), threads, |i| {
        probe_cdf(store, ranks[i], 200)
    });
    for c in &mut curves {
        c.corpus_freq = corpus_frequency(synth_cfg, c.rank, 1e11); // 100B-token corpus
    }
    curves
}

/// JSON dump for plotting.
pub fn to_json(curves: &[ProbeCurve]) -> Json {
    Json::Arr(
        curves
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("rank", Json::num(c.rank as f64)),
                    ("corpus_freq", Json::num(c.corpus_freq as f64)),
                    ("n50", Json::num(c.n50 as f64)),
                    ("n80", Json::num(c.n80 as f64)),
                    ("n90", Json::num(c.n90 as f64)),
                    (
                        "series",
                        Json::Arr(
                            c.series
                                .iter()
                                .map(|(x, y)| Json::Arr(vec![Json::num(*x), Json::num(*y)]))
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::generate;

    #[test]
    fn paper_shape_common_flat_rare_peaked() {
        let cfg = SynthConfig::tiny();
        let s = generate(&cfg);
        let common = probe_cdf(&s, 0, 50);
        let rare = probe_cdf(&s, cfg.n - 1, 50);
        assert!(
            common.n80 > rare.n80 * 5,
            "common n80 {} should dwarf rare n80 {}",
            common.n80,
            rare.n80
        );
        // CDF sanity: monotone, ends at 1.
        for w in common.series.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
        assert!((common.series.last().unwrap().1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn thresholds_ordered() {
        let cfg = SynthConfig::tiny();
        let s = generate(&cfg);
        let c = probe_cdf(&s, 500, 50);
        assert!(c.n50 <= c.n80 && c.n80 <= c.n90);
    }

    #[test]
    fn run_produces_probe_set_and_json() {
        let cfg = SynthConfig::tiny();
        let s = generate(&cfg);
        let curves = run(&s, &cfg, 4);
        assert!(curves.len() >= 4);
        assert!(curves[0].corpus_freq > curves.last().unwrap().corpus_freq);
        let j = to_json(&curves);
        assert!(j.as_arr().unwrap().len() == curves.len());
    }
}
