//! Table 2: estimator robustness to query noise. Gaussian noise with
//! relative norm ∈ {0%, 10%, 20%, 30%} is added to the query vectors;
//! the paper finds MIMPS essentially flat (0.8 → 0.9) while Uniform and
//! FMBE drift slightly and MINCE stays uniformly bad.
//!
//! Settings per the paper's caption: MIMPS k = l = 1000; MINCE k = 1,
//! l = 1000; Uniform l = 1000; FMBE D = 50k (scaled via config).

use super::common::{build_workload, per_seed_errors, standard_queries, Setting};
use crate::bench::harness::Table;
use crate::config::Config;
use crate::data::embeddings::EmbeddingStore;
use crate::estimators::{fmbe, EstimateContext, Estimator, EstimatorKind};
use crate::metrics::{abs_rel_err_pct, Cell};
use crate::oracle::RetrievalError;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::threadpool;

pub const NOISE_LEVELS: [f32; 4] = [0.0, 0.10, 0.20, 0.30];

#[derive(Clone, Debug)]
pub struct Table2 {
    /// row label → one (μ, σ) per noise level.
    pub rows: Vec<(String, Vec<Cell>)>,
}

/// FMBE feature count used for the FMBE row (paper: 50k).
pub fn run(store: &EmbeddingStore, cfg: &Config, fmbe_d: usize) -> Table2 {
    let k = cfg.k.min(store.len() / 2);
    let l = cfg.l.min(store.len() - k);
    let settings = [
        (
            "Uniform".to_string(),
            Setting {
                kind: EstimatorKind::Uniform,
                k: 0,
                l,
            },
        ),
        (
            "MIMPS".to_string(),
            Setting {
                kind: EstimatorKind::Mimps,
                k,
                l,
            },
        ),
        (
            "MINCE".to_string(),
            Setting {
                kind: EstimatorKind::Mince,
                k: 1,
                l,
            },
        ),
    ];
    let mut rows: Vec<(String, Vec<Cell>)> = settings
        .iter()
        .map(|(label, _)| (label.clone(), Vec::new()))
        .collect();
    rows.push(("FMBE".to_string(), Vec::new()));

    // One FMBE fit shared across noise levels (the data doesn't change).
    let fmbe_est = fmbe::Fmbe::fit(
        store,
        fmbe::FmbeConfig {
            p_features: fmbe_d,
            seed: cfg.seed,
            threads: cfg.threads,
            ..Default::default()
        },
    );
    let no_head: Vec<crate::mips::Hit> = Vec::new();

    for &noise in &NOISE_LEVELS {
        let queries = standard_queries(store, cfg.queries, noise, cfg.seed);
        let evals = build_workload(store, &queries, k.max(1), cfg.threads);
        for (i, (_, setting)) in settings.iter().enumerate() {
            let per_seed = per_seed_errors(
                store,
                &queries,
                &evals,
                setting,
                &RetrievalError::none(),
                cfg.seeds,
                cfg.seed,
                cfg.threads,
            );
            rows[i].1.push(Cell::from_seed_means(&per_seed));
        }
        // FMBE row.
        let errs = threadpool::par_map(queries.len(), cfg.threads, |qi| {
            let mut rng = Rng::seeded(2 + qi as u64);
            let dummy = super::common::FixedIndex::new(&no_head, store.len());
            let mut ctx = EstimateContext::new(store, &dummy, &mut rng);
            abs_rel_err_pct(fmbe_est.estimate(&mut ctx, &queries[qi]), evals[qi].z_true)
        });
        let mu = crate::metrics::mean(&errs);
        let fmbe_row = rows.last_mut().unwrap();
        fmbe_row.1.push(Cell { mu, sigma: crate::metrics::std_err(&errs) });
        log::info!("table2: noise {:.0}% done", noise * 100.0);
    }
    Table2 { rows }
}

pub fn render(t: &Table2) -> String {
    let mut tab = Table::new(&[
        "", "noise=0% mu", "s", "noise=10% mu", "s", "noise=20% mu", "s", "noise=30% mu", "s",
    ]);
    for (label, cells) in &t.rows {
        let mut row = vec![label.clone()];
        for c in cells {
            row.push(format!("{:.1}", c.mu));
            row.push(format!("{:.1}", c.sigma));
        }
        tab.row(row);
    }
    tab.render()
}

pub fn to_json(t: &Table2) -> Json {
    Json::Arr(
        t.rows
            .iter()
            .map(|(label, cells)| {
                Json::obj(vec![
                    ("label", Json::str(label)),
                    (
                        "cells",
                        Json::Arr(
                            cells
                                .iter()
                                .map(|c| {
                                    Json::obj(vec![
                                        ("mu", Json::num(c.mu)),
                                        ("sigma", Json::num(c.sigma)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};

    #[test]
    fn mimps_flat_under_noise() {
        let store = generate(&SynthConfig::tiny());
        let cfg = Config {
            n: store.len(),
            d: store.dim(),
            queries: 30,
            seeds: 2,
            k: 500,
            l: 500,
            threads: 4,
            ..Config::smoke()
        };
        let t = run(&store, &cfg, 300);
        let mimps = &t.rows.iter().find(|(l, _)| l == "MIMPS").unwrap().1;
        let uniform = &t.rows.iter().find(|(l, _)| l == "Uniform").unwrap().1;
        assert_eq!(mimps.len(), 4);
        // MIMPS stays accurate and roughly flat across noise levels.
        for c in mimps {
            assert!(c.mu < 25.0, "MIMPS mu {} too high under noise", c.mu);
        }
        let spread = mimps.iter().map(|c| c.mu).fold(0.0f64, f64::max)
            - mimps.iter().map(|c| c.mu).fold(f64::INFINITY, f64::min);
        assert!(spread < 15.0, "MIMPS should be noise-robust, spread {spread}");
        // Uniform is far worse at every level.
        for (u, m) in uniform.iter().zip(mimps) {
            assert!(u.mu > 3.0 * m.mu, "Uniform {} vs MIMPS {}", u.mu, m.mu);
        }
    }
}
