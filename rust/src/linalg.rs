//! Dense f32 kernels for the Rust-side hot paths: dot products, GEMV over
//! a row-major matrix, norms, axpy. These back the MIPS indexes and the
//! native (non-PJRT) scoring path; the unrolled dot is the single hottest
//! function in the whole system (profiled in EXPERIMENTS.md §Perf).

/// Dot product with 8-way manual unrolling; the compiler auto-vectorizes
/// each lane group. f32 accumulate in 8 partials, final sum in f64 to
/// reduce cancellation over long vectors.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let mut acc = [0f32; 8];
    // Safety-free indexing: slice patterns over exact chunks.
    for i in 0..chunks {
        let o = i * 8;
        let (x, y) = (&a[o..o + 8], &b[o..o + 8]);
        acc[0] += x[0] * y[0];
        acc[1] += x[1] * y[1];
        acc[2] += x[2] * y[2];
        acc[3] += x[3] * y[3];
        acc[4] += x[4] * y[4];
        acc[5] += x[5] * y[5];
        acc[6] += x[6] * y[6];
        acc[7] += x[7] * y[7];
    }
    let mut tail = 0f32;
    for i in chunks * 8..n {
        tail += a[i] * b[i];
    }
    let head: f32 = acc.iter().sum();
    head + tail
}

/// Squared L2 norm.
#[inline]
pub fn norm_sq(a: &[f32]) -> f32 {
    dot(a, a)
}

/// L2 norm.
#[inline]
pub fn norm(a: &[f32]) -> f32 {
    norm_sq(a).sqrt()
}

/// Squared Euclidean distance.
#[inline]
pub fn dist_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0f32;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        acc += d * d;
    }
    acc
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// Scale in place.
#[inline]
pub fn scale(x: &mut [f32], alpha: f32) {
    for v in x {
        *v *= alpha;
    }
}

/// out = M · q for row-major `m` of shape (rows × d). Writes `rows` scores.
pub fn gemv(m: &[f32], rows: usize, d: usize, q: &[f32], out: &mut [f32]) {
    debug_assert_eq!(m.len(), rows * d);
    debug_assert_eq!(q.len(), d);
    debug_assert_eq!(out.len(), rows);
    for (r, o) in out.iter_mut().enumerate() {
        *o = dot(&m[r * d..(r + 1) * d], q);
    }
}

/// Blocked GEMV that processes 4 rows at a time to reuse the streamed `q`
/// from L1 cache and expose more ILP than row-at-a-time `gemv`.
pub fn gemv_blocked(m: &[f32], rows: usize, d: usize, q: &[f32], out: &mut [f32]) {
    debug_assert_eq!(m.len(), rows * d);
    debug_assert_eq!(q.len(), d);
    debug_assert_eq!(out.len(), rows);
    let quads = rows / 4;
    for b in 0..quads {
        let r = b * 4;
        let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
        let row0 = &m[r * d..(r + 1) * d];
        let row1 = &m[(r + 1) * d..(r + 2) * d];
        let row2 = &m[(r + 2) * d..(r + 3) * d];
        let row3 = &m[(r + 3) * d..(r + 4) * d];
        for j in 0..d {
            let qj = q[j];
            s0 += row0[j] * qj;
            s1 += row1[j] * qj;
            s2 += row2[j] * qj;
            s3 += row3[j] * qj;
        }
        out[r] = s0;
        out[r + 1] = s1;
        out[r + 2] = s2;
        out[r + 3] = s3;
    }
    for r in quads * 4..rows {
        out[r] = dot(&m[r * d..(r + 1) * d], q);
    }
}

/// exp(scores) in place, with optional max-subtraction for stability.
/// Returns the subtracted max (0.0 when `stabilize` is false) so callers
/// can undo the shift: true_sum = exp(max) * Σ exp(u - max).
pub fn exp_inplace(scores: &mut [f32], stabilize: bool) -> f32 {
    let mx = if stabilize {
        scores.iter().copied().fold(f32::NEG_INFINITY, f32::max)
    } else {
        0.0
    };
    let mx = if mx.is_finite() { mx } else { 0.0 };
    for s in scores.iter_mut() {
        *s = (*s - mx).exp();
    }
    mx
}

/// Kahan-compensated sum of f32 slice in f64.
pub fn sum_f64(xs: &[f32]) -> f64 {
    let mut sum = 0f64;
    let mut c = 0f64;
    for &x in xs {
        let y = x as f64 - c;
        let t = sum + y;
        c = (t - sum) - y;
        sum = t;
    }
    sum
}

/// Σ exp(u_i) computed in f64 without materializing the exp'd array.
pub fn sum_exp(scores: &[f32]) -> f64 {
    let mut acc = 0f64;
    for &s in scores {
        acc += (s as f64).exp();
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_dot(a: &[f32], b: &[f32]) -> f64 {
        a.iter()
            .zip(b)
            .map(|(x, y)| *x as f64 * *y as f64)
            .sum::<f64>()
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Rng::seeded(1);
        for d in [0, 1, 3, 7, 8, 9, 16, 33, 300, 301] {
            let a = rng.normal_vec(d);
            let b = rng.normal_vec(d);
            let got = dot(&a, &b) as f64;
            let want = naive_dot(&a, &b);
            assert!(
                (got - want).abs() < 1e-3 * (1.0 + want.abs()),
                "d={d}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn gemv_variants_agree() {
        let mut rng = Rng::seeded(2);
        let (rows, d) = (37, 65);
        let m = rng.normal_vec(rows * d);
        let q = rng.normal_vec(d);
        let mut o1 = vec![0f32; rows];
        let mut o2 = vec![0f32; rows];
        gemv(&m, rows, d, &q, &mut o1);
        gemv_blocked(&m, rows, d, &q, &mut o2);
        for (a, b) in o1.iter().zip(&o2) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn exp_inplace_stabilized_matches_direct() {
        let mut rng = Rng::seeded(3);
        let mut s: Vec<f32> = (0..100).map(|_| rng.normal() as f32 * 3.0).collect();
        let direct: f64 = s.iter().map(|&x| (x as f64).exp()).sum();
        let mx = exp_inplace(&mut s, true);
        let total = (mx as f64).exp() * sum_f64(&s);
        assert!((total - direct).abs() < 1e-6 * direct, "{total} vs {direct}");
    }

    #[test]
    fn exp_inplace_all_neg_inf_guard() {
        let mut s = vec![f32::NEG_INFINITY; 4];
        let mx = exp_inplace(&mut s, true);
        assert_eq!(mx, 0.0);
        assert!(s.iter().all(|x| *x == 0.0));
    }

    #[test]
    fn sum_exp_matches_exp_sum() {
        let mut rng = Rng::seeded(4);
        let s: Vec<f32> = (0..1000).map(|_| rng.normal() as f32).collect();
        let a = sum_exp(&s);
        let b: f64 = s.iter().map(|&x| (x as f64).exp()).sum();
        assert!((a - b).abs() < 1e-9 * b);
    }

    #[test]
    fn axpy_and_scale() {
        let x = vec![1.0f32, 2.0, 3.0];
        let mut y = vec![1.0f32; 3];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0]);
        scale(&mut y, 0.5);
        assert_eq!(y, vec![1.5, 2.5, 3.5]);
    }

    #[test]
    fn dist_sq_basic() {
        assert_eq!(dist_sq(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
    }

    #[test]
    fn kahan_sum_precision() {
        // 1 + 1e-8 repeated: naive f32 accumulation loses the small terms.
        let xs = vec![1e-8f32; 1_000_000];
        let s = sum_f64(&xs);
        assert!((s - 1e-2).abs() < 1e-6, "{s}");
    }
}
