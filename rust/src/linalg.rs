//! Dense f32 kernels for the Rust-side hot paths: dot products, GEMV and
//! multi-query GEMM over row-major matrices, fused exp-sums, norms, axpy.
//! These back the MIPS indexes and the native (non-PJRT) scoring path; the
//! inner-product kernels are the hottest functions in the whole system
//! (profiled in EXPERIMENTS.md §Perf).
//!
//! ## Dispatch
//!
//! Every public kernel dispatches at runtime between an explicit
//! `std::arch` AVX2+FMA implementation (the private `avx2` module,
//! x86_64 with both
//! features detected) and a portable scalar fallback ([`scalar`], every
//! other case — and forceable with `ZEST_NO_SIMD=1` for A/B benching).
//! The detection result is cached in an atomic so the per-call cost is a
//! single relaxed load and a predictable branch.
//!
//! The AVX2 kernels share one accumulation pattern — a single 8-lane FMA
//! accumulator walked left to right, horizontal-summed, then a scalar
//! remainder loop — so a given row produces bit-identical scores whether
//! it was computed by [`dot`], a [`gemv_blocked`] row quad, or a [`gemm`]
//! tile. That keeps single-query and batched retrieval consistent to the
//! last ulp on SIMD machines.

use std::sync::atomic::{AtomicU8, Ordering};

/// Which kernel family the runtime dispatch ([`backend`]) selected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Scalar,
    Avx2,
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::Scalar => write!(f, "scalar"),
            Backend::Avx2 => write!(f, "avx2+fma"),
        }
    }
}

// 0 = undetected, 1 = scalar, 2 = avx2.
static BACKEND: AtomicU8 = AtomicU8::new(0);

fn detect_backend() -> Backend {
    if std::env::var_os("ZEST_NO_SIMD").is_some_and(|v| v != "0" && !v.is_empty()) {
        return Backend::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_64_feature_detected!("avx2")
            && std::arch::is_x86_64_feature_detected!("fma")
        {
            return Backend::Avx2;
        }
    }
    Backend::Scalar
}

/// The kernel backend in use for this process (cached after first call).
#[inline]
pub fn backend() -> Backend {
    match BACKEND.load(Ordering::Relaxed) {
        1 => Backend::Scalar,
        2 => Backend::Avx2,
        _ => {
            let b = detect_backend();
            BACKEND.store(if b == Backend::Avx2 { 2 } else { 1 }, Ordering::Relaxed);
            b
        }
    }
}

#[inline]
fn use_avx2() -> bool {
    backend() == Backend::Avx2
}

/// Dot product of two equal-length slices.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    // Hard assert: the AVX2 kernels read through raw pointers, so a
    // length mismatch must stay a deterministic panic in release builds
    // rather than an out-of-bounds read.
    assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if use_avx2() {
        // Safety: avx2+fma presence verified by `backend()`; equal
        // lengths asserted above.
        return unsafe { avx2::dot(a, b) };
    }
    scalar::dot(a, b)
}

/// Squared L2 norm.
#[inline]
pub fn norm_sq(a: &[f32]) -> f32 {
    dot(a, a)
}

/// L2 norm.
#[inline]
pub fn norm(a: &[f32]) -> f32 {
    norm_sq(a).sqrt()
}

/// Squared Euclidean distance.
#[inline]
pub fn dist_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0f32;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        acc += d * d;
    }
    acc
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// Scale in place.
#[inline]
pub fn scale(x: &mut [f32], alpha: f32) {
    for v in x {
        *v *= alpha;
    }
}

/// out = M · q for row-major `m` of shape (rows × d). Writes `rows` scores.
/// Row-at-a-time; prefer [`gemv_blocked`] on hot paths.
pub fn gemv(m: &[f32], rows: usize, d: usize, q: &[f32], out: &mut [f32]) {
    debug_assert_eq!(m.len(), rows * d);
    debug_assert_eq!(q.len(), d);
    debug_assert_eq!(out.len(), rows);
    for (r, o) in out.iter_mut().enumerate() {
        *o = dot(&m[r * d..(r + 1) * d], q);
    }
}

/// Blocked GEMV that processes 4 rows at a time to reuse the streamed `q`
/// from L1 cache and expose more ILP than row-at-a-time [`gemv`].
pub fn gemv_blocked(m: &[f32], rows: usize, d: usize, q: &[f32], out: &mut [f32]) {
    // Hard asserts: see `dot` — these bound the unsafe kernel's reads.
    assert_eq!(m.len(), rows * d);
    assert_eq!(q.len(), d);
    assert_eq!(out.len(), rows);
    #[cfg(target_arch = "x86_64")]
    if use_avx2() {
        // Safety: avx2+fma presence verified by `backend()`; shapes
        // asserted above.
        unsafe { avx2::gemv_blocked(m, rows, d, q, out) };
        return;
    }
    scalar::gemv_blocked(m, rows, d, q, out);
}

/// Multi-query GEMM: `out[r * nq + qi] = m[r] · qs[qi]` for row-major `m`
/// (rows × d) and row-major query block `qs` (nq × d). The micro-kernel
/// processes a 4-row × 4-query register tile so every streamed matrix row
/// is reused across the whole query tile instead of being re-read once
/// per query — this is the batched scoring engine's core primitive.
pub fn gemm(m: &[f32], rows: usize, d: usize, qs: &[f32], nq: usize, out: &mut [f32]) {
    // Hard asserts: see `dot` — these bound the unsafe kernel's reads.
    assert_eq!(m.len(), rows * d);
    assert_eq!(qs.len(), nq * d);
    assert_eq!(out.len(), rows * nq);
    if rows == 0 || nq == 0 {
        return;
    }
    #[cfg(target_arch = "x86_64")]
    if use_avx2() {
        // Safety: avx2+fma presence verified by `backend()`; shapes
        // asserted above.
        unsafe { avx2::gemm(m, rows, d, qs, nq, out) };
        return;
    }
    scalar::gemm(m, rows, d, qs, nq, out);
}

/// Row tile of [`exp_sum_gemv`]. `store::exp_sum_view` replays the same
/// tiling over sharded views to stay bit-identical to this kernel — the
/// two must share this constant.
pub const EXP_SUM_TILE: usize = 256;

/// Row tile of [`exp_sum_gemm`]; shared with `store::exp_sum_view_batch`
/// for the same bit-stability reason.
pub const EXP_SUM_BATCH_TILE: usize = 64;

/// Fused Σ exp(m[r] · q) over all rows, accumulated in f64 without
/// materializing an N-sized score vector: scores are produced by the
/// blocked GEMV into a small cache-resident tile and exp-summed
/// immediately. This is the single-query partition-function kernel.
pub fn exp_sum_gemv(m: &[f32], rows: usize, d: usize, q: &[f32]) -> f64 {
    debug_assert_eq!(m.len(), rows * d);
    let mut tile = [0f32; EXP_SUM_TILE];
    let mut acc = 0f64;
    let mut r = 0usize;
    while r < rows {
        let hi = (r + EXP_SUM_TILE).min(rows);
        let nrows = hi - r;
        gemv_blocked(&m[r * d..hi * d], nrows, d, q, &mut tile[..nrows]);
        for &s in &tile[..nrows] {
            acc += (s as f64).exp();
        }
        r = hi;
    }
    acc
}

/// Fused batched exp-sum: `zs[j] += Σ_r exp(m[r] · q_j)` for every query
/// `j` of the flat row-major (nq × d) block, without materializing the
/// full (rows × nq) score matrix: scores are produced tile-by-tile by
/// the multi-query [`gemm`] into a cache-resident buffer and exp-summed
/// in f64 immediately. This is the batched partition-function kernel
/// shared by `BruteIndex::partition_batch` and `Exact::estimate_batch`.
pub fn exp_sum_gemm(m: &[f32], rows: usize, d: usize, qs_flat: &[f32], nq: usize, zs: &mut [f64]) {
    assert_eq!(m.len(), rows * d);
    assert_eq!(qs_flat.len(), nq * d);
    assert_eq!(zs.len(), nq);
    if rows == 0 || nq == 0 {
        return;
    }
    // Row tile keeps the (EXP_SUM_BATCH_TILE × nq) score block
    // cache-resident while still amortizing each streamed row over all
    // nq queries.
    let mut tile = vec![0f32; EXP_SUM_BATCH_TILE * nq];
    let mut lo = 0usize;
    while lo < rows {
        let hi = (lo + EXP_SUM_BATCH_TILE).min(rows);
        let nrows = hi - lo;
        gemm(&m[lo * d..hi * d], nrows, d, qs_flat, nq, &mut tile[..nrows * nq]);
        for r in 0..nrows {
            for (qi, z) in zs.iter_mut().enumerate() {
                *z += (tile[r * nq + qi] as f64).exp();
            }
        }
        lo = hi;
    }
}

/// Flatten a query set into one contiguous row-major (nq × d) block for
/// the multi-query kernels. Panics on dimensionality mismatch.
pub fn flatten_queries(qs: &[Vec<f32>], d: usize) -> Vec<f32> {
    let mut flat = Vec::with_capacity(qs.len() * d);
    for q in qs {
        assert_eq!(q.len(), d, "query dimensionality mismatch");
        flat.extend_from_slice(q);
    }
    flat
}

/// exp(scores) in place, with optional max-subtraction for stability.
/// Returns the subtracted max (0.0 when `stabilize` is false) so callers
/// can undo the shift: true_sum = exp(max) * Σ exp(u - max).
pub fn exp_inplace(scores: &mut [f32], stabilize: bool) -> f32 {
    let mx = if stabilize {
        scores.iter().copied().fold(f32::NEG_INFINITY, f32::max)
    } else {
        0.0
    };
    let mx = if mx.is_finite() { mx } else { 0.0 };
    for s in scores.iter_mut() {
        *s = (*s - mx).exp();
    }
    mx
}

/// Kahan-compensated sum of f32 slice in f64.
pub fn sum_f64(xs: &[f32]) -> f64 {
    let mut sum = 0f64;
    let mut c = 0f64;
    for &x in xs {
        let y = x as f64 - c;
        let t = sum + y;
        c = (t - sum) - y;
        sum = t;
    }
    sum
}

/// Σ exp(u_i) computed in f64 without materializing the exp'd array.
pub fn sum_exp(scores: &[f32]) -> f64 {
    let mut acc = 0f64;
    for &s in scores {
        acc += (s as f64).exp();
    }
    acc
}

/// Portable scalar kernels — the fallback on non-AVX2 hardware, and the
/// baseline the SIMD kernels are benchmarked and tested against. Exposed
/// `pub` so `perf_hotpath` and the agreement tests can call them directly
/// regardless of the detected backend.
pub mod scalar {
    /// Dot product with 8-way manual unrolling; the compiler
    /// auto-vectorizes each lane group. f32 accumulate in 8 partials.
    #[inline]
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 8;
        let mut acc = [0f32; 8];
        // Safety-free indexing: slice patterns over exact chunks.
        for i in 0..chunks {
            let o = i * 8;
            let (x, y) = (&a[o..o + 8], &b[o..o + 8]);
            acc[0] += x[0] * y[0];
            acc[1] += x[1] * y[1];
            acc[2] += x[2] * y[2];
            acc[3] += x[3] * y[3];
            acc[4] += x[4] * y[4];
            acc[5] += x[5] * y[5];
            acc[6] += x[6] * y[6];
            acc[7] += x[7] * y[7];
        }
        let mut tail = 0f32;
        for i in chunks * 8..n {
            tail += a[i] * b[i];
        }
        let head: f32 = acc.iter().sum();
        head + tail
    }

    /// 4-row blocked GEMV (see [`super::gemv_blocked`]).
    pub fn gemv_blocked(m: &[f32], rows: usize, d: usize, q: &[f32], out: &mut [f32]) {
        let quads = rows / 4;
        for b in 0..quads {
            let r = b * 4;
            let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
            let row0 = &m[r * d..(r + 1) * d];
            let row1 = &m[(r + 1) * d..(r + 2) * d];
            let row2 = &m[(r + 2) * d..(r + 3) * d];
            let row3 = &m[(r + 3) * d..(r + 4) * d];
            for j in 0..d {
                let qj = q[j];
                s0 += row0[j] * qj;
                s1 += row1[j] * qj;
                s2 += row2[j] * qj;
                s3 += row3[j] * qj;
            }
            out[r] = s0;
            out[r + 1] = s1;
            out[r + 2] = s2;
            out[r + 3] = s3;
        }
        for r in quads * 4..rows {
            out[r] = dot(&m[r * d..(r + 1) * d], q);
        }
    }

    /// Register-blocked 4×4 multi-query GEMM (see [`super::gemm`]): each
    /// loaded element of a matrix row feeds all four queries of the tile.
    pub fn gemm(m: &[f32], rows: usize, d: usize, qs: &[f32], nq: usize, out: &mut [f32]) {
        let rquads = rows / 4 * 4;
        let qquads = nq / 4 * 4;
        let mut r = 0usize;
        while r < rquads {
            let row0 = &m[r * d..(r + 1) * d];
            let row1 = &m[(r + 1) * d..(r + 2) * d];
            let row2 = &m[(r + 2) * d..(r + 3) * d];
            let row3 = &m[(r + 3) * d..(r + 4) * d];
            let mut qi = 0usize;
            while qi < qquads {
                let q0 = &qs[qi * d..(qi + 1) * d];
                let q1 = &qs[(qi + 1) * d..(qi + 2) * d];
                let q2 = &qs[(qi + 2) * d..(qi + 3) * d];
                let q3 = &qs[(qi + 3) * d..(qi + 4) * d];
                let mut acc = [[0f32; 4]; 4];
                for j in 0..d {
                    let rv = [row0[j], row1[j], row2[j], row3[j]];
                    let qv = [q0[j], q1[j], q2[j], q3[j]];
                    for (ar, &rj) in acc.iter_mut().zip(&rv) {
                        for (a, &qj) in ar.iter_mut().zip(&qv) {
                            *a += rj * qj;
                        }
                    }
                }
                for (rr, ar) in acc.iter().enumerate() {
                    for (qq, &a) in ar.iter().enumerate() {
                        out[(r + rr) * nq + qi + qq] = a;
                    }
                }
                qi += 4;
            }
            while qi < nq {
                let q = &qs[qi * d..(qi + 1) * d];
                out[r * nq + qi] = dot(row0, q);
                out[(r + 1) * nq + qi] = dot(row1, q);
                out[(r + 2) * nq + qi] = dot(row2, q);
                out[(r + 3) * nq + qi] = dot(row3, q);
                qi += 1;
            }
            r += 4;
        }
        while r < rows {
            let row = &m[r * d..(r + 1) * d];
            for qi in 0..nq {
                out[r * nq + qi] = dot(row, &qs[qi * d..(qi + 1) * d]);
            }
            r += 1;
        }
    }
}

/// Explicit AVX2+FMA kernels. All functions here are `unsafe` because
/// they require the `avx2` and `fma` target features, which callers must
/// verify via [`backend`] before entering.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// Horizontal sum of one 8-lane accumulator. Store-and-sum keeps the
    /// reduction order identical everywhere it is used, which is what
    /// makes dot / gemv / gemm bit-consistent per row.
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        let mut buf = [0f32; 8];
        _mm256_storeu_ps(buf.as_mut_ptr(), v);
        let mut s = 0f32;
        for x in buf {
            s += x;
        }
        s
    }

    /// Single-row dot: one 8-lane FMA accumulator + scalar remainder.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc = _mm256_setzero_ps();
        let mut j = 0usize;
        while j + 8 <= n {
            acc = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(j)), _mm256_loadu_ps(bp.add(j)), acc);
            j += 8;
        }
        let mut s = hsum(acc);
        while j < n {
            s += *ap.add(j) * *bp.add(j);
            j += 1;
        }
        s
    }

    /// 4-row blocked GEMV: the query chunk is loaded once per 8 lanes and
    /// fed to four row FMAs.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn gemv_blocked(m: &[f32], rows: usize, d: usize, q: &[f32], out: &mut [f32]) {
        let qp = q.as_ptr();
        let quads = rows / 4;
        for b in 0..quads {
            let r = b * 4;
            let r0 = m.as_ptr().add(r * d);
            let r1 = m.as_ptr().add((r + 1) * d);
            let r2 = m.as_ptr().add((r + 2) * d);
            let r3 = m.as_ptr().add((r + 3) * d);
            let mut a0 = _mm256_setzero_ps();
            let mut a1 = _mm256_setzero_ps();
            let mut a2 = _mm256_setzero_ps();
            let mut a3 = _mm256_setzero_ps();
            let mut j = 0usize;
            while j + 8 <= d {
                let qv = _mm256_loadu_ps(qp.add(j));
                a0 = _mm256_fmadd_ps(_mm256_loadu_ps(r0.add(j)), qv, a0);
                a1 = _mm256_fmadd_ps(_mm256_loadu_ps(r1.add(j)), qv, a1);
                a2 = _mm256_fmadd_ps(_mm256_loadu_ps(r2.add(j)), qv, a2);
                a3 = _mm256_fmadd_ps(_mm256_loadu_ps(r3.add(j)), qv, a3);
                j += 8;
            }
            let mut s0 = hsum(a0);
            let mut s1 = hsum(a1);
            let mut s2 = hsum(a2);
            let mut s3 = hsum(a3);
            while j < d {
                let qj = *qp.add(j);
                s0 += *r0.add(j) * qj;
                s1 += *r1.add(j) * qj;
                s2 += *r2.add(j) * qj;
                s3 += *r3.add(j) * qj;
                j += 1;
            }
            out[r] = s0;
            out[r + 1] = s1;
            out[r + 2] = s2;
            out[r + 3] = s3;
        }
        for r in quads * 4..rows {
            out[r] = dot(&m[r * d..(r + 1) * d], q);
        }
    }

    /// 4-row × 4-query register-tiled GEMM micro-kernel: 16 accumulators,
    /// each matrix-row load shared by four query FMAs.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn gemm(m: &[f32], rows: usize, d: usize, qs: &[f32], nq: usize, out: &mut [f32]) {
        let rquads = rows / 4 * 4;
        let qquads = nq / 4 * 4;
        let mp = m.as_ptr();
        let qp = qs.as_ptr();
        let mut r = 0usize;
        while r < rquads {
            let rp = [
                mp.add(r * d),
                mp.add((r + 1) * d),
                mp.add((r + 2) * d),
                mp.add((r + 3) * d),
            ];
            let mut qi = 0usize;
            while qi < qquads {
                let qps = [
                    qp.add(qi * d),
                    qp.add((qi + 1) * d),
                    qp.add((qi + 2) * d),
                    qp.add((qi + 3) * d),
                ];
                let mut acc = [[_mm256_setzero_ps(); 4]; 4];
                let mut j = 0usize;
                while j + 8 <= d {
                    let rv = [
                        _mm256_loadu_ps(rp[0].add(j)),
                        _mm256_loadu_ps(rp[1].add(j)),
                        _mm256_loadu_ps(rp[2].add(j)),
                        _mm256_loadu_ps(rp[3].add(j)),
                    ];
                    for qq in 0..4 {
                        let qv = _mm256_loadu_ps(qps[qq].add(j));
                        acc[0][qq] = _mm256_fmadd_ps(rv[0], qv, acc[0][qq]);
                        acc[1][qq] = _mm256_fmadd_ps(rv[1], qv, acc[1][qq]);
                        acc[2][qq] = _mm256_fmadd_ps(rv[2], qv, acc[2][qq]);
                        acc[3][qq] = _mm256_fmadd_ps(rv[3], qv, acc[3][qq]);
                    }
                    j += 8;
                }
                for rr in 0..4 {
                    for qq in 0..4 {
                        let mut s = hsum(acc[rr][qq]);
                        let mut jj = j;
                        while jj < d {
                            s += *rp[rr].add(jj) * *qps[qq].add(jj);
                            jj += 1;
                        }
                        out[(r + rr) * nq + qi + qq] = s;
                    }
                }
                qi += 4;
            }
            while qi < nq {
                let q = std::slice::from_raw_parts(qp.add(qi * d), d);
                for (rr, &rrp) in rp.iter().enumerate() {
                    let row = std::slice::from_raw_parts(rrp, d);
                    out[(r + rr) * nq + qi] = dot(row, q);
                }
                qi += 1;
            }
            r += 4;
        }
        while r < rows {
            let row = &m[r * d..(r + 1) * d];
            for qi in 0..nq {
                out[r * nq + qi] = dot(row, &qs[qi * d..(qi + 1) * d]);
            }
            r += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_dot(a: &[f32], b: &[f32]) -> f64 {
        a.iter()
            .zip(b)
            .map(|(x, y)| *x as f64 * *y as f64)
            .sum::<f64>()
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Rng::seeded(1);
        for d in [0, 1, 3, 7, 8, 9, 16, 33, 300, 301] {
            let a = rng.normal_vec(d);
            let b = rng.normal_vec(d);
            let got = dot(&a, &b) as f64;
            let want = naive_dot(&a, &b);
            assert!(
                (got - want).abs() < 1e-3 * (1.0 + want.abs()),
                "d={d}: {got} vs {want}"
            );
        }
    }

    /// SIMD-vs-scalar agreement for every remainder-lane shape: dims 0–130
    /// cover all (d mod 8) classes plus multi-chunk lengths. On non-AVX2
    /// hosts the dispatching kernels equal the scalar ones trivially.
    #[test]
    fn simd_dot_matches_scalar_all_remainders() {
        let mut rng = Rng::seeded(41);
        for d in 0..=130usize {
            let a = rng.normal_vec(d);
            let b = rng.normal_vec(d);
            let got = dot(&a, &b) as f64;
            let want = scalar::dot(&a, &b) as f64;
            assert!(
                (got - want).abs() <= 1e-3 * (1.0 + want.abs()),
                "d={d}: dispatch {got} vs scalar {want} (backend {})",
                backend()
            );
        }
    }

    #[test]
    fn simd_gemv_matches_scalar_all_remainders() {
        let mut rng = Rng::seeded(42);
        for d in 0..=130usize {
            let rows = 7; // exercises the quad path + 3 remainder rows
            let m = rng.normal_vec(rows * d);
            let q = rng.normal_vec(d);
            let mut got = vec![0f32; rows];
            let mut want = vec![0f32; rows];
            gemv_blocked(&m, rows, d, &q, &mut got);
            scalar::gemv_blocked(&m, rows, d, &q, &mut want);
            for (r, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (g - w).abs() <= 1e-3 * (1.0 + w.abs()),
                    "d={d} row={r}: {g} vs {w}"
                );
            }
        }
    }

    #[test]
    fn gemv_variants_agree() {
        let mut rng = Rng::seeded(2);
        let (rows, d) = (37, 65);
        let m = rng.normal_vec(rows * d);
        let q = rng.normal_vec(d);
        let mut o1 = vec![0f32; rows];
        let mut o2 = vec![0f32; rows];
        gemv(&m, rows, d, &q, &mut o1);
        gemv_blocked(&m, rows, d, &q, &mut o2);
        for (a, b) in o1.iter().zip(&o2) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    /// gemm-vs-gemv agreement over shapes that hit every micro-kernel
    /// edge: row remainders, query remainders, and sub-tile sizes.
    #[test]
    fn gemm_matches_per_query_gemv() {
        let mut rng = Rng::seeded(43);
        for (rows, d, nq) in [
            (1usize, 5usize, 1usize),
            (3, 8, 2),
            (4, 16, 4),
            (5, 17, 5),
            (12, 33, 7),
            (33, 64, 9),
            (40, 130, 16),
        ] {
            let m = rng.normal_vec(rows * d);
            let qs = rng.normal_vec(nq * d);
            let mut got = vec![0f32; rows * nq];
            gemm(&m, rows, d, &qs, nq, &mut got);
            let mut scalar_got = vec![0f32; rows * nq];
            scalar::gemm(&m, rows, d, &qs, nq, &mut scalar_got);
            for qi in 0..nq {
                let q = &qs[qi * d..(qi + 1) * d];
                let mut want = vec![0f32; rows];
                gemv_blocked(&m, rows, d, q, &mut want);
                for r in 0..rows {
                    let g = got[r * nq + qi];
                    let w = want[r];
                    assert!(
                        (g - w).abs() <= 1e-3 * (1.0 + w.abs()),
                        "rows={rows} d={d} nq={nq} r={r} qi={qi}: gemm {g} vs gemv {w}"
                    );
                    let sg = scalar_got[r * nq + qi];
                    assert!(
                        (sg - w).abs() <= 1e-3 * (1.0 + w.abs()),
                        "scalar gemm {sg} vs gemv {w}"
                    );
                }
            }
        }
    }

    #[test]
    fn gemm_empty_shapes_are_noops() {
        let mut out: Vec<f32> = vec![];
        gemm(&[], 0, 4, &[1.0, 2.0, 3.0, 4.0], 1, &mut []);
        gemm(&[1.0, 2.0], 1, 2, &[], 0, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn exp_sum_gemv_matches_unfused() {
        let mut rng = Rng::seeded(44);
        for rows in [0usize, 1, 4, 255, 256, 257, 700] {
            let d = 19;
            let m = rng.normal_vec(rows * d);
            let q = rng.normal_vec(d);
            let got = exp_sum_gemv(&m, rows, d, &q);
            let mut scores = vec![0f32; rows];
            gemv_blocked(&m, rows, d, &q, &mut scores);
            let want = sum_exp(&scores);
            assert!(
                (got - want).abs() <= 1e-9 * (1.0 + want.abs()),
                "rows={rows}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn exp_sum_gemm_matches_per_query_exp_sum_gemv() {
        let mut rng = Rng::seeded(45);
        for (rows, d, nq) in [(0usize, 7usize, 3usize), (63, 7, 1), (64, 9, 4), (130, 16, 5)] {
            let m = rng.normal_vec(rows * d);
            let qs: Vec<Vec<f32>> = (0..nq).map(|_| rng.normal_vec(d)).collect();
            let qs_flat = flatten_queries(&qs, d);
            let mut zs = vec![0f64; nq];
            exp_sum_gemm(&m, rows, d, &qs_flat, nq, &mut zs);
            for (q, z) in qs.iter().zip(&zs) {
                let want = exp_sum_gemv(&m, rows, d, q);
                assert!(
                    (z - want).abs() <= 1e-6 * (1.0 + want.abs()),
                    "rows={rows} d={d} nq={nq}: {z} vs {want}"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "query dimensionality mismatch")]
    fn flatten_queries_rejects_bad_dims() {
        flatten_queries(&[vec![1.0, 2.0], vec![3.0]], 2);
    }

    #[test]
    fn exp_inplace_stabilized_matches_direct() {
        let mut rng = Rng::seeded(3);
        let mut s: Vec<f32> = (0..100).map(|_| rng.normal() as f32 * 3.0).collect();
        let direct: f64 = s.iter().map(|&x| (x as f64).exp()).sum();
        let mx = exp_inplace(&mut s, true);
        let total = (mx as f64).exp() * sum_f64(&s);
        assert!((total - direct).abs() < 1e-6 * direct, "{total} vs {direct}");
    }

    #[test]
    fn exp_inplace_all_neg_inf_guard() {
        let mut s = vec![f32::NEG_INFINITY; 4];
        let mx = exp_inplace(&mut s, true);
        assert_eq!(mx, 0.0);
        assert!(s.iter().all(|x| *x == 0.0));
    }

    #[test]
    fn sum_exp_matches_exp_sum() {
        let mut rng = Rng::seeded(4);
        let s: Vec<f32> = (0..1000).map(|_| rng.normal() as f32).collect();
        let a = sum_exp(&s);
        let b: f64 = s.iter().map(|&x| (x as f64).exp()).sum();
        assert!((a - b).abs() < 1e-9 * b);
    }

    #[test]
    fn axpy_and_scale() {
        let x = vec![1.0f32, 2.0, 3.0];
        let mut y = vec![1.0f32; 3];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0]);
        scale(&mut y, 0.5);
        assert_eq!(y, vec![1.5, 2.5, 3.5]);
    }

    #[test]
    fn dist_sq_basic() {
        assert_eq!(dist_sq(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
    }

    #[test]
    fn kahan_sum_precision() {
        // 1 + 1e-8 repeated: naive f32 accumulation loses the small terms.
        let xs = vec![1e-8f32; 1_000_000];
        let s = sum_f64(&xs);
        assert!((s - 1e-2).abs() < 1e-6, "{s}");
    }

    #[test]
    fn backend_is_cached_and_consistent() {
        let a = backend();
        let b = backend();
        assert_eq!(a, b);
    }
}
