//! Benchmark harness (substitute for criterion).
pub mod harness;
