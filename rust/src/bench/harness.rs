//! Micro/macro benchmark harness (substitute for `criterion`, which is
//! unavailable offline): warmup, fixed-iteration timing, median / mean /
//! p95 reporting, and a simple table printer shared by all `cargo bench`
//! targets so their output matches the paper's tables row-for-row.

use std::time::{Duration, Instant};

/// Timing summary over bench iterations.
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl Timing {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

impl std::fmt::Display for Timing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {:>10.3?}  median {:>10.3?}  p95 {:>10.3?}  min {:>10.3?}  ({} iters)",
            self.mean, self.median, self.p95, self.min, self.iters
        )
    }
}

/// Run `f` for `warmup` unrecorded iterations then `iters` timed ones.
pub fn time<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters.max(1));
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    summarize(&mut samples)
}

/// Time a single run of `f`, returning both its result and duration.
pub fn time_once<R, F: FnOnce() -> R>(f: F) -> (R, Duration) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed())
}

fn summarize(samples: &mut [Duration]) -> Timing {
    samples.sort();
    let iters = samples.len();
    let total: Duration = samples.iter().sum();
    let median = samples[iters / 2];
    let p95 = samples[(((iters - 1) as f64) * 0.95) as usize];
    Timing {
        iters,
        mean: total / iters as u32,
        median,
        p95,
        min: samples[0],
    }
}

/// Markdown-ish table printer: fixed-width columns, header + separator.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {c:>w$} |", w = w));
            }
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_reports_sane_stats() {
        let t = time(1, 10, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert_eq!(t.iters, 10);
        assert!(t.min <= t.median && t.median <= t.p95);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "mu"]);
        t.row(vec!["MIMPS".into(), "0.8".into()]);
        t.row(vec!["Uniform".into(), "101.8".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0].len(), lines[2].len());
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
