//! Typed run configuration. Configs can be loaded from a JSON file
//! (`--config path`) and overridden by CLI flags, so every experiment in
//! EXPERIMENTS.md is reproducible from a single file + seed.

use crate::util::cli::Args;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::Path;

/// Top-level configuration shared by the CLI subcommands and examples.
#[derive(Clone, Debug)]
pub struct Config {
    /// Vocabulary / category count N.
    pub n: usize,
    /// Embedding dimensionality d.
    pub d: usize,
    /// Base PRNG seed (experiments run `seeds` replicas at seed+0,1,…).
    pub seed: u64,
    /// Number of seed replicas for mean/stderr reporting.
    pub seeds: usize,
    /// Number of query vectors per replica.
    pub queries: usize,
    /// Head size k (top-k retrieved set S_k).
    pub k: usize,
    /// Tail sample size l.
    pub l: usize,
    /// FMBE feature-map dimension P.
    pub fmbe_p: usize,
    /// Worker threads.
    pub threads: usize,
    /// Directory holding AOT artifacts (*.hlo.txt + meta.json).
    pub artifacts_dir: String,
    /// Output directory for experiment results.
    pub out_dir: String,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            n: 100_000,
            d: 300,
            seed: 0,
            seeds: 3,
            queries: 10_000,
            k: 1000,
            l: 1000,
            fmbe_p: 10_000,
            threads: crate::util::threadpool::default_threads(),
            artifacts_dir: "artifacts".to_string(),
            out_dir: "results".to_string(),
        }
    }
}

impl Config {
    /// Small config for tests and smoke runs.
    pub fn smoke() -> Self {
        Config {
            n: 2_000,
            d: 32,
            seeds: 2,
            queries: 50,
            k: 100,
            l: 100,
            fmbe_p: 500,
            ..Default::default()
        }
    }

    /// Load from a JSON object file; unknown keys are rejected to catch typos.
    pub fn from_json_file(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("parse {path:?}: {e}"))?;
        Self::from_json(&v)
    }

    pub fn from_json(v: &Json) -> Result<Config> {
        let obj = v
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("config must be a JSON object"))?;
        let mut cfg = Config::default();
        for (key, val) in obj {
            match key.as_str() {
                "n" => cfg.n = val.as_usize().context("n")?,
                "d" => cfg.d = val.as_usize().context("d")?,
                "seed" => cfg.seed = val.as_usize().context("seed")? as u64,
                "seeds" => cfg.seeds = val.as_usize().context("seeds")?,
                "queries" => cfg.queries = val.as_usize().context("queries")?,
                "k" => cfg.k = val.as_usize().context("k")?,
                "l" => cfg.l = val.as_usize().context("l")?,
                "fmbe_p" => cfg.fmbe_p = val.as_usize().context("fmbe_p")?,
                "threads" => cfg.threads = val.as_usize().context("threads")?,
                "artifacts_dir" => {
                    cfg.artifacts_dir = val.as_str().context("artifacts_dir")?.to_string()
                }
                "out_dir" => cfg.out_dir = val.as_str().context("out_dir")?.to_string(),
                other => anyhow::bail!("unknown config key {other:?}"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply CLI flag overrides on top of this config.
    pub fn apply_args(mut self, args: &Args) -> Result<Config> {
        self.n = args.get_or("n", self.n);
        self.d = args.get_or("d", self.d);
        self.seed = args.get_or("seed", self.seed);
        self.seeds = args.get_or("seeds", self.seeds);
        self.queries = args.get_or("queries", self.queries);
        self.k = args.get_or("k", self.k);
        self.l = args.get_or("l", self.l);
        self.fmbe_p = args.get_or("fmbe-p", self.fmbe_p);
        self.threads = args.get_or("threads", self.threads);
        if let Some(a) = args.get("artifacts-dir") {
            self.artifacts_dir = a.to_string();
        }
        if let Some(o) = args.get("out-dir") {
            self.out_dir = o.to_string();
        }
        self.validate()?;
        Ok(self)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.n > 0, "n must be positive");
        anyhow::ensure!(self.d > 0, "d must be positive");
        anyhow::ensure!(self.k <= self.n, "k ({}) must be <= n ({})", self.k, self.n);
        anyhow::ensure!(
            self.k + self.l <= self.n,
            "k + l ({}) must be <= n ({}) so the tail sample excludes the head",
            self.k + self.l,
            self.n
        );
        anyhow::ensure!(self.threads > 0, "threads must be positive");
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n", Json::num(self.n as f64)),
            ("d", Json::num(self.d as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("seeds", Json::num(self.seeds as f64)),
            ("queries", Json::num(self.queries as f64)),
            ("k", Json::num(self.k as f64)),
            ("l", Json::num(self.l as f64)),
            ("fmbe_p", Json::num(self.fmbe_p as f64)),
            ("threads", Json::num(self.threads as f64)),
            ("artifacts_dir", Json::str(&self.artifacts_dir)),
            ("out_dir", Json::str(&self.out_dir)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let cfg = Config::smoke();
        let j = cfg.to_json();
        let back = Config::from_json(&j).unwrap();
        assert_eq!(back.n, cfg.n);
        assert_eq!(back.k, cfg.k);
        assert_eq!(back.artifacts_dir, cfg.artifacts_dir);
    }

    #[test]
    fn unknown_key_rejected() {
        let j = Json::parse(r#"{"n": 10, "bogus": 1}"#).unwrap();
        assert!(Config::from_json(&j).is_err());
    }

    #[test]
    fn invalid_shapes_rejected() {
        let j = Json::parse(r#"{"n": 10, "k": 20}"#).unwrap();
        assert!(Config::from_json(&j).is_err());
        let j = Json::parse(r#"{"n": 10, "k": 6, "l": 6}"#).unwrap();
        assert!(Config::from_json(&j).is_err(), "k+l > n must be rejected");
    }

    #[test]
    fn args_override() {
        let args =
            crate::util::cli::Args::parse(["--n", "500", "--k", "7"].map(String::from)).unwrap();
        let cfg = Config::smoke().apply_args(&args).unwrap();
        assert_eq!(cfg.n, 500);
        assert_eq!(cfg.k, 7);
        assert_eq!(cfg.d, Config::smoke().d);
    }
}
